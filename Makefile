# Convenience targets (see README.md).  Everything runs from source via
# PYTHONPATH=src; no install step.

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

# Benchmark-run environment (DESIGN.md §16): 64-bit jnp scalars so the
# device tier matches the host codec bit-for-bit, a multi-device host
# platform so batched dispatch exercises real device placement on CPU
# containers, and tcmalloc preloaded when present (allocator jitter is
# visible in realized `*/wall` rows on shared cores).
TCMALLOC := $(firstword $(wildcard /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so* \
	/usr/lib/x86_64-linux-gnu/libtcmalloc.so* /usr/lib/libtcmalloc_minimal.so*))
BENCH_ENV := JAX_ENABLE_X64=1 XLA_FLAGS=--xla_force_host_platform_device_count=2 \
	$(if $(TCMALLOC),LD_PRELOAD=$(TCMALLOC))

.PHONY: test bench smoke chaos lint quickstart

test:  ## tier-1 suite
	$(PY) -m pytest -x -q

bench:  ## full benchmark harness (CSV on stdout)
	PYTHONPATH=src:. $(BENCH_ENV) $(PY) benchmarks/run.py

smoke:  ## fast benchmark smoke (executor + cluster + pruning + expr + cascade + device + service + obs + faults; the CI step).  Emits BENCH_<pr>.json + BENCH_<pr>_trace.json.
	PYTHONPATH=src:. $(BENCH_ENV) $(PY) benchmarks/run.py --smoke --json \
		--only pipeline,cluster,prune,expr,cascade,device,service,obs,faults

chaos:  ## seeded fault-injection sweep (tests/test_chaos.py)
	$(PY) -m pytest -q -m chaos tests/test_chaos.py

lint:  ## style/correctness lint (pip install -r requirements-dev.txt)
	ruff check src tests benchmarks examples tools
	$(PY) -m tools.skimlint src/repro --self-test --verify-fixtures
	$(PY) tools/check_extras.py

quickstart:
	$(PY) examples/quickstart.py
