# Convenience targets (see README.md).  Everything runs from source via
# PYTHONPATH=src; no install step.

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test bench smoke chaos lint quickstart

test:  ## tier-1 suite
	$(PY) -m pytest -x -q

bench:  ## full benchmark harness (CSV on stdout)
	PYTHONPATH=src:. $(PY) benchmarks/run.py

smoke:  ## fast benchmark smoke (executor + cluster + pruning + expr + cascade + service + obs + faults; the CI step).  Emits BENCH_<pr>.json + BENCH_<pr>_trace.json.
	PYTHONPATH=src:. $(PY) benchmarks/run.py --smoke --json \
		--only pipeline,cluster,prune,expr,cascade,service,obs,faults

chaos:  ## seeded fault-injection sweep (tests/test_chaos.py)
	$(PY) -m pytest -q -m chaos tests/test_chaos.py

lint:  ## style/correctness lint (pip install -r requirements-dev.txt)
	ruff check src tests benchmarks examples tools
	$(PY) -m tools.skimlint src/repro --self-test --verify-fixtures
	$(PY) tools/check_extras.py

quickstart:
	$(PY) examples/quickstart.py
