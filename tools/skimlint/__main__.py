"""CLI: ``python -m tools.skimlint [paths...] [options]``.

Exit status is 0 only when every requested check passes: lint findings
(unsuppressed), self-test corpus failures, and fixture-verification
failures all exit 1.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.skimlint.core import all_rules, lint_paths, render_json


def _ensure_repro_importable() -> None:
    """``--verify-fixtures`` needs ``repro``; insert ``src/`` when the
    caller did not set PYTHONPATH (running from the repo root)."""
    try:
        import repro  # noqa: F401
    except ImportError:
        src = Path(__file__).resolve().parents[2] / "src"
        if src.is_dir():
            sys.path.insert(0, str(src))


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.skimlint",
        description="repo-native static analysis (DESIGN.md §15)",
    )
    parser.add_argument(
        "paths", nargs="*", default=["src/repro"],
        help="files/directories to lint (default: src/repro)",
    )
    parser.add_argument("--json", action="store_true", help="JSON output")
    parser.add_argument(
        "--select", default=None,
        help="comma-separated rule IDs to run (default: all)",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    parser.add_argument(
        "--self-test", action="store_true",
        help="run the per-rule violating/clean snippet corpus",
    )
    parser.add_argument(
        "--verify-fixtures", action="store_true",
        help="compile + statically verify the representative query corpus",
    )
    parser.add_argument(
        "--no-lint", action="store_true",
        help="skip the lint pass (run only --self-test/--verify-fixtures)",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rid, r in sorted(all_rules().items()):
            print(f"{rid}  {r.title}")
        return 0

    failed = False

    if args.self_test:
        from tools.skimlint.selftest import run_selftest

        failures = run_selftest()
        for f in failures:
            print(f"self-test: {f}", file=sys.stderr)
        print(f"skimlint --self-test: {'FAIL' if failures else 'ok'}")
        failed |= bool(failures)

    if not args.no_lint:
        select = (
            {s.strip() for s in args.select.split(",")} if args.select else None
        )
        result = lint_paths(args.paths, select=select)
        if args.json:
            print(render_json(result))
        else:
            print(result.render_text())
        failed |= bool(result.findings)

    if args.verify_fixtures:
        _ensure_repro_importable()
        from tools.skimlint.fixtures import FIXTURE_QUERIES, verify_fixtures

        failures = verify_fixtures()
        for f in failures:
            print(f"verify-fixtures: {f}", file=sys.stderr)
        print(
            f"skimlint --verify-fixtures: "
            f"{'FAIL' if failures else 'ok'} "
            f"({len(FIXTURE_QUERIES)} queries compiled + verified)"
        )
        failed |= bool(failures)

    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
