"""skimlint: repo-native AST static analysis (DESIGN.md §15).

The repo's signature invariant — every fast path bit-identical to the
single-node reference — is enforced dynamically by tests and chaos
seeds, but the bug classes that break it are *statically* detectable:
wall-clock leaking into modeled time, unsorted iteration feeding a
content address, a lock held across a generator ``yield``.  Each lint
rule here encodes one invariant the codebase previously enforced only by
convention in DESIGN.md.

Zero dependencies beyond the standard library ``ast`` module.  See
``tools/skimlint/rules.py`` for the rule catalog, ``core.py`` for the
framework (suppressions, output formats), ``fixtures.py`` for the
``--verify-fixtures`` compiled-artifact corpus, and ``selftest.py`` for
the per-rule violating/clean snippet corpus.

Usage::

    python -m tools.skimlint src/repro            # lint, text output
    python -m tools.skimlint src/repro --json     # machine-readable
    python -m tools.skimlint --self-test          # rule corpus check
    python -m tools.skimlint --verify-fixtures    # compile+verify corpus
"""

from tools.skimlint.core import (
    JSON_SCHEMA_VERSION,
    Finding,
    LintResult,
    Rule,
    all_rules,
    lint_paths,
    lint_source,
    rule,
)
from tools.skimlint import rules as _rules  # noqa: F401  (registers rules)

__all__ = [
    "JSON_SCHEMA_VERSION",
    "Finding",
    "LintResult",
    "Rule",
    "all_rules",
    "lint_paths",
    "lint_source",
    "rule",
]
