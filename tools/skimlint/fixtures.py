"""Representative query corpus for ``--verify-fixtures``.

The lint half of skimlint proves source-level invariants; this half
exercises the *compiled-artifact* verifier (``repro.analysis.verify``)
over queries spanning every predicate-node kind — flat cuts, trigger
ORs, object selections, HT, invariant-mass windows, ΔR, arithmetic
expressions — plus the era-robustness (absent trigger) and strict
variants.  Each fixture is compiled to a :class:`Program` and lowered to
a pruned+cascaded :class:`SkimPlan` against a small synthetic store,
then ``verify_program``/``verify_plan`` must accept it.

Importing this module requires ``repro`` on the path (``__main__``
inserts ``src/`` when needed); the lint half never imports it.
"""

from __future__ import annotations

#: every entry must plan+compile+verify cleanly against the fixture store
FIXTURE_QUERIES: list[dict] = [
    {
        "name": "presel-flat-cut",
        "branches": ["MET_*"],
        "selection": {
            "preselection": [{"branch": "MET_pt", "op": ">", "value": 40.0}]
        },
    },
    {
        "name": "object-selection",
        "branches": ["Electron_*", "nElectron"],
        "selection": {
            "object": [
                {
                    "collection": "Electron",
                    "cuts": [
                        {"var": "pt", "op": ">", "value": 20.0},
                        {"var": "eta", "op": "abs<", "value": 2.4},
                    ],
                    "min_count": 1,
                }
            ]
        },
    },
    {
        "name": "trigger-or",
        "branches": ["MET_pt"],
        "selection": {
            "event": [
                {"type": "any", "branches": ["HLT_IsoMu24", "HLT_Ele32_WPTight_Gsf"]}
            ]
        },
    },
    {
        "name": "trigger-or-era-absent",
        "branches": ["MET_pt"],
        "selection": {
            "event": [
                {"type": "any", "branches": ["HLT_IsoMu24", "HLT_NotInThisEra_v7"]}
            ]
        },
    },
    {
        "name": "ht-cut",
        "branches": ["Jet_*", "nJet"],
        "selection": {
            "event": [
                {
                    "type": "ht",
                    "collection": "Jet",
                    "var": "pt",
                    "object_cuts": [{"var": "pt", "op": ">", "value": 30.0}],
                    "op": ">",
                    "value": 150.0,
                }
            ]
        },
    },
    {
        "name": "mass-window",
        "branches": ["Electron_*", "nElectron"],
        "selection": {
            "event": [
                {
                    "type": "mass",
                    "collections": ["Electron", "Electron"],
                    "window": [60.0, 120.0],
                }
            ]
        },
    },
    {
        "name": "delta-r",
        "branches": ["Electron_*", "Jet_*"],
        "selection": {
            "event": [
                {
                    "type": "deltaR",
                    "collections": ["Electron", "Jet"],
                    "op": ">",
                    "value": 0.4,
                }
            ]
        },
    },
    {
        "name": "expr",
        "branches": ["MET_pt", "Jet_*", "nJet"],
        "selection": {
            "event": [
                {
                    "type": "expr",
                    "expr": "MET_pt + 0.5*sum(Jet_pt)",
                    "op": ">",
                    "value": 100.0,
                }
            ]
        },
    },
    {
        "name": "kitchen-sink",
        "branches": ["Electron_*", "Jet_*", "MET_*", "HLT_*"],
        "cascade": True,
        "selection": {
            "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
            "object": [
                {
                    "collection": "Electron",
                    "cuts": [{"var": "pt", "op": ">", "value": 15.0}],
                    "min_count": 1,
                }
            ],
            "event": [
                {"type": "any", "branches": ["HLT_IsoMu24"]},
                {"type": "cut", "branch": "MET_pt", "op": ">", "value": 20.0},
                {
                    "type": "ht",
                    "collection": "Jet",
                    "var": "pt",
                    "object_cuts": [],
                    "op": ">",
                    "value": 50.0,
                },
                {
                    "type": "expr",
                    "expr": "abs(MET_pt - 10.0)",
                    "op": ">",
                    "value": 5.0,
                },
            ],
        },
    },
    {
        "name": "strict-variant",
        "branches": ["MET_pt"],
        "strict": True,
        "selection": {
            "event": [{"type": "any", "branches": ["HLT_IsoMu24"]}]
        },
    },
    {
        "name": "cascade-off-variant",
        "branches": ["MET_pt"],
        "cascade": False,
        "selection": {
            "preselection": [{"branch": "MET_pt", "op": ">", "value": 25.0}]
        },
    },
]

#: fixture-store shape (small but multi-window so pruning has spans)
FIXTURE_STORE = {"n_events": 4096, "n_hlt": 8, "basket_events": 512, "seed": 7}
FIXTURE_WINDOW_EVENTS = 1024


def verify_fixtures() -> list[str]:
    """Compile + plan + verify every fixture; returns failure strings."""
    from repro.analysis.verify import VerifyError, verify_plan, verify_program
    from repro.core.planner import plan_skim
    from repro.core.query import parse_query
    from repro.data.synth import make_nanoaod_like
    from repro.kernels.predicate_eval import compile_query

    store = make_nanoaod_like(**FIXTURE_STORE)
    failures: list[str] = []
    for doc in FIXTURE_QUERIES:
        name = doc.get("name", "<unnamed>")
        try:
            query = parse_query({k: v for k, v in doc.items() if k != "name"})
            program = compile_query(query)
            verify_program(program)
            plan = plan_skim(
                query,
                store,
                window_events=FIXTURE_WINDOW_EVENTS,
                prune=True,
                cascade=doc.get("cascade", True),
            )
            verify_plan(plan, store)
        except VerifyError as exc:
            failures.append(f"{name}: {exc}")
        except Exception as exc:  # noqa: BLE001 — report, don't crash the lint run
            failures.append(f"{name}: {type(exc).__name__}: {exc}")
    return failures
