"""skimlint rule catalog (DESIGN.md §15).

Each rule encodes one invariant the repo previously enforced only by
convention:

==== =====================================================================
D001 no wall-clock / sleep / unseeded randomness in ``src/repro`` —
     modeled time flows through ``ManualClock`` and priced costs
D002 no lock held across a ``yield`` in a generator (the streaming
     executors suspend mid-iteration; a held lock is a deadlock/race)
D003 determinism of hashing: ``json.dumps`` must pass ``sort_keys=True``,
     and no set iteration inside hash/manifest/cache-key contexts
D004 typed failure model in ``cluster/``/``serve/``: never raise bare
     ``Exception``/``RuntimeError`` (use ``ClusterError`` subclasses,
     ``CorruptBasket``, ``IntegrityError``, ``ServiceError``, ...)
D005 every thread is named: ``threading.Thread`` needs ``name=``,
     ``ThreadPoolExecutor`` needs ``thread_name_prefix=`` (PR 8's
     ``skim-*`` convention — leaked threads must be identifiable)
E001 no bare ``extras["..."]`` writes outside ``repro/obs/schema.py``
     (the versioned report schema owns the extras key set)
P001 no per-iteration device dispatch outside the kernel tier: building
     a ``jax.jit`` / ``pallas_call`` inside a ``for``/``while`` loop
     re-traces (and may recompile) every iteration — batch the windows
     and dispatch once (DESIGN.md §16); ``kernels/`` is exempt (it owns
     the dispatch discipline and its caching wrappers)
==== =====================================================================

All rules are pure ``ast`` analyses — no imports of the linted code, no
regex string matching (E001's old regex core matched inside strings and
docstrings; the AST form cannot).
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from tools.skimlint.core import Rule, rule

# ---------------------------------------------------------------------------
# name resolution through import aliases
# ---------------------------------------------------------------------------


class ImportMap:
    """Canonical dotted names for expressions, through import aliases.

    ``import numpy as np`` makes ``np.random.rand`` resolve to
    ``numpy.random.rand``; ``from time import time as now`` makes
    ``now`` resolve to ``time.time``.
    """

    def __init__(self, tree: ast.Module):
        self.modules: dict[str, str] = {}  # alias -> module dotted name
        self.members: dict[str, str] = {}  # alias -> module.member
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.modules[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                for a in node.names:
                    self.members[a.asname or a.name] = f"{node.module}.{a.name}"

    def resolve(self, expr: ast.expr) -> str | None:
        """Dotted canonical name of a Name/Attribute chain, or ``None``."""
        parts: list[str] = []
        while isinstance(expr, ast.Attribute):
            parts.append(expr.attr)
            expr = expr.value
        if not isinstance(expr, ast.Name):
            return None
        parts.reverse()
        base = expr.id
        if base in self.modules:
            return ".".join([self.modules[base], *parts])
        if base in self.members:
            return ".".join([self.members[base], *parts])
        return ".".join([base, *parts])


def _has_kwarg(call: ast.Call, name: str) -> bool:
    return any(kw.arg == name for kw in call.keywords)


def _kwarg_value(call: ast.Call, name: str) -> ast.expr | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _local_walk(fn: ast.AST):
    """Walk a function body without descending into nested scopes."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _functions(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# ---------------------------------------------------------------------------
# D001 — modeled time, not wall-clock
# ---------------------------------------------------------------------------

#: unconditionally forbidden calls (wall-clock reads, sleeps, global-RNG
#: draws).  ``time.perf_counter`` is deliberately absent: observed wall
#: timings (extras["wall_s"], span stamps) are legitimate *measurements*;
#: they must never feed modeled time or content addresses.
_D001_FORBIDDEN = frozenset(
    {
        "time.time",
        "time.time_ns",
        "time.sleep",
        "time.monotonic",
        "time.monotonic_ns",
        "datetime.datetime.now",
        "datetime.datetime.utcnow",
        "datetime.datetime.today",
        "datetime.date.today",
    }
    | {
        f"random.{fn}"
        for fn in (
            "random", "randint", "randrange", "uniform", "choice", "choices",
            "shuffle", "sample", "gauss", "normalvariate", "expovariate",
            "betavariate", "triangular", "getrandbits", "seed",
        )
    }
    | {
        f"numpy.random.{fn}"
        for fn in (
            "rand", "randn", "randint", "random", "uniform", "choice",
            "shuffle", "normal", "permutation", "seed",
        )
    }
)

#: forbidden only when called with no arguments (argless = unseeded)
_D001_NEEDS_SEED = frozenset({"random.Random", "numpy.random.default_rng"})


@rule
class WallClockRule(Rule):
    id = "D001"
    title = "wall-clock/sleep/unseeded randomness (modeled time only)"

    def check(self, tree, source, path):
        imports = ImportMap(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = imports.resolve(node.func)
            if name is None:
                continue
            if name in _D001_FORBIDDEN:
                yield self.finding(
                    node, path,
                    f"`{name}` — modeled time flows through ManualClock/"
                    "priced costs; randomness must be seeded",
                )
            elif name in _D001_NEEDS_SEED and not node.args and not node.keywords:
                yield self.finding(
                    node, path, f"`{name}()` without a seed is nondeterministic"
                )


# ---------------------------------------------------------------------------
# D002 — no lock held across a yield
# ---------------------------------------------------------------------------

_LOCKISH_NAME = re.compile(r"(?:^|_)(?:lock|mutex|cond|sem|semaphore)s?$", re.I)
_LOCK_CTORS = frozenset(
    f"threading.{n}"
    for n in ("Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore")
)


def _is_lockish(expr: ast.expr, imports: ImportMap) -> bool:
    if isinstance(expr, ast.Call):
        name = imports.resolve(expr.func)
        return name in _LOCK_CTORS
    terminal = None
    if isinstance(expr, ast.Attribute):
        terminal = expr.attr
    elif isinstance(expr, ast.Name):
        terminal = expr.id
    return terminal is not None and _LOCKISH_NAME.search(terminal) is not None


@rule
class LockAcrossYieldRule(Rule):
    id = "D002"
    title = "lock held across a generator yield"

    def check(self, tree, source, path):
        imports = ImportMap(tree)
        for fn in _functions(tree):
            local = list(_local_walk(fn))
            if not any(isinstance(n, (ast.Yield, ast.YieldFrom)) for n in local):
                continue  # not a generator
            for node in local:
                if not isinstance(node, (ast.With, ast.AsyncWith)):
                    continue
                if not any(_is_lockish(i.context_expr, imports) for i in node.items):
                    continue
                held = [
                    n
                    for stmt in node.body
                    for n in ast.walk(stmt)
                    if isinstance(n, (ast.Yield, ast.YieldFrom))
                ]
                if held:
                    yield self.finding(
                        node, path,
                        f"generator `{fn.name}` yields while holding a lock — "
                        "the consumer may never resume it (deadlock/race; "
                        "snapshot under the lock, yield outside)",
                    )


# ---------------------------------------------------------------------------
# D003 — determinism of hashing
# ---------------------------------------------------------------------------

_HASH_CALLS = frozenset(
    f"hashlib.{n}" for n in ("sha256", "sha1", "sha512", "md5", "blake2b", "new")
) | {"zlib.crc32"}
_HASH_CONTEXT = re.compile(
    r"hash|manifest|cache_key|canonical|digest|content_addr|chrome_trace|trace_json",
    re.I,
)


def _is_set_expr(expr: ast.expr) -> bool:
    if isinstance(expr, (ast.Set, ast.SetComp)):
        return True
    return (
        isinstance(expr, ast.Call)
        and isinstance(expr.func, ast.Name)
        and expr.func.id in ("set", "frozenset")
    )


@rule
class HashDeterminismRule(Rule):
    id = "D003"
    title = "nondeterminism feeding a hash/manifest/cache key"

    def check(self, tree, source, path):
        imports = ImportMap(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if imports.resolve(node.func) != "json.dumps":
                continue
            sk = _kwarg_value(node, "sort_keys")
            if sk is None:
                yield self.finding(
                    node, path,
                    "`json.dumps` without `sort_keys=True` — dict order is "
                    "construction order, not content (content addresses and "
                    "manifests must not depend on it)",
                )
            elif isinstance(sk, ast.Constant) and sk.value is not True:
                yield self.finding(
                    node, path, "`json.dumps(sort_keys=False)` in a repo that hashes JSON"
                )
        # set iteration inside hash contexts: iteration order of a set is
        # salted per-process, so anything it feeds is nondeterministic
        for fn in _functions(tree):
            local = list(_local_walk(fn))
            hashy = _HASH_CONTEXT.search(fn.name) is not None or any(
                isinstance(n, ast.Call)
                and (imports.resolve(n.func) or "") in _HASH_CALLS
                for n in local
            )
            if not hashy:
                continue
            iters: list[ast.expr] = []
            for n in local:
                if isinstance(n, (ast.For, ast.AsyncFor)):
                    iters.append(n.iter)
                elif isinstance(n, (ast.ListComp, ast.SetComp, ast.GeneratorExp, ast.DictComp)):
                    iters.extend(g.iter for g in n.generators)
            for it in iters:
                if _is_set_expr(it):
                    yield self.finding(
                        it, path,
                        f"iteration over a set inside hash context `{fn.name}` — "
                        "sort it (`sorted(...)`) before it feeds a digest",
                    )


# ---------------------------------------------------------------------------
# D004 — typed failure model in cluster/ and serve/
# ---------------------------------------------------------------------------


@rule
class TypedFailureRule(Rule):
    id = "D004"
    title = "untyped raise in cluster/serve (use the typed failure model)"

    def applies_to(self, path: str) -> bool:
        parts = Path(path).parts
        return "cluster" in parts or "serve" in parts

    def check(self, tree, source, path):
        for node in ast.walk(tree):
            if not isinstance(node, ast.Raise) or node.exc is None:
                continue
            exc = node.exc
            name = None
            if isinstance(exc, ast.Call) and isinstance(exc.func, ast.Name):
                name = exc.func.id
            elif isinstance(exc, ast.Name):
                name = exc.id
            if name in ("Exception", "RuntimeError", "BaseException"):
                yield self.finding(
                    node, path,
                    f"bare `raise {name}` — cluster/serve failures are typed "
                    "(ClusterError subclasses, CorruptBasket, IntegrityError, "
                    "ServiceError) so callers can classify retry/degrade",
                )


# ---------------------------------------------------------------------------
# D005 — every thread is named
# ---------------------------------------------------------------------------


@rule
class NamedThreadRule(Rule):
    id = "D005"
    title = "unnamed thread (skim-* naming, DESIGN.md §14)"

    def check(self, tree, source, path):
        imports = ImportMap(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = imports.resolve(node.func)
            if name == "threading.Thread" and not _has_kwarg(node, "name"):
                yield self.finding(
                    node, path,
                    "`threading.Thread` without `name=` — leaked/hung threads "
                    "must be identifiable (use a `skim-*` name)",
                )
            elif name == "concurrent.futures.ThreadPoolExecutor" and not _has_kwarg(
                node, "thread_name_prefix"
            ):
                yield self.finding(
                    node, path,
                    "`ThreadPoolExecutor` without `thread_name_prefix=` — "
                    "pool workers must carry a `skim-*` name",
                )


# ---------------------------------------------------------------------------
# P001 — no per-iteration device dispatch outside the kernel tier
# ---------------------------------------------------------------------------

#: dispatch constructors whose appearance inside a loop body means the
#: program is traced/compiled per iteration instead of once per batch
_P001_DISPATCHERS = frozenset(
    {
        "jax.jit",
        "jax.pmap",
        "jax.experimental.pallas.pallas_call",
    }
)


@rule
class PerWindowDispatchRule(Rule):
    id = "P001"
    title = "per-iteration device dispatch outside kernels/ (batch the windows)"

    def applies_to(self, path: str) -> bool:
        # the kernel tier owns dispatch: its wrappers cache jitted
        # callables and are allowed to construct them wherever they like
        return "kernels" not in Path(path).parts

    def check(self, tree, source, path):
        imports = ImportMap(tree)
        for loop in ast.walk(tree):
            if not isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
                continue
            for stmt in loop.body + loop.orelse:
                for node in ast.walk(stmt):
                    if not isinstance(node, ast.Call):
                        continue
                    name = imports.resolve(node.func)
                    if name in _P001_DISPATCHERS:
                        yield self.finding(
                            node, path,
                            f"`{name}` inside a loop — each iteration "
                            "re-traces the program (one dispatch per "
                            "window); hoist the jitted callable out of "
                            "the loop or batch the windows and dispatch "
                            "once (DESIGN.md §16)",
                        )


# ---------------------------------------------------------------------------
# E001 — extras writes go through the obs schema
# ---------------------------------------------------------------------------


def _extras_subscript(target: ast.expr) -> bool:
    if not isinstance(target, ast.Subscript):
        return False
    value = target.value
    if isinstance(value, ast.Name):
        return value.id == "extras"
    if isinstance(value, ast.Attribute):
        return value.attr == "extras"
    return False


@rule
class ExtrasWriteRule(Rule):
    id = "E001"
    title = "bare extras[...] write outside repro/obs/schema.py"

    def applies_to(self, path: str) -> bool:
        return not path.replace("\\", "/").endswith("obs/schema.py")

    def check(self, tree, source, path):
        for node in ast.walk(tree):
            targets: list[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            for t in targets:
                if _extras_subscript(t):
                    yield self.finding(
                        node, path,
                        "bare extras write — go through repro.obs.schema "
                        "(SkimReport / make_extras), the one place the key "
                        "set can grow",
                    )
