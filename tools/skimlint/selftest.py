"""Per-rule self-test corpus: violating, clean, and suppressed snippets.

``python -m tools.skimlint --self-test`` runs every snippet through the
framework and asserts the expected outcome, so a rule regression is
caught by the tool itself (tests/test_skimlint.py drives the same corpus
plus its own cases).  Every rule MUST ship at least one ``bad`` and one
``good`` snippet; ``bad`` snippets with a suppression comment appear
under ``suppressed``.
"""

from __future__ import annotations

from tools.skimlint.core import all_rules, lint_source

#: rule id -> {"bad": [...], "good": [...], "suppressed": [...]}
#: ``path`` tunes rules scoped by directory (D004) / exemption (E001).
CORPUS: dict[str, dict[str, list[str]]] = {
    "D001": {
        "bad": [
            "import time\nt0 = time.time()\n",
            "import time as t\nt.sleep(0.1)\n",
            "from time import sleep\nsleep(1)\n",
            "from datetime import datetime\nstamp = datetime.now()\n",
            "import random\nx = random.random()\n",
            "import random\nrng = random.Random()\n",
            "import numpy as np\nrng = np.random.default_rng()\n",
            "import numpy as np\nx = np.random.rand(3)\n",
        ],
        "good": [
            "import time\nt0 = time.perf_counter()\n",
            "import random\nrng = random.Random(1234)\n",
            "import numpy as np\nrng = np.random.default_rng(0)\n",
            "x = 'time.time() inside a string is fine'\n",
        ],
        "suppressed": [
            "import time\nt0 = time.time()  # skimlint: ignore[D001]\n",
        ],
    },
    "D002": {
        "bad": [
            (
                "import threading\n"
                "lock = threading.Lock()\n"
                "def gen(items):\n"
                "    with lock:\n"
                "        for x in items:\n"
                "            yield x\n"
            ),
            (
                "class S:\n"
                "    def iter_run(self):\n"
                "        with self._lock:\n"
                "            yield 1\n"
            ),
        ],
        "good": [
            (
                "class S:\n"
                "    def iter_run(self):\n"
                "        with self._lock:\n"
                "            snap = list(self._items)\n"
                "        yield from snap\n"
            ),
            (
                "class S:\n"
                "    def run(self):\n"
                "        with self._lock:\n"
                "            return list(self._items)\n"
            ),
            (
                "def gen(path):\n"
                "    with open(path) as f:\n"
                "        yield from f\n"
            ),
        ],
        "suppressed": [
            (
                "class S:\n"
                "    def iter_run(self):\n"
                "        with self._lock:  # skimlint: ignore[D002]\n"
                "            yield 1\n"
            ),
        ],
    },
    "D003": {
        "bad": [
            "import json\ndoc = json.dumps({'b': 1, 'a': 2})\n",
            "import json\ndoc = json.dumps({'a': 1}, sort_keys=False)\n",
            (
                "import hashlib\n"
                "def manifest_hash(names):\n"
                "    h = hashlib.sha256()\n"
                "    for n in set(names):\n"
                "        h.update(n.encode())\n"
                "    return h.hexdigest()\n"
            ),
            (
                "import hashlib\n"
                "def cache_key(parts):\n"
                "    body = ','.join(p for p in {x.strip() for x in parts})\n"
                "    return hashlib.sha256(body.encode()).hexdigest()\n"
            ),
        ],
        "good": [
            "import json\ndoc = json.dumps({'a': 1}, sort_keys=True)\n",
            (
                "import hashlib\n"
                "def manifest_hash(names):\n"
                "    h = hashlib.sha256()\n"
                "    for n in sorted(set(names)):\n"
                "        h.update(n.encode())\n"
                "    return h.hexdigest()\n"
            ),
            (
                "def plain_loop(names):\n"
                "    out = 0\n"
                "    for n in set(names):\n"
                "        out += len(n)\n"
                "    return out\n"
            ),
        ],
        "suppressed": [
            "import json\ndoc = json.dumps([1, 2])  # skimlint: ignore[D003]\n",
        ],
    },
    "D004": {
        "path": "src/repro/cluster/snippet.py",
        "bad": [
            "def f():\n    raise RuntimeError('shard failed')\n",
            "def f():\n    raise Exception('boom')\n",
        ],
        "good": [
            (
                "class ClusterError(Exception):\n"
                "    pass\n"
                "def f():\n"
                "    raise ClusterError('shard failed')\n"
            ),
            "def f():\n    raise ValueError('bad argument')\n",
        ],
        "suppressed": [
            "def f():\n    raise RuntimeError('x')  # skimlint: ignore[D004]\n",
        ],
    },
    "D005": {
        "bad": [
            "import threading\nt = threading.Thread(target=print)\n",
            (
                "from concurrent.futures import ThreadPoolExecutor\n"
                "ex = ThreadPoolExecutor(max_workers=2)\n"
            ),
        ],
        "good": [
            "import threading\nt = threading.Thread(target=print, name='skim-io-0')\n",
            (
                "from concurrent.futures import ThreadPoolExecutor\n"
                "ex = ThreadPoolExecutor(max_workers=2, thread_name_prefix='skim-gather')\n"
            ),
        ],
        "suppressed": [
            "import threading\nt = threading.Thread(target=print)  # skimlint: ignore[D005]\n",
        ],
    },
    "E001": {
        "bad": [
            "def f(extras):\n    extras['phase1_bytes'] = 7\n",
            "def f(res):\n    res.extras['windows'] += 1\n",
            "def f(extras):\n    extras['flags'] |= 4\n",
        ],
        "good": [
            "def f(extras):\n    x = extras['phase1_bytes']\n",
            "def f(extras):\n    ok = 'windows' in extras\n",
            "def f(extras):\n    y = extras.get('windows', 0)\n",
            '"""docstring mentioning extras["key"] = value is not a write"""\n',
        ],
        "suppressed": [
            "def f(extras):\n    extras['k'] = 1  # skimlint: ignore[E001]\n",
        ],
    },
    "P001": {
        "bad": [
            (
                "import jax\n"
                "def run(windows):\n"
                "    for w in windows:\n"
                "        out = jax.jit(step)(w)\n"
            ),
            (
                "from jax.experimental import pallas as pl\n"
                "def run(windows):\n"
                "    i = 0\n"
                "    while i < len(windows):\n"
                "        out = pl.pallas_call(kernel, out_shape=shape)(windows[i])\n"
                "        i += 1\n"
            ),
            (
                "from jax import jit\n"
                "def run(windows):\n"
                "    for w in windows:\n"
                "        f = jit(step)\n"
                "        out = f(w)\n"
            ),
        ],
        "good": [
            (
                "import jax\n"
                "step_jit = jax.jit(step)\n"
                "def run(windows):\n"
                "    for w in windows:\n"
                "        out = step_jit(w)\n"
            ),
            (
                "import jax\n"
                "def run(batch):\n"
                "    return jax.jit(step)(batch)\n"
            ),
        ],
        "suppressed": [
            (
                "import jax\n"
                "def run(windows):\n"
                "    for w in windows:\n"
                "        out = jax.jit(step)(w)  # skimlint: ignore[P001]\n"
            ),
        ],
    },
    "X001": {
        "bad": [
            "import time\nt0 = time.perf_counter()  # skimlint: ignore\n",
        ],
        "good": [
            "import time\nt0 = time.perf_counter()  # plain comment\n",
        ],
        "suppressed": [],
    },
}


def run_selftest() -> list[str]:
    """Run the corpus; returns a list of failure descriptions (empty = pass)."""
    failures: list[str] = []
    for rid in sorted(set(CORPUS) | set(all_rules())):
        cases = CORPUS.get(rid)
        if cases is None:
            failures.append(f"{rid}: rule has no self-test corpus entry")
            continue
        path = cases.get("path", ["src/repro/snippet.py"])
        path = path if isinstance(path, str) else path[0]
        for i, src in enumerate(cases.get("bad", ())):
            res = lint_source(src, path=path)
            if not any(f.rule == rid for f in res.findings):
                failures.append(f"{rid} bad[{i}]: expected a finding, got none")
        for i, src in enumerate(cases.get("good", ())):
            res = lint_source(src, path=path)
            hits = [f for f in res.findings if f.rule == rid]
            if hits:
                failures.append(f"{rid} good[{i}]: unexpected finding {hits[0].message!r}")
        for i, src in enumerate(cases.get("suppressed", ())):
            res = lint_source(src, path=path)
            if any(f.rule == rid for f in res.findings):
                failures.append(f"{rid} suppressed[{i}]: finding not suppressed")
            if not any(f.rule == rid for f in res.suppressed):
                failures.append(f"{rid} suppressed[{i}]: nothing recorded as suppressed")
    return failures
