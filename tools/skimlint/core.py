"""skimlint framework: rule registry, suppressions, runner, output.

A :class:`Rule` is a named check over one parsed module.  Rules register
themselves with the :func:`rule` decorator (importing
``tools.skimlint.rules`` populates the registry), so adding a rule is
one class in one file — the runner, suppression handling, and both
output formats come for free.

Suppressions are per-line and must carry the rule ID::

    t0 = time.time()  # skimlint: ignore[D001]
    t0 = time.time()  # skimlint: ignore[D001,D003]   (several rules)

A bare ``# skimlint: ignore`` without a rule ID does not suppress
anything — it is itself reported as a finding (rule ``X001``), so every
suppression in the repo names the invariant it waives.  A suppression
on a multi-line statement's *first* line covers findings anchored there.
"""

from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path

#: bump when the JSON output shape changes (tests pin this)
JSON_SCHEMA_VERSION = 1

_SUPPRESS = re.compile(r"#\s*skimlint:\s*ignore\[([A-Z]\d{3}(?:\s*,\s*[A-Z]\d{3})*)\]")
_SUPPRESS_BARE = re.compile(r"#\s*skimlint:\s*ignore(?!\[)")


@dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source line."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def as_dict(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
        }

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


class Rule:
    """Base class: subclass, set ``id``/``title``, implement ``check``.

    ``check`` receives the parsed module, the source text, and the path,
    and returns an iterable of :class:`Finding`.  ``applies_to`` scopes a
    rule to path patterns (e.g. D004 only inspects ``cluster/`` and
    ``serve/``); the default applies everywhere.
    """

    id: str = "X000"
    title: str = ""

    def applies_to(self, path: str) -> bool:
        return True

    def check(self, tree: ast.Module, source: str, path: str):  # pragma: no cover
        raise NotImplementedError

    def finding(self, node: ast.AST, path: str, message: str) -> Finding:
        return Finding(
            rule=self.id,
            path=path,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0) + 1,
            message=message,
        )


_REGISTRY: dict[str, Rule] = {}


def rule(cls: type[Rule]) -> type[Rule]:
    """Class decorator: instantiate and register a rule by its ID."""
    inst = cls()
    if inst.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {inst.id}")
    _REGISTRY[inst.id] = inst
    return cls


def all_rules() -> dict[str, Rule]:
    """Registered rules by ID (import ``tools.skimlint`` to populate)."""
    return dict(_REGISTRY)


@dataclass
class LintResult:
    """Findings plus suppression accounting for one lint run."""

    findings: list[Finding] = field(default_factory=list)
    suppressed: list[Finding] = field(default_factory=list)
    files: int = 0

    def as_dict(self) -> dict:
        counts: dict[str, int] = {}
        for f in self.findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return {
            "version": JSON_SCHEMA_VERSION,
            "files": self.files,
            "findings": [f.as_dict() for f in sorted_findings(self.findings)],
            "suppressed": len(self.suppressed),
            "counts": dict(sorted(counts.items())),
        }

    def render_text(self) -> str:
        lines = [f.render() for f in sorted_findings(self.findings)]
        lines.append(
            f"skimlint: {len(self.findings)} finding(s), "
            f"{len(self.suppressed)} suppressed, {self.files} file(s)"
        )
        return "\n".join(lines)


def sorted_findings(findings: list[Finding]) -> list[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def _suppressions(source: str) -> tuple[dict[int, set[str]], list[tuple[int, int]]]:
    """Per-line suppressed rule IDs, plus bare-ignore (line, col) markers."""
    by_line: dict[int, set[str]] = {}
    bare: list[tuple[int, int]] = []
    for i, text in enumerate(source.splitlines(), 1):
        m = _SUPPRESS.search(text)
        if m:
            by_line[i] = {s.strip() for s in m.group(1).split(",")}
        elif _SUPPRESS_BARE.search(text):
            bare.append((i, _SUPPRESS_BARE.search(text).start() + 1))
    return by_line, bare


def lint_source(
    source: str,
    path: str = "<string>",
    select: set[str] | None = None,
) -> LintResult:
    """Lint one module's source text with every registered rule."""
    result = LintResult(files=1)
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        result.findings.append(
            Finding("E999", path, exc.lineno or 0, (exc.offset or 0), f"syntax error: {exc.msg}")
        )
        return result
    suppressed_by_line, bare = _suppressions(source)
    for line, col in bare:
        result.findings.append(
            Finding(
                "X001", path, line, col,
                "suppression without a rule ID — use `# skimlint: ignore[Dnnn]`",
            )
        )
    for rid, r in sorted(_REGISTRY.items()):
        if select is not None and rid not in select:
            continue
        if not r.applies_to(path):
            continue
        for f in r.check(tree, source, path):
            if f.rule in suppressed_by_line.get(f.line, ()):
                result.suppressed.append(f)
            else:
                result.findings.append(f)
    return result


def iter_py_files(paths) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    return files


def lint_paths(paths, select: set[str] | None = None) -> LintResult:
    """Lint files/directories; aggregates per-file results."""
    total = LintResult()
    for f in iter_py_files(paths):
        one = lint_source(f.read_text(), path=str(f), select=select)
        total.findings.extend(one.findings)
        total.suppressed.extend(one.suppressed)
        total.files += 1
    return total


def render_json(result: LintResult) -> str:
    return json.dumps(result.as_dict(), sort_keys=True, indent=2)
