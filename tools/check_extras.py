#!/usr/bin/env python
"""Forbid bare ``extras["..."]`` writes outside the obs schema module.

PR 7 moved result metadata behind the versioned report schema
(``repro.obs.schema``): engines attach a ``SkimReport`` and render the
compatibility ``extras`` dict through ``SkimReport.legacy_extras()`` /
``make_extras()``.  This checker keeps it that way — any NEW direct
``extras["key"] = ...`` (or ``+=`` / ``|=``) assignment in ``src/repro``
fails the lint step, so the extras key set can only grow deliberately in
one place (``KNOWN_EXTRAS``).

Reads (``extras["key"]`` on the right-hand side, ``.get(...)``, ``in``)
are fine everywhere; only writes are schema mutations.

Usage::

    python tools/check_extras.py            # scan src/repro
    python tools/check_extras.py PATH...    # scan specific files/dirs
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

#: subscript-assignment to an extras dict: ``extras["k"] =``, ``+=``,
#: ``|=`` — but not ``==`` comparisons
_WRITE = re.compile(
    r"""\bextras\s*\[\s*['"][^'"\]]*['"]\s*\]\s*(?:=(?!=)|\+=|\|=)"""
)

#: the one module allowed to define extras shapes
_EXEMPT = ("obs/schema.py",)


def scan(paths: list[str | Path]) -> list[tuple[str, int, str]]:
    """Return ``(path, lineno, line)`` for every bare extras write."""
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    violations = []
    for f in files:
        if any(str(f).endswith(e) for e in _EXEMPT):
            continue
        for i, line in enumerate(f.read_text().splitlines(), 1):
            code = line.split("#", 1)[0]
            if _WRITE.search(code):
                violations.append((str(f), i, line.strip()))
    return violations


def main(argv: list[str]) -> int:
    paths = argv or ["src/repro"]
    violations = scan(paths)
    for path, lineno, line in violations:
        print(f"{path}:{lineno}: bare extras write: {line}")
    if violations:
        print(
            f"\n{len(violations)} bare extras write(s) found — go through "
            "repro.obs.schema (SkimReport / make_extras) instead.",
            file=sys.stderr,
        )
        return 1
    print(f"check_extras: clean ({', '.join(map(str, paths))})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
