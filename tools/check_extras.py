#!/usr/bin/env python
"""Forbid bare ``extras["..."]`` writes outside the obs schema module.

PR 7 moved result metadata behind the versioned report schema
(``repro.obs.schema``): engines attach a ``SkimReport`` and render the
compatibility ``extras`` dict through ``SkimReport.legacy_extras()`` /
``make_extras()``.  This checker keeps it that way — any NEW direct
``extras["key"] = ...`` (or ``+=`` / ``|=``) assignment in ``src/repro``
fails the lint step, so the extras key set can only grow deliberately in
one place (``KNOWN_EXTRAS``).

Since PR 9 the regex core is retired: this is a thin shim over the
skimlint **E001** rule (``tools/skimlint/rules.py``), which matches the
same writes on the AST instead — strings and comments can never false-
positive, and attribute writes (``res.extras[...] = ...``) are caught
too.  The ``scan()`` / ``main()`` API and exit codes are unchanged, so
existing ``make lint`` / CI invocations keep working.

Usage::

    python tools/check_extras.py            # scan src/repro
    python tools/check_extras.py PATH...    # scan specific files/dirs
"""

from __future__ import annotations

import sys
from pathlib import Path

if __package__ in (None, ""):  # loaded by path (CLI, importlib spec)
    _root = Path(__file__).resolve().parents[1]
    if str(_root) not in sys.path:
        sys.path.insert(0, str(_root))

from tools.skimlint.core import lint_paths  # noqa: E402


def scan(paths: list[str | Path]) -> list[tuple[str, int, str]]:
    """Return ``(path, lineno, line)`` for every bare extras write."""
    result = lint_paths([str(p) for p in paths], select={"E001"})
    violations = []
    for f in sorted(result.findings, key=lambda f: (f.path, f.line)):
        lines = Path(f.path).read_text().splitlines()
        line = lines[f.line - 1].strip() if 0 < f.line <= len(lines) else ""
        violations.append((f.path, f.line, line))
    return violations


def main(argv: list[str]) -> int:
    paths = argv or ["src/repro"]
    violations = scan(paths)
    for path, lineno, line in violations:
        print(f"{path}:{lineno}: bare extras write: {line}")
    if violations:
        print(
            f"\n{len(violations)} bare extras write(s) found — go through "
            "repro.obs.schema (SkimReport / make_extras) instead.",
            file=sys.stderr,
        )
        return 1
    print(f"check_extras: clean ({', '.join(map(str, paths))})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
