"""FetchStats / Breakdown merge helpers — the coordinator's gather math.

The scatter-gather coordinator sums per-shard accounting with
``FetchStats.merged`` / ``Breakdown.merged``; these pin the exact field
semantics (every field sums, by-branch maps union-sum, inputs are never
mutated, and the empty merge is the zero object).
"""

import pytest

from repro.core.engine import Breakdown
from repro.data.store import FetchStats


def _stats(nbytes, reqs, branch, bbytes):
    s = FetchStats()
    s.record(branch, bbytes, n_requests=reqs)
    s.bytes_fetched = nbytes  # decouple total from the single record
    return s


def test_fetchstats_merge_sums_fields_and_branches():
    a = FetchStats()
    a.record("Jet_pt", 100, n_requests=2)
    b = FetchStats()
    b.record("Jet_pt", 50)
    b.record("MET_pt", 7, n_requests=3)
    a.merge(b)
    assert a.bytes_fetched == 157
    assert a.requests == 6
    assert a.by_branch == {"Jet_pt": 150, "MET_pt": 7}


def test_fetchstats_merged_is_pure():
    parts = [_stats(10, 1, "a", 10), _stats(20, 2, "b", 20), _stats(5, 1, "a", 5)]
    out = FetchStats.merged(parts)
    assert out.bytes_fetched == 35
    assert out.requests == 4
    assert out.by_branch == {"a": 15, "b": 20}
    # inputs untouched
    assert [p.bytes_fetched for p in parts] == [10, 20, 5]
    assert parts[0].by_branch == {"a": 10}
    # fresh object, not an alias
    assert out is not parts[0]
    assert FetchStats.merged([]).bytes_fetched == 0


def test_breakdown_merge_accumulates_every_stage():
    a = Breakdown(fetch=1.0, decompress=2.0, deserialize=3.0,
                  filter=4.0, write=5.0, output_transfer=6.0)
    b = Breakdown(fetch=0.5, decompress=0.5, deserialize=0.5,
                  filter=0.5, write=0.5, output_transfer=0.5)
    a.merge(b)
    assert a.as_dict() == {
        "fetch": 1.5, "decompress": 2.5, "deserialize": 3.5,
        "filter": 4.5, "write": 5.5, "output_transfer": 6.5,
        "total": pytest.approx(24.0),
    }


def test_breakdown_merged_is_pure():
    parts = [Breakdown(fetch=1.0), Breakdown(filter=2.0), Breakdown(write=3.0)]
    out = Breakdown.merged(parts)
    assert out.total() == pytest.approx(6.0)
    assert parts[0].total() == pytest.approx(1.0)  # untouched
    assert Breakdown.merged([]).total() == 0.0
    # merged-of-merged == flat merge (associativity)
    nested = Breakdown.merged([Breakdown.merged(parts[:2]), parts[2]])
    assert nested.as_dict() == out.as_dict()
