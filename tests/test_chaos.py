"""Seeded chaos sweep (ISSUE 8 / DESIGN.md §14): ``pytest -m chaos``.

Each seed expands to a deterministic fault schedule (tests/chaos.py) —
node failures, modeled stragglers, corrupt baskets, mixed faults,
replica-less degradation, and journaled crash-restarts — and every run
must end in exactly one of two declared outcomes:

  1. bit-identity with the single-node reference (faults absorbed by
     replicas / hedges / recovery, ledgered exactly), or
  2. an *explicit* :class:`DegradedResult` whose manifest names every
     missing window.

Anything else — silent corruption, a hang, an unledgered retry — is a
failure.  The sweep runs under the ``chaos`` marker so CI can invoke it
as its own step with the seed range echoed.
"""

import pytest

from repro.core.engine import run_skim
from repro.data.synth import make_nanoaod_like
from tests.chaos import SCENARIOS, draw_schedule, run_chaos
from tests.test_query import QUERY

#: every scenario kind appears at least twice across the sweep
CHAOS_SEEDS = list(range(18))


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(10_000, n_hlt=16, n_filler=8, basket_events=2048)


@pytest.fixture(scope="module")
def reference(store):
    return run_skim(store, QUERY, mode="near_data")


def test_sweep_covers_every_scenario():
    drawn = {draw_schedule(s).scenario for s in CHAOS_SEEDS}
    assert drawn == set(SCENARIOS)


def test_schedules_are_deterministic():
    for seed in CHAOS_SEEDS:
        assert draw_schedule(seed).describe() == draw_schedule(seed).describe()


@pytest.mark.chaos
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_seed(store, reference, seed):
    ledger = run_chaos(store, reference, seed)
    # the harness asserted bit-identity / explicit degradation inside;
    # the returned ledger documents what the seed exercised
    assert ledger["schedule"].startswith(f"seed={seed}")
