"""Device-resident batched cascade + on-device basket decode (DESIGN.md §16).

The acceptance contract of the window-batched device path:

  * batched cascade runs are **bit-identical** on survivors to the
    per-window reference for batch sizes {1, 3, all} — across the
    engine (serial and threaded), the shared-scan batch engine, and
    the cluster scatter-gather path;
  * shape buckets are grow-only: a window sweep whose padded object
    multiplicity (``pad_K``) grows late re-compiles once per bucket
    growth, then the compiled-program counter is pinned (no
    per-batch recompiles);
  * on-device basket decode round-trips every bitpack kind — zigzag
    ints, xor-prefix floats, bools, raw-f32 bail-outs — bit-identically
    to the host codec, including non-word-aligned basket tails;
  * without an accelerator the decode tier resolves to host, and a
    device request over a codec with no device path falls back loudly
    (``decode_fallbacks``) instead of silently.
"""

import numpy as np
import pytest

from repro.cluster import build_cluster
from repro.core.engine import Breakdown, run_skim
from repro.core.plan import CascadeExecutor
from repro.core.planner import plan_skim
from repro.core.query import parse_query
from repro.data import codecs
from repro.data.store import EventStore, FetchStats
from repro.data.synth import make_nanoaod_like
from repro.kernels import ops
from repro.serve.engine import SharedScanEngine

N_EVENTS = 12_000
BASKET = 2048

QUERY = {
    "branches": ["Electron_*", "MET_*", "event", "luminosityBlock"],
    "selection": {
        "preselection": [
            {"branch": "luminosityBlock", "op": "<=", "value": 2}
        ],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 15.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                ],
                "min_count": 1,
            }
        ],
        "event": [
            {"type": "any", "branches": ["HLT_IsoMu24", "HLT_absent_path"]},
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 15.0},
        ],
    },
}

SECOND = {
    "branches": ["MET_*", "event"],
    "selection": {
        "preselection": [{"branch": "MET_pt", "op": ">", "value": 21.0}]
    },
}


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(
        N_EVENTS, n_hlt=16, n_filler=8, basket_events=BASKET
    )


@pytest.fixture(scope="module")
def reference(store):
    return run_skim(
        store, QUERY, mode="near_data", fused=False, pipeline=False,
        prune=False, cascade=False,
    )


def _assert_same_output(res, ref):
    assert res.n_passed == ref.n_passed
    assert res.n_input == ref.n_input
    for name in ref.output.branch_names():
        br = ref.output.branches[name]
        if br.jagged:
            v0, c0 = ref.output.read_jagged(name)
            v1, c1 = res.output.read_jagged(name)
            np.testing.assert_array_equal(c1, c0)
            np.testing.assert_array_equal(v1, v0)
        else:
            np.testing.assert_array_equal(
                res.output.read_flat(name), ref.output.read_flat(name)
            )


# ---------------------------------------------------------------------------
# batched-cascade bit-identity: engine / shared-scan / cluster
# ---------------------------------------------------------------------------

# "all": larger than the window count, so one batch covers the sweep
ALL = N_EVENTS // BASKET + 1


@pytest.mark.parametrize("device_batch", [1, 3, ALL])
@pytest.mark.parametrize("pipeline", [False, "threads"])
def test_batched_engine_bit_identical(store, reference, device_batch, pipeline):
    res = run_skim(
        store, QUERY, mode="near_data", pipeline=pipeline, prune=False,
        cascade=True, device_batch=device_batch,
    )
    _assert_same_output(res, reference)
    assert res.extras["device_batch"] == device_batch
    assert "device_dispatches" in res.extras


@pytest.mark.parametrize("device_batch", [1, 3, ALL])
def test_batched_engine_ledger_exact(store, device_batch):
    """fetched + skipped == the preload reference's fetched bytes, even
    under batching (the batch ledger dedups exactly like per-window)."""
    preload = run_skim(
        store, QUERY, mode="near_data", pipeline=False, prune=False,
        cascade=False,
    )
    res = run_skim(
        store, QUERY, mode="near_data", pipeline=False, prune=False,
        cascade=True, device_batch=device_batch,
    )
    assert (
        res.stats.bytes_fetched + res.stats.cascade_bytes_skipped
        == preload.stats.bytes_fetched
    )


@pytest.mark.parametrize("device_batch", [1, 3, ALL])
def test_batched_shared_scan_bit_identical(store, device_batch):
    batch = SharedScanEngine(
        store, cascade=True, device_batch=device_batch
    ).run_batch([QUERY, SECOND])
    ref = SharedScanEngine(store, cascade=True).run_batch([QUERY, SECOND])
    for res, solo in zip(batch.results, ref.results):
        _assert_same_output(res, solo)
    assert batch.shared_stats.bytes_fetched == ref.shared_stats.bytes_fetched


@pytest.mark.parametrize("device_batch", [1, 3, ALL])
def test_batched_cluster_bit_identical(store, reference, device_batch):
    coord = build_cluster(
        store, 3, replication=False, cascade=True, device_batch=device_batch
    )
    _assert_same_output(coord.run(QUERY), reference)


def test_device_batch_validated(store):
    with pytest.raises(ValueError):
        run_skim(store, QUERY, device_batch=0)
    with pytest.raises(ValueError):
        SharedScanEngine(store, device_batch=-2)
    with pytest.raises(ValueError):
        run_skim(store, QUERY, fused_backend="cuda")


# ---------------------------------------------------------------------------
# recompile regression: grow-only shape buckets
# ---------------------------------------------------------------------------


def _spiky_store() -> EventStore:
    """Last window's electron multiplicity is ~8x the rest: ``pad_K``
    grows only on the final batch of a sweep."""
    rng = np.random.default_rng(5)
    n = 8 * BASKET
    lam = np.where(np.arange(n) < n - BASKET, 1.2, 10.0)
    n_el = rng.poisson(lam).astype(np.int32)
    tot = int(n_el.sum())
    cols = {
        "nElectron": n_el,
        "Electron_pt": (rng.exponential(25.0, tot) + 3.0).astype(np.float32),
        "Electron_eta": rng.uniform(-2.5, 2.5, tot).astype(np.float32),
        "MET_pt": (rng.exponential(30.0, n) + 1.0).astype(np.float32),
        "HLT_IsoMu24": rng.random(n) < 0.3,
        "event": np.arange(n, dtype=np.int32),
        "luminosityBlock": (np.arange(n) // 1000).astype(np.int32),
    }
    jagged = {"Electron_pt": "nElectron", "Electron_eta": "nElectron"}
    return EventStore.from_arrays(cols, jagged=jagged, basket_events=BASKET)


def _run_sweep(ex, store, batch: int):
    outs = []
    windows = [
        (a, min(a + BASKET, store.n_events))
        for a in range(0, store.n_events, BASKET)
    ]
    for i in range(0, len(windows), batch):
        entries = [
            (a, b, None, Breakdown(), FetchStats(), {})
            for a, b in windows[i : i + batch]
        ]
        outs.extend(ex.run_window_batch(entries, pad_B=batch))
    return outs


def test_recompile_count_pinned_with_late_growing_pad_k():
    store = _spiky_store()
    plan = plan_skim(parse_query(QUERY), store, cascade=True)
    ex = CascadeExecutor(plan, store, adaptive=False, backend="xla")
    ops.reset_dispatch_stats()
    first = _run_sweep(ex, store, batch=3)
    compiles_after_first = ops.dispatch_stats()["compiles"]
    assert compiles_after_first > 0
    # the last batch grew the pad_K bucket once; the buckets are now
    # saturated — a second identical sweep must not compile anything
    second = _run_sweep(ex, store, batch=3)
    stats = ops.dispatch_stats()
    assert stats["compiles"] == compiles_after_first, stats
    # it must still dispatch (cache reuse, not short-circuit) ...
    assert stats["dispatches"] > 0
    # ... and stay bit-identical between sweeps
    for o1, o2 in zip(first, second):
        np.testing.assert_array_equal(o1.mask, o2.mask)


def test_warmups_ledgered_outside_dispatches():
    """Shape-bucket warm-up dispatches are counted separately so stage
    timers (and the device_dispatches ledger) see steady state only."""
    store = _spiky_store()
    plan = plan_skim(parse_query(QUERY), store, cascade=True)
    ex = CascadeExecutor(plan, store, adaptive=False, backend="xla")
    ops.reset_dispatch_stats()
    _run_sweep(ex, store, batch=3)
    stats = ops.dispatch_stats()
    assert stats["warmups"] > 0
    assert stats["dispatches"] > 0


# ---------------------------------------------------------------------------
# on-device basket decode: round-trip every kind, any tail
# ---------------------------------------------------------------------------


def _kind_values(kind: str, n: int, rng) -> np.ndarray:
    if kind == "int":
        return rng.integers(-500, 2_000_000, n).astype(np.int32)
    if kind == "bool":
        return rng.random(n) < 0.37
    if kind == "float":
        # low-entropy mantissas: xor-prefix packing stays under the
        # raw-f32 bail-out threshold
        return (rng.integers(0, 64, n).astype(np.float32) * 0.25 + 8.0)
    if kind == "raw":
        # full-entropy floats trip the bail-out (KIND_RAW_F32 passthrough)
        return rng.random(n).astype(np.float32) * 1e3
    raise AssertionError(kind)


@pytest.mark.parametrize("kind,dtype", [
    ("int", np.int32), ("bool", np.bool_),
    ("float", np.float32), ("raw", np.float32),
])
@pytest.mark.parametrize("n", [1024, 1001, 777, 333, 32, 1])
def test_device_decode_round_trip(kind, dtype, n):
    rng = np.random.default_rng(11)
    values = _kind_values(kind, n, rng)
    blob = codecs.bitpack_encode(values)
    if kind == "raw" and n >= 32:
        # (a 1-element basket xor-prefixes to zero bits and legitimately
        # stays KIND_FLOAT — the round-trip below still must hold)
        assert codecs.bitpack_raw_parts(blob)["kind"] == codecs.KIND_RAW_F32
    host = codecs.bitpack_decode(blob, dtype)
    np.testing.assert_array_equal(host, values.astype(dtype))
    [dev] = codecs.decode_basket_batch([blob], "bitpack", dtype, backend="device")
    assert np.asarray(dev).dtype == host.dtype
    np.testing.assert_array_equal(np.asarray(dev), host)


def test_device_decode_mixed_kind_batch():
    """One decode round over a mixed-kind, mixed-tail blob list."""
    rng = np.random.default_rng(3)
    cases = [
        ("int", np.int32, 1001), ("bool", np.bool_, 777),
        ("float", np.float32, 333), ("raw", np.float32, 501),
        ("int", np.int32, 2048), ("float", np.float32, 64),
    ]
    blobs = [codecs.bitpack_encode(_kind_values(k, n, rng)) for k, _, n in cases]
    # per-call dtype is uniform in the store API; group by dtype here
    for dtype in (np.int32, np.bool_, np.float32):
        sel = [i for i, (_, dt, _) in enumerate(cases) if dt == dtype]
        got = codecs.decode_basket_batch(
            [blobs[i] for i in sel], "bitpack", dtype, backend="device"
        )
        for i, arr in zip(sel, got):
            np.testing.assert_array_equal(
                np.asarray(arr), codecs.bitpack_decode(blobs[i], dtype)
            )


# ---------------------------------------------------------------------------
# decode tier selection + fallback visibility
# ---------------------------------------------------------------------------


def _tiny_store(codec: str, decode_backend=None) -> EventStore:
    rng = np.random.default_rng(9)
    n = 3 * BASKET
    cols = {
        "MET_pt": (rng.exponential(30.0, n) + 1.0).astype(np.float32),
        "event": np.arange(n, dtype=np.int32),
    }
    return EventStore.from_arrays(
        cols, basket_events=BASKET, codec=codec, decode_backend=decode_backend
    )


def test_decode_backend_resolves_host_without_accelerator():
    import jax

    st = _tiny_store("bitpack")
    if jax.default_backend() == "tpu":  # pragma: no cover - TPU CI only
        assert st.resolved_decode_backend() == "device"
        return
    assert st.resolved_decode_backend() == "host"
    st.read_flat("MET_pt")
    stats = st.decode_backend_stats()
    assert stats["host_baskets"] > 0 and stats["device_baskets"] == 0


def test_forced_device_decode_is_bit_identical_on_cpu():
    dev = _tiny_store("bitpack", decode_backend="device")
    host = _tiny_store("bitpack", decode_backend="host")
    np.testing.assert_array_equal(
        dev.read_flat("MET_pt"), host.read_flat("MET_pt")
    )
    np.testing.assert_array_equal(dev.read_flat("event"), host.read_flat("event"))
    dstats = dev.decode_backend_stats()
    assert dstats["device_baskets"] > 0
    assert dstats["fallbacks"] == 0
    assert host.decode_backend_stats()["host_baskets"] > 0


def test_non_bitpack_device_request_falls_back_visibly():
    st = _tiny_store("zlib", decode_backend="device")
    ref = _tiny_store("zlib", decode_backend="host")
    np.testing.assert_array_equal(
        st.read_flat("MET_pt"), ref.read_flat("MET_pt")
    )
    stats = st.decode_backend_stats()
    assert stats["fallbacks"] > 0, stats
    assert stats["device_baskets"] == 0


def test_invalid_decode_backend_rejected():
    with pytest.raises(ValueError):
        _tiny_store("bitpack", decode_backend="gpu")


def test_batched_run_with_device_decode_bit_identical(reference):
    """End to end: batched cascade + forced device decode tier."""
    st = make_nanoaod_like(
        N_EVENTS, n_hlt=16, n_filler=8, basket_events=BASKET
    )
    st.decode_backend = "device"
    res = run_skim(
        st, QUERY, mode="near_data", pipeline=False, prune=False,
        cascade=True, device_batch=3,
    )
    _assert_same_output(res, reference)
    assert res.extras["decode_backend"] == "device"
    assert st.decode_backend_stats()["device_baskets"] > 0
