import os
import sys

# tests run single-device CPU; dry-run owns the 512-device flag
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
