import os
import sys

# tests run single-device CPU; dry-run owns the 512-device flag
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# tier-1 runs with the static verifier on: every compile_query/plan_skim
# in the suite proves its artifact's invariants (repro.analysis.verify).
# Benchmarks force it off — verification is a test-time gate, not a cost.
os.environ.setdefault("REPRO_VERIFY", "1")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
