"""Fault-tolerance layer (ISSUE 8 / DESIGN.md §14).

Pins the tentpole invariant: every recovered result is bit-identical to
the single-node reference, and every degradation is explicit and
ledgered.  Covers the data layer's basket integrity digests, the
cluster's retry/hedge policies, explicit degradation manifests, the
serial-mode modeled deadline, gather-thread leak semantics, and the
prefetcher's cancellation-under-fault contract.
"""

import threading

import numpy as np
import pytest

from repro.cluster import (
    ClusterCoordinator,
    ClusterError,
    DegradedResult,
    HedgePolicy,
    IntegrityError,
    NodeTimeout,
    RetryPolicy,
    SkimResultCache,
    StorageNode,
    classify_fault,
    partition_store,
)
from repro.cluster.node import NodeFailure
from repro.core.engine import run_skim
from repro.data.codecs import basket_digest
from repro.data.store import (
    INTEGRITY_VERSION,
    BasketMeta,
    CorruptBasket,
    EventStore,
    FetchStats,
    WindowPrefetcher,
)
from repro.data.synth import make_nanoaod_like
from repro.obs.metrics import MetricsRegistry
from tests.test_query import QUERY


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(10_000, n_hlt=16, n_filler=8, basket_events=2048)


@pytest.fixture(scope="module")
def reference(store):
    return run_skim(store, QUERY, mode="near_data")


@pytest.fixture(scope="module")
def shards3(store):
    return partition_store(store, 3)


def _coord(
    shards,
    store,
    cache=None,
    replication=True,
    concurrency="serial",
    prune=True,
    cascade=True,
    **kw,
):
    nodes = [StorageNode(sh, prune=prune, cascade=cascade) for sh in shards]
    replicas = (
        {
            sh.shard_id: StorageNode(
                sh, node_id=100 + sh.shard_id, prune=prune, cascade=cascade
            )
            for sh in shards
        }
        if replication
        else {}
    )
    return ClusterCoordinator(
        nodes,
        replicas=replicas,
        cache=cache,
        concurrency=concurrency,
        basket_events=store.basket_events,
        codec=store.codec,
        prune=prune,
        **kw,
    )


def _assert_same_output(res, ref):
    assert res.n_passed == ref.n_passed
    assert res.n_input == ref.n_input
    assert res.output.compressed_bytes() == ref.output.compressed_bytes()
    for name in ref.output.branch_names():
        br = ref.output.branches[name]
        if br.jagged:
            v0, c0 = ref.output.read_jagged(name)
            v1, c1 = res.output.read_jagged(name)
            np.testing.assert_array_equal(c1, c0)
            np.testing.assert_array_equal(v1, v0)
        else:
            np.testing.assert_array_equal(
                res.output.read_flat(name), ref.output.read_flat(name)
            )


# ---------------------------------------------------------------------------
# data layer: basket integrity digests
# ---------------------------------------------------------------------------


def test_basket_digest_deterministic_and_sensitive():
    blob = b"\x01\x02\x03\x04" * 100
    d = basket_digest(blob)
    assert isinstance(d, int) and 0 <= d <= 0xFFFFFFFF
    assert basket_digest(blob) == d
    flipped = bytes([blob[0] ^ 0xFF]) + blob[1:]
    assert basket_digest(flipped) != d


def test_every_basket_meta_carries_matching_digest(store):
    assert INTEGRITY_VERSION >= 1
    for name in store.branch_names():
        for i, meta in enumerate(store._baskets[name]):
            blob = store._blobs[name][i]
            assert meta.digest == basket_digest(blob)


def test_corrupt_fetch_raises_typed_error():
    small = make_nanoaod_like(2_000, n_hlt=4, n_filler=2, basket_events=512)
    restore = small.corrupt_blob("MET_pt", 1)
    with pytest.raises(CorruptBasket) as ei:
        small.read_flat("MET_pt")
    exc = ei.value
    assert exc.branch == "MET_pt"
    assert exc.basket_id == 1
    assert exc.expected != exc.actual
    assert classify_fault(exc) == "corrupt"
    restore()  # transient read-path corruption: clean bytes come back
    assert len(small.read_flat("MET_pt")) == 2_000


def test_verify_off_restores_unchecked_fast_path():
    small = make_nanoaod_like(1_000, n_hlt=4, n_filler=2, basket_events=512)
    small.verify = False
    restore = small.corrupt_blob("run", 0)
    # no digest check: the corrupt blob decodes to garbage, silently
    small.fetch_basket("run", 0)
    restore()


def test_legacy_meta_without_digest_degrades_to_skip():
    """A store written before INTEGRITY_VERSION has no digests; the
    check degrades to a no-op — never to a false alarm."""
    small = make_nanoaod_like(1_000, n_hlt=4, n_filler=2, basket_events=512)
    meta = small._baskets["MET_pt"][0]
    legacy_row = meta.stats_row()[:8]  # pre-digest 8-element row
    legacy = BasketMeta(*legacy_row)
    assert legacy.digest is None
    small._baskets["MET_pt"][0] = legacy
    restore = small.corrupt_blob("MET_pt", 0)
    small.fetch_basket("MET_pt", 0)  # unverifiable: no raise
    restore()


def test_save_load_roundtrips_digests(tmp_path):
    small = make_nanoaod_like(1_000, n_hlt=4, n_filler=2, basket_events=512)
    path = str(tmp_path / "t.skim")
    small.save(path)
    loaded = EventStore.load(path)
    for name in small.branch_names():
        for m0, m1 in zip(small._baskets[name], loaded._baskets[name]):
            assert m1.digest == m0.digest is not None
    # loaded stores verify too
    restore = loaded.corrupt_blob("MET_pt", 0)
    with pytest.raises(CorruptBasket):
        loaded.fetch_basket("MET_pt", 0)
    restore()


# ---------------------------------------------------------------------------
# retry + hedge policies
# ---------------------------------------------------------------------------


def test_retry_backoff_deterministic_exponential_capped():
    p = RetryPolicy(budget=4, base_delay_s=0.1, multiplier=2.0,
                    max_delay_s=0.5, jitter=0.0)
    assert p.backoff_s(1) == pytest.approx(0.1)
    assert p.backoff_s(2) == pytest.approx(0.2)
    assert p.backoff_s(3) == pytest.approx(0.4)
    assert p.backoff_s(4) == pytest.approx(0.5)  # capped
    j = RetryPolicy(jitter=0.1, seed=7)
    assert j.backoff_s(1, shard_id=3) == j.backoff_s(1, shard_id=3)
    assert j.backoff_s(1, shard_id=3) != j.backoff_s(1, shard_id=4)
    lo, hi = 0.05 * 0.9, 0.05 * 1.1
    assert lo <= j.backoff_s(1, shard_id=3) <= hi


def test_retry_targets_cover_every_configuration():
    p, r = object(), object()
    assert RetryPolicy(budget=1).targets(p, r) == [r]
    assert RetryPolicy(budget=3).targets(p, r) == [r, r, r]
    assert RetryPolicy(budget=3, retry_primary=True).targets(p, r) == [r, p, r]
    assert RetryPolicy(budget=2).targets(p, None) == []
    assert RetryPolicy(budget=2, retry_primary=True).targets(p, None) == [p, p]
    assert RetryPolicy(budget=0).targets(p, r) == []


def test_hedge_delay_fixed_and_quantile():
    assert HedgePolicy(delay_s=0.25).delay([9.0, 9.0]) == 0.25
    h = HedgePolicy(quantile=0.5, multiplier=2.0, min_delay_s=0.01,
                    min_samples=2)
    assert h.delay([]) == 0.01  # cold start: floor
    assert h.delay([1.0, 2.0, 3.0]) == pytest.approx(4.0)  # 2 x median-ish


def test_classify_fault_taxonomy():
    assert classify_fault(CorruptBasket("b", 0, 1, 2)) == "corrupt"
    assert classify_fault(NodeTimeout("slow")) == "timeout"
    assert classify_fault(NodeFailure("down")) == "fail"
    assert classify_fault(RuntimeError("other")) == "fail"


# ---------------------------------------------------------------------------
# cluster: corrupt-basket recovery + quarantine
# ---------------------------------------------------------------------------


def test_corrupt_basket_retries_on_replica(store, shards3, reference):
    metrics = MetricsRegistry()
    coord = _coord(
        shards3, store, prune=False, cascade=False, metrics=metrics
    )
    coord.nodes[1].inject_fault("corrupt")
    res = coord.run(QUERY)
    _assert_same_output(res, reference)
    assert res.retries == [(1, coord.nodes[1].node_id, 101)]
    # the incident is quarantined on the node that read the bad bytes
    assert len(coord.nodes[1].quarantine) == 1
    ((sid, branch, basket),) = coord.nodes[1].quarantine
    assert sid == 1 and basket == 0
    assert res.extras["corrupt_baskets"] == 1
    assert res.extras["retry_attempts"] == 1
    assert res.extras["retry_backoff_s"] > 0
    assert metrics.counter("cluster_corrupt_baskets_total") == 1
    assert metrics.counter("cluster_retries_total", error="corrupt") == 1


def test_corrupt_without_replica_is_terminal(store, shards3):
    coord = _coord(shards3, store, replication=False, prune=False,
                   cascade=False)
    coord.nodes[0].inject_fault("corrupt")
    with pytest.raises(ClusterError, match="corrupt.*no replica"):
        coord.run(QUERY)
    assert len(coord.nodes[0].quarantine) == 1


def test_retry_budget_exhaustion_message(store, shards3):
    coord = _coord(shards3, store, retry_policy=RetryPolicy(budget=2))
    coord.nodes[1].inject_fault("fail", n=3)  # primary + both re-issues
    coord.replicas[1].inject_fault("fail", n=2)
    with pytest.raises(ClusterError, match="both failed.*budget 2"):
        coord.run(QUERY)


# ---------------------------------------------------------------------------
# cluster: modeled hedging
# ---------------------------------------------------------------------------


def _clean_max_modeled(shards, store):
    clean = _coord(shards, store, replication=False).run(QUERY)
    return max(r.modeled_s for r in clean.responses)


def test_hedge_beats_modeled_straggler(store, shards3, reference):
    base = _clean_max_modeled(shards3, store)
    delay = base * 1.5
    straggle = base * 10 + 5.0
    metrics = MetricsRegistry()
    unhedged = _coord(shards3, store)
    unhedged.nodes[1].inject_fault("straggle", delay_s=straggle)
    slow = unhedged.run(QUERY)
    assert slow.modeled_total_s > straggle

    hedged = _coord(
        shards3, store,
        hedge=HedgePolicy(delay_s=delay), metrics=metrics,
    )
    hedged.nodes[1].inject_fault("straggle", delay_s=straggle)
    res = hedged.run(QUERY)
    _assert_same_output(res, reference)
    assert res.extras["hedges_won"] == 1
    assert res.extras["hedges_lost"] == 0
    # the winning response finishes the modeled race at delay + replica
    assert res.modeled_total_s < slow.modeled_total_s
    assert metrics.counter("cluster_hedges_total", outcome="won") == 1


def test_hedge_losses_keep_primary_bit_identical(store, shards3, reference):
    # delay 0: every shard hedges; equal-work modeled times differ only
    # by measurement jitter, which the policy's jitter_guard absorbs —
    # the replica never wins the race
    coord = _coord(shards3, store, hedge=HedgePolicy(delay_s=0.0))
    res = coord.run(QUERY)
    _assert_same_output(res, reference)
    assert res.extras["hedges_won"] == 0
    assert res.extras["hedges_lost"] == len(
        [r for r in res.responses if not r.pruned]
    )


def test_hedge_jitter_guard_validates():
    with pytest.raises(ValueError, match="jitter_guard"):
        HedgePolicy(jitter_guard=1.0)
    with pytest.raises(ValueError, match="jitter_guard"):
        HedgePolicy(jitter_guard=-0.1)


def test_hedge_mismatch_raises_integrity_error(store, shards3):
    base = _clean_max_modeled(shards3, store)
    coord = _coord(shards3, store, hedge=HedgePolicy(delay_s=base * 1.5))
    coord.nodes[1].inject_fault("straggle", delay_s=base * 10 + 5.0)
    replica = coord.replicas[1]
    real = replica.execute

    def lying(query):
        resp = real(query)
        resp.result.n_passed += 1  # disagree bit-for-bit
        return resp

    replica.execute = lying
    with pytest.raises(IntegrityError, match="shard 1.*refusing to pick"):
        coord.run(QUERY)


def test_hedge_fault_is_cancelled_not_fatal(store, shards3, reference):
    base = _clean_max_modeled(shards3, store)
    coord = _coord(shards3, store, hedge=HedgePolicy(delay_s=base * 1.5))
    coord.nodes[1].inject_fault("straggle", delay_s=base * 10 + 5.0)
    coord.replicas[1].inject_fault("fail")
    res = coord.run(QUERY)
    _assert_same_output(res, reference)  # primary's answer stands
    assert res.extras["hedges_cancelled"] == 1


# ---------------------------------------------------------------------------
# cluster: explicit degradation
# ---------------------------------------------------------------------------


def test_partial_results_refused_by_default(store, shards3):
    coord = _coord(shards3, store, replication=False)
    coord.nodes[1].inject_fault("fail")
    with pytest.raises(ClusterError, match="no replica"):
        coord.run(QUERY)


def test_allow_partial_yields_exact_degradation_manifest(store, shards3):
    metrics = MetricsRegistry()
    coord = _coord(shards3, store, replication=False, metrics=metrics)
    coord.nodes[1].inject_fault("fail")
    res = coord.run(QUERY, allow_partial=True)
    assert isinstance(res, DegradedResult)
    assert res.degraded and res.extras["degraded"]
    (err,) = res.errors
    assert err.shard_id == 1
    assert err.kind == "fail"
    assert err.window_ids == list(coord.nodes[1].shard.window_ids)
    assert res.extras["missing_windows"] == sorted(err.window_ids)
    assert err.missing_events == sum(b - a for a, b in err.spans)
    assert metrics.counter("cluster_degraded_shards_total", error="fail") == 1

    # every SURVIVING window is bit-identical to the single-node
    # reference restricted to the surviving spans
    surviving = sorted(
        (w * n.shard.window_events,
         min(w * n.shard.window_events + n.shard.window_events,
             store.n_events))
        for n in (coord.nodes[0], coord.nodes[2])
        for w in n.shard.window_ids
    )
    sub = store.slice_events(surviving)
    ref = run_skim(sub, QUERY, mode="near_data")
    assert res.n_passed == ref.n_passed
    assert res.n_input == ref.n_input
    for name in ref.output.branch_names():
        if ref.output.branches[name].jagged:
            v0, c0 = ref.output.read_jagged(name)
            v1, c1 = res.output.read_jagged(name)
            np.testing.assert_array_equal(c1, c0)
            np.testing.assert_array_equal(v1, v0)
        else:
            np.testing.assert_array_equal(
                res.output.read_flat(name), ref.output.read_flat(name)
            )


def test_all_shards_failed_raises_even_with_allow_partial(store, shards3):
    coord = _coord(shards3, store, replication=False, prune=False,
                   cascade=False)
    for node in coord.nodes:
        node.inject_fault("fail")
    with pytest.raises(ClusterError, match="every shard failed"):
        coord.run(QUERY, allow_partial=True)


def test_degraded_results_never_poison_the_cache(store, shards3, reference):
    cache = SkimResultCache(budget_bytes=1 << 30)
    coord = _coord(shards3, store, cache=cache)
    coord.replicas.pop(1)  # shard 1 has no cover
    coord.nodes[1].inject_fault("fail")
    res = coord.run(QUERY, allow_partial=True)
    assert res.degraded
    # healed: the failed shard re-executes (nothing stale cached for it)
    res2 = coord.run(QUERY)
    assert not res2.degraded
    _assert_same_output(res2, reference)


def test_integrity_error_not_swallowed_by_allow_partial(store, shards3):
    base = _clean_max_modeled(shards3, store)
    coord = _coord(shards3, store, hedge=HedgePolicy(delay_s=base * 1.5),
                   allow_partial=True)
    coord.nodes[1].inject_fault("straggle", delay_s=base * 10 + 5.0)
    replica = coord.replicas[1]
    real = replica.execute

    def lying(query):
        resp = real(query)
        resp.result.n_passed += 1
        return resp

    replica.execute = lying
    with pytest.raises(IntegrityError):
        coord.run(QUERY)


# ---------------------------------------------------------------------------
# satellite 1: serial mode enforces the modeled deadline
# ---------------------------------------------------------------------------


def test_serial_mode_enforces_modeled_deadline(store, shards3):
    """``shard_timeout_s`` used to be silently ignored in serial mode;
    it is now enforced against the modeled clock."""
    coord = _coord(shards3, store, replication=False, shard_timeout_s=5.0)
    coord.nodes[1].inject_fault("straggle", delay_s=60.0)
    with pytest.raises(NodeTimeout, match="shard 1.*deadline.*no replica"):
        coord.run(QUERY)


def test_serial_modeled_timeout_falls_back_to_replica(
    store, shards3, reference
):
    coord = _coord(shards3, store, shard_timeout_s=5.0)
    coord.nodes[1].inject_fault("straggle", delay_s=60.0)
    res = coord.run(QUERY)
    _assert_same_output(res, reference)
    assert res.retries == [(1, coord.nodes[1].node_id, 101)]


def test_serial_deadline_ignores_fast_shards(store, shards3, reference):
    coord = _coord(shards3, store, replication=False, shard_timeout_s=1e9)
    _assert_same_output(coord.run(QUERY), reference)


# ---------------------------------------------------------------------------
# satellite 2: gather-thread leak semantics
# ---------------------------------------------------------------------------


def _hang_node(node):
    release = threading.Event()
    orig = node.execute

    def blocked(query):
        release.wait()
        return orig(query)

    node.execute = blocked
    return release


def test_leaked_gather_thread_named_and_subsequent_query_clean(
    store, shards3, reference
):
    """A timed-out worker leaks by design (see NodeTimeout docstring);
    it must be identifiable by name and must not affect the next query
    on the same coordinator."""
    coord = _coord(shards3, store, concurrency="threads",
                   shard_timeout_s=0.2)
    release = _hang_node(coord.nodes[1])
    try:
        res = coord.run(QUERY)
        _assert_same_output(res, reference)
        leaked = [
            t for t in threading.enumerate()
            if t.name.startswith("skim-gather") and t.is_alive()
        ]
        assert leaked, "hung worker should still be parked, identifiable"
        # a fresh pool per gather: the same coordinator serves the next
        # query without inheriting the hung worker
        res2 = coord.run(QUERY)
        _assert_same_output(res2, reference)
    finally:
        release.set()


# ---------------------------------------------------------------------------
# satellite 3: prefetcher cancellation under fault
# ---------------------------------------------------------------------------


def _no_prefetch_threads():
    return not any(
        t.name.startswith("skim-prefetch") and t.is_alive()
        for t in threading.enumerate()
    )


def test_prefetcher_worker_fault_joins_cleanly():
    started = []

    def load(start, stop):
        started.append(start)
        if start == 40:
            raise ValueError("injected decode fault")
        return FetchStats(bytes_fetched=stop - start)

    pf = WindowPrefetcher(100, 20, load, depth=2)
    consumed = []
    with pytest.raises(ValueError, match="injected decode fault"):
        for start, _stop, payload in pf:
            consumed.append((start, payload.bytes_fetched))
    # the fault surfaced at the faulting window; later windows were
    # never yielded, and the pool joined (no deadlock, no zombie)
    assert [s for s, _ in consumed] == [0, 20]
    assert _no_prefetch_threads()
    # each started window started exactly once: nothing double-runs
    assert len(started) == len(set(started))


def test_prefetcher_close_mid_stream_no_double_accounting():
    loads = []

    def load(start, stop):
        loads.append(start)
        return FetchStats(bytes_fetched=stop - start)

    pf = WindowPrefetcher(100, 20, load, depth=2)
    merged = FetchStats()
    gen = iter(pf)
    start, stop, payload = next(gen)
    merged.merge(payload)
    gen.close()  # cancellation point: service-layer close during fault
    assert _no_prefetch_threads()
    # only the yielded window reached the consumer ledger; speculative
    # loads beyond it were dropped, not merged — no double accounting
    assert merged.bytes_fetched == 20
    assert len(loads) == len(set(loads))
    assert len(loads) <= 3  # at most depth+1 speculative starts
