"""Cascaded phase-1 execution: end-to-end invariants (DESIGN.md §11).

The acceptance contract of the cascade subsystem:

  * cascaded runs are **bit-identical** on survivors to the
    ``cascade=False`` preload path and to the staged ``fused=False``
    reference — across the engine (serial, modeled-pipelined, threaded),
    the shared-scan batch engine, and the cluster scatter-gather path,
    and for ANY permutation of the stage order;
  * the byte ledger is exact: ``bytes_fetched + cascade_bytes_skipped``
    equals the preload reference's fetched bytes — every basket either
    moves once or is provably skipped;
  * a branch shared by two cascade stages decodes **once per basket**
    (the decoded-basket LRU absorbs stage re-entry);
  * the canonical query form carries the cascade flag
    (``CACHE_KEY_VERSION=4``) and cached results keep hitting across
    the upgrade when semantics are unchanged.
"""

import itertools

import numpy as np
import pytest

from repro.cluster import SkimResultCache, build_cluster
from repro.cluster.cache import CACHE_KEY_VERSION, canonical_query, cache_key
from repro.core.engine import Breakdown, run_skim
from repro.core.plan import CascadeExecutor, CascadeState, build_cascade
from repro.core.planner import plan_skim
from repro.core.query import eval_stage, parse_query
from repro.data.store import EventStore, FetchStats
from repro.data.synth import make_nanoaod_like
from repro.serve.engine import SharedScanEngine

N_EVENTS = 12_000
BASKET = 2048

# multi-stage skim: a cheap selective run-range cut, an object selection,
# a trigger OR, and an event cut — enough stages for the order to matter
QUERY = {
    "branches": ["Electron_*", "MET_*", "event", "luminosityBlock"],
    "selection": {
        "preselection": [
            {"branch": "luminosityBlock", "op": "<=", "value": 2}
        ],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 15.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                ],
                "min_count": 1,
            }
        ],
        "event": [
            {"type": "any", "branches": ["HLT_IsoMu24", "HLT_absent_path"]},
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 15.0},
        ],
    },
}

SECOND = {
    "branches": ["MET_*", "event"],
    "selection": {
        "preselection": [{"branch": "MET_pt", "op": ">", "value": 21.0}]
    },
}


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(
        N_EVENTS, n_hlt=16, n_filler=8, basket_events=BASKET
    )


@pytest.fixture(scope="module")
def reference(store):
    return run_skim(
        store, QUERY, mode="near_data", fused=False, pipeline=False,
        prune=False, cascade=False,
    )


def _assert_same_output(res, ref):
    assert res.n_passed == ref.n_passed
    assert res.n_input == ref.n_input
    assert res.output.compressed_bytes() == ref.output.compressed_bytes()
    for name in ref.output.branch_names():
        br = ref.output.branches[name]
        if br.jagged:
            v0, c0 = ref.output.read_jagged(name)
            v1, c1 = res.output.read_jagged(name)
            np.testing.assert_array_equal(c1, c0)
            np.testing.assert_array_equal(v1, v0)
        else:
            np.testing.assert_array_equal(
                res.output.read_flat(name), ref.output.read_flat(name)
            )


# ---------------------------------------------------------------------------
# bit-identity across executors
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pipeline", [False, True, "threads"])
@pytest.mark.parametrize("prune", [False, True])
def test_cascade_bit_identical_engine(store, reference, pipeline, prune):
    res = run_skim(
        store, QUERY, mode="near_data", fused=True, pipeline=pipeline,
        prune=prune, cascade=True,
    )
    _assert_same_output(res, reference)
    assert res.extras["cascade"]
    assert sorted(res.extras["cascade_order"]) == list(
        range(len(res.extras["cascade_order"]))
    )


def test_cascade_off_is_preload_path(store, reference):
    res = run_skim(
        store, QUERY, mode="near_data", fused=True, pipeline=False,
        prune=False, cascade=False,
    )
    _assert_same_output(res, reference)
    assert not res.extras["cascade"]
    assert res.stats.cascade_bytes_skipped == 0


def test_cascade_moves_fewer_phase1_bytes(store):
    ref = run_skim(
        store, QUERY, mode="near_data", fused=True, pipeline=False,
        prune=False, cascade=False,
    )
    res = run_skim(
        store, QUERY, mode="near_data", fused=True, pipeline=False,
        prune=False, cascade=True,
    )
    # the run-range cut kills most windows at the head of the cascade, so
    # the remaining stages never fetch them
    assert res.extras["phase1_bytes"] < ref.extras["phase1_bytes"]
    assert res.stats.cascade_bytes_skipped > 0


def test_cascade_ledger_exact_vs_preload(store):
    """Every byte either moves once or is ledgered as skipped: fetched +
    cascade_bytes_skipped == the preload reference's fetched bytes."""
    ref = run_skim(
        store, QUERY, mode="near_data", fused=True, pipeline=False,
        prune=False, cascade=False,
    )
    res = run_skim(
        store, QUERY, mode="near_data", fused=True, pipeline=False,
        prune=False, cascade=True,
    )
    assert (
        res.stats.bytes_fetched + res.stats.cascade_bytes_skipped
        == ref.stats.bytes_fetched
    )


@pytest.mark.parametrize("chunk", [256, 1024, 777])
def test_cascade_ledger_exact_multi_basket_windows(chunk):
    """The savings ledger is exact even when windows span several baskets
    (or are not basket-aligned): a filter∩output basket that dies in
    phase 1 but is re-fetched by a surviving window's phase 2 must NOT
    be credited as skipped."""
    n, basket = 16 * 256, 256
    rng = np.random.default_rng(0)
    cols = {
        # filter AND output branch, dead on alternating baskets
        "x": (
            np.where((np.arange(n) // basket) % 2 == 0, 5.0, -5.0)
            + rng.random(n)
        ).astype(np.float32),
        "h": rng.random(n).astype(np.float32),  # filter-only
        "event": np.arange(n, dtype=np.int32),
    }
    st = EventStore.from_arrays(cols, basket_events=basket)
    q = {
        "branches": ["x", "event"],
        "selection": {
            "preselection": [
                {"branch": "x", "op": ">", "value": 0.0},
                {"branch": "h", "op": ">=", "value": -1.0},
            ]
        },
    }
    kw = dict(mode="near_data", fused=True, pipeline=False, prune=False)
    from repro.core.engine import SkimEngine

    eng = SkimEngine(st, chunk_events=chunk)
    ref = eng.run(q, prune=False, cascade=False)
    res = eng.run(q, prune=False, cascade=True)
    _assert_same_output(res, ref)
    assert (
        res.stats.bytes_fetched + res.stats.cascade_bytes_skipped
        == ref.stats.bytes_fetched
    ), kw


def test_pipelined_cascade_fetchstats_invariant(store):
    """Serial, modeled-pipelined, and threaded cascade runs account
    identically (the head stage is pinned; adaptation happens in window
    order on the consumer side)."""

    def tup(stats):
        return (
            stats.bytes_fetched, stats.requests, stats.cascade_bytes_skipped,
            dict(stats.by_branch),
        )

    serial = run_skim(
        store, QUERY, mode="near_data", fused=True, pipeline=False,
        cascade=True,
    )
    for pipeline in (True, "threads"):
        piped = run_skim(
            store, QUERY, mode="near_data", fused=True, pipeline=pipeline,
            cascade=True,
        )
        assert tup(piped.stats) == tup(serial.stats)
        assert piped.extras["cascade_order"] == serial.extras["cascade_order"]


def test_query_level_cascade_flag(store, reference):
    doc = dict(QUERY)
    doc["cascade"] = False
    res = run_skim(store, doc, mode="near_data")
    assert not res.extras["cascade"]
    _assert_same_output(res, reference)
    doc["cascade"] = True
    res = run_skim(store, doc, mode="near_data")
    assert res.extras["cascade"]
    _assert_same_output(res, reference)


# ---------------------------------------------------------------------------
# any stage-order permutation is bit-identical on survivors
# ---------------------------------------------------------------------------


def test_stage_order_permutations_bit_identical(store):
    q = parse_query(QUERY)
    plan = plan_skim(q, store, window_events=BASKET, cascade=True)
    n_stages = plan.cascade.n_stages
    assert n_stages == 4

    # reference mask from the staged evaluator over fully decoded data
    data = {}
    for b in plan.filter_branches:
        br = store.branches[b]
        data[b] = store.read_jagged(b)[0] if br.jagged else store.read_flat(b)
    want = np.ones(store.n_events, dtype=bool)
    for _, stage in q.stages():
        want &= eval_stage(stage, data, store.n_events)

    spans = [
        (s, min(s + BASKET, store.n_events))
        for s in range(0, store.n_events, BASKET)
    ]
    for perm in itertools.permutations(range(n_stages)):
        ex = CascadeExecutor(plan, store, order=list(perm))
        got = []
        for a, b in spans:
            out = ex.run_window(a, b, None, Breakdown(), FetchStats())
            got.append(out.mask)
        np.testing.assert_array_equal(
            np.concatenate(got), want, err_msg=f"order {perm} diverged"
        )


# ---------------------------------------------------------------------------
# adaptivity
# ---------------------------------------------------------------------------


def test_observed_selectivities_adapt_order(store):
    res = run_skim(store, QUERY, mode="near_data", cascade=True, prune=False)
    report = res.extras["cascade_stages"]
    # every executed stage carries an observed pass rate
    ran = [r for r in report if r["windows"]]
    assert ran and all(r["observed_selectivity"] is not None for r in ran)
    # the run-range head kills the tail windows, so later stages must
    # have been skipped for them
    assert any(r["windows_skipped"] > 0 for r in report)


def test_cascade_state_reorders_on_observation():
    q = parse_query(QUERY)
    store = make_nanoaod_like(4_000, n_hlt=16, basket_events=1024)
    cplan = build_cascade(q, store)
    state = CascadeState(cplan)
    head, *tail0 = state.order()
    # feed observations inverting the estimated selectivities: the most
    # accepting tail stage becomes provably useless, the least accepting
    # becomes a guaranteed killer — the tail must re-rank
    state.observe(tail0[0], 1000, 1000, 0)  # passes everything
    state.observe(tail0[-1], 1000, 0, 0)  # kills everything
    head2, *tail1 = state.order()
    assert head2 == head  # the head stays pinned for the prefetcher
    assert tail1[0] == tail0[-1]
    assert tail1[-1] == tail0[0]


def test_describe_reports_cascade_and_window_decisions(store):
    q = parse_query(QUERY)
    plan = plan_skim(q, store, window_events=BASKET, prune=True, cascade=True)
    desc = plan.describe()
    assert "cascade[4 stages:" in desc
    assert "windows[prune=" in desc and "accept_all=" in desc
    plain = plan_skim(q, store).describe()
    assert "cascade=off" in plain and "windows=unpruned" in plain


# ---------------------------------------------------------------------------
# decoded-basket LRU under cascade re-entry
# ---------------------------------------------------------------------------


def test_shared_branch_decodes_once_per_basket():
    """nElectron feeds both the preselection and the object stage (and
    phase 2): under the cascade it must decode once per basket, with
    every re-entry served from the LRU."""
    st = make_nanoaod_like(4_000, n_hlt=4, n_filler=2, basket_events=1024)
    n_baskets = st.n_baskets("MET_pt")
    q = {
        "branches": ["nElectron", "Electron_pt", "MET_pt", "event"],
        "selection": {
            "preselection": [{"branch": "nElectron", "op": ">=", "value": 0}],
            "object": [
                {
                    "collection": "Electron",
                    "cuts": [{"var": "pt", "op": ">", "value": -1.0}],
                    "min_count": 0,
                }
            ],
        },
    }
    res = run_skim(st, q, mode="near_data", prune=False, cascade=True)
    assert res.extras["cascade"]
    assert res.n_passed == st.n_events  # every window survives: no dead
    touched = set(res.plan.filter_branches) | set(res.plan.output_branches)
    stats = st.decode_cache_stats()
    # once per (branch, basket) — stage re-entry and phase 2 are hits
    assert stats["misses"] == len(touched) * n_baskets
    assert stats["hits"] >= n_baskets  # nElectron's second stage at least


# ---------------------------------------------------------------------------
# shared scan + cluster
# ---------------------------------------------------------------------------


def test_shared_scan_cascade_matches_solo(store):
    batch = SharedScanEngine(store, cascade=True).run_batch([QUERY, SECOND])
    for q, res in zip([QUERY, SECOND], batch.results):
        solo = run_skim(
            store, q, mode="near_data", fused=True, pipeline=False,
            prune=False, cascade=False,
        )
        _assert_same_output(res, solo)
        assert res.extras["cascade"]
    # the shared cascaded pass never pays the union preload in full
    ref = SharedScanEngine(store, cascade=False).run_batch([QUERY, SECOND])
    assert (
        batch.shared_stats.bytes_fetched <= ref.shared_stats.bytes_fetched
    )
    assert batch.shared_stats.cascade_bytes_skipped > 0


def test_cluster_cascade_bit_identical(store, reference):
    coord = build_cluster(store, 3, replication=False, cascade=True)
    res = coord.run(QUERY)
    _assert_same_output(res, reference)
    # the cascade can only reduce cluster bytes vs the preload nodes
    ref_nodes = build_cluster(store, 3, replication=False, cascade=False)
    assert (
        res.stats.bytes_fetched
        <= ref_nodes.run(QUERY).stats.bytes_fetched
    )


# ---------------------------------------------------------------------------
# cache key: the canonical form grew the cascade flag (v4)
# ---------------------------------------------------------------------------


def test_cache_key_version_bumped():
    assert CACHE_KEY_VERSION == 4


def test_canonical_query_carries_cascade_flag():
    base = canonical_query(QUERY)
    assert '"cascade":null' in base
    on = dict(QUERY)
    on["cascade"] = True
    off = dict(QUERY)
    off["cascade"] = False
    assert canonical_query(on) != canonical_query(off) != base
    # semantics-neutral normalizations still collapse
    assert canonical_query(dict(QUERY)) == base


def test_cache_hits_across_cascade_upgrade(store):
    """Unchanged semantics keep hitting across the v4 upgrade: the same
    query against byte-identical shards addresses identically whether
    the cluster's nodes cascade or not (the flag lives in the QUERY's
    canonical form; engine defaults don't re-address content)."""
    cache = SkimResultCache(budget_bytes=64 << 20)
    c1 = build_cluster(store, 3, replication=False, cache=cache, cascade=True)
    cold = c1.run(QUERY)
    live = 3 - len(cold.pruned_shards)
    assert cache.stats.insertions == live
    # a second cluster over re-encoded identical shards — and a different
    # node-level cascade default — keeps hitting
    cols, jag = {}, {}
    for name, br in store.branches.items():
        if br.jagged:
            jag[name] = br.counts_branch
            cols[name] = store.read_jagged(name)[0]
        else:
            cols[name] = store.read_flat(name)
    twin = EventStore.from_arrays(
        cols, jagged=jag, basket_events=store.basket_events, codec=store.codec
    )
    c2 = build_cluster(twin, 3, replication=False, cache=cache, cascade=False)
    warm = c2.run(QUERY)
    assert warm.cache_hits == live
    _assert_same_output(warm, cold)


def test_cache_key_format_includes_version(store):
    key = cache_key(QUERY, store.manifest_hash())
    assert key.startswith(f"v{CACHE_KEY_VERSION}.")
