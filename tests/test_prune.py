"""Zone-map predicate pushdown: end-to-end invariants (DESIGN.md §9).

The acceptance contract of the pruning subsystem:

  * pruned runs are **bit-identical** to the ``prune=False`` reference —
    rows, counts, output bytes — across every two-phase mode, fused and
    staged, serial and pipelined, the shared-scan batch engine, and the
    cluster scatter-gather path,
  * pruning strictly reduces fetched bytes on selective queries, with the
    savings ledgered in ``FetchStats.bytes_skipped``/``requests_skipped``
    and ``extras["pruned_windows"]``,
  * manifests carry the stats: ``manifest_hash()`` is stable across
    re-encode of identical data (the cluster cache keeps hitting across
    the stats upgrade) and changes when stats change,
  * the coordinator answers fully-pruned shards without any RPC,
  * the decoded-basket LRU dedupes phase-1/phase-2 decodes and exposes
    hit counts.
"""

import numpy as np
import pytest

from repro.cluster import SkimResultCache, build_cluster
from repro.core.engine import run_skim
from repro.data.store import EventStore
from repro.data.synth import make_nanoaod_like
from repro.serve.engine import SharedScanEngine

N_EVENTS = 12_000
BASKET = 2048

# a run-range style skim: luminosityBlock is monotone in the synthetic
# store, so most windows are provably empty; MET keeps scan windows busy
SELECTIVE = {
    "branches": ["Electron_*", "MET_*", "event", "luminosityBlock"],
    "selection": {
        "preselection": [
            {"branch": "luminosityBlock", "op": "<=", "value": 0}
        ],
        "event": [{"type": "cut", "branch": "MET_pt", "op": ">", "value": 25.0}],
    },
}

# 100% selectivity: synthetic MET_pt = exponential + 1.0 >= 1.0
ACCEPT = {
    "branches": ["MET_*", "event"],
    "selection": {
        "preselection": [{"branch": "MET_pt", "op": ">", "value": 0.5}]
    },
}


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(
        N_EVENTS, n_hlt=16, n_filler=8, basket_events=BASKET
    )


@pytest.fixture(scope="module")
def reference(store):
    return run_skim(
        store, SELECTIVE, mode="near_data", fused=False, pipeline=False,
        prune=False,
    )


def _assert_same_output(res, ref):
    """rows, counts, output bytes — the bit-identity contract."""
    assert res.n_passed == ref.n_passed
    assert res.n_input == ref.n_input
    assert res.output.compressed_bytes() == ref.output.compressed_bytes()
    for name in ref.output.branch_names():
        br = ref.output.branches[name]
        if br.jagged:
            v0, c0 = ref.output.read_jagged(name)
            v1, c1 = res.output.read_jagged(name)
            np.testing.assert_array_equal(c1, c0)
            np.testing.assert_array_equal(v1, v0)
        else:
            np.testing.assert_array_equal(
                res.output.read_flat(name), ref.output.read_flat(name)
            )


# ---------------------------------------------------------------------------
# bit-identity across every executor configuration
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["client_opt", "server_side", "near_data"])
@pytest.mark.parametrize("fused", [False, True])
def test_pruned_bit_identical_all_modes(store, reference, mode, fused):
    res = run_skim(
        store, SELECTIVE, mode=mode, fused=fused, pipeline=False, prune=True
    )
    _assert_same_output(res, reference)
    assert res.extras["prune"]
    assert res.stats.bytes_skipped > 0


@pytest.mark.parametrize("pipeline", [True, "threads"])
def test_pruned_bit_identical_pipelined(store, reference, pipeline):
    res = run_skim(
        store, SELECTIVE, mode="near_data", fused=True, pipeline=pipeline,
        prune=True,
    )
    _assert_same_output(res, reference)
    # pruned windows contribute zero-load records; the modeled schedule
    # still exists and bounds below the serial sum
    assert res.extras["pipeline_total"] <= res.breakdown.total() + 1e-9


def test_accept_all_bit_identical_and_single_round(store):
    ref = run_skim(
        store, ACCEPT, mode="near_data", fused=True, pipeline=False,
        prune=False,
    )
    res = run_skim(
        store, ACCEPT, mode="near_data", fused=True, pipeline=False,
        prune=True,
    )
    _assert_same_output(res, ref)
    assert res.n_passed == store.n_events
    assert all(
        d == "accept_all" for _, _, d in res.extras["pruned_windows"]
    )
    # the output set moves exactly once: same bytes, fewer round trips
    assert res.stats.bytes_fetched == ref.stats.bytes_fetched
    assert res.stats.requests < ref.stats.requests
    assert res.breakdown.filter < ref.breakdown.filter + 1e-9


def test_prune_savings_ledger_exact_for_preload_reference(store):
    """Against the preloading (fused) reference, fetched + skipped bytes
    must account for every byte the reference moved.  ``cascade=False``
    pins the preload executor the ledger is priced against (the cascaded
    executor has its own exact ledger — tests/test_cascade.py)."""
    ref = run_skim(
        store, SELECTIVE, mode="near_data", fused=True, pipeline=False,
        prune=False, cascade=False,
    )
    res = run_skim(
        store, SELECTIVE, mode="near_data", fused=True, pipeline=False,
        prune=True, cascade=False,
    )
    assert res.stats.bytes_fetched + res.stats.bytes_skipped == (
        ref.stats.bytes_fetched
    )
    assert res.stats.requests + res.stats.requests_skipped == (
        ref.stats.requests
    )
    assert res.stats.bytes_fetched < ref.stats.bytes_fetched / 2
    pruned = [w for w in res.extras["pruned_windows"] if w[2] == "prune"]
    assert len(pruned) == len(res.extras["pruned_windows"]) > 0
    # pruned windows report zero survivors in the mergeable ledger
    rows = dict(
        ((a, b), k) for a, b, k in res.extras["window_rows"]
    )
    for a, b, _ in pruned:
        assert rows[(a, b)] == 0


def test_prune_off_is_reference(store, reference):
    res = run_skim(
        store, SELECTIVE, mode="near_data", fused=False, pipeline=False,
        prune=False,
    )
    assert res.stats.bytes_skipped == 0
    assert res.extras["pruned_windows"] == []
    assert not res.extras["prune"]
    _assert_same_output(res, reference)


# ---------------------------------------------------------------------------
# shared-scan batch engine
# ---------------------------------------------------------------------------


def test_shared_scan_pruned_matches_solo_reference(store):
    tenants = [SELECTIVE, ACCEPT]
    batch = SharedScanEngine(store, prune=True).run_batch(tenants)
    ref = SharedScanEngine(store, prune=False).run_batch(tenants)
    for res, q in zip(batch.results, tenants):
        solo = run_skim(
            store, q, mode="near_data", fused=True, pipeline=False,
            prune=False,
        )
        _assert_same_output(res, solo)
    # the ACCEPT tenant is accept-all (not prune) on the tail windows, so
    # the shared union pass stays alive for it — pruning must never trade
    # shared bytes for private re-fetches
    assert batch.shared_stats.bytes_skipped == 0
    assert batch.shared_stats.bytes_fetched == ref.shared_stats.bytes_fetched
    total = batch.shared_stats.bytes_fetched + sum(
        r.stats.bytes_fetched for r in batch.results
    )
    ref_total = ref.shared_stats.bytes_fetched + sum(
        r.stats.bytes_fetched for r in ref.results
    )
    assert total <= ref_total
    assert batch.results[0].extras["pruned_windows"]
    assert all(
        d == "accept_all"
        for _, _, d in batch.results[1].extras["pruned_windows"]
    )


def test_shared_scan_skips_union_fetch_when_no_tenant_scans(store):
    """Two selective tenants over disjoint run ranges: the tail windows
    are pruned for both, so the shared pass never fetches them."""
    t2 = {
        "branches": ["MET_*", "event", "luminosityBlock"],
        "selection": {
            "preselection": [
                {"branch": "luminosityBlock", "op": "<=", "value": 1}
            ]
        },
    }
    eng = SharedScanEngine(store, prune=True)
    batch = eng.run_batch([SELECTIVE, t2])
    ref = SharedScanEngine(store, prune=False).run_batch([SELECTIVE, t2])
    for res, refres in zip(batch.results, ref.results):
        _assert_same_output(res, refres)
    assert batch.shared_stats.bytes_skipped > 0
    assert batch.shared_stats.bytes_fetched < ref.shared_stats.bytes_fetched


# ---------------------------------------------------------------------------
# cluster: shard-level skip + bit-identity
# ---------------------------------------------------------------------------


def test_cluster_pruned_bit_identical_and_skips_shards(store, reference):
    n_windows = -(-store.n_events // BASKET)
    coord = build_cluster(store, n_windows, replication=False)
    res = coord.run(SELECTIVE)
    _assert_same_output(res, reference)
    # every shard holding only high-lumi windows is answered by the
    # coordinator from its manifest — the node never sees a request
    assert len(res.pruned_shards) == n_windows - 1
    assert res.extras["pruned_shards"] == res.pruned_shards
    assert res.extras["prune_saved_bytes"] > 0
    for node in coord.nodes:
        if node.shard.shard_id in res.pruned_shards:
            assert node.requests_served == 0


def test_cluster_prune_false_reference_path(store, reference):
    coord = build_cluster(store, 3, replication=False, prune=False)
    res = coord.run(SELECTIVE)
    _assert_same_output(res, reference)
    assert res.pruned_shards == []
    assert res.stats.bytes_skipped == 0


def test_cluster_pruned_matches_unpruned_accounting(store):
    """Pruned cluster vs pruned single node: window-aligned shards keep
    the byte/request model identical (the PR-2 contract, now with
    pruning on both sides)."""
    single = run_skim(
        store, SELECTIVE, mode="near_data", fused=True, pipeline=True,
        prune=True,
    )
    coord = build_cluster(store, 3, replication=False)
    res = coord.run(SELECTIVE)
    assert res.stats.bytes_fetched == single.stats.bytes_fetched
    assert res.stats.requests == single.stats.requests
    assert res.stats.bytes_skipped == single.stats.bytes_skipped


def test_cluster_batch_pruned_matches_solo(store):
    coord = build_cluster(store, 3, replication=False)
    batch = coord.run_batch([SELECTIVE, ACCEPT])
    for res, q in zip(batch.results, [SELECTIVE, ACCEPT]):
        solo = run_skim(
            store, q, mode="near_data", fused=True, pipeline=False,
            prune=False,
        )
        assert res.n_passed == solo.n_passed
        assert res.output.compressed_bytes() == solo.output.compressed_bytes()


# ---------------------------------------------------------------------------
# manifests, hashes, cache upgrade
# ---------------------------------------------------------------------------


def _rebuild_identical(store):
    cols, jag = {}, {}
    for name, br in store.branches.items():
        if br.jagged:
            jag[name] = br.counts_branch
            cols[name] = store.read_jagged(name)[0]
        else:
            cols[name] = store.read_flat(name)
    return EventStore.from_arrays(
        cols, jagged=jag, basket_events=store.basket_events, codec=store.codec
    )


def test_manifest_hash_stable_across_reencode(store):
    assert _rebuild_identical(store).manifest_hash() == store.manifest_hash()


def test_manifest_hash_changes_when_stats_change(store):
    other = _rebuild_identical(store)
    meta = other._baskets["MET_pt"][0]
    assert meta.vmin is not None
    meta.vmin -= 1.0  # a stats-only mutation must re-address the content
    assert other.manifest_hash() != store.manifest_hash()


def test_manifest_carries_stats_and_version(store):
    doc = store.manifest()
    assert doc["zonemap_version"] >= 1
    assert doc["integrity_version"] >= 1
    rows = doc["baskets"]["MET_pt"]
    assert all(len(r) == 9 for r in rows)
    vmin, vmax = rows[0][5], rows[0][6]
    assert vmin is not None and vmax is not None and vmin <= vmax
    # bool branches carry true-counts
    hlt = doc["baskets"]["HLT_IsoMu24"]
    assert all(isinstance(r[7], int) for r in hlt)
    # every basket row carries its CRC-32 integrity digest
    assert all(isinstance(r[8], int) for r in rows)


def test_save_load_roundtrip_preserves_stats(store, tmp_path):
    path = str(tmp_path / "st.skim")
    store.save(path)
    loaded = EventStore.load(path)
    assert loaded.manifest_hash() == store.manifest_hash()
    m0 = store._baskets["MET_pt"][0]
    m1 = loaded._baskets["MET_pt"][0]
    assert (m1.vmin, m1.vmax, m1.n_true) == (m0.vmin, m0.vmax, m0.n_true)
    # a loaded store prunes exactly like the original
    res = run_skim(loaded, SELECTIVE, mode="near_data", prune=True)
    ref = run_skim(store, SELECTIVE, mode="near_data", prune=True)
    assert res.stats.bytes_skipped == ref.stats.bytes_skipped


def test_legacy_header_without_stats_still_loads(store, tmp_path):
    """Stores written before ZONEMAP_VERSION deserialize with unknown
    stats and simply never prune."""
    import json

    path = str(tmp_path / "legacy.skim")
    store.save(path)
    with open(path, "rb") as f:
        hlen = int.from_bytes(f.read(8), "little")
        header = json.loads(f.read(hlen).decode())
        body = f.read()
    header.pop("zonemap_version")
    header["baskets"] = {
        n: [r[:5] for r in rows] for n, rows in header["baskets"].items()
    }
    hbytes = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(len(hbytes).to_bytes(8, "little"))
        f.write(hbytes)
        f.write(body)
    legacy = EventStore.load(path)
    assert legacy._baskets["MET_pt"][0].vmin is None
    res = run_skim(legacy, SELECTIVE, mode="near_data", prune=True)
    ref = run_skim(store, SELECTIVE, mode="near_data", prune=False)
    _assert_same_output(res, ref)
    assert res.stats.bytes_skipped == 0  # nothing provable -> no pruning


def test_cluster_cache_hits_across_stats_upgrade(store):
    """The versioned manifest key: re-encoding identical data (e.g. a
    store rewritten after the stats upgrade) produces the same content
    address, so warm shards keep hitting."""
    cache = SkimResultCache(budget_bytes=64 << 20)
    c1 = build_cluster(store, 3, replication=False, cache=cache)
    cold = c1.run(SELECTIVE)
    live_shards = 3 - len(cold.pruned_shards)
    assert cache.stats.insertions == live_shards

    c2 = build_cluster(
        _rebuild_identical(store), 3, replication=False, cache=cache
    )
    warm = c2.run(SELECTIVE)
    assert warm.cache_hits == live_shards
    _assert_same_output(warm, cold)


def test_versioned_cache_key_format(store):
    from repro.cluster import cache_key
    from repro.cluster.cache import CACHE_KEY_VERSION

    key = cache_key(SELECTIVE, store.manifest_hash())
    assert key.startswith(f"v{CACHE_KEY_VERSION}.")
    assert key.endswith(store.manifest_hash())


# ---------------------------------------------------------------------------
# decoded-basket LRU
# ---------------------------------------------------------------------------


def test_decode_cache_hits_are_counted():
    st = make_nanoaod_like(4_000, n_hlt=4, n_filler=2, basket_events=1024)
    st.read_flat("MET_pt")
    misses = st.decode_cache_stats()["misses"]
    assert misses > 0
    before_hits = st.decode_cache_stats()["hits"]
    out = st.read_flat("MET_pt")
    assert st.decode_cache_stats()["hits"] > before_hits
    assert st.decode_cache_stats()["misses"] == misses
    np.testing.assert_array_equal(out, st.read_flat("MET_pt"))


def test_decode_cache_dedupes_repeat_scans():
    """Repeat queries over the same store (the multi-tenant norm) decode
    each basket once: the second run's phase 1 is all hits."""
    st = make_nanoaod_like(4_000, n_hlt=4, n_filler=2, basket_events=1024)
    st.decode_cache_baskets = 10_000  # hold everything for the assertion
    first = run_skim(st, SELECTIVE, mode="near_data", fused=True, pipeline=False)
    misses = st.decode_cache_stats()["misses"]
    second = run_skim(st, SELECTIVE, mode="near_data", fused=True, pipeline=False)
    s = st.decode_cache_stats()
    assert s["misses"] == misses  # nothing decoded twice
    assert s["hits"] > 0
    _assert_same_output(second, first)


def test_decode_cache_disabled_and_bounded():
    st = make_nanoaod_like(
        4_000, n_hlt=4, n_filler=2, basket_events=1024
    )
    st.decode_cache_baskets = 0
    a = st.read_flat("MET_pt")
    b = st.read_flat("MET_pt")
    np.testing.assert_array_equal(a, b)
    assert st.decode_cache_stats() == {
        "hits": 0, "misses": 0, "resident": 0,
        "hit_bytes": 0, "miss_bytes": 0, "saved_decode_bytes": 0,
        "hit_rate": 0.0,
    }
    st.decode_cache_baskets = 2
    st.read_flat("MET_pt")  # 4 baskets through a 2-entry cache
    assert st.decode_cache_stats()["resident"] <= 2


def test_decode_cache_entries_are_frozen():
    st = make_nanoaod_like(2_000, n_hlt=4, n_filler=2, basket_events=1024)
    blob = st.fetch_basket("MET_pt", 0)
    vals = st.decode_blob("MET_pt", blob)
    assert not vals.flags.writeable
    again = st.decode_blob("MET_pt", blob)
    assert again is vals  # served from cache, content-addressed
