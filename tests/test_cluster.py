"""Distributed skim cluster: sharding, scatter-gather merge, cache.

Pins the tentpole invariant (ISSUE 2 / DESIGN.md §5): for any node
count and shard policy, the merged cluster output — rows, counts,
output bytes — is bit-identical to the single-node ``run_skim`` result,
including with an injected node failure (replica retry), with a warm
result cache, and under threaded scatter.  Cluster byte accounting
(fetched bytes AND request counts) equals the single-node run's.
"""

import numpy as np
import pytest

from repro.cluster import (
    ClusterCoordinator,
    ClusterError,
    SkimResultCache,
    StorageNode,
    build_cluster,
    canonical_query,
    partition_store,
    query_hash,
)
from repro.cluster.shard import ShardMap, assign_windows, window_spans
from repro.core.engine import run_skim
from repro.data.synth import make_nanoaod_like
from tests.test_query import QUERY


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(10_000, n_hlt=16, n_filler=8, basket_events=2048)


@pytest.fixture(scope="module")
def reference(store):
    return run_skim(store, QUERY, mode="near_data")


@pytest.fixture(scope="module")
def shards3(store):
    return partition_store(store, 3)


def _coord(shards, store, cache=None, replication=True, concurrency="serial"):
    nodes = [StorageNode(sh) for sh in shards]
    replicas = (
        {sh.shard_id: StorageNode(sh, node_id=100 + sh.shard_id) for sh in shards}
        if replication
        else {}
    )
    return ClusterCoordinator(
        nodes,
        replicas=replicas,
        cache=cache,
        concurrency=concurrency,
        basket_events=store.basket_events,
        codec=store.codec,
    )


def _assert_same_output(res, ref):
    """rows, counts, output bytes — the bit-identity acceptance contract."""
    assert res.n_passed == ref.n_passed
    assert res.n_input == ref.n_input
    assert res.output.compressed_bytes() == ref.output.compressed_bytes()
    for name in ref.output.branch_names():
        br = ref.output.branches[name]
        if br.jagged:
            v0, c0 = ref.output.read_jagged(name)
            v1, c1 = res.output.read_jagged(name)
            np.testing.assert_array_equal(c1, c0)
            np.testing.assert_array_equal(v1, v0)
        else:
            np.testing.assert_array_equal(
                res.output.read_flat(name), ref.output.read_flat(name)
            )


# ---------------------------------------------------------------------------
# the cluster correctness invariant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["round_robin", "size_balanced"])
@pytest.mark.parametrize("n_nodes", [1, 2, 5])
def test_cluster_bit_identical_to_single_node(store, reference, n_nodes, policy):
    coord = build_cluster(store, n_nodes, policy=policy, replication=False)
    res = coord.run(QUERY)
    _assert_same_output(res, reference)
    # aligned shards ⇒ the cluster moved exactly the single node's bytes
    assert res.stats.bytes_fetched == reference.stats.bytes_fetched
    assert res.stats.requests == reference.stats.requests
    assert res.modeled_total_s > 0


def test_more_nodes_than_windows(reference, store):
    """Empty shards are legal: 10k events / 2048-event windows = 5 windows
    spread over 7 nodes leaves two nodes empty."""
    coord = build_cluster(store, 7, replication=False)
    assert sum(not n.shard.window_ids for n in coord.nodes) == 2
    _assert_same_output(coord.run(QUERY), reference)


def test_threads_concurrency_matches_serial(store, shards3, reference):
    res = _coord(shards3, store, concurrency="threads").run(QUERY)
    _assert_same_output(res, reference)


def test_failed_node_retries_on_replica(store, shards3, reference):
    coord = _coord(shards3, store)
    coord.nodes[1].inject_fault("fail")
    res = coord.run(QUERY)
    _assert_same_output(res, reference)
    assert res.retries == [(1, coord.nodes[1].node_id, 101)]


def test_failure_without_replica_raises(store, shards3):
    coord = _coord(shards3, store, replication=False)
    coord.nodes[0].inject_fault("fail")
    with pytest.raises(ClusterError, match="no replica"):
        coord.run(QUERY)


def test_primary_and_replica_failure_raises(store, shards3):
    coord = _coord(shards3, store)
    coord.nodes[2].inject_fault("fail")
    coord.replicas[2].inject_fault("fail")
    with pytest.raises(ClusterError, match="both failed"):
        coord.run(QUERY)


def test_straggler_stretches_modeled_makespan(store, shards3):
    coord = _coord(shards3, store)
    base = coord.run(QUERY)
    coord.nodes[0].inject_fault("straggle", delay_s=5.0)
    slow = coord.run(QUERY)
    assert slow.responses[0].straggle_s == 5.0
    assert slow.modeled_total_s > base.modeled_total_s + 4.0
    # straggling is a schedule property, not a data property
    assert slow.n_passed == base.n_passed


def test_warm_cache_bit_identical_and_skips_execution(store, shards3, reference):
    cache = SkimResultCache(budget_bytes=32 << 20)
    coord = _coord(shards3, store, cache=cache)
    cold = coord.run(QUERY)
    assert cold.cache_hits == 0
    served = [n.requests_served for n in coord.nodes]
    warm = coord.run(QUERY)
    _assert_same_output(warm, reference)
    assert warm.cache_hits == len(coord.nodes)
    # no node executed anything on the warm run
    assert [n.requests_served for n in coord.nodes] == served
    assert cache.stats.hits == len(coord.nodes)
    assert cache.stats.saved_fetch_bytes == cold.stats.bytes_fetched
    # a warm run only pays output transfer + merge
    assert warm.modeled_total_s < cold.modeled_total_s


def test_warm_cache_with_failure_never_touches_nodes(store, shards3, reference):
    """A dead primary behind a warm cache is invisible."""
    cache = SkimResultCache()
    coord = _coord(shards3, store, cache=cache, replication=False)
    coord.run(QUERY)
    coord.nodes[0].inject_fault("fail", n=100)
    _assert_same_output(coord.run(QUERY), reference)


def test_run_does_not_mutate_caller_query(store, shards3):
    """The coordinator compiles into a private copy: a caller-held Query
    stays clean, so later edits to it are never shadowed by a stale
    attached program."""
    from repro.core.query import parse_query

    q = parse_query(QUERY)
    _coord(shards3, store).run(q)
    assert "_compiled_program" not in q.meta


def test_batch_primary_and_replica_failure_raises(store, shards3):
    coord = _coord(shards3, store)
    coord.nodes[1].inject_fault("fail")
    coord.replicas[1].inject_fault("fail")
    with pytest.raises(ClusterError, match="both failed"):
        coord.run_batch([QUERY])


def test_batch_failed_node_retries_on_replica(store, shards3, reference):
    coord = _coord(shards3, store)
    coord.nodes[0].inject_fault("fail")
    batch = coord.run_batch([QUERY])
    _assert_same_output(batch.results[0], reference)
    assert batch.results[0].retries == [(0, coord.nodes[0].node_id, 100)]


def test_cache_get_many_all_or_nothing():
    cache = SkimResultCache(budget_bytes=100)
    cache.put("a", "A", nbytes=10, fetch_bytes=5)
    cache.put("b", "B", nbytes=10, fetch_bytes=5)
    assert cache.get_many(["a", "b"]) == ["A", "B"]
    assert cache.stats.hits == 2
    assert cache.get_many(["a", "missing"]) is None
    assert cache.stats.hits == 2  # partial probe accounts no hit
    assert cache.stats.misses == 1
    assert cache.stats.saved_fetch_bytes == 10


def test_cluster_batch_matches_solo_runs(store, shards3, reference):
    other = {
        "branches": ["Muon_*", "MET_*"],
        "selection": {
            "preselection": [{"branch": "MET_pt", "op": ">", "value": 25.0}],
            "object": [{"collection": "Muon",
                        "cuts": [{"var": "pt", "op": ">", "value": 15.0}]}],
        },
    }
    cache = SkimResultCache()
    coord = _coord(shards3, store, cache=cache)
    batch = coord.run_batch([QUERY, other])
    _assert_same_output(batch.results[0], reference)
    _assert_same_output(batch.results[1], run_skim(store, other, mode="near_data"))
    assert batch.shared_phase1_bytes < batch.naive_phase1_bytes
    assert batch.amortization > 1.0
    # second batch: every (tenant, shard) is cached
    warm = coord.run_batch([QUERY, other])
    assert warm.cached_tenants == [0, 1]
    _assert_same_output(warm.results[0], reference)
    # a warm batch still models the cached shards' output transfer
    assert warm.modeled_total_s > max(
        r.modeled_s for res in warm.results for r in res.responses
    ) > 0


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def test_assignment_policies_cover_all_windows_once():
    for policy, sizes in (("round_robin", None), ("size_balanced", [5, 1, 9, 3, 7])):
        got = assign_windows(5, 2, policy, sizes)
        flat = sorted(w for shard in got for w in shard)
        assert flat == [0, 1, 2, 3, 4]
        for shard in got:
            assert shard == sorted(shard)


def test_size_balanced_beats_round_robin_on_skew():
    sizes = [100, 1, 1, 1, 100, 1, 1, 1]  # round_robin piles both on shard 0
    rr = assign_windows(8, 2, "round_robin")
    sb = assign_windows(8, 2, "size_balanced", sizes)
    load = lambda a: [sum(sizes[w] for w in sh) for sh in a]  # noqa: E731
    assert max(load(sb)) < max(load(rr))


def test_partition_rejects_bad_inputs(store):
    with pytest.raises(ValueError, match="policy"):
        partition_store(store, 2, policy="hash")
    with pytest.raises(ValueError, match="multiple"):
        partition_store(store, 2, window_events=store.basket_events + 1)
    with pytest.raises(ValueError, match="n_shards"):
        assign_windows(4, 0)


def test_shard_map_validates_ownership(shards3, store):
    smap = ShardMap.build(shards3, store.n_events)
    assert sorted(smap.owner) == list(range(len(window_spans(store.n_events, 2048))))
    with pytest.raises(ValueError, match="owned by two"):
        ShardMap.build([shards3[0], shards3[0]], store.n_events)


def test_shard_manifest_hashes(store, shards3):
    hashes = [sh.manifest_hash for sh in shards3]
    assert len(set(hashes)) == len(hashes)  # distinct content ⇒ distinct address
    again = partition_store(store, 3)
    assert [sh.manifest_hash for sh in again] == hashes  # deterministic
    assert all(sh.comp_bytes > 0 for sh in shards3)


def test_sliced_shards_preserve_bytes(store, shards3):
    """Window-aligned slicing re-encodes to byte-identical baskets."""
    assert sum(sh.store.compressed_bytes() for sh in shards3) == (
        store.compressed_bytes()
    )
    assert sum(sh.n_events for sh in shards3) == store.n_events


# ---------------------------------------------------------------------------
# cache + canonical query form
# ---------------------------------------------------------------------------


def test_canonical_query_normalizes_commutative_order():
    a = {"branches": ["MET_*"], "selection": {
        "event": [
            {"type": "any", "branches": ["HLT_IsoMu24", "HLT_Ele32_WPTight_Gsf"]},
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 40.0},
        ]}}
    b = {"branches": ["MET_*"], "selection": {
        "event": [
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 40.0},
            {"type": "any", "branches": ["HLT_Ele32_WPTight_Gsf", "HLT_IsoMu24"]},
        ]}}
    assert canonical_query(a) == canonical_query(b)
    assert query_hash(a) == query_hash(b)
    c = {"branches": ["MET_*"], "selection": {
        "event": [{"type": "cut", "branch": "MET_pt", "op": ">", "value": 41.0}]}}
    assert query_hash(c) != query_hash(a)
    # output patterns are part of the contract: order matters
    d = {"branches": ["Muon_*", "MET_*"], "selection": {}}
    e = {"branches": ["MET_*", "Muon_*"], "selection": {}}
    assert query_hash(d) != query_hash(e)


def test_cache_lru_eviction_and_accounting():
    cache = SkimResultCache(budget_bytes=100)
    assert cache.put("a", "A", nbytes=40, fetch_bytes=400)
    assert cache.put("b", "B", nbytes=40, fetch_bytes=400)
    assert cache.get("a") == "A"  # refresh a; b is now LRU
    assert cache.put("c", "C", nbytes=40)  # evicts b
    assert cache.get("b") is None
    assert cache.get("a") == "A" and cache.get("c") == "C"
    assert cache.stats.evictions == 1
    assert cache.stats.stored_bytes == 80
    assert cache.stats.hits == 3 and cache.stats.misses == 1
    assert cache.stats.hit_bytes == 120
    assert cache.stats.saved_fetch_bytes == 800
    assert not cache.put("huge", "X", nbytes=101)  # over the whole budget
    assert cache.contains("a") and not cache.contains("huge")
    assert 0 < cache.stats.hit_rate < 1
    cache.clear()
    assert len(cache) == 0 and cache.stats.stored_bytes == 0


# ---------------------------------------------------------------------------
# per-shard deadline (threads mode): NodeTimeout + replica fallback
# ---------------------------------------------------------------------------


def _hang_node(node):
    """Replace a node's execute with one that blocks until released.

    Returns the release event; the hung call returns (on a detached
    worker thread) once the test sets it, so nothing leaks past the
    test even though the coordinator deliberately does not join it."""
    import threading

    release = threading.Event()
    orig = node.execute

    def blocked(query):
        release.wait()
        return orig(query)

    node.execute = blocked
    return release


def test_shard_timeout_raises_node_timeout(store, shards3):
    """A straggling primary without a replica used to hang the threaded
    gather forever; with a deadline it surfaces as NodeTimeout."""
    from repro.cluster import NodeTimeout

    nodes = [StorageNode(sh) for sh in shards3]
    coord = ClusterCoordinator(
        nodes,
        replicas={},
        concurrency="threads",
        basket_events=store.basket_events,
        codec=store.codec,
        shard_timeout_s=0.05,
    )
    release = _hang_node(nodes[1])
    try:
        with pytest.raises(NodeTimeout, match="shard 1.*no replica"):
            coord.run(QUERY)
    finally:
        release.set()


def test_shard_timeout_falls_back_to_replica(store, shards3, reference):
    """With a replica configured the deadline degrades gracefully: the
    replica serves the shard, the retry is ledgered, and the merged
    result stays bit-identical."""
    nodes = [StorageNode(sh) for sh in shards3]
    replicas = {
        sh.shard_id: StorageNode(sh, node_id=100 + sh.shard_id)
        for sh in shards3
    }
    coord = ClusterCoordinator(
        nodes,
        replicas=replicas,
        concurrency="threads",
        basket_events=store.basket_events,
        codec=store.codec,
        shard_timeout_s=0.05,
    )
    release = _hang_node(nodes[0])
    try:
        res = coord.run(QUERY)
    finally:
        release.set()
    assert res.retries == [(0, nodes[0].node_id, replicas[0].node_id)]
    _assert_same_output(res, reference)


def test_shard_timeout_validation(store, shards3):
    with pytest.raises(ValueError, match="shard_timeout_s"):
        ClusterCoordinator(
            [StorageNode(sh) for sh in shards3], shard_timeout_s=0.0
        )


def test_no_timeout_waits_indefinitely_by_default(store, shards3, reference):
    """Without a deadline configured, threads mode behaves exactly as
    before (waits for every shard, joins the pool)."""
    coord = _coord(shards3, store, concurrency="threads")
    assert coord.shard_timeout_s is None
    _assert_same_output(coord.run(QUERY), reference)
