"""Cost-model accuracy regression (ISSUE 6 satellite).

The service's admission control rejects queries using
:func:`repro.core.plan.estimate_plan_bytes` — a priced estimate computed
from basket metadata alone.  If that model silently drifts away from
what the executor actually fetches, quotas become meaningless (a 100x
underestimate admits everything; a 100x overestimate rejects
everything).  This test pins the estimate against the observed ledger on
the bench_cascade era-correlated store — the adversarial workload where
zone maps prune nothing and only the cascade's alive-fraction model
does any work — with deliberately loose but *bounded* tolerances.

Pinned baseline on this store (n=20k): observed 1,068,856 B fetched over
22 requests vs 502,949 B / 30 requests priced — the correlated-limit
alive-fraction model underestimates by ~2x (it assumes perfectly
correlated stage survival; reality is messier).  The tolerances below
give that headroom without letting an order-of-magnitude drift through.
"""

import pytest

from benchmarks.bench_cascade import QUERY, _make_store
from repro.core.engine import SkimEngine
from repro.serve import price_query

N_EVENTS = 20_000  # smoke-sized: 5 windows of the era-correlated store


@pytest.fixture(scope="module")
def store():
    return _make_store(N_EVENTS)


@pytest.fixture(scope="module")
def engine(store):
    return SkimEngine(store, prune=True, cascade=True)


@pytest.fixture(scope="module")
def observed(engine):
    return engine.run(QUERY, mode="near_data")


@pytest.fixture(scope="module")
def estimate(engine, store):
    return price_query(
        QUERY,
        store,
        window_events=engine.chunk_events,
        link=engine.near_input_link,
    )


def test_estimate_is_metadata_only(store):
    fetches = []
    orig = store.fetch_window

    def spy(*args, **kwargs):
        fetches.append(args)
        return orig(*args, **kwargs)

    store.fetch_window = spy
    try:
        price_query(QUERY, store)
    finally:
        store.fetch_window = orig
    assert fetches == []


def test_total_bytes_within_pinned_tolerance(estimate, observed):
    obs = observed.stats.bytes_fetched
    assert obs > 0
    ratio = estimate.est_bytes / obs
    # correlated-limit model: allowed to undershoot ~2x, never 5x; and
    # never to overshoot 2x (that would start rejecting good queries)
    assert 0.2 <= ratio <= 2.0, (
        f"cost model drifted: priced {estimate.est_bytes} B vs "
        f"observed {obs} B (ratio {ratio:.2f})"
    )


def test_requests_within_pinned_tolerance(estimate, observed):
    obs = observed.stats.requests
    assert obs > 0
    ratio = estimate.est_requests / obs
    assert 0.5 <= ratio <= 2.5, (
        f"request model drifted: priced {estimate.est_requests} vs "
        f"observed {obs} (ratio {ratio:.2f})"
    )


def test_per_stage_bytes_within_pinned_tolerance(estimate, observed):
    """Each cascade stage's priced bytes tracks its observed fetch.

    The pinned head stage reports ``bytes_fetched == 0`` in the ledger
    (the window prefetcher accounts its load), so only the demand-paged
    tail stages are comparable here.
    """
    stages = observed.extras["cascade_stages"]
    assert stages, "cascade did not run"
    compared = 0
    for st in stages:
        obs = st["bytes_fetched"]
        if obs == 0:
            continue  # prefetcher-accounted head stage
        est = estimate.per_stage.get(st["stage"])
        assert est is not None, f"stage {st['stage']} missing from estimate"
        ratio = est / obs
        assert 0.1 <= ratio <= 4.0, (
            f"stage {st['stage']} ({st['branches']}): priced {est} B vs "
            f"observed {obs} B (ratio {ratio:.2f})"
        )
        compared += 1
    assert compared >= 3  # presel, object, and the heavy tail stages


def test_model_ranks_the_heavy_stage_heaviest(estimate, observed):
    """Admission explanations hinge on the byte *ranking*: the stage the
    model prices heaviest must be the stage that actually dominated."""
    stages = [
        st for st in observed.extras["cascade_stages"]
        if st["bytes_fetched"] > 0
    ]
    obs_heaviest = max(stages, key=lambda st: st["bytes_fetched"])["stage"]
    est_heaviest = max(
        (si for si in estimate.per_stage if si != _head_stage(observed)),
        key=lambda si: estimate.per_stage[si],
    )
    assert est_heaviest == obs_heaviest


def _head_stage(observed) -> int:
    return next(
        st["stage"]
        for st in observed.extras["cascade_stages"]
        if st["bytes_fetched"] == 0
    )


def test_estimate_internally_consistent(estimate):
    assert estimate.est_bytes == (
        estimate.est_phase1_bytes + estimate.est_phase2_bytes
    )
    assert estimate.est_phase1_bytes == sum(estimate.per_stage.values())
    assert estimate.n_windows == 5
    assert 0.0 < estimate.est_selectivity < 1.0
    assert estimate.est_wall_s > 0.0
    assert "MB" in estimate.describe()


def test_selectivity_estimate_tracks_observed(estimate, observed):
    # within one order of magnitude — it drives the phase-2 pricing
    assert 0.1 <= estimate.est_selectivity / observed.selectivity <= 10.0
