"""Unified trace/metrics layer (DESIGN.md §13).

Pins the PR-7 observability contracts:

  * span trees: parenting, begin/end stack discipline, adoption;
  * byte-deterministic Chrome-trace export under a ManualClock;
  * a service job's complete lifecycle span tree
    (admission -> queue -> execution -> settle);
  * cluster scatter-gather: node spans adopted exactly once, under the
    coordinator's merge span;
  * the versioned SkimReport + its extras compatibility shim;
  * priced-vs-observed calibration feeding back into admission pricing;
  * unified cache metrics and the result-cache replacement fix;
  * the no-op tracer changes nothing about results.
"""

from __future__ import annotations

import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

from repro.cluster import SkimResultCache, build_cluster
from repro.core.engine import SkimEngine, run_skim
from repro.core.plan import estimate_plan_bytes, stage_kind
from repro.core.planner import plan_skim
from repro.core.query import parse_query
from repro.data.synth import make_nanoaod_like
from repro.obs import (
    NULL_TRACER,
    MetricsRegistry,
    SkimReport,
    Tracer,
    chrome_trace,
    collect_cache_metrics,
    make_extras,
    trace_json,
    unified_cache_report,
)
from repro.serve import ManualClock, SkimService
from repro.serve.engine import SharedScanEngine
from repro.serve.service import ClusterBackend, EngineBackend
from tests.test_query import QUERY

ROOT = Path(__file__).resolve().parents[1]

N_EVENTS = 10_000
BASKET = 2048


def _store(seed: int = 11):
    return make_nanoaod_like(
        n_events=N_EVENTS, basket_events=BASKET, seed=seed
    )


@pytest.fixture(scope="module")
def store():
    return _store()


# ---------------------------------------------------------------------------
# tracer basics
# ---------------------------------------------------------------------------


def test_tracer_nesting_and_stack():
    tr = Tracer(clock=ManualClock())
    a = tr.begin("a", kind="query")
    b = tr.begin("b", kind="window")
    with tr.span("c", kind="fetch") as sp:
        sp["bytes"] = 7
    tr.end(b)
    tr.end(a, n_passed=3)
    spans = {s.name: s for s in tr.spans()}
    assert spans["a"].parent is None
    assert spans["b"].parent == a
    assert spans["c"].parent == b
    assert spans["c"].attrs["bytes"] == 7
    assert spans["a"].attrs["n_passed"] == 3
    # ending a parent pops dangling children off the stack
    d = tr.begin("d", kind="query")
    tr.begin("e", kind="window")
    tr.end(d)
    f = tr.begin("f", kind="query")
    assert tr.get(f).parent is None


def test_tracer_adopt_reparents_exactly_once():
    child = Tracer(clock=ManualClock())
    r = child.begin("node_query", kind="query")
    child.end(child.begin("w0", kind="window"))
    child.end(r)

    parent = Tracer(clock=ManualClock())
    shard = parent.begin("shard[0]", kind="shard")
    n = parent.adopt(child.spans(), parent=shard)
    parent.end(shard)
    assert n == 2
    by_name = {s.name: s for s in parent.spans()}
    assert by_name["node_query"].parent == shard
    # internal parent links remapped to the NEW ids, not the child's
    assert by_name["w0"].parent == by_name["node_query"].sid


def test_null_tracer_is_inert():
    assert NULL_TRACER.begin("x") == 0
    NULL_TRACER.end(0, anything=1)
    with NULL_TRACER.span("y") as sp:
        sp["k"] = "v"
    assert NULL_TRACER.spans() == []
    assert NULL_TRACER.adopt([1, 2, 3]) == 0


def test_chrome_trace_shape():
    tr = Tracer(clock=ManualClock())
    tr.end(tr.begin("q", kind="query"))
    doc = chrome_trace([(3, "job-3", tr)])
    events = doc["traceEvents"]
    assert events[0]["ph"] == "M"  # process_name metadata
    assert events[0]["args"]["name"] == "job-3"
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == 1 and xs[0]["pid"] == 3 and xs[0]["cat"] == "query"
    json.loads(trace_json(doc))  # serializes to valid JSON


def test_trace_json_coerces_numpy():
    tr = Tracer(clock=ManualClock())
    sid = tr.begin("q", kind="query")
    tr.end(sid, n=np.int64(5), b=np.bool_(True))
    payload = trace_json(tr.chrome_trace())
    args = json.loads(payload)["traceEvents"][1]["args"]
    assert args["n"] == 5


# ---------------------------------------------------------------------------
# deterministic engine traces
# ---------------------------------------------------------------------------


def _traced_run(seed: int = 11) -> tuple[Tracer, object]:
    st = _store(seed)
    tr = Tracer(clock=ManualClock())
    eng = SkimEngine(st, chunk_events=BASKET, pipeline=False)
    res = eng.run(QUERY, mode="near_data", tracer=tr)
    return tr, res


def test_engine_trace_deterministic_bytes():
    tr1, res1 = _traced_run()
    tr2, res2 = _traced_run()
    assert res1.n_passed == res2.n_passed
    j1 = trace_json(chrome_trace([(0, "q", tr1)]))
    j2 = trace_json(chrome_trace([(0, "q", tr2)]))
    assert j1 == j2  # byte-identical under the manual clock


def test_engine_trace_covers_the_pipeline():
    tr, res = _traced_run()
    kinds = {s.kind for s in tr.spans()}
    assert {"query", "plan", "window", "fetch", "decode"} <= kinds
    roots = tr.roots()
    assert len(roots) == 1 and roots[0].kind == "query"
    # one window span per executed window
    windows = [s for s in tr.spans() if s.kind == "window"]
    assert len(windows) == len(res.extras["window_rows"])
    assert all(s.parent == roots[0].sid for s in windows)


def test_null_tracer_equivalent_result(store):
    a = run_skim(store, QUERY, mode="near_data", fused=True, pipeline=False)
    tr = Tracer(clock=ManualClock())
    eng = SkimEngine(store, chunk_events=BASKET, pipeline=False)
    b = eng.run(QUERY, mode="near_data", tracer=tr)
    assert a.n_passed == b.n_passed
    assert a.stats.bytes_fetched == b.stats.bytes_fetched


# ---------------------------------------------------------------------------
# service lifecycle span tree
# ---------------------------------------------------------------------------


def _traced_service(seed: int = 11, **kw) -> SkimService:
    return SkimService(
        EngineBackend(_store(seed)),
        clock=ManualClock(),
        tracing=True,
        **kw,
    )


def test_service_job_complete_span_tree():
    svc = _traced_service()
    job = svc.submit(QUERY, tenant="atlas")
    svc.run_until_idle()
    assert job.state == "DONE"
    tr = job.tracer
    kinds = {s.kind for s in tr.spans()}
    assert {
        "job", "admission", "queue", "query", "plan", "window", "settle"
    } <= kinds
    roots = tr.roots()
    assert len(roots) == 1 and roots[0].kind == "job"
    # lifecycle spans parent directly under the job root
    by_kind = {}
    for s in tr.spans():
        by_kind.setdefault(s.kind, []).append(s)
    for kind in ("admission", "queue", "settle", "query"):
        assert all(s.parent == roots[0].sid for s in by_kind[kind])
    assert by_kind["settle"][0].attrs["state"] == "DONE"
    assert by_kind["admission"][0].attrs["admitted"] is True
    # the export is valid JSON with one pid per job
    doc = svc.export_trace()
    json.loads(trace_json(doc))
    assert {e["pid"] for e in doc["traceEvents"]} == {job.job_id}


def test_service_rejected_job_traced():
    from repro.serve import TenantQuota

    svc = _traced_service(quotas={"t": TenantQuota(byte_budget=1)})
    job = svc.submit(QUERY, tenant="t")
    assert job.state == "REJECTED"
    spans = {s.kind: s for s in job.tracer.spans()}
    assert spans["admission"].attrs["admitted"] is False
    assert spans["job"].attrs["state"] == "REJECTED"
    assert svc.metrics.counter(
        "service_jobs_total", state="REJECTED", tenant="t"
    ) == 1


def test_service_drain_export_deterministic(tmp_path):
    def drain():
        svc = _traced_service(calibrate=True)
        for i in range(4):
            svc.submit(QUERY, tenant=f"t{i % 2}")
        svc.run_until_idle()
        return svc

    p = tmp_path / "trace.json"
    doc = drain().export_trace(str(p))
    on_disk = p.read_text()
    assert on_disk == trace_json(doc)
    assert trace_json(drain().export_trace()) == on_disk
    assert len({e["pid"] for e in doc["traceEvents"]}) == 4


def test_service_batch_drain_traced():
    svc = SkimService(
        EngineBackend(_store()),
        clock=ManualClock(),
        tracing=True,
        batching=True,
    )
    jobs = [svc.submit(QUERY, tenant=f"t{i}") for i in range(3)]
    svc.run_until_idle()
    assert all(j.state == "DONE" for j in jobs)
    doc = svc.export_trace()
    pids = {e["pid"] for e in doc["traceEvents"]}
    # three job pids + the shared batch pass at 10000
    assert pids == {1, 2, 3, 10_000}
    batch_events = [e for e in doc["traceEvents"] if e["pid"] == 10_000]
    assert any(e.get("cat") == "window" for e in batch_events)


# ---------------------------------------------------------------------------
# cluster scatter-gather re-parenting
# ---------------------------------------------------------------------------


def test_cluster_trace_adopts_each_node_exactly_once(store):
    cl = build_cluster(store, n_nodes=3)
    tr = Tracer(clock=ManualClock())
    res = cl.run(QUERY, tracer=tr)
    by_id = {s.sid: s for s in tr.spans()}
    roots = tr.roots()
    assert len(roots) == 1 and roots[0].name == "cluster_query"
    merges = [s for s in tr.spans() if s.kind == "merge"]
    assert len(merges) == 1 and merges[0].parent == roots[0].sid
    shards = [s for s in tr.spans() if s.kind == "shard"]
    assert len(shards) == 3
    assert all(s.parent == merges[0].sid for s in shards)
    # each node's root query span adopted exactly once, under its shard
    node_queries = [
        s for s in tr.spans() if s.kind == "query" and s.name == "query"
    ]
    assert len(node_queries) == len(res.responses) == 3
    assert sorted(by_id[s.parent].kind for s in node_queries) == [
        "shard", "shard", "shard"
    ]


def test_cluster_cached_responses_carry_no_trace(store):
    cache = SkimResultCache()
    cl = build_cluster(store, n_nodes=2, cache=cache)
    tr_cold = Tracer(clock=ManualClock())
    cl.run(QUERY, tracer=tr_cold)
    n_cold = len(tr_cold.spans())
    tr_warm = Tracer(clock=ManualClock())
    warm = cl.run(QUERY, tracer=tr_warm)
    assert warm.cache_hits == 2
    shards = [s for s in tr_warm.spans() if s.kind == "shard"]
    assert all(s.attrs["cached"] for s in shards)
    # no node spans re-adopted from the cached responses
    assert not any(
        s.kind == "query" and s.name == "query" for s in tr_warm.spans()
    )
    assert len(tr_warm.spans()) < n_cold


# ---------------------------------------------------------------------------
# SkimReport + extras compatibility shim
# ---------------------------------------------------------------------------


def test_skimreport_attached_and_extras_match(store):
    res = run_skim(store, QUERY, mode="near_data", fused=True, pipeline=False)
    assert isinstance(res.report, SkimReport)
    assert res.extras == res.report.legacy_extras()
    assert res.report.version == 1
    # the historical single-engine key set, exactly
    assert {
        "output_bytes", "fused", "pipelined", "window_rows",
        "pruned_windows", "prune", "phase1_bytes", "phase2_bytes",
        "overlap_total", "phase_wall_s",
    } <= set(res.extras)
    assert "shared_scan" not in res.extras
    assert "shard_pruned" not in res.extras


def test_skimreport_shared_scan_shim(store):
    eng = SharedScanEngine(store, chunk_events=BASKET)
    batch = eng.run_batch([QUERY, QUERY])
    for r in batch.results:
        assert isinstance(r.report, SkimReport)
        assert r.extras == r.report.legacy_extras()
        assert r.extras["shared_scan"] is True
        assert "phase1_bytes" not in r.extras  # tenants share the scan


def test_make_extras_rejects_unknown_keys():
    assert make_extras(output_bytes=1, tenant=0) == {
        "output_bytes": 1, "tenant": 0
    }
    with pytest.raises(KeyError):
        make_extras(totally_new_key=1)


# ---------------------------------------------------------------------------
# calibration: priced vs observed
# ---------------------------------------------------------------------------


def test_estimate_calibration_scales_stages(store):
    plan = plan_skim(
        parse_query(QUERY), store, window_events=BASKET, cascade=True
    )
    base = estimate_plan_bytes(plan, store, BASKET)
    kinds = set(base["per_stage_kinds"].values())
    assert kinds  # the cascade priced real stages
    half = estimate_plan_bytes(
        plan, store, BASKET, calibration={k: 0.5 for k in kinds}
    )
    assert half["phase1"] < base["phase1"]
    # ratios clamp at 20x: an absurd prior cannot blow the estimate up
    # 1000x (small slack for per-stage integer rounding)
    wild = estimate_plan_bytes(
        plan, store, BASKET, calibration={k: 1000.0 for k in kinds}
    )
    assert wild["phase1"] < base["phase1"] * 21


def test_stage_kind_taxonomy(store):
    plan = plan_skim(
        parse_query(QUERY), store, window_events=BASKET, cascade=True
    )
    kinds = {stage_kind(s) for s in plan.cascade.stages}
    known = {
        "cut", "trigger", "object", "ht", "mass", "deltaR", "expr",
        "const", "other",
    }
    assert kinds <= known


def test_metrics_registry_calibration_roundtrip():
    m = MetricsRegistry()
    m.record_price_ratio("cut", 100, 50)
    m.record_price_ratio("cut", 100, 70)
    m.record_price_ratio("trigger", 0, 10)
    summary = m.calibration_summary()
    assert summary["cut"]["n"] == 2
    assert summary["cut"]["ratio"] == pytest.approx(120 / 200)
    assert summary["trigger"]["ratio"] is None  # zero priced bytes
    priors = m.calibration_priors(min_samples=2)
    assert priors == {"cut": pytest.approx(0.6)}


def test_service_calibration_feedback():
    svc = _traced_service(calibrate=True)
    j1 = svc.submit(QUERY, tenant="a")
    svc.run_until_idle()
    summary = svc.calibration_summary()
    assert summary["total"]["observed_bytes"] == j1.result.stats.bytes_fetched
    priors = svc.metrics.calibration_priors()
    assert "total" in priors
    # the second submission prices THROUGH the accumulated priors
    j2 = svc.submit(QUERY, tenant="a")
    assert j2.estimate.est_bytes != j1.estimate.est_bytes


# ---------------------------------------------------------------------------
# metrics: registry + unified caches
# ---------------------------------------------------------------------------


def test_metrics_counters_gauges_histograms():
    m = MetricsRegistry()
    m.inc("jobs", state="DONE")
    m.inc("jobs", state="DONE")
    m.inc("jobs", state="FAILED")
    assert m.counter("jobs", state="DONE") == 2
    m.set_gauge("depth", 4)
    assert m.gauge("depth") == 4
    m.observe("wait_s", 1.0)
    m.observe("wait_s", 3.0)
    h = m.histogram("wait_s")
    assert h["count"] == 2 and h["sum"] == 4.0 and h["max"] == 3.0
    snap = m.snapshot()
    assert snap["counters"]["jobs{state=DONE}"] == 2


def test_service_metrics_recorded():
    svc = _traced_service()
    job = svc.submit(QUERY, tenant="atlas")
    svc.run_until_idle()
    m = svc.metrics
    assert m.counter("service_jobs_total", state="DONE", tenant="atlas") == 1
    assert m.histogram("service_queue_wait_s")["count"] == 1
    assert m.histogram("service_first_partial_s")["count"] == 1
    assert m.gauge("tenant_spent_bytes", tenant="atlas") == (
        job.result.stats.bytes_fetched
    )


def test_unified_cache_report_and_gauges():
    st = make_nanoaod_like(4_000, n_hlt=4, basket_events=1024)
    st.read_flat("MET_pt")
    st.read_flat("MET_pt")  # second read hits
    cache = SkimResultCache()
    cache.get("absent")
    report = unified_cache_report(store=st, result_cache=cache)
    dec = report["decode"]
    assert dec["hits"] > 0 and dec["saved_bytes"] > 0
    assert dec["hit_rate"] == pytest.approx(
        dec["hits"] / (dec["hits"] + dec["misses"])
    )
    assert report["result"]["misses"] == 1
    m = MetricsRegistry()
    collect_cache_metrics(m, store=st, result_cache=cache)
    assert m.gauge("cache_hits", cache="decode") == dec["hits"]
    assert m.gauge("cache_misses", cache="result") == 1


def test_decode_cache_byte_weighted_stats():
    st = make_nanoaod_like(4_000, n_hlt=4, basket_events=1024)
    st.read_flat("MET_pt")
    s0 = st.decode_cache_stats()
    assert s0["miss_bytes"] > 0 and s0["hit_bytes"] == 0
    st.read_flat("MET_pt")
    s1 = st.decode_cache_stats()
    assert s1["hit_bytes"] > 0
    assert s1["saved_decode_bytes"] == s1["hit_bytes"]
    assert s1["miss_bytes"] == s0["miss_bytes"]  # nothing re-decoded


def test_result_cache_replacement_not_double_counted():
    cache = SkimResultCache()
    assert cache.put("k", "v1", nbytes=100, fetch_bytes=10)
    # the timed-out-primary race: same content address re-put
    assert cache.put("k", "v1", nbytes=100, fetch_bytes=10)
    s = cache.stats
    assert s.insertions == 1
    assert s.replacements == 1
    assert s.miss_bytes == 100  # counted once, not twice
    assert s.stored_bytes == 100
    assert len(cache) == 1


# ---------------------------------------------------------------------------
# the extras lint checker
# ---------------------------------------------------------------------------


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_extras", ROOT / "tools" / "check_extras.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_extras_repo_is_clean():
    checker = _load_checker()
    assert checker.scan([ROOT / "src" / "repro"]) == []


def test_check_extras_flags_bare_writes(tmp_path):
    checker = _load_checker()
    bad = tmp_path / "bad.py"
    bad.write_text(
        'extras["new_key"] = 1\n'
        'extras["n"] += 2\n'
        'ok = extras["read"]\n'          # reads are fine
        '# extras["comment"] = 3\n'      # comments are fine
        'if extras["x"] == 1: pass\n'    # comparisons are fine
    )
    hits = checker.scan([bad])
    assert [h[1] for h in hits] == [1, 2]
