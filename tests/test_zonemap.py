"""Zone-map interval analysis (DESIGN.md §9).

The safety contract, pinned both by hand-built edge cases and by
hypothesis property tests over random stores and random predicates:

  * a window classified PRUNE never contains a survivor,
  * a window classified ACCEPT_ALL never contains a failure,

for every AST shape (flat cut, trigger OR, object selection, HT), every
operator (including the float32-rounding ``==``/``!=``/``abs`` edges),
and windows whose statistics are partially or wholly unknown.
"""

import numpy as np

from repro.core.query import eval_stage, parse_query
from repro.core.zonemap import ACCEPT_ALL, PRUNE, SCAN, classify_span, classify_windows
from repro.data.store import EventStore

# the hand-built edge cases below run everywhere; only the random
# property tests need hypothesis (guarded like the other hypothesis
# files, but per-section so the deterministic half still runs)
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

BASKET = 32


def _store_from(columns, jagged=None, basket_events=BASKET):
    return EventStore.from_arrays(
        columns, jagged=jagged or {}, basket_events=basket_events
    )


def _spans(store, window_events):
    return [
        (s, min(s + window_events, store.n_events))
        for s in range(0, store.n_events, window_events)
    ]


def _window_data(columns, jagged, start, stop):
    """Ground-truth decoded window: exactly what the executor hands the
    evaluator for [start, stop)."""
    out = {}
    for name, arr in columns.items():
        if name in (jagged or {}):
            counts = columns[jagged[name]]
            offsets = np.concatenate([[0], np.cumsum(counts)])
            out[name] = arr[offsets[start]:offsets[stop]]
        else:
            out[name] = arr[start:stop]
    return out


def _true_mask(query, data, m):
    mask = np.ones(m, dtype=bool)
    for _, stage in query.stages():
        mask &= eval_stage(stage, data, m)
    return mask


def _check_invariants(query, store, columns, jagged, window_events=BASKET):
    for (a, b), kind in zip(
        spans := _spans(store, window_events),
        classify_windows(query, store, spans),
    ):
        data = _window_data(columns, jagged, a, b)
        mask = _true_mask(query, data, b - a)
        if kind == PRUNE:
            assert not mask.any(), (
                f"window [{a},{b}) pruned but has {int(mask.sum())} survivors"
            )
        elif kind == ACCEPT_ALL:
            assert mask.all(), (
                f"window [{a},{b}) accept-all but fails "
                f"{int((~mask).sum())} events"
            )


# ---------------------------------------------------------------------------
# hand-built edge cases
# ---------------------------------------------------------------------------


def test_monotone_branch_prunes_tail_windows():
    lumi = (np.arange(256) // 64).astype(np.int32)
    store = _store_from({"lumi": lumi, "x": np.zeros(256, np.float32)})
    q = parse_query({"branches": ["x"], "selection": {
        "preselection": [{"branch": "lumi", "op": "<=", "value": 0}]}})
    kinds = classify_windows(q, store, _spans(store, 64))
    assert kinds == [ACCEPT_ALL, PRUNE, PRUNE, PRUNE]


def test_floor_cut_accepts_all():
    met = (np.random.default_rng(0).exponential(30, 200) + 1).astype(np.float32)
    store = _store_from({"met": met})
    q = parse_query({"branches": ["met"], "selection": {
        "preselection": [{"branch": "met", "op": ">", "value": 0.5}]}})
    assert set(classify_windows(q, store, _spans(store, BASKET))) == {ACCEPT_ALL}


def test_selection_free_query_is_accept_all():
    store = _store_from({"x": np.arange(100, dtype=np.int32)})
    q = parse_query({"branches": ["x"]})
    assert classify_span(q, store, 0, 100) == ACCEPT_ALL


def test_float32_threshold_rounding_edge():
    """0.1 rounds UP through float32; a window holding exactly
    float32(0.1) must classify as the evaluator compares (NEVER for
    ``> 0.1``), not as the raw float64 interval would suggest."""
    x = np.full(64, np.float32(0.1), dtype=np.float32)
    store = _store_from({"x": x})
    q = parse_query({"branches": ["x"], "selection": {
        "preselection": [{"branch": "x", "op": ">", "value": 0.1}]}})
    cols = {"x": x}
    assert classify_span(q, store, 0, 64) == PRUNE
    assert not _true_mask(q, cols, 64).any()
    q2 = parse_query({"branches": ["x"], "selection": {
        "preselection": [{"branch": "x", "op": "<=", "value": 0.1}]}})
    assert classify_span(q2, store, 0, 64) == ACCEPT_ALL
    assert _true_mask(q2, cols, 64).all()


def test_unknown_stats_degrade_to_scan():
    store = _store_from({"x": np.arange(64, dtype=np.float32)})
    q = parse_query({"branches": ["x"], "selection": {
        "preselection": [{"branch": "x", "op": "<", "value": -1.0}]}})
    assert classify_span(q, store, 0, 64) == PRUNE
    # strip the stats (a store written before ZONEMAP_VERSION)
    for m in store._baskets["x"]:
        m.vmin = m.vmax = m.n_true = None
    assert classify_span(q, store, 0, 64) == SCAN


def test_nonfinite_data_never_prunes():
    x = np.array([np.nan] * 32 + [1.0] * 32, dtype=np.float32)
    store = _store_from({"x": x})
    q = parse_query({"branches": ["x"], "selection": {
        "preselection": [{"branch": "x", "op": ">", "value": 100.0}]}})
    # the NaN basket carries no stats -> scan, never a wrong prune; the
    # finite basket (all 1.0) proves out normally
    assert classify_windows(q, store, _spans(store, BASKET)) == [SCAN, PRUNE]


def test_trigger_or_prunes_and_accepts():
    cols = {
        "a": np.zeros(96, dtype=bool),
        "b": np.array([False] * 32 + [True] * 32 + [False] * 32),
    }
    store = _store_from(cols)
    q = parse_query({"branches": ["a"], "selection": {
        "event": [{"type": "any", "branches": ["a", "b"]}]}})
    assert classify_windows(q, store, _spans(store, BASKET)) == [
        PRUNE, ACCEPT_ALL, PRUNE,
    ]


def test_object_selection_prunes_on_counts_and_values():
    counts = np.array([0] * 32 + [2] * 64, dtype=np.int32)
    pt = np.full(int(counts.sum()), 10.0, dtype=np.float32)
    pt[64:] = 50.0  # last window's objects all pass
    cols = {"nObj": counts, "Obj_pt": pt}
    store = _store_from(cols, jagged={"Obj_pt": "nObj"})
    q = parse_query({"branches": ["Obj_*"], "selection": {"object": [
        {"collection": "Obj",
         "cuts": [{"var": "pt", "op": ">", "value": 20.0}]}]}})
    kinds = classify_windows(q, store, _spans(store, BASKET))
    # w0: no objects at all; w1: objects exist but none passes; w2: every
    # object passes and every event has >= 1
    assert kinds == [PRUNE, PRUNE, ACCEPT_ALL]
    _check_invariants(q, store, cols, {"Obj_pt": "nObj"})


def test_ht_zero_and_bounded():
    counts = np.array([0] * 32 + [3] * 32, dtype=np.int32)
    pt = np.full(96, 50.0, dtype=np.float32)
    cols = {"nJet": counts, "Jet_pt": pt}
    store = _store_from(cols, jagged={"Jet_pt": "nJet"})
    jag = {"Jet_pt": "nJet"}
    q = parse_query({"branches": ["Jet_*"], "selection": {"event": [
        {"type": "ht", "collection": "Jet", "var": "pt",
         "op": ">", "value": 100.0}]}})
    # w0: HT == 0 exactly -> prune; w1: HT == 150 > 100 provably
    assert classify_windows(q, store, _spans(store, BASKET)) == [
        PRUNE, ACCEPT_ALL,
    ]
    _check_invariants(q, store, cols, jag)
    # object_cuts that nothing passes force HT == 0 everywhere
    q2 = parse_query({"branches": ["Jet_*"], "selection": {"event": [
        {"type": "ht", "collection": "Jet", "var": "pt",
         "object_cuts": [{"var": "pt", "op": ">", "value": 60.0}],
         "op": "<", "value": 1.0}]}})
    assert classify_windows(q2, store, _spans(store, BASKET)) == [
        ACCEPT_ALL, ACCEPT_ALL,
    ]
    _check_invariants(q2, store, cols, jag)


# ---------------------------------------------------------------------------
# property tests: random stores x random predicates
# ---------------------------------------------------------------------------

_OPS = [">", ">=", "<", "<=", "==", "!=", "abs<", "abs>"]

if HAVE_HYPOTHESIS:
    _threshold = st.one_of(
        st.floats(min_value=-150.0, max_value=150.0,
                  allow_nan=False, allow_infinity=False),
        st.sampled_from([0.0, 0.1, 1.0, 30.0, -30.0, 2.5]),
    )

    @st.composite
    def _random_case(draw):
        seed = draw(st.integers(0, 2**16))
        n_events = draw(st.integers(33, 160))
        rng = np.random.default_rng(seed)
        counts = rng.poisson(
            draw(st.floats(0.0, 3.0)), n_events
        ).astype(np.int32)
        columns = {
            "met": (rng.normal(30.0, 25.0, n_events)).astype(np.float32),
            "cnt": rng.integers(-5, 40, n_events).astype(np.int32),
            "trig": rng.random(n_events)
            < draw(st.sampled_from([0.0, 0.3, 1.0])),
            "trig2": rng.random(n_events)
            < draw(st.sampled_from([0.0, 0.5, 1.0])),
            "nObj": counts,
            "Obj_pt": (
                rng.exponential(25.0, int(counts.sum())) - 10.0
            ).astype(np.float32),
        }
        jagged = {"Obj_pt": "nObj"}

        sel: dict = {}
        if draw(st.booleans()):
            sel.setdefault("preselection", []).append(
                {"branch": draw(st.sampled_from(["met", "cnt", "nObj"])),
                 "op": draw(st.sampled_from(_OPS)),
                 "value": draw(_threshold)}
            )
        if draw(st.booleans()):
            cuts = [
                {"var": "pt", "op": draw(st.sampled_from(_OPS)),
                 "value": draw(_threshold)}
                for _ in range(draw(st.integers(0, 2)))
            ]
            sel.setdefault("object", []).append(
                {"collection": "Obj", "cuts": cuts,
                 "min_count": draw(st.integers(0, 3))}
            )
        events = []
        if draw(st.booleans()):
            events.append({"type": "any", "branches": ["trig", "trig2"]})
        if draw(st.booleans()):
            ht = {"type": "ht", "collection": "Obj", "var": "pt",
                  "op": draw(st.sampled_from(_OPS)),
                  "value": draw(_threshold)}
            if draw(st.booleans()):
                ht["object_cuts"] = [{"var": "pt",
                                      "op": draw(st.sampled_from(_OPS)),
                                      "value": draw(_threshold)}]
            events.append(ht)
        if events:
            sel["event"] = events
        doc = {"branches": ["met", "Obj_*", "cnt"], "selection": sel}
        return columns, jagged, doc

    @given(_random_case())
    @settings(max_examples=150, deadline=None)
    def test_prune_never_drops_survivors_accept_never_keeps_failures(case):
        columns, jagged, doc = case
        store = _store_from(columns, jagged=jagged)
        query = parse_query(doc)
        _check_invariants(query, store, columns, jagged)

    @given(_random_case(), st.integers(1, 3))
    @settings(max_examples=60, deadline=None)
    def test_invariants_hold_for_multi_basket_windows(case, nb):
        """Windows spanning several baskets aggregate stats; the
        contract must survive the aggregation."""
        columns, jagged, doc = case
        store = _store_from(columns, jagged=jagged)
        query = parse_query(doc)
        _check_invariants(
            query, store, columns, jagged, window_events=BASKET * nb
        )
