"""The skimlint framework and rule catalog (``tools/skimlint``).

Three layers: the per-rule snippet corpus (violating / clean /
suppressed — the same corpus ``--self-test`` runs), framework behavior
(suppressions, JSON schema stability, syntax-error handling, the CLI's
exit codes), and the repo-is-clean end-to-end gate: ``src/repro`` lints
with zero unsuppressed findings, and every suppression names a rule ID
(a bare ``# skimlint: ignore`` is itself a finding, X001).
"""

import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.skimlint import (  # noqa: E402
    JSON_SCHEMA_VERSION,
    all_rules,
    lint_paths,
    lint_source,
)
from tools.skimlint.__main__ import main as skimlint_main  # noqa: E402
from tools.skimlint.core import render_json  # noqa: E402
from tools.skimlint.selftest import CORPUS, run_selftest  # noqa: E402


# ---------------------------------------------------------------------------
# the rule corpus
# ---------------------------------------------------------------------------


def test_selftest_corpus_passes():
    assert run_selftest() == []


def test_every_registered_rule_has_a_corpus_entry():
    for rid in all_rules():
        assert rid in CORPUS, f"{rid}: no self-test corpus entry"
        assert CORPUS[rid]["bad"], f"{rid}: no violating snippet"
        assert CORPUS[rid]["good"], f"{rid}: no clean snippet"


@pytest.mark.parametrize("rid", sorted(CORPUS))
def test_rule_corpus(rid):
    """Per-rule granularity over the same snippets ``--self-test`` runs."""
    cases = CORPUS[rid]
    path = cases.get("path", "src/repro/snippet.py")
    path = path if isinstance(path, str) else path[0]
    for src in cases.get("bad", ()):
        res = lint_source(src, path=path)
        assert any(f.rule == rid for f in res.findings), src
    for src in cases.get("good", ()):
        res = lint_source(src, path=path)
        assert not [f for f in res.findings if f.rule == rid], src
    for src in cases.get("suppressed", ()):
        res = lint_source(src, path=path)
        assert not any(f.rule == rid for f in res.findings), src
        assert any(f.rule == rid for f in res.suppressed), src


def test_import_alias_resolution():
    """D001 sees through every import spelling of the same callable."""
    for src in (
        "import time\nt0 = time.time()\n",
        "import time as t\nt0 = t.time()\n",
        "from time import time\nt0 = time()\n",
        "from time import time as now\nt0 = now()\n",
        "import numpy.random as npr\nnpr.shuffle([1])\n",
    ):
        res = lint_source(src, path="src/repro/x.py")
        assert [f.rule for f in res.findings] == ["D001"], src


def test_d004_scoped_to_cluster_and_serve():
    src = "def f():\n    raise RuntimeError('x')\n"
    for path, hits in (
        ("src/repro/cluster/a.py", 1),
        ("src/repro/serve/a.py", 1),
        ("src/repro/core/a.py", 0),
    ):
        res = lint_source(src, path=path)
        assert len([f for f in res.findings if f.rule == "D004"]) == hits, path


def test_e001_exempts_obs_schema():
    src = "def f(extras):\n    extras['k'] = 1\n"
    assert lint_source(src, path="src/repro/obs/schema.py").findings == []
    assert [
        f.rule for f in lint_source(src, path="src/repro/obs/other.py").findings
    ] == ["E001"]


# ---------------------------------------------------------------------------
# framework behavior
# ---------------------------------------------------------------------------


def test_suppression_is_per_rule():
    """An ignore[D001] must not blanket-suppress other rules on the line."""
    src = (
        "import time, json\n"
        "doc = json.dumps({'a': time.time()})  # skimlint: ignore[D001]\n"
    )
    res = lint_source(src, path="src/repro/x.py")
    assert [f.rule for f in res.findings] == ["D003"]
    assert [f.rule for f in res.suppressed] == ["D001"]


def test_bare_suppression_is_a_finding():
    src = "x = 1  # skimlint: ignore\n"
    res = lint_source(src, path="src/repro/x.py")
    assert [f.rule for f in res.findings] == ["X001"]


def test_syntax_error_is_a_finding_not_a_crash():
    res = lint_source("def f(:\n", path="src/repro/x.py")
    assert [f.rule for f in res.findings] == ["E999"]


def test_select_filters_rules():
    src = "import time, json\ndoc = json.dumps({'a': time.time()})\n"
    res = lint_source(src, path="src/repro/x.py", select={"D003"})
    assert [f.rule for f in res.findings] == ["D003"]


def test_json_schema_stable():
    """The JSON output shape is a contract: version + exact key sets."""
    assert JSON_SCHEMA_VERSION == 1
    res = lint_source("import time\nt0 = time.time()\n", path="src/repro/x.py")
    doc = json.loads(render_json(res))
    assert doc["version"] == JSON_SCHEMA_VERSION
    assert sorted(doc) == ["counts", "files", "findings", "suppressed", "version"]
    assert doc["counts"] == {"D001": 1}
    (finding,) = doc["findings"]
    assert sorted(finding) == ["col", "line", "message", "path", "rule"]
    assert finding["rule"] == "D001"
    assert finding["line"] == 2
    # deterministic serialization: two renders are byte-identical
    assert render_json(res) == render_json(res)


def test_cli_exit_codes(tmp_path):
    assert skimlint_main(["--list-rules"]) == 0
    assert skimlint_main(["--no-lint", "--self-test"]) == 0
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert skimlint_main([str(clean)]) == 0
    dirty = tmp_path / "dirty.py"
    dirty.write_text("import time\nt0 = time.time()\n")
    assert skimlint_main([str(dirty)]) == 1


# ---------------------------------------------------------------------------
# the repo gate
# ---------------------------------------------------------------------------


def test_src_repro_is_clean():
    """Zero unsuppressed findings in the tree — and because X001 flags
    bare ignores, zero findings also proves every suppression in the
    tree names the rule it suppresses."""
    res = lint_paths([str(ROOT / "src" / "repro")])
    assert res.findings == [], [f.render() for f in res.findings]
    assert res.files > 30  # the walk actually saw the tree
