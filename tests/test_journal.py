"""Durable jobs: journal + crash recovery (ISSUE 8 / DESIGN.md §14).

Pins the write-ahead contract: every lifecycle transition is journaled
before the service moves on, and :meth:`SkimService.recover` replays a
journal into a fresh service whose post-recovery stream is exactly the
uninterrupted run's suffix — bit-identical final result, tenant
accounting intact, and recovery composing across repeated crashes.
"""

import pytest

from repro.core.engine import run_skim
from repro.data.synth import make_nanoaod_like
from repro.obs.metrics import MetricsRegistry
from repro.serve import (
    DONE,
    PENDING,
    REJECTED,
    JOURNAL_EVENTS,
    JOURNAL_VERSION,
    JobJournal,
    SkimService,
    TenantQuota,
)
from tests.test_query import QUERY

N_EVENTS = 10_000
BASKET = 2048
N_WINDOWS = 5  # ceil(N_EVENTS / BASKET)


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(
        N_EVENTS, n_hlt=16, n_filler=8, basket_events=BASKET
    )


@pytest.fixture(scope="module")
def ref(store):
    return run_skim(store, QUERY, mode="near_data")


@pytest.fixture(scope="module")
def uninterrupted(store):
    """The reference journaled run: completes without a crash."""
    svc = SkimService(store, journal=JobJournal())
    job = svc.submit(QUERY, tenant="t")
    svc.result(job.job_id)
    return job


# ---------------------------------------------------------------------------
# JobJournal unit behavior
# ---------------------------------------------------------------------------


def test_journal_validates_events():
    j = JobJournal()
    rec = j.append("submit", 1, 0.0, tenant="t")
    assert rec["v"] == JOURNAL_VERSION
    with pytest.raises(ValueError, match="unknown journal event"):
        j.append("explode", 1, 0.0)
    assert set(JOURNAL_EVENTS) == {
        "submit", "admit", "reject", "start", "window", "settle"
    }


def test_journal_rejects_non_jsonable_records():
    j = JobJournal()
    with pytest.raises(TypeError, match="dict/str docs"):
        j.append("submit", 1, 0.0, query=object())
    assert len(j) == 1 - 1  # nothing half-appended


def test_journal_records_filter_and_len():
    j = JobJournal()
    j.append("submit", 1, 0.0)
    j.append("window", 1, 1.0, seq=0)
    j.append("window", 1, 2.0, seq=1)
    assert len(j) == 3
    assert [r["seq"] for r in j.records("window")] == [0, 1]
    assert [r["event"] for r in j.records()] == ["submit", "window", "window"]


def test_journal_persists_and_reopens(tmp_path):
    path = str(tmp_path / "jobs.journal")
    j = JobJournal(path)
    j.append("submit", 1, 0.0, tenant="t", query="q")
    j.append("settle", 1, 1.0, state=DONE)
    j.close()
    reopened = JobJournal(path)
    assert len(reopened) == 2
    assert reopened.records() == j.records()
    # append-only: reopening appends after the existing records
    reopened.append("submit", 2, 2.0)
    assert len(JobJournal(path)) == 3


def test_service_requires_jsonable_query_docs(store):
    from repro.core.query import parse_query

    svc = SkimService(store, journal=JobJournal())
    with pytest.raises(TypeError, match="dict/str docs"):
        svc.submit(parse_query(QUERY))  # Query object: no serializer


# ---------------------------------------------------------------------------
# journaled lifecycle coverage
# ---------------------------------------------------------------------------


def test_every_transition_journaled(uninterrupted, store):
    svc = SkimService(store, journal=JobJournal())
    job = svc.submit(QUERY, tenant="t")
    svc.result(job.job_id)
    j = svc.journal
    assert [r["event"] for r in j.records()] == (
        ["submit", "admit", "start"]
        + ["window"] * N_WINDOWS
        + ["settle"]
    )
    assert [r["seq"] for r in j.records("window")] == list(range(N_WINDOWS))
    (settle,) = j.records("settle")
    assert settle["state"] == DONE
    assert settle["observed_bytes"] == job.result.stats.bytes_fetched


def test_rejections_are_journaled(store):
    svc = SkimService(
        store,
        quotas={"t": TenantQuota(byte_budget=1)},
        journal=JobJournal(),
    )
    job = svc.submit(QUERY, tenant="t")
    assert job.state == REJECTED
    (rej,) = svc.journal.records("reject")
    assert "over byte quota" in rej["reason"]


# ---------------------------------------------------------------------------
# crash recovery
# ---------------------------------------------------------------------------


def _crash_after(store, path, n_windows, quotas=None, **kw):
    """Run a journaled service until ``n_windows`` partials streamed,
    then abandon it (the simulated crash: nothing is settled)."""
    svc = SkimService(
        store, journal=JobJournal(path), quotas=quotas or {}, **kw
    )
    job = svc.submit(QUERY, tenant="t")
    while len(job.partials) < n_windows:
        assert svc.step()
    svc.journal.close()
    return job


def test_recover_resumes_running_job_from_watermark(
    store, tmp_path, uninterrupted
):
    path = str(tmp_path / "crash.journal")
    crashed = _crash_after(store, path, 2)
    assert crashed.state != DONE

    svc2 = SkimService.recover(JobJournal(path), store)
    job2 = svc2.jobs[crashed.job_id]
    assert job2.state == PENDING
    assert job2.resume_skip == 2
    done = svc2.result(job2.job_id)
    assert done.state == DONE
    # the post-recovery stream is exactly the uninterrupted suffix
    assert done.windows_streamed() == uninterrupted.windows_streamed()[2:]
    assert [p.n_passed for p in done.partials] == [
        p.n_passed for p in uninterrupted.partials[2:]
    ]
    # and the final result is bit-identical to the no-crash run
    assert (
        done.result.output.manifest_hash()
        == uninterrupted.result.output.manifest_hash()
    )


def test_recovery_composes_across_repeated_crashes(
    store, tmp_path, uninterrupted
):
    path = str(tmp_path / "crash2.journal")
    crashed = _crash_after(store, path, 1)

    # crash again mid-resume: one more window streamed, then abandoned
    svc2 = SkimService.recover(JobJournal(path), store)
    job2 = svc2.jobs[crashed.job_id]
    while len(job2.partials) < 1:
        assert svc2.step()
    svc2.journal.close()

    # second recovery: the watermark is GLOBAL (resume_skip + local seq),
    # so the third incarnation skips both previously streamed windows
    svc3 = SkimService.recover(JobJournal(path), store)
    job3 = svc3.jobs[crashed.job_id]
    assert job3.resume_skip == 2
    done = svc3.result(job3.job_id)
    assert done.state == DONE
    assert done.windows_streamed() == uninterrupted.windows_streamed()[2:]
    assert (
        done.result.output.manifest_hash()
        == uninterrupted.result.output.manifest_hash()
    )


def test_recover_restores_pending_and_terminal_jobs(store, tmp_path):
    path = str(tmp_path / "mixed.journal")
    quotas = {"t": TenantQuota(byte_budget=10**12)}
    svc = SkimService(store, journal=JobJournal(path), quotas=quotas)
    done_job = svc.submit(QUERY, tenant="t")
    svc.result(done_job.job_id)
    rejected = svc.submit(
        QUERY, tenant="broke"
    )  # fine: unlimited default quota
    pending = svc.submit(QUERY, tenant="t")
    assert pending.state == PENDING
    usage_before = svc.tenant_usage("t")
    svc.journal.close()

    svc2 = SkimService.recover(JobJournal(path), store, quotas=quotas)
    assert svc2.jobs[done_job.job_id].state == DONE
    assert svc2.jobs[rejected.job_id].state == rejected.state
    j2 = svc2.jobs[pending.job_id]
    assert j2.state == PENDING and j2.resume_skip == 0
    assert j2.vfinish == pending.vfinish
    # tenant accounting (spent + reserved) survives the crash
    usage_after = svc2.tenant_usage("t")
    for k in ("spent_bytes", "spent_wall_s", "reserved_bytes",
              "reserved_wall_s"):
        assert usage_after[k] == pytest.approx(usage_before[k]), k
    # and the queue drains to the same answer
    assert svc2.result(pending.job_id).state == DONE


def test_recovered_service_continues_ids_and_keeps_journaling(
    store, tmp_path
):
    path = str(tmp_path / "ids.journal")
    crashed = _crash_after(store, path, 1)
    svc2 = SkimService.recover(JobJournal(path), store)
    newer = svc2.submit(QUERY, tenant="u")
    assert newer.job_id == crashed.job_id + 1
    assert newer.seq == crashed.seq + 1
    # the recovered service journals to the same journal
    assert svc2.journal.records("submit")[-1]["job_id"] == newer.job_id


def test_recover_counts_replays_and_traces(store, tmp_path):
    path = str(tmp_path / "obs.journal")
    _crash_after(store, path, 2)
    metrics = MetricsRegistry()
    svc2 = SkimService.recover(
        JobJournal(path), store, metrics=metrics, tracing=True
    )
    assert metrics.counter("journal_replays_total", event="submit") == 1
    assert metrics.counter("journal_replays_total", event="window") == 2
    (job,) = svc2.jobs.values()
    spans = [s for s in job.tracer.spans() if s.kind == "recover"]
    assert len(spans) == 1
    assert spans[0].attrs["resume_skip"] == 2
