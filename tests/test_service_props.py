"""Property tests for the service streaming contract (ISSUE 6).

The invariant under ANY interleaving of submissions, cancellations, and
scheduler quanta:

  * a DONE job's streamed partial windows, unioned in stream order, are
    bit-identical to the synchronous ``run_skim`` result for its query;
  * no window is ever streamed twice (per job: spans are unique, sorted,
    and gapless up to where the stream stopped);
  * a CANCELLED job's partials are a prefix of that same window
    sequence.

Two drivers over one interleaving machine: a seeded-random explorer
that always runs, and a Hypothesis-driven one (skipped cleanly when
hypothesis isn't installed — the container doesn't ship it) that lets
shrinking find minimal counterexample schedules.
"""

import random

import numpy as np
import pytest

from repro.core.engine import run_skim
from repro.data.synth import make_nanoaod_like
from repro.serve import CANCELLED, DONE, SkimService, union_columns
from tests.test_query import QUERY

N_EVENTS = 6_000
BASKET = 2048
SPANS = [(0, 2048), (2048, 4096), (4096, 6000)]

QUERY_TIGHT = {
    **QUERY,
    "selection": {
        **QUERY["selection"],
        "event": [
            {"type": "any", "branches": ["HLT_IsoMu24"]},
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 35.0},
        ],
    },
}
QUERIES = [QUERY, QUERY_TIGHT]


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(
        N_EVENTS, n_hlt=16, n_filler=8, basket_events=BASKET
    )


@pytest.fixture(scope="module")
def refs(store):
    return [run_skim(store, q, mode="near_data") for q in QUERIES]


def _run_interleaving(store, actions):
    """Drive one service through an action script.

    ``actions`` is a list of (op, arg) pairs: ("submit", query_index),
    ("cancel", job_ordinal), ("step", n_quanta).  Cancels resolve
    against the submission order (modulo how many exist); the tail
    always drains the queue.  Returns the service.
    """
    svc = SkimService(store, batching=False)
    submitted = []
    for op, arg in actions:
        if op == "submit":
            job = svc.submit(QUERIES[arg], tenant=f"t{arg}")
            submitted.append(job)
        elif op == "cancel" and submitted:
            svc.cancel(submitted[arg % len(submitted)].job_id)
        elif op == "step":
            for _ in range(arg):
                if not svc.step():
                    break
    svc.run_until_idle()
    return svc


def _check_invariants(svc, refs):
    for job in svc.jobs.values():
        assert job.terminal, job.state
        spans = job.windows_streamed()
        # never a duplicate window, always in window order
        assert len(spans) == len(set(spans))
        assert spans == sorted(spans)
        qi = 0 if job.query is QUERIES[0] else 1
        ref = refs[qi]
        if job.state == DONE:
            assert spans == SPANS  # full gapless cover, each exactly once
            assert job.n_passed == ref.n_passed
            cols, _ = union_columns(job)
            for name in ref.output.branch_names():
                br = ref.output.branches[name]
                expect = (
                    ref.output.read_jagged(name)[0]
                    if br.jagged
                    else ref.output.read_flat(name)
                )
                np.testing.assert_array_equal(
                    cols.get(name, np.empty(0, expect.dtype)), expect
                )
        elif job.state == CANCELLED:
            assert spans == SPANS[: len(spans)]  # prefix, nothing skipped


def _random_actions(rng, n):
    actions = []
    for _ in range(n):
        r = rng.random()
        if r < 0.45:
            actions.append(("submit", rng.randrange(len(QUERIES))))
        elif r < 0.65:
            actions.append(("cancel", rng.randrange(8)))
        else:
            actions.append(("step", rng.randrange(1, 5)))
    return actions


@pytest.mark.parametrize("seed", range(12))
def test_random_interleavings(store, refs, seed):
    rng = random.Random(seed)
    svc = _run_interleaving(store, _random_actions(rng, rng.randrange(3, 14)))
    _check_invariants(svc, refs)


def test_interleaving_machine_exercises_every_op(store, refs):
    """One hand-picked script covering submit-while-running,
    cancel-while-running, and cancel-before-start in a single pass."""
    svc = _run_interleaving(
        store,
        [
            ("submit", 0),
            ("step", 2),  # job 1 starts, streams a window
            ("submit", 1),
            ("cancel", 0),  # cancel job 1 mid-stream
            ("submit", 0),
            ("cancel", 1),  # cancel job 2 before it ever runs
            ("step", 1),
        ],
    )
    states = sorted(j.state for j in svc.jobs.values())
    assert states == [CANCELLED, CANCELLED, DONE]
    _check_invariants(svc, refs)


# ---------------------------------------------------------------------------
# hypothesis-driven exploration (optional dependency)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # container doesn't ship hypothesis; seeded tests above still run
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    _action = st.one_of(
        st.tuples(st.just("submit"), st.integers(0, len(QUERIES) - 1)),
        st.tuples(st.just("cancel"), st.integers(0, 7)),
        st.tuples(st.just("step"), st.integers(1, 4)),
    )

    @given(actions=st.lists(_action, max_size=12))
    @settings(
        max_examples=25,
        deadline=None,
        derandomize=True,  # replayable in CI
        suppress_health_check=[HealthCheck.function_scoped_fixture],
    )
    def test_streamed_union_equals_sync_for_any_interleaving(
        store, refs, actions
    ):
        svc = _run_interleaving(store, actions)
        _check_invariants(svc, refs)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_streamed_union_equals_sync_for_any_interleaving():
        pass
