"""The compiled-artifact verifier (``repro.analysis.verify``).

Two directions, per invariant: the verifier must ACCEPT every
representative fixture query (the same corpus ``python -m tools.skimlint
--verify-fixtures`` drives), and it must REJECT hand-corrupted Programs
and SkimPlans with a typed :class:`VerifyError` naming the broken
invariant.  Plus the ``REPRO_VERIFY`` gating contract (explicit-string
env check, hooks fire only when on, off costs zero calls) and the pinned
regressions for the determinism fixes the lint rules surfaced.
"""

import dataclasses
import os
import sys
import threading
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from repro.analysis.verify import (  # noqa: E402
    CANONICAL_QUERY_FIELDS,
    VerifyError,
    program_reads,
    verify_cache_key_coverage,
    verify_enabled,
    verify_plan,
    verify_program,
)
from repro.core.expr import RPN_CONST  # noqa: E402
from repro.core.planner import plan_skim  # noqa: E402
from repro.core.query import parse_query  # noqa: E402
from repro.core.zonemap import WindowDecision  # noqa: E402
from repro.data.synth import make_nanoaod_like  # noqa: E402
from repro.kernels.predicate_eval import compile_query  # noqa: E402
from tools.skimlint.fixtures import (  # noqa: E402
    FIXTURE_QUERIES,
    FIXTURE_STORE,
    FIXTURE_WINDOW_EVENTS,
    verify_fixtures,
)

KITCHEN_SINK = next(d for d in FIXTURE_QUERIES if d["name"] == "kitchen-sink")


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(**FIXTURE_STORE)


@pytest.fixture(scope="module")
def query():
    return parse_query({k: v for k, v in KITCHEN_SINK.items() if k != "name"})


@pytest.fixture(scope="module")
def program(query):
    return compile_query(query)


@pytest.fixture()
def plan(query, store):
    # function-scoped: corruption tests mutate the plan in place
    return plan_skim(
        query, store, window_events=FIXTURE_WINDOW_EVENTS, prune=True, cascade=True
    )


# ---------------------------------------------------------------------------
# accept: the fixture corpus
# ---------------------------------------------------------------------------


def test_fixture_corpus_verifies_clean():
    assert verify_fixtures() == []


def test_program_reads_equal_stage_fetch_sets(plan, store):
    """The coverage invariant holds stage-by-stage on a live plan: the
    read set derived from the compiled sub-Program alone equals the fetch
    set the planner derived from the AST node."""
    assert plan.cascade is not None and plan.cascade.n_stages >= 4
    for stage in plan.cascade.stages:
        assert program_reads(stage.program, store) == set(stage.branches)


# ---------------------------------------------------------------------------
# reject: corrupted Programs
# ---------------------------------------------------------------------------


def _replace_group(program, g, **kw):
    groups = list(program.groups)
    groups[g] = dataclasses.replace(groups[g], **kw)
    return dataclasses.replace(program, groups=tuple(groups))


def _expr_group_index(program):
    return next(i for i, g in enumerate(program.groups) if g.rpn)


def _raises_invariant(fn, invariant):
    with pytest.raises(VerifyError) as exc:
        fn()
    assert exc.value.invariant == invariant
    assert invariant in str(exc.value)


def test_program_accepts_baseline(program):
    verify_program(program)


def test_rejects_out_of_range_term_slot(program):
    bad = _replace_group(program, 0, term_ids=(999,))
    _raises_invariant(lambda: verify_program(bad), "term-slot-bounds")


def test_rejects_unknown_group_kind(program):
    bad = _replace_group(program, 0, kind=42)
    _raises_invariant(lambda: verify_program(bad), "group-opcode")


def test_rejects_unknown_term_op(program):
    grp = program.groups[0]
    bad = _replace_group(program, 0, ops=(99,) * len(grp.ops))
    _raises_invariant(lambda: verify_program(bad), "group-opcode")


def test_rejects_group_wiring_length_mismatch(program):
    bad = dataclasses.replace(
        program, group_collections=program.group_collections[:-1]
    )
    _raises_invariant(lambda: verify_program(bad), "group-wiring")


def test_rejects_negative_min_count(program):
    count_g = next(
        i for i, g in enumerate(program.groups) if g.kind == 0 and g.min_count >= 0
    )
    bad = _replace_group(program, count_g, min_count=-1)
    _raises_invariant(lambda: verify_program(bad), "group-shape")


def test_rejects_unknown_rpn_opcode(program):
    g = _expr_group_index(program)
    rpn = list(program.groups[g].rpn)
    rpn[0] = (99, rpn[0][1])
    bad = _replace_group(program, g, rpn=tuple(rpn))
    _raises_invariant(lambda: verify_program(bad), "rpn-opcode")


def test_rejects_unbalanced_rpn(program):
    g = _expr_group_index(program)
    rpn = program.groups[g].rpn
    # an extra operand push leaves stack depth 2 at the end
    bad = _replace_group(program, g, rpn=rpn + ((RPN_CONST, 1.0),))
    _raises_invariant(lambda: verify_program(bad), "rpn-stack-balance")


def test_rejects_rpn_underflow(program):
    g = _expr_group_index(program)
    # binary op on a single-element stack
    from repro.core.expr import RPN_ADD

    bad = _replace_group(program, g, rpn=((RPN_CONST, 1.0), (RPN_ADD, 0), *[]))
    _raises_invariant(lambda: verify_program(bad), "rpn-stack-balance")


def test_rejects_non_finite_rpn_constant(program):
    g = _expr_group_index(program)
    rpn = ((RPN_CONST, float("nan")),)
    bad = _replace_group(program, g, rpn=rpn)
    _raises_invariant(lambda: verify_program(bad), "rpn-constant")


# ---------------------------------------------------------------------------
# reject: corrupted plans
# ---------------------------------------------------------------------------


def _replace_stage(plan, i, **kw):
    plan.cascade.stages[i] = dataclasses.replace(plan.cascade.stages[i], **kw)


def test_plan_accepts_baseline(plan, store):
    verify_plan(plan, store)


def test_rejects_missing_fetch_branch(plan, store):
    i = next(
        i for i, s in enumerate(plan.cascade.stages) if len(s.branches) > 1
    )
    _replace_stage(plan, i, branches=plan.cascade.stages[i].branches[:-1])
    _raises_invariant(lambda: verify_plan(plan, store), "stage-fetch-coverage")


def test_rejects_overfetched_branch(plan, store):
    stage = plan.cascade.stages[0]
    extra = next(
        b for b in store.branch_names() if b not in set(stage.branches)
    )
    _replace_stage(plan, 0, branches=stage.branches + (extra,))
    _raises_invariant(lambda: verify_plan(plan, store), "stage-fetch-coverage")


def test_rejects_unpinned_head(plan, store):
    order = plan.cascade.static_order
    assert len(order) >= 2
    plan.cascade.static_order = list(reversed(order))
    _raises_invariant(lambda: verify_plan(plan, store), "pinned-head")


def test_rejects_non_permutation_order(plan, store):
    plan.cascade.static_order = [0] * plan.cascade.n_stages
    _raises_invariant(lambda: verify_plan(plan, store), "pinned-head")


def test_rejects_bad_stage_prices(plan, store):
    _replace_stage(plan, 0, est_selectivity=1.5)
    _raises_invariant(lambda: verify_plan(plan, store), "stage-price")


def test_rejects_negative_stage_bytes(plan, store):
    _replace_stage(plan, 0, est_bytes=-1)
    _raises_invariant(lambda: verify_plan(plan, store), "stage-price")


def test_rejects_broken_branch_partition(plan, store):
    assert plan.output_only_branches  # phase 2 nonempty for this query
    plan.output_only_branches = plan.output_only_branches[:-1]
    _raises_invariant(lambda: verify_plan(plan, store), "plan-branch-partition")


def test_rejects_unknown_plan_branch(plan, store):
    plan.filter_branches = [*plan.filter_branches, "NoSuch_branch"]
    _raises_invariant(lambda: verify_plan(plan, store), "plan-branch-partition")


def test_rejects_non_tiling_window_decisions(plan, store):
    plan.window_decisions = [
        WindowDecision(0, store.n_events // 2, "scan", 0, 0, 0, 0)
    ]
    _raises_invariant(lambda: verify_plan(plan, store), "window-decisions")


# ---------------------------------------------------------------------------
# cache-key field coverage
# ---------------------------------------------------------------------------


def test_cache_key_coverage_accepts_current_query():
    verify_cache_key_coverage()


def test_rejects_unrecorded_cache_key_version(monkeypatch):
    from repro.cluster import cache

    monkeypatch.setattr(cache, "CACHE_KEY_VERSION", 99)
    _raises_invariant(verify_cache_key_coverage, "cache-key-version")


def test_rejects_new_query_field_without_version_bump(monkeypatch):
    """Simulate a Query field landing without a cache-key bump by
    shrinking the recorded field set for the current version."""
    from repro.cluster.cache import CACHE_KEY_VERSION

    recorded = CANONICAL_QUERY_FIELDS[CACHE_KEY_VERSION]
    monkeypatch.setitem(
        CANONICAL_QUERY_FIELDS, CACHE_KEY_VERSION, recorded - {"cascade"}
    )
    _raises_invariant(verify_cache_key_coverage, "cache-key-coverage")


# ---------------------------------------------------------------------------
# REPRO_VERIFY gating
# ---------------------------------------------------------------------------


def test_suite_runs_with_verification_on():
    """conftest defaults REPRO_VERIFY=1: every compile/plan in tier-1 is
    a verified compile/plan.  An explicit REPRO_VERIFY=0 (the documented
    overhead A/B, EXPERIMENTS.md) skips rather than fails — the guard is
    that conftest *sets* the default, not that nobody may override it."""
    assert os.environ.get("REPRO_VERIFY") is not None
    if not verify_enabled():
        pytest.skip("REPRO_VERIFY explicitly disabled for this run")


@pytest.mark.parametrize(
    "value,on",
    [
        ("1", True), ("true", True), ("on", True), ("TRUE", True),
        ("0", False), ("", False), ("false", False), ("off", False),
    ],
)
def test_verify_enabled_parses_explicitly(monkeypatch, value, on):
    """The gate must parse the string — `bool(\"0\")` is True in Python,
    so an implicit-truthiness gate would run verification under
    REPRO_VERIFY=0."""
    monkeypatch.setenv("REPRO_VERIFY", value)
    assert verify_enabled() is on


def test_verification_off_costs_zero_calls(monkeypatch, query, store):
    """With the gate off, the hooks never reach the verifier: the
    bench-smoke guarantee that REPRO_VERIFY=0 skims price verification
    at exactly zero."""
    import repro.analysis.verify as verify_mod

    calls = []
    monkeypatch.setattr(
        verify_mod, "verify_program", lambda p: calls.append("program")
    )
    monkeypatch.setattr(
        verify_mod, "verify_plan", lambda p, s: calls.append("plan")
    )
    monkeypatch.setenv("REPRO_VERIFY", "0")
    compile_query(query)
    plan_skim(query, store, window_events=FIXTURE_WINDOW_EVENTS, cascade=True)
    assert calls == []
    monkeypatch.setenv("REPRO_VERIFY", "1")
    compile_query(query)
    plan_skim(query, store, window_events=FIXTURE_WINDOW_EVENTS, cascade=True)
    assert "program" in calls and "plan" in calls


def test_hook_rejects_at_plan_time(monkeypatch, query, store):
    """A corrupted artifact fails at plan time, not mid-scan: break the
    cache-key record and the very next plan_skim refuses."""
    from repro.cluster import cache

    monkeypatch.setenv("REPRO_VERIFY", "1")
    monkeypatch.setattr(cache, "CACHE_KEY_VERSION", 99)
    with pytest.raises(VerifyError):
        plan_skim(query, store, window_events=FIXTURE_WINDOW_EVENTS, cascade=True)


# ---------------------------------------------------------------------------
# pinned regressions for the violations the lint rules surfaced
# ---------------------------------------------------------------------------


def test_query_hash_pinned_across_sort_keys_fix():
    """D003 fix (cluster/cache.py stage-sort key gained sort_keys=True):
    node docs are JSON *lists*, so the canonical form is byte-identical —
    this pin was recorded BEFORE the fix and must never drift, or every
    warm cache in the fleet silently misses."""
    from repro.cluster.cache import query_hash

    doc = {
        "branches": ["Electron_*", "MET_*", "HLT_*"],
        "selection": {
            "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
            "object": [
                {
                    "collection": "Electron",
                    "cuts": [
                        {"var": "pt", "op": ">", "value": 20.0},
                        {"var": "eta", "op": "abs<", "value": 2.4},
                    ],
                    "min_count": 1,
                }
            ],
            "event": [
                {
                    "type": "any",
                    "branches": ["HLT_IsoMu24", "HLT_Ele32_WPTight_Gsf"],
                },
                {"type": "cut", "branch": "MET_pt", "op": ">", "value": 40.0},
                {
                    "type": "mass",
                    "collections": ["Electron", "Electron"],
                    "window": [80.0, 100.0],
                },
                {
                    "type": "expr",
                    "expr": "MET_pt + 0.5*sum(Jet_pt)",
                    "op": ">",
                    "value": 150.0,
                },
            ],
        },
    }
    assert query_hash(doc) == (
        "387d94bbaa795809527acb5c08ba0a952ff8048eb5b85e27ad5a372bf6c729cc"
    )


def test_store_header_roundtrips_with_sorted_keys(tmp_path, store):
    """D003 fix (store save header json.dumps gained sort_keys=True):
    the header must round-trip and the manifest hash must not depend on
    dict insertion order."""
    from repro.data.store import EventStore

    import numpy as np

    path = tmp_path / "store.bin"
    store.save(str(path))
    loaded = EventStore.load(str(path))
    # loaded stores carry branches in sorted (canonical) order; the
    # content — names, manifest address, decoded values — is identical
    assert loaded.branch_names() == sorted(store.branch_names())
    assert set(loaded.branch_names()) == set(store.branch_names())
    assert loaded.manifest_hash() == store.manifest_hash()
    np.testing.assert_array_equal(
        loaded.read_flat("MET_pt"), store.read_flat("MET_pt")
    )
    v0, c0 = store.read_jagged("Electron_pt")
    v1, c1 = loaded.read_jagged("Electron_pt")
    np.testing.assert_array_equal(c1, c0)
    np.testing.assert_array_equal(v1, v0)


def test_service_error_is_typed(store):
    """D004 fix: quantum-budget exhaustion raises the typed ServiceError
    (still a RuntimeError for pre-existing callers)."""
    from repro.serve import ServiceError, SkimService

    assert issubclass(ServiceError, RuntimeError)
    svc = SkimService(store)
    svc.submit(
        {
            "branches": ["MET_pt"],
            "selection": {
                "preselection": [{"branch": "MET_pt", "op": ">", "value": 10.0}]
            },
        }
    )
    with pytest.raises(ServiceError, match="still busy after 1 quanta"):
        svc.run_until_idle(max_quanta=1)


def test_batch_scatter_threads_are_named(store):
    """D005 fix: the tenant-batch scatter pool carries the skim-* thread
    naming convention (PR 8), so profiles/stack dumps attribute its work."""
    from repro.cluster import StorageNode, build_cluster

    coord = build_cluster(store, 2, replication=False)
    coord.concurrency = "threads"
    seen = []
    orig = StorageNode.execute_batch

    def spy(self, queries):
        seen.append(threading.current_thread().name)
        return orig(self, queries)

    StorageNode.execute_batch = spy
    try:
        coord.run_batch(
            [
                {
                    "branches": ["MET_pt"],
                    "selection": {
                        "preselection": [
                            {"branch": "MET_pt", "op": ">", "value": 10.0}
                        ]
                    },
                }
            ]
        )
    finally:
        StorageNode.execute_batch = orig
    assert seen and all(n.startswith("skim-batch") for n in seen)
