"""Async skim job service (ISSUE 6 / DESIGN.md §12).

Pins the tentpole contracts on the deterministic harness — injectable
:class:`ManualClock` + single-threaded :class:`DeterministicExecutor`,
no wall-clock sleeps anywhere:

  * lifecycle: submit → PENDING → RUNNING → streamed partials → DONE,
    every transition stamped by the injected clock;
  * streaming: the union of a completed job's window-granular partials
    is bit-identical to the synchronous ``run_skim`` result, each window
    streamed exactly once;
  * scheduling: per-tenant FIFO, weighted-fair across tenants (cheap
    queries are never head-of-line blocked by expensive ones), replays
    identically;
  * admission: over-quota submissions are REJECTED with the plan-priced
    estimate attached and provably zero bytes fetched;
  * cancellation: cooperative at window boundaries, streamed partials
    kept, batch members cancel without aborting the shared pass;
  * batching: coalesced shared-scan jobs finish bit-identical to solo
    runs and to ``SharedScanEngine.run_batch``;
  * faults: a cluster node failure FAILs the job with a cause and the
    queue keeps draining.
"""

import numpy as np
import pytest

from repro.cluster import build_cluster
from repro.core.engine import run_skim
from repro.data.synth import make_nanoaod_like
from repro.serve import (
    CANCELLED,
    DONE,
    FAILED,
    PENDING,
    REJECTED,
    RUNNING,
    ClusterBackend,
    ManualClock,
    SharedScanEngine,
    SkimService,
    TenantQuota,
    union_columns,
)
from tests.test_query import QUERY

N_EVENTS = 10_000
BASKET = 2048
N_WINDOWS = 5  # ceil(N_EVENTS / BASKET)

#: a second tenant's (compatible) query: same shape, tighter MET cut
QUERY_B = {
    **QUERY,
    "selection": {
        **QUERY["selection"],
        "event": [
            {"type": "any", "branches": ["HLT_IsoMu24"]},
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 35.0},
        ],
    },
}


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(
        N_EVENTS, n_hlt=16, n_filler=8, basket_events=BASKET
    )


@pytest.fixture(scope="module")
def ref(store):
    return run_skim(store, QUERY, mode="near_data")


@pytest.fixture(scope="module")
def ref_b(store):
    return run_skim(store, QUERY_B, mode="near_data")


def _assert_union_matches(job, ref):
    """The streaming contract: branch-wise union of streamed partials
    equals the synchronous output bit-for-bit."""
    cols, jagged = union_columns(job)
    assert job.n_passed == ref.n_passed
    for name in ref.output.branch_names():
        br = ref.output.branches[name]
        if br.jagged:
            v0, _ = ref.output.read_jagged(name)
            np.testing.assert_array_equal(cols[name], v0)
        else:
            np.testing.assert_array_equal(
                cols[name], ref.output.read_flat(name)
            )


def _assert_result_matches(res, ref):
    assert res.n_passed == ref.n_passed
    assert res.output.compressed_bytes() == ref.output.compressed_bytes()
    for name in ref.output.branch_names():
        br = ref.output.branches[name]
        if br.jagged:
            v0, c0 = ref.output.read_jagged(name)
            v1, c1 = res.output.read_jagged(name)
            np.testing.assert_array_equal(c1, c0)
            np.testing.assert_array_equal(v1, v0)
        else:
            np.testing.assert_array_equal(
                res.output.read_flat(name), ref.output.read_flat(name)
            )


# ---------------------------------------------------------------------------
# lifecycle + streaming
# ---------------------------------------------------------------------------


def test_lifecycle_and_clock(store, ref):
    clock = ManualClock()
    svc = SkimService(store, clock=clock)
    clock.advance(5.0)
    job = svc.submit(QUERY, tenant="alice")
    assert job.state == PENDING
    assert job.submitted_at == 5.0
    assert job.started_at is None
    assert job.estimate is not None and job.estimate.est_bytes > 0

    clock.advance(1.0)
    assert svc.step()  # first quantum starts the job
    assert job.state == RUNNING
    assert job.started_at == 6.0

    clock.advance(2.0)
    svc.run_until_idle()
    assert job.state == DONE
    assert job.finished_at == 8.0
    assert job.result is not None
    _assert_result_matches(job.result, ref)


def test_streamed_union_bit_identical(store, ref):
    svc = SkimService(store)
    job = svc.submit(QUERY)
    parts = list(svc.stream(job.job_id))
    assert job.state == DONE
    assert len(parts) == N_WINDOWS
    _assert_union_matches(job, ref)
    # the job's ledger is the engine's, exposed per job
    assert job.stats.bytes_fetched == ref.stats.bytes_fetched
    assert job.stats.requests == ref.stats.requests


def test_each_window_streamed_exactly_once(store):
    svc = SkimService(store)
    job = svc.submit(QUERY)
    svc.result(job.job_id)
    spans = job.windows_streamed()
    assert spans == sorted(spans)
    assert len(spans) == len(set(spans)) == N_WINDOWS
    # gapless cover of the event range
    assert spans[0][0] == 0 and spans[-1][1] == store.n_events
    for (_, stop), (start, _) in zip(spans, spans[1:]):
        assert start == stop


# ---------------------------------------------------------------------------
# scheduling: FIFO, weighted fairness, deterministic replay
# ---------------------------------------------------------------------------


def test_same_tenant_fifo(store):
    svc = SkimService(store)
    j1 = svc.submit(QUERY, "t")
    j2 = svc.submit(QUERY, "t")
    assert j1.vfinish < j2.vfinish  # backlog continues, never overtakes
    svc.run_until_idle()
    order = [picked for _, picked, _ in svc.trace]
    assert order.index(j2.job_id) > max(
        i for i, p in enumerate(order) if p == j1.job_id
    )


def test_cheap_query_not_head_of_line_blocked(store):
    """A cheap query submitted AFTER two expensive ones must run to
    completion before the second expensive one ever starts."""
    cheap = {
        "input": "in.skim",
        "output": "out.skim",
        "branches": ["nMuon"],
        "selection": {
            "preselection": [{"branch": "nMuon", "op": ">=", "value": 100}]
        },
    }
    svc = SkimService(store)
    big1 = svc.submit(QUERY, "heavy")
    big2 = svc.submit(QUERY, "heavy")
    small = svc.submit(cheap, "light")
    assert small.vfinish < big2.vfinish
    svc.run_until_idle()
    order = [picked for _, picked, _ in svc.trace]
    assert order.index(small.job_id) < order.index(big2.job_id)
    assert all(j.state == DONE for j in (big1, big2, small))


def test_weight_scales_fair_share(store):
    """Same backlog, but the weighted tenant's virtual finish shrinks
    by its weight — a weight-4 tenant schedules 4x earlier."""
    sv_flat = SkimService(store)
    sv_wtd = SkimService(store, quotas={"t": TenantQuota(weight=4.0)})
    j_flat = sv_flat.submit(QUERY, "t")
    j_wtd = sv_wtd.submit(QUERY, "t")
    assert j_wtd.vfinish == pytest.approx(j_flat.vfinish / 4.0)


def test_deterministic_replay(store):
    def run_once():
        svc = SkimService(store)
        svc.submit(QUERY, "a")
        svc.submit(QUERY_B, "b")
        svc.submit(QUERY, "a")
        svc.run_until_idle()
        return svc.trace

    assert run_once() == run_once()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_over_quota_rejected_without_fetching(store):
    fetches = []
    orig = store.fetch_window

    def spy(*args, **kwargs):
        fetches.append(args)
        return orig(*args, **kwargs)

    store.fetch_window = spy
    try:
        svc = SkimService(
            store, quotas={"bob": TenantQuota(byte_budget=10.0)}
        )
        job = svc.submit(QUERY, tenant="bob")
    finally:
        store.fetch_window = orig
    assert job.state == REJECTED
    assert fetches == []  # pricing is metadata-only
    assert job.stats.bytes_fetched == 0 and job.stats.requests == 0
    # the priced estimate is attached and explains the rejection
    assert job.estimate is not None
    assert "over byte quota" in job.error
    assert f"priced {job.estimate.est_bytes}" in job.error
    # rejected jobs never enter the queue
    assert svc.queue_depth() == 0 and not svc.step()


def test_wall_clock_quota(store):
    svc = SkimService(store, quotas={"t": TenantQuota(wall_budget_s=1e-9)})
    job = svc.submit(QUERY, "t")
    assert job.state == REJECTED and "over wall-clock quota" in job.error


def test_done_jobs_charge_observed_bytes(store, ref):
    # budget fits one run's estimate but not two runs' observed spend
    budget = ref.stats.bytes_fetched * 1.2
    svc = SkimService(store, quotas={"t": TenantQuota(byte_budget=budget)})
    j1 = svc.submit(QUERY, "t")
    assert j1.state == PENDING
    svc.run_until_idle()
    assert j1.state == DONE
    usage = svc.tenant_usage("t")
    assert usage["spent_bytes"] == ref.stats.bytes_fetched
    assert usage["reserved_bytes"] == 0  # reservation released on settle
    j2 = svc.submit(QUERY, "t")  # spent + new estimate now exceeds budget
    assert j2.state == REJECTED


def test_malformed_query_rejected_at_the_door(store):
    svc = SkimService(store)
    job = svc.submit(
        {
            "branches": ["event"],
            "selection": {
                "preselection": [
                    {"branch": "NoSuchBranch", "op": ">", "value": 1}
                ]
            },
        }
    )
    assert job.state == REJECTED
    assert "unpriceable query" in job.error


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------


def test_cancel_pending_job(store):
    svc = SkimService(store)
    j1 = svc.submit(QUERY, "a")
    j2 = svc.submit(QUERY, "b")
    assert svc.cancel(j2.job_id)
    assert j2.state == CANCELLED and j2.partials == []
    svc.run_until_idle()
    assert j1.state == DONE
    assert not svc.cancel(j2.job_id)  # already terminal


def test_cancel_mid_stream_keeps_partials(store):
    svc = SkimService(store)
    job = svc.submit(QUERY)
    stream = svc.stream(job.job_id)
    got = [next(stream), next(stream)]
    svc.cancel(job.job_id)
    assert list(stream) == []  # stream ends at the window boundary
    assert job.state == CANCELLED
    assert job.partials == got and len(got) == 2
    assert job.result is None
    # the service is idle again: nothing left to run
    assert not svc.step()


# ---------------------------------------------------------------------------
# batching mode
# ---------------------------------------------------------------------------


def test_batch_coalesced_bit_identical(store, ref, ref_b):
    svc = SkimService(store, batching=True)
    j1 = svc.submit(QUERY, "a")
    j2 = svc.submit(QUERY_B, "b")
    svc.run_until_idle()
    assert j1.state == DONE and j2.state == DONE
    # one coalesced run unit served both jobs: every quantum lists both
    assert all(members == (1, 2) for _, _, members in svc.trace)
    _assert_result_matches(j1.result, ref)
    _assert_result_matches(j2.result, ref_b)
    _assert_union_matches(j1, ref)
    _assert_union_matches(j2, ref_b)
    # and matches the synchronous shared-scan batch exactly
    batch = SharedScanEngine(store).run_batch([QUERY, QUERY_B])
    _assert_result_matches(batch.results[0], ref)
    _assert_result_matches(batch.results[1], ref_b)


def test_batch_member_cancel_keeps_shared_pass(store, ref):
    svc = SkimService(store, batching=True)
    j1 = svc.submit(QUERY, "a")
    j2 = svc.submit(QUERY_B, "b")
    svc.step()  # starts the coalesced pass, streams window 0 to both
    assert j1.state == RUNNING and j2.state == RUNNING
    svc.cancel(j2.job_id)
    svc.run_until_idle()
    assert j2.state == CANCELLED and len(j2.partials) == 1
    # the surviving member finished bit-identically on the shared pass
    assert j1.state == DONE
    _assert_result_matches(j1.result, ref)
    _assert_union_matches(j1, ref)


# ---------------------------------------------------------------------------
# cluster backend: streaming, bit-identity, failure injection
# ---------------------------------------------------------------------------


def test_cluster_backend_bit_identical(store, ref):
    coord = build_cluster(store, 3)
    svc = SkimService(ClusterBackend(coord))
    job = svc.submit(QUERY)
    assert job.estimate.est_bytes > 0  # priced across all shards
    svc.run_until_idle()
    assert job.state == DONE
    # one shard-granular partial per shard, in shard order
    assert [p.meta["window"] for p in job.partials] == [0, 1, 2]
    assert sum(p.n_passed for p in job.partials) == ref.n_passed
    _assert_result_matches(job.result, ref)


def test_cluster_node_fault_fails_job_queue_drains(store, ref):
    coord = build_cluster(store, 3, replication=False)
    coord.nodes[1].inject_fault("fail")  # one-shot: only the first job hits it
    svc = SkimService(ClusterBackend(coord))
    j1 = svc.submit(QUERY, "a")
    j2 = svc.submit(QUERY, "b")
    svc.run_until_idle()
    assert j1.state == FAILED
    assert "shard 1" in j1.error and "no replica" in j1.error
    assert j1.result is None
    # the queue kept draining past the failure
    assert j2.state == DONE
    _assert_result_matches(j2.result, ref)
