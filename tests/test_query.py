import logging

import numpy as np
import pytest

from repro.core.branchmap import expand_branches, register_minimal_set
from repro.core.planner import plan_skim
from repro.core.query import Cut, eval_node, eval_stage, parse_query
from repro.data.synth import make_nanoaod_like

QUERY = {
    "input": "in.skim",
    "output": "out.skim",
    "branches": [
        "Electron_*", "Muon_*", "Jet_*", "MET_*", "HLT_*", "Filler_*",
        "PV_npvs", "run", "event", "luminosityBlock",
    ],
    "selection": {
        "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                ],
                "min_count": 1,
            }
        ],
        "event": [
            {
                "type": "ht",
                "collection": "Jet",
                "var": "pt",
                "object_cuts": [{"var": "pt", "op": ">", "value": 30.0}],
                "op": ">",
                "value": 100.0,
            },
            {"type": "any", "branches": ["HLT_IsoMu24"]},
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 20.0},
        ],
    },
}


def test_parse_structure():
    q = parse_query(QUERY)
    assert len(q.preselection) == 1
    assert len(q.object_stage) == 1
    assert len(q.event_stage) == 3
    fb = q.filter_branches()
    assert "Electron_pt" in fb and "nElectron" in fb and "MET_pt" in fb
    assert "Jet_pt" in fb and "HLT_IsoMu24" in fb


def test_eval_cut_matches_numpy():
    data = {"MET_pt": np.array([10.0, 25.0, 50.0])}
    mask = eval_node(Cut("MET_pt", ">", 20.0), data)
    np.testing.assert_array_equal(mask, [False, True, True])


def test_object_selection_jagged():
    q = parse_query(QUERY)
    # 3 events: [no electrons], [1 passing], [2, one fails eta]
    data = {
        "nElectron": np.array([0, 1, 2]),
        "Electron_pt": np.array([25.0, 30.0, 40.0]),
        "Electron_eta": np.array([1.0, 3.0, -1.0]),
    }
    mask = eval_node(q.object_stage[0], data)
    np.testing.assert_array_equal(mask, [False, True, True])


def test_ht_cut():
    q = parse_query(QUERY)
    ht_node = q.event_stage[0]
    data = {
        "nJet": np.array([2, 1]),
        "Jet_pt": np.array([80.0, 50.0, 90.0]),
    }
    # event0: 80+50=130 > 100 True; event1: 90 < 100 False
    np.testing.assert_array_equal(eval_node(ht_node, data), [True, False])


def test_stage_and_semantics():
    q = parse_query(QUERY)
    data = {
        "MET_pt": np.array([30.0, 30.0]),
        "HLT_IsoMu24": np.array([True, False]),
        "nJet": np.array([1, 1]),
        "Jet_pt": np.array([200.0, 200.0]),
    }
    mask = eval_stage(q.event_stage, data, 2)
    np.testing.assert_array_equal(mask, [True, False])


def test_branchmap_minimal_set(caplog):
    avail = [*(f"HLT_path{i:03d}" for i in range(20)), "HLT_IsoMu24", "MET_pt"]
    with caplog.at_level(logging.WARNING, logger="repro.branchmap"):
        sel, excl = expand_branches(["HLT_*", "MET_pt"], avail)
    assert sel == ["HLT_IsoMu24", "MET_pt"]
    assert len(excl) == 20
    assert any("excluded by optimization" in r.message for r in caplog.records)


def test_branchmap_force_all():
    avail = [*(f"HLT_path{i:03d}" for i in range(20)), "HLT_IsoMu24"]
    sel, excl = expand_branches(["HLT_*"], avail, force_all=True)
    assert len(sel) == 21 and not excl


def test_register_minimal_set():
    register_minimal_set("Trig_*", ("Trig_A",))
    sel, excl = expand_branches(["Trig_*"], ["Trig_A", "Trig_B"])
    assert sel == ["Trig_A"] and excl == ["Trig_B"]


def test_plan_two_phase_split():
    store = make_nanoaod_like(2000, n_hlt=16, n_filler=4)
    q = parse_query(QUERY)
    plan = plan_skim(q, store)
    # filter branches are the paper's O(10) set
    assert 5 <= len(plan.filter_branches) <= 15
    # output includes Electron_* group + counts + filter extras
    assert "Electron_phi" in plan.output_branches
    assert set(plan.output_only_branches).isdisjoint(plan.filter_branches)
    assert plan.excluded_by_optimization  # HLT_* was reduced


def test_unknown_branch_raises():
    store = make_nanoaod_like(100, n_hlt=4)
    bad = dict(QUERY)
    bad["selection"] = {
        "preselection": [{"branch": "NoSuchBranch", "op": ">", "value": 0}]
    }
    with pytest.raises(KeyError):
        plan_skim(parse_query(bad), store)
