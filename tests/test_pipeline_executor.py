"""Pipelined/fused near-data executor vs the reference two-pass path.

Pins the tentpole contracts:

  * fused (host / xla / pallas backends) == unfused: same survivor sets,
    same output payload rows, bit-identical,
  * pipelined == serial: identical FetchStats (bytes, requests,
    per-branch accounting) — the schedule must not change the byte model,
  * the modeled double-buffered makespan never exceeds the serial sum,
  * shared-scan batch == per-query individual runs, with phase-1 byte
    amortization across overlapping tenants.
"""

import numpy as np
import pytest

from repro.core.engine import LOCAL_DISK, SkimEngine, run_skim
from repro.core.neardata import fused_window_skim, program_eval_np
from repro.core.planner import plan_skim
from repro.core.query import eval_stage, parse_query
from repro.data.store import WindowPrefetcher
from repro.data.synth import make_nanoaod_like
from repro.serve.engine import SharedScanEngine
from tests.test_query import QUERY


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(10_000, n_hlt=16, n_filler=8, basket_events=2048)


@pytest.fixture(scope="module")
def reference(store):
    return run_skim(store, QUERY, mode="near_data", fused=False, pipeline=False)


def _assert_same_output(res, ref):
    assert res.n_passed == ref.n_passed
    for name in ref.output.branch_names():
        br = ref.output.branches[name]
        if br.jagged:
            v0, c0 = ref.output.read_jagged(name)
            v1, c1 = res.output.read_jagged(name)
            np.testing.assert_array_equal(c1, c0)
            np.testing.assert_array_equal(v1, v0)
        else:
            np.testing.assert_array_equal(
                res.output.read_flat(name), ref.output.read_flat(name)
            )


# ---------------------------------------------------------------------------
# fused-vs-unfused equivalence
# ---------------------------------------------------------------------------


def test_fused_matches_reference_bit_identical(store, reference):
    res = run_skim(store, QUERY, mode="near_data", fused=True, pipeline=False)
    _assert_same_output(res, reference)


def test_fused_pipelined_matches_reference(store, reference):
    res = run_skim(store, QUERY, mode="near_data", fused=True, pipeline=True)
    _assert_same_output(res, reference)


def test_fused_threaded_prefetch_matches_reference(store, reference):
    res = run_skim(store, QUERY, mode="near_data", fused=True, pipeline="threads")
    _assert_same_output(res, reference)


@pytest.mark.parametrize("backend", ["host", "xla", "pallas"])
def test_fused_window_backends_agree(store, backend):
    """Every fused backend reproduces the host evaluator's mask and
    compacted payload on a decoded window."""
    q = parse_query(QUERY)
    plan = plan_skim(q, store)
    program = plan.compiled_program()
    data = {}
    for b in plan.filter_branches:
        br = store.branches[b]
        data[b] = store.read_jagged(b)[0] if br.jagged else store.read_flat(b)
    n = store.n_events

    want = np.ones(n, dtype=bool)
    for _, stage in q.stages():
        want &= eval_stage(stage, data, n)

    mask, cols = fused_window_skim(
        data, program, store,
        payload_branches=plan.payload_branches, backend=backend,
    )
    np.testing.assert_array_equal(mask, want)
    for name in plan.payload_branches:
        np.testing.assert_array_equal(cols[name], np.asarray(data[name])[want])


def test_program_interpreter_matches_staged_evaluator(store):
    """The compiled-program host interpreter == the staged AST evaluator
    on several query shapes (flat cut, trigger OR, object, HT)."""
    queries = [
        {"branches": ["MET_*"], "selection": {
            "preselection": [{"branch": "MET_pt", "op": ">", "value": 30.0}]}},
        {"branches": ["MET_*"], "selection": {
            "event": [{"type": "any",
                       "branches": ["HLT_IsoMu24", "HLT_Ele32_WPTight_Gsf"]}]}},
        {"branches": ["Jet_*"], "selection": {
            "object": [{"collection": "Jet",
                        "cuts": [{"var": "pt", "op": ">", "value": 25.0}],
                        "min_count": 2}]}},
        {"branches": ["Jet_*"], "selection": {
            "event": [{"type": "ht", "collection": "Jet", "var": "pt",
                       "object_cuts": [{"var": "pt", "op": ">", "value": 30.0}],
                       "op": ">", "value": 100.0}]}},
    ]
    for doc in queries:
        q = parse_query(doc)
        plan = plan_skim(q, store)
        data = {}
        for b in plan.filter_branches:
            br = store.branches[b]
            data[b] = store.read_jagged(b)[0] if br.jagged else store.read_flat(b)
        n = store.n_events
        want = np.ones(n, dtype=bool)
        for _, stage in q.stages():
            want &= eval_stage(stage, data, n)
        got = program_eval_np(data, plan.compiled_program(), n)
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# pipelined-vs-serial accounting invariance
# ---------------------------------------------------------------------------


def _stats_tuple(stats):
    return stats.bytes_fetched, stats.requests, dict(stats.by_branch)


@pytest.mark.parametrize("pipeline", [True, "threads"])
def test_pipelined_fetchstats_invariant(store, pipeline):
    serial = run_skim(store, QUERY, mode="near_data", fused=True, pipeline=False)
    piped = run_skim(store, QUERY, mode="near_data", fused=True, pipeline=pipeline)
    assert _stats_tuple(piped.stats) == _stats_tuple(serial.stats)


def test_pipeline_makespan_bounded(store):
    """Exact double-buffered schedule: never worse than the serial sum,
    never better than its compute component alone."""
    eng = SkimEngine(store, near_input_link=LOCAL_DISK)
    res = eng.run(QUERY, "near_data", fused=True, pipeline=True)
    serial_sum = res.breakdown.total()
    pipe = res.extras["pipeline_total"]
    # the schedule can only hide work, never invent it: bounded above by
    # the serial sum, below by the unoverlappable tail
    assert pipe <= serial_sum + 1e-9
    assert pipe >= res.breakdown.write + res.breakdown.output_transfer
    assert pipe > 0


def test_window_prefetcher_zero_event_dataset():
    """A zero-window dataset yields nothing, threaded or serial, and never
    invokes the loader."""
    calls = []
    for enabled in (False, True):
        pf = WindowPrefetcher(0, 1024, lambda s, e: calls.append((s, e)),
                              enabled=enabled)
        assert pf.windows() == []
        assert list(pf) == []
    assert calls == []


def test_window_prefetcher_single_window():
    for enabled in (False, True):
        got = list(WindowPrefetcher(100, 1024, lambda s, e: (s, e),
                                    enabled=enabled))
        assert got == [(0, 100, (0, 100))]


def test_window_prefetcher_depth_exceeds_window_count():
    """depth > #windows must not duplicate, drop, or reorder windows."""
    loads = []

    def load(start, stop):
        loads.append((start, stop))
        return start

    got = list(WindowPrefetcher(5_000, 2_000, load, depth=16, enabled=True))
    assert [(s, e) for s, e, _ in got] == [(0, 2000), (2000, 4000), (4000, 5000)]
    assert [p for _, _, p in got] == [0, 2000, 4000]
    assert sorted(loads) == [(0, 2000), (2000, 4000), (4000, 5000)]


@pytest.mark.parametrize("enabled", [False, True])
def test_window_prefetcher_worker_exception_propagates(enabled):
    """A loader crash surfaces to the consumer (not swallowed in the
    worker thread), whichever schedule runs it."""

    def load(start, stop):
        if start >= 4_000:
            raise RuntimeError("basket decode blew up")
        return start

    pf = WindowPrefetcher(10_000, 2_000, load, enabled=enabled)
    got = []
    with pytest.raises(RuntimeError, match="basket decode blew up"):
        for start, _, _ in pf:
            got.append(start)
    # the windows before the crash were delivered in order
    assert got == [0, 2000]


def test_window_prefetcher_rejects_bad_window_size():
    with pytest.raises(ValueError, match="window_events"):
        WindowPrefetcher(100, 0, lambda s, e: None)


def test_window_prefetcher_order_and_coverage():
    """The prefetcher yields every window exactly once, in order, with
    identical payloads whether threaded or serial."""
    loads: list[tuple[int, int]] = []

    def load(start, stop):
        loads.append((start, stop))
        return start * 1000 + stop

    serial = list(WindowPrefetcher(10_000, 3_000, load, enabled=False))
    loads_serial, loads[:] = list(loads), []
    threaded = list(WindowPrefetcher(10_000, 3_000, load, enabled=True))
    assert loads_serial == sorted(loads)
    assert serial == threaded
    assert [(s, e) for s, e, _ in serial] == [
        (0, 3000), (3000, 6000), (6000, 9000), (9000, 10000)
    ]
    assert [p for _, _, p in serial] == [3000, 3006000, 6009000, 9010000]


# ---------------------------------------------------------------------------
# shared-scan batch mode
# ---------------------------------------------------------------------------


def _tenant(extra: dict) -> dict:
    return {
        "branches": ["Electron_*", "Muon_*", "MET_*"],
        "selection": {
            "preselection": [{"branch": "MET_pt", "op": ">", "value": 20.0}],
            "event": [{"type": "any", "branches": ["HLT_IsoMu24"]}],
            **extra,
        },
    }


@pytest.fixture(scope="module")
def tenants():
    return [
        _tenant({"object": [{"collection": "Electron",
                             "cuts": [{"var": "pt", "op": ">", "value": 20.0}]}]}),
        _tenant({"object": [{"collection": "Muon",
                             "cuts": [{"var": "pt", "op": ">", "value": 15.0}]}]}),
        _tenant({}),
    ]


def test_shared_scan_matches_individual_runs(store, tenants):
    batch = SharedScanEngine(store).run_batch(tenants)
    eng = SkimEngine(store)
    assert batch.n_queries == len(tenants)
    for q, res in zip(tenants, batch.results):
        solo = eng.run(q, "near_data")
        _assert_same_output(res, solo)


def test_shared_scan_amortizes_phase1_bytes(store, tenants):
    batch = SharedScanEngine(store).run_batch(tenants)
    # one scan of the union must beat N scans of the parts
    assert batch.shared_stats.bytes_fetched < batch.naive_phase1_bytes
    assert batch.amortization > 1.5
    assert batch.saved_bytes == (
        batch.naive_phase1_bytes - batch.shared_stats.bytes_fetched
    )


def test_selection_free_query_all_paths(store):
    """A query with no selection (pure projection) must pass every event
    through every executor, including the fused default and shared scan."""
    q = {"branches": ["MET_*"], "selection": {}}
    ref = run_skim(store, q, mode="near_data", fused=False, pipeline=False)
    assert ref.n_passed == store.n_events
    for kw in (dict(fused=True, pipeline=False), dict(fused=True, pipeline=True),
               dict(fused=True, pipeline="threads")):
        res = run_skim(store, q, mode="near_data", **kw)
        _assert_same_output(res, ref)
    batch = SharedScanEngine(store).run_batch([q])
    _assert_same_output(batch.results[0], ref)


def test_invalid_pipeline_value_rejected(store):
    with pytest.raises(ValueError, match="pipeline"):
        SkimEngine(store).run(QUERY, "near_data", pipeline="bogus")


def test_shared_scan_single_query_degenerates(store):
    """A batch of one tenant behaves like the plain engine."""
    batch = SharedScanEngine(store).run_batch([QUERY])
    solo = SkimEngine(store).run(QUERY, "near_data")
    _assert_same_output(batch.results[0], solo)
    assert batch.amortization == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# late-growing pad_K regression
# ---------------------------------------------------------------------------


def _ragged_store_and_query(peak_window: int):
    """3-window store whose max object multiplicity (9 -> pad_K 16) first
    appears in window ``peak_window``; every other event has <= 2."""
    from repro.data.store import EventStore

    rng = np.random.default_rng(5)
    chunk, n = 256, 3 * 256
    counts = rng.integers(0, 3, n).astype(np.int32)
    lo = peak_window * chunk
    counts[lo + 7 : lo + 10] = 9
    total = int(counts.sum())
    columns = {
        "nObj": counts,
        "Obj_pt": rng.exponential(30.0, total).astype(np.float32),
        "met": rng.normal(30.0, 10.0, n).astype(np.float32),
    }
    store = EventStore.from_arrays(
        columns, jagged={"Obj_pt": "nObj"}, basket_events=chunk
    )
    query = {
        "branches": ["met", "Obj_*"],
        "selection": {
            "object": [{"collection": "Obj",
                        "cuts": [{"var": "pt", "op": ">", "value": 25.0}],
                        "min_count": 2}],
            "event": [{"type": "expr", "expr": "met + 0.1*sum(Obj_pt)",
                       "op": ">", "value": 25.0}],
        },
    }
    return store, query, chunk


@pytest.mark.parametrize("peak_window", [0, 1, 2])
def test_late_growing_pad_k_engine_bit_identical(peak_window):
    """A window late in the file with the max multiplicity must not
    mis-pad earlier or later windows, wherever the peak lands."""
    store, query, _ = _ragged_store_and_query(peak_window)
    ref = run_skim(store, query, mode="near_data", fused=False,
                   pipeline=False, prune=False)
    assert 0 < ref.n_passed < store.n_events
    res = run_skim(store, query, mode="near_data", fused=True,
                   pipeline=False, prune=False)
    _assert_same_output(res, ref)


@pytest.mark.parametrize("peak_window", [1, 2])
def test_late_growing_pad_k_device_windows(peak_window):
    """The engine's monotonic pad_K growth on the padded device backend:
    early windows evaluate at the small K, the peak window forces the
    jump, later windows run wider than they need — every mask must match
    the staged evaluator, and K must grow exactly once."""
    from repro.core.neardata import window_pad_K

    store, query, chunk = _ragged_store_and_query(peak_window)
    q = parse_query(query)
    plan = plan_skim(q, store)
    program = plan.compiled_program()
    pad_K, seen_K = 0, []
    for start in range(0, store.n_events, chunk):
        stop = min(start + chunk, store.n_events)
        data = {
            "met": store.read_flat("met", start, stop),
            "nObj": store.read_flat("nObj", start, stop),
            "Obj_pt": store.read_jagged("Obj_pt", start, stop)[0],
        }
        pad_K = max(pad_K, window_pad_K(data, program, store))
        seen_K.append(pad_K)
        mask, _ = fused_window_skim(
            data, program, store, K=pad_K, pad_to=chunk, backend="xla"
        )
        want = np.ones(stop - start, dtype=bool)
        for _, stage in q.stages():
            want &= eval_stage(stage, data, stop - start)
        np.testing.assert_array_equal(mask, want, err_msg=f"window {start}")
    # one growth step: 2 -> 16 at the peak window, stable afterwards
    assert seen_K[peak_window:] == [16] * (3 - peak_window)
    assert all(k == 2 for k in seen_K[:peak_window])
