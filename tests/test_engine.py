import numpy as np
import pytest

from repro.core.engine import (
    LAN_100G,
    WAN_1G,
    NetworkModel,
    SkimEngine,
    run_skim,
)
from repro.data.synth import make_nanoaod_like
from tests.test_query import QUERY

MODES = ["client_plain", "client_opt", "server_side", "near_data"]


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(12_000, n_hlt=16, n_filler=8, basket_events=2048)


@pytest.fixture(scope="module")
def results(store):
    return {m: run_skim(store, QUERY, mode=m) for m in MODES}


def test_all_modes_agree_on_selection(results):
    counts = {m: r.n_passed for m, r in results.items()}
    assert len(set(counts.values())) == 1, counts
    ref = results["client_plain"].output.read_flat("event")
    for m in MODES[1:]:
        np.testing.assert_array_equal(
            results[m].output.read_flat("event"), ref
        )


def test_outputs_identical_jagged(results):
    v0, c0 = results["client_plain"].output.read_jagged("Electron_pt")
    for m in MODES[1:]:
        v, c = results[m].output.read_jagged("Electron_pt")
        np.testing.assert_array_equal(c, c0)
        np.testing.assert_allclose(v, v0)


def test_two_phase_reduces_deserialize(results):
    """Paper Fig. 4b: Client Opt's gain is deserialize (240.4s -> 16.8s);
    basket fetch stays — every basket holding >=1 survivor still moves."""
    b_plain = results["client_plain"].breakdown
    b_opt = results["client_opt"].breakdown
    assert b_opt.deserialize < 0.2 * b_plain.deserialize


def test_two_phase_skips_empty_baskets(store):
    """With a selective-enough cut, whole baskets have no survivors and
    their output-only branches never move (byte savings appear)."""
    harsh = {
        "branches": ["Electron_*", "Jet_*", "Filler_*", "MET_*"],
        "selection": {
            "preselection": [{"branch": "MET_pt", "op": ">", "value": 250.0}]
        },
    }
    plain = run_skim(store, harsh, mode="client_plain")
    opt = run_skim(store, harsh, mode="client_opt")
    assert 0 < opt.n_passed == plain.n_passed
    assert opt.stats.bytes_fetched < 0.8 * plain.stats.bytes_fetched


def test_near_data_fastest(results):
    totals = {m: r.breakdown.total() for m, r in results.items()}
    assert totals["near_data"] < totals["client_opt"]
    assert totals["near_data"] < totals["client_plain"]
    assert totals["near_data"] < totals["server_side"]


def test_client_plain_deserialize_dominated(results):
    b = results["client_plain"].breakdown
    assert b.deserialize > b.filter  # row materialization dominates


def test_server_side_pays_per_basket_requests(results):
    # no TTreeCache locally -> requests scale with basket count
    assert results["server_side"].stats.requests > results["near_data"].stats.requests


def test_output_transfer_only_for_remote_filtering(results):
    assert results["client_plain"].breakdown.output_transfer == 0
    assert results["near_data"].breakdown.output_transfer > 0


def test_bandwidth_sensitivity(store):
    slow = SkimEngine(store, input_link=WAN_1G).run(QUERY, "client_opt")
    fast = SkimEngine(store, input_link=LAN_100G).run(QUERY, "client_opt")
    assert fast.breakdown.fetch < slow.breakdown.fetch
    assert slow.n_passed == fast.n_passed


def test_near_data_insensitive_to_client_link(store):
    # filtering happens at storage; only the small output crosses the WAN
    slow = SkimEngine(
        store, input_link=NetworkModel(0.1, rtt_s=0.05), output_link=NetworkModel(0.1)
    ).run(QUERY, "near_data")
    # input fetch stays on the PCIe-class link regardless of client tier
    assert slow.breakdown.fetch < 0.1


def test_selectivity_sane(results):
    sel = results["near_data"].selectivity
    assert 0.0 < sel < 0.2  # physics skims cut by orders of magnitude


def test_empty_selection_ok(store):
    q = dict(QUERY)
    q["selection"] = {
        "preselection": [{"branch": "MET_pt", "op": ">", "value": 1e9}]
    }
    r = run_skim(store, q, mode="near_data")
    assert r.n_passed == 0
    assert r.output.n_events == 0
