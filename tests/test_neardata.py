"""Device predicate path vs host evaluator; sharded near-data skim."""

import subprocess
import sys
import os

import numpy as np
import pytest

from repro.core import parse_query
from repro.core.neardata import (
    build_padded_inputs,
    compile_query,
    compact_jnp,
    skim_mask,
)
from repro.core.query import eval_stage
from repro.data.synth import make_nanoaod_like
from tests.test_query import QUERY


@pytest.fixture(scope="module")
def setup():
    store = make_nanoaod_like(4000, n_hlt=8, basket_events=1024, seed=3)
    q = parse_query(QUERY)
    data = {}
    need = set(q.filter_branches()) | {"nJet", "nElectron"}
    for b in sorted(need):
        br = store.branches[b]
        if br.jagged:
            data[b], _ = store.read_jagged(b)
        else:
            data[b] = store.read_flat(b)
    return store, q, data


def test_device_mask_matches_host(setup):
    store, q, data = setup
    prog = compile_query(q)
    want = np.ones(store.n_events, bool)
    for _, stage in q.stages():
        want &= eval_stage(stage, data, store.n_events)
    pb = build_padded_inputs(data, prog, store, K=16, payload_branches=["MET_pt"])
    got = np.asarray(skim_mask(pb.terms, pb.valid, pb.weights, prog))
    np.testing.assert_array_equal(got, want)


def test_padding_overflow_documented(setup):
    """K smaller than max multiplicity only affects events with > K objects."""
    store, q, data = setup
    prog = compile_query(q)
    pb16 = build_padded_inputs(data, prog, store, K=16)
    pb2 = build_padded_inputs(data, prog, store, K=2)
    m16 = np.asarray(skim_mask(pb16.terms, pb16.valid, pb16.weights, prog))
    m2 = np.asarray(skim_mask(pb2.terms, pb2.valid, pb2.weights, prog))
    overflow = (data["nJet"] > 2) | (data["nElectron"] > 2)
    np.testing.assert_array_equal(m16[~overflow], m2[~overflow])


def test_compact_returns_survivors_only(setup):
    store, q, data = setup
    prog = compile_query(q)
    pb = build_padded_inputs(data, prog, store, K=16, payload_branches=["MET_pt"])
    mask = skim_mask(pb.terms, pb.valid, pb.weights, prog)
    packed, count = compact_jnp(pb.payload, mask)
    k = int(count)
    np.testing.assert_allclose(
        np.sort(np.asarray(packed[:k, 0])),
        np.sort(data["MET_pt"][np.asarray(mask)]),
        rtol=1e-6,
    )
    assert np.all(np.asarray(packed[k:]) == 0)


SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
import tests.conftest  # noqa: F401  (path setup)
from repro.core import parse_query
from repro.core.neardata import build_padded_inputs, compile_query, sharded_skim, skim_mask
from repro.data.synth import make_nanoaod_like
from tests.test_query import QUERY

store = make_nanoaod_like(4096, n_hlt=8, seed=5)
q = parse_query(QUERY)
prog = compile_query(q)
data = {}
for b in sorted(set(q.filter_branches()) | {"nJet", "nElectron"}):
    br = store.branches[b]
    data[b] = store.read_jagged(b)[0] if br.jagged else store.read_flat(b)

pb = build_padded_inputs(data, prog, store, K=16, payload_branches=["MET_pt"])
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
fn = sharded_skim(mesh, prog)
with mesh:
    packed, mask, total = fn(pb.terms, pb.valid, pb.weights, pb.payload)
want = np.asarray(skim_mask(pb.terms, pb.valid, pb.weights, prog))
assert int(total) == int(want.sum()), (int(total), int(want.sum()))
np.testing.assert_array_equal(np.asarray(mask).astype(bool), want)
print("SHARDED_OK", int(total))
"""


def test_sharded_skim_multidevice():
    env = dict(os.environ)
    # force the CPU platform: images bundling libtpu make an unset
    # JAX_PLATFORMS probe for TPUs for minutes before falling back,
    # blowing the subprocess timeout (host-device forcing needs cpu anyway)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=300,
    )
    assert "SHARDED_OK" in out.stdout, out.stderr[-2000:]
