"""Derived-expression query tier (DESIGN.md §10).

Pins the tentpole contracts:

  * the expression language parses/evaluates correctly (precedence,
    functions, sum() reductions, error cases),
  * mass/ΔR leading-pair kinematics match hand-computed physics,
  * derived queries are bit-identical across the staged evaluator, the
    compiled-program host interpreter, the xla device backend, fused and
    pruned engine modes, shared-scan, and the cluster,
  * zone-map interval analysis over expression trees prunes provably
    empty windows and never drops a survivor (deterministic edges here;
    the random property tests are hypothesis-guarded).
"""

import numpy as np
import pytest

from repro.core import expr as xpr
from repro.core.engine import SkimEngine, run_skim
from repro.core.neardata import fused_window_skim, program_eval_np
from repro.core.planner import plan_skim
from repro.core.query import eval_node, eval_stage, parse_query
from repro.core.zonemap import ACCEPT_ALL, PRUNE, SCAN, classify_windows
from repro.data.store import EventStore
from repro.data.synth import make_nanoaod_like
from repro.serve.engine import SharedScanEngine

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# expression language
# ---------------------------------------------------------------------------


def _eval(text, data):
    return xpr.eval_expr_np(xpr.to_rpn(xpr.parse_expr(text)), data)


def test_expr_precedence_and_functions():
    data = {"a": np.array([2.0, -3.0]), "b": np.array([4.0, 5.0])}
    np.testing.assert_array_equal(_eval("a + 2*b", data), [10.0, 7.0])
    np.testing.assert_array_equal(_eval("(a + 2) * b", data), [16.0, -5.0])
    np.testing.assert_array_equal(_eval("-a", data), [-2.0, 3.0])
    np.testing.assert_array_equal(_eval("abs(a - b)", data), [2.0, 8.0])
    np.testing.assert_array_equal(_eval("min(a, b)", data), [2.0, -3.0])
    np.testing.assert_array_equal(_eval("max(a, b) - 1", data), [3.0, 4.0])
    np.testing.assert_array_equal(_eval("a / b", data), [0.5, -0.6])
    np.testing.assert_array_equal(_eval("a - b - 1", data), [-3.0, -9.0])


def test_expr_sum_reduction_is_float64_segment_sum():
    data = {
        "nObj": np.array([2, 0, 1], dtype=np.int32),
        "Obj_pt": np.array([1.5, 2.5, 7.0], dtype=np.float32),
        "met": np.array([10.0, 20.0, 30.0], dtype=np.float32),
    }
    np.testing.assert_array_equal(_eval("sum(Obj_pt)", data), [4.0, 0.0, 7.0])
    np.testing.assert_array_equal(
        _eval("met + 0.5*sum(Obj_pt)", data), [12.0, 20.0, 33.5]
    )


@pytest.mark.parametrize("bad", [
    "1 + 1",          # no branches: constant predicate
    "a +",            # dangling operator
    "foo(a)",         # unknown function
    "min(a)",         # wrong arity
    "sum(1)",         # sum needs a branch identifier
    "a $ b",          # bad character
    "(a",             # unbalanced paren
    "a b",            # trailing input
])
def test_expr_rejects_malformed(bad):
    with pytest.raises(ValueError):
        xpr.compile_expr(bad)


def test_expr_branch_discovery_includes_sum_counts():
    rpn = xpr.compile_expr("MET_pt + sum(Jet_pt)/2")
    assert xpr.rpn_branches(rpn) == {"MET_pt", "Jet_pt", "nJet"}


def test_expr_validation_against_store():
    store = make_nanoaod_like(200, n_hlt=4)
    # bare jagged branch must be rejected (use sum() or an object node)
    q = parse_query({"branches": ["MET_*"], "selection": {"event": [
        {"type": "expr", "expr": "Jet_pt + 1", "op": ">", "value": 0.0}]}})
    with pytest.raises(ValueError, match="jagged"):
        plan_skim(q, store)
    # sum() of a flat branch is equally malformed
    q2 = parse_query({"branches": ["MET_*"], "selection": {"event": [
        {"type": "expr", "expr": "sum(MET_pt)", "op": ">", "value": 0.0}]}})
    with pytest.raises(ValueError, match="jagged"):
        plan_skim(q2, store)


# ---------------------------------------------------------------------------
# leading-pair kinematics
# ---------------------------------------------------------------------------


def _pair_data(**over):
    """Three events: [2e back-to-back], [1e], [3e with a soft leader tie]."""
    base = {
        "nElectron": np.array([2, 1, 3], dtype=np.int32),
        "Electron_pt": np.array([40.0, 40.0, 25.0, 30.0, 10.0, 30.0],
                                dtype=np.float32),
        "Electron_eta": np.array([0.0, 0.0, 1.0, 0.5, 0.0, -0.5],
                                 dtype=np.float32),
        "Electron_phi": np.array([0.0, np.pi, 2.0, 1.0, 0.0, -1.0],
                                 dtype=np.float32),
        "Electron_mass": np.zeros(6, dtype=np.float32),
    }
    base.update(over)
    return base


def test_mass_back_to_back_pair():
    # massless, equal pt, opposite phi, eta 0: E = 40 + 40, p cancels -> 80
    m, ok = xpr.leading_pair_mass(_pair_data(), "Electron", "Electron")
    assert ok.tolist() == [True, False, True]
    assert m[0] == pytest.approx(80.0, rel=1e-12)


def test_mass_window_node_insufficient_objects_fail():
    node = parse_query({"selection": {"event": [
        {"type": "mass", "collections": ["Electron", "Electron"],
         "window": [0.0, 1e9]}]}}).event_stage[0]
    mask = eval_node(node, _pair_data(), 3)
    # the wide-open window passes every event that HAS a pair; event 1
    # (single electron) fails regardless
    assert mask.tolist() == [True, False, True]


def test_mass_leading_pair_ties_use_storage_order():
    """Event 2 has pt (30, 10, 30): the leading pair is the tied 30s in
    storage order — matching the device argmax first-occurrence tiebreak."""
    data = _pair_data()
    (i1, i2), _ = xpr._leading_indices(
        data["Electron_pt"][3:], np.array([3]), 2
    )
    assert (int(i1[0]), int(i2[0])) == (0, 2)


def test_delta_r_wraps_phi():
    data = {
        "nElectron": np.array([1], dtype=np.int32),
        "Electron_pt": np.array([50.0], dtype=np.float32),
        "Electron_eta": np.array([0.3], dtype=np.float32),
        "Electron_phi": np.array([3.0], dtype=np.float32),
        "nJet": np.array([1], dtype=np.int32),
        "Jet_pt": np.array([60.0], dtype=np.float32),
        "Jet_eta": np.array([0.3], dtype=np.float32),
        "Jet_phi": np.array([-3.0], dtype=np.float32),
    }
    dr, ok = xpr.leading_delta_r(data, "Electron", "Jet")
    assert ok[0]
    # dphi = 6.0 wrapped to 2*pi - 6.0
    want = abs(2 * np.pi - 6.0)
    assert dr[0] == pytest.approx(want, rel=1e-6)


def test_delta_r_mixed_pair_picks_each_leading():
    data = {
        "nElectron": np.array([2], dtype=np.int32),
        "Electron_pt": np.array([10.0, 90.0], dtype=np.float32),
        "Electron_eta": np.array([2.0, 0.0], dtype=np.float32),
        "Electron_phi": np.array([1.0, 0.0], dtype=np.float32),
        "nJet": np.array([2], dtype=np.int32),
        "Jet_pt": np.array([80.0, 20.0], dtype=np.float32),
        "Jet_eta": np.array([1.0, -2.0], dtype=np.float32),
        "Jet_phi": np.array([0.0, 3.0], dtype=np.float32),
    }
    dr, ok = xpr.leading_delta_r(data, "Electron", "Jet")
    # leading e is index 1 (eta 0, phi 0), leading jet index 0 (eta 1, phi 0)
    assert ok[0] and dr[0] == pytest.approx(1.0, rel=1e-12)


# ---------------------------------------------------------------------------
# end-to-end bit-identity across every executor
# ---------------------------------------------------------------------------

ZQUERY = {
    "branches": ["Electron_*", "Jet_pt", "MET_*", "luminosityBlock"],
    "selection": {
        "event": [
            {"type": "mass", "collections": ["Electron", "Electron"],
             "window": [5.0, 120.0]},
            {"type": "deltaR", "collections": ["Electron", "Jet"],
             "op": ">", "value": 0.4},
            {"type": "expr", "expr": "MET_pt + 0.5*sum(Jet_pt)",
             "op": ">", "value": 60.0},
        ],
    },
}


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(8_000, n_hlt=8, n_filler=4, basket_events=1024)


@pytest.fixture(scope="module")
def reference(store):
    return run_skim(store, ZQUERY, mode="near_data", fused=False,
                    pipeline=False, prune=False)


def _assert_same_output(res, ref):
    assert res.n_passed == ref.n_passed
    for name in ref.output.branch_names():
        br = ref.output.branches[name]
        if br.jagged:
            v0, c0 = ref.output.read_jagged(name)
            v1, c1 = res.output.read_jagged(name)
            np.testing.assert_array_equal(c1, c0)
            np.testing.assert_array_equal(v1, v0)
        else:
            np.testing.assert_array_equal(
                res.output.read_flat(name), ref.output.read_flat(name)
            )


def test_reference_selects_something(reference, store):
    assert 0 < reference.n_passed < store.n_events


@pytest.mark.parametrize("kw", [
    dict(fused=True, pipeline=False, prune=False),
    dict(fused=True, pipeline=True, prune=False),
    dict(fused=True, pipeline="threads", prune=False),
    dict(fused=False, pipeline=False, prune=True),
    dict(fused=True, pipeline=True, prune=True),
])
def test_derived_query_modes_bit_identical(store, reference, kw):
    res = run_skim(store, ZQUERY, mode="near_data", **kw)
    _assert_same_output(res, reference)


def test_derived_query_shared_scan_matches_solo(store):
    tenants = [ZQUERY,
               {"branches": ["MET_*"], "selection": {"event": [
                   {"type": "expr", "expr": "MET_pt*2", "op": ">",
                    "value": 80.0}]}}]
    batch = SharedScanEngine(store).run_batch(tenants)
    eng = SkimEngine(store)
    for q, res in zip(tenants, batch.results):
        _assert_same_output(res, eng.run(q, "near_data"))


def test_derived_query_cluster_matches_single_node(store, reference):
    from repro.cluster.coordinator import build_cluster

    res = build_cluster(store, 4).run(ZQUERY)
    assert res.n_passed == reference.n_passed
    _assert_same_output(res, reference)


@pytest.mark.parametrize("backend", ["host", "xla"])
def test_derived_fused_window_backends_agree(store, backend):
    q = parse_query(ZQUERY)
    plan = plan_skim(q, store)
    data = {}
    for b in plan.filter_branches:
        br = store.branches[b]
        data[b] = store.read_jagged(b)[0] if br.jagged else store.read_flat(b)
    n = store.n_events
    want = np.ones(n, dtype=bool)
    for _, stage in q.stages():
        want &= eval_stage(stage, data, n)
    mask, _ = fused_window_skim(
        data, plan.compiled_program(), store, backend=backend
    )
    np.testing.assert_array_equal(mask, want)


def test_program_interpreter_matches_staged_for_derived_nodes(store):
    queries = [
        {"branches": ["MET_*"], "selection": {"event": [
            {"type": "expr", "expr": "abs(MET_pt - 30)", "op": "<",
             "value": 10.0}]}},
        {"branches": ["MET_*"], "selection": {"event": [
            {"type": "expr", "expr": "min(MET_pt, sum(Jet_pt))", "op": ">",
             "value": 25.0}]}},
        {"branches": ["Electron_*"], "selection": {"event": [
            {"type": "mass", "collections": ["Electron", "Electron"],
             "window": [0.0, 60.0]}]}},
        {"branches": ["Electron_*"], "selection": {"event": [
            {"type": "deltaR", "collections": ["Electron", "Muon"],
             "op": "<", "value": 2.0}]}},
        {"branches": ["Electron_*"], "selection": {"event": [
            {"type": "deltaR", "collections": ["Jet", "Jet"],
             "op": ">", "value": 1.0}]}},
    ]
    n = store.n_events
    for doc in queries:
        q = parse_query(doc)
        plan = plan_skim(q, store)
        data = {}
        for b in plan.filter_branches:
            br = store.branches[b]
            data[b] = (
                store.read_jagged(b)[0] if br.jagged else store.read_flat(b)
            )
        want = np.ones(n, dtype=bool)
        for _, stage in q.stages():
            want &= eval_stage(stage, data, n)
        got = program_eval_np(data, plan.compiled_program(), n)
        np.testing.assert_array_equal(got, want, err_msg=str(doc))


# ---------------------------------------------------------------------------
# zone-map interval analysis over expressions
# ---------------------------------------------------------------------------

BASKET = 32


def _spans(store, window_events=BASKET):
    return [
        (s, min(s + window_events, store.n_events))
        for s in range(0, store.n_events, window_events)
    ]


def _expr_query(expr, op, value):
    return parse_query({"branches": ["met"], "selection": {"event": [
        {"type": "expr", "expr": expr, "op": op, "value": value}]}})


def _check_window_invariants(query, store, columns, jagged=None):
    """PRUNE windows hold no survivor, ACCEPT_ALL windows no failure."""
    jagged = jagged or {}
    for (a, b), kind in zip(
        spans := _spans(store), classify_windows(query, store, spans)
    ):
        data = {}
        for name, arr in columns.items():
            if name in jagged:
                counts = columns[jagged[name]]
                off = np.concatenate([[0], np.cumsum(counts)])
                data[name] = arr[off[a]:off[b]]
            else:
                data[name] = arr[a:b]
        mask = np.ones(b - a, dtype=bool)
        for _, stage in query.stages():
            mask &= eval_stage(stage, data, b - a)
        if kind == PRUNE:
            assert not mask.any(), (a, b)
        elif kind == ACCEPT_ALL:
            assert mask.all(), (a, b)


def test_expr_interval_prunes_monotone_ramp():
    n = 4 * BASKET
    columns = {
        "met": np.full(n, 10.0, dtype=np.float32),
        "ramp": np.arange(n, dtype=np.float32),
    }
    store = EventStore.from_arrays(columns, basket_events=BASKET)
    q = _expr_query("2*ramp + 0.1*met", "<", 2.0 * BASKET)
    kinds = classify_windows(q, store, _spans(store))
    assert kinds[0] == ACCEPT_ALL  # 2*31 + 1 < 64 for the whole window
    assert kinds[2] == PRUNE and kinds[3] == PRUNE
    _check_window_invariants(q, store, columns)


def test_expr_interval_division_by_straddling_interval_scans():
    n = 2 * BASKET
    columns = {
        # every window straddles zero: the divisor interval may vanish
        "met": np.tile(np.array([-3.0, 4.0], np.float32), n // 2),
        "x": np.full(n, 1.0, dtype=np.float32),
    }
    store = EventStore.from_arrays(columns, basket_events=BASKET)
    q = _expr_query("x / met", ">", 1000.0)
    assert classify_windows(q, store, _spans(store)) == [SCAN, SCAN]
    # a strictly positive divisor is decidable again: |met/x| <= 4
    q2 = _expr_query("met / x", ">", 1000.0)
    assert classify_windows(q2, store, _spans(store)) == [PRUNE, PRUNE]


def test_expr_interval_sum_zero_objects_is_exact():
    n = 2 * BASKET
    counts = np.zeros(n, dtype=np.int32)
    counts[:BASKET] = 2  # objects only in the first window
    total = int(counts.sum())
    columns = {
        "met": np.full(n, 50.0, dtype=np.float32),
        "nObj": counts,
        "Obj_pt": np.full(total, 30.0, dtype=np.float32),
    }
    store = EventStore.from_arrays(
        columns, jagged={"Obj_pt": "nObj"}, basket_events=BASKET
    )
    q = _expr_query("sum(Obj_pt)", ">", 5.0)
    kinds = classify_windows(q, store, _spans(store))
    # second window: no objects anywhere, the sum is exactly 0.0 -> PRUNE
    assert kinds[1] == PRUNE
    _check_window_invariants(q, store, columns, {"Obj_pt": "nObj"})


def test_mass_and_deltar_degrade_to_scan():
    store = make_nanoaod_like(4 * BASKET, n_hlt=4, basket_events=BASKET)
    q = parse_query({"branches": ["Electron_*"], "selection": {"event": [
        {"type": "mass", "collections": ["Electron", "Electron"],
         "window": [80.0, 100.0]}]}})
    assert set(classify_windows(q, store, _spans(store))) == {SCAN}
    q2 = parse_query({"branches": ["Electron_*"], "selection": {"event": [
        {"type": "deltaR", "collections": ["Electron", "Jet"],
         "op": ">", "value": 0.4}]}})
    assert set(classify_windows(q2, store, _spans(store))) == {SCAN}


# ---------------------------------------------------------------------------
# property tests: random expressions never prune a survivor
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _leaf = st.sampled_from(["met", "cnt", "sum(Obj_pt)", "3", "0.25", "-2"])
    _binop = st.sampled_from(["+", "-", "*"])

    @st.composite
    def _random_expr(draw) -> str:
        depth = draw(st.integers(1, 3))

        def build(d: int) -> str:
            if d <= 0 or draw(st.booleans()):
                return draw(_leaf)
            shape = draw(st.integers(0, 3))
            if shape == 0:
                return f"abs({build(d - 1)})"
            if shape == 1:
                fn = draw(st.sampled_from(["min", "max"]))
                return f"{fn}({build(d - 1)}, {build(d - 1)})"
            return f"({build(d - 1)} {draw(_binop)} {build(d - 1)})"

        text = build(depth)
        # guarantee at least one branch reference
        if not (set("abcdefghijklmnopqrstuvwxyz") - set("sum")) & set(text):
            text = f"met + {text}"
        return text

    @st.composite
    def _random_case(draw):
        seed = draw(st.integers(0, 2**16))
        n_events = draw(st.integers(33, 129))
        rng = np.random.default_rng(seed)
        counts = rng.poisson(draw(st.floats(0.0, 2.5)), n_events).astype(
            np.int32
        )
        columns = {
            "met": rng.normal(30.0, 25.0, n_events).astype(np.float32),
            "cnt": rng.integers(-5, 40, n_events).astype(np.int32),
            "nObj": counts,
            "Obj_pt": (
                rng.exponential(25.0, int(counts.sum())) - 10.0
            ).astype(np.float32),
        }
        doc = {
            "branches": ["met", "Obj_*", "cnt"],
            "selection": {"event": [{
                "type": "expr",
                "expr": draw(_random_expr()),
                "op": draw(st.sampled_from(
                    [">", ">=", "<", "<=", "==", "!=", "abs<", "abs>"]
                )),
                "value": draw(st.one_of(
                    st.floats(-150.0, 150.0, allow_nan=False,
                              allow_infinity=False),
                    st.sampled_from([0.0, 1.0, 30.0, -30.0]),
                )),
            }]},
        }
        return columns, doc

    @given(_random_case())
    @settings(max_examples=150, deadline=None)
    def test_expr_interval_never_prunes_a_survivor(case):
        columns, doc = case
        jagged = {"Obj_pt": "nObj"}
        store = EventStore.from_arrays(
            columns, jagged=jagged, basket_events=BASKET
        )
        try:
            query = parse_query(doc)
        except ValueError:
            return  # constant-only random expression: rejected by parse
        _check_window_invariants(query, store, columns, jagged)

    @given(_random_case())
    @settings(max_examples=60, deadline=None)
    def test_expr_engine_prune_bit_identical(case):
        columns, doc = case
        jagged = {"Obj_pt": "nObj"}
        store = EventStore.from_arrays(
            columns, jagged=jagged, basket_events=BASKET
        )
        try:
            query = parse_query(doc)
        except ValueError:
            return
        ref = run_skim(store, query, mode="near_data", fused=False,
                       pipeline=False, prune=False)
        res = run_skim(store, query, mode="near_data", fused=True,
                       pipeline=False, prune=True)
        assert res.n_passed == ref.n_passed
