"""Per-kernel shape/dtype sweeps against the ref.py oracles (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.codecs import bitpack_encode, bitpack_raw_parts
from repro.kernels import ops, ref
from repro.kernels.predicate_eval import Group, Program
from repro.kernels.ref import GROUP_ANY, GROUP_COUNT, GROUP_HT, OP_IDS

RNG = np.random.default_rng(7)


def _program():
    return Program(
        groups=(
            Group(GROUP_COUNT, (0, 1), (OP_IDS[">"], OP_IDS["abs<"]), (20.0, 2.4)),
            Group(GROUP_HT, (2,), (OP_IDS[">"],), (30.0,),
                  cmp_op=OP_IDS[">"], cmp_thr=100.0),
            Group(GROUP_ANY, (3,), (OP_IDS[">="],), (0.5,)),
        ),
        term_branches=("pt", "eta", "jpt", "trig"),
        group_collections=("Electron", "Jet", None),
        group_weights=(None, "jpt", None),
    )


@pytest.mark.parametrize("E", [64, 257, 1000, 2048])
@pytest.mark.parametrize("K", [1, 4, 8])
def test_predicate_eval_sweep(E, K):
    prog = _program()
    terms = RNG.normal(20, 20, (4, E, K)).astype(np.float32)
    valid = (RNG.random((3, E, K)) < 0.5).astype(np.float32)
    weights = np.abs(RNG.normal(40, 20, (3, E, K))).astype(np.float32)
    got = np.asarray(ops.predicate_eval(terms, valid, weights, prog))
    want = np.asarray(
        ref.predicate_eval_ref(
            jnp.asarray(terms), jnp.asarray(valid), jnp.asarray(weights), prog
        )
    )
    np.testing.assert_array_equal(got.astype(bool), want)


@pytest.mark.parametrize("op", list(OP_IDS.values()))
def test_predicate_all_ops(op):
    prog = Program(
        groups=(Group(GROUP_COUNT, (0,), (op,), (5.0,)),),
        term_branches=("x",),
        group_collections=(None,),
        group_weights=(None,),
    )
    terms = RNG.normal(5, 5, (1, 256, 1)).astype(np.float32)
    valid = np.ones((1, 256, 1), np.float32)
    weights = np.zeros((1, 256, 1), np.float32)
    got = np.asarray(ops.predicate_eval(terms, valid, weights, prog))
    want = np.asarray(
        ref.predicate_eval_ref(
            jnp.asarray(terms), jnp.asarray(valid), jnp.asarray(weights), prog
        )
    )
    np.testing.assert_array_equal(got.astype(bool), want)


@pytest.mark.parametrize("E,D", [(128, 1), (512, 7), (1000, 16), (2048, 3)])
@pytest.mark.parametrize("rate", [0.0, 0.13, 0.5, 1.0])
def test_stream_compact_sweep(E, D, rate):
    payload = RNG.normal(size=(E, D)).astype(np.float32)
    mask = RNG.random(E) < rate
    packed, count = ops.stream_compact(payload, mask)
    wpacked, wcount = ref.stream_compact_ref(jnp.asarray(payload), jnp.asarray(mask))
    assert int(count) == int(wcount) == int(mask.sum())
    np.testing.assert_allclose(np.asarray(packed), np.asarray(wpacked), rtol=1e-6)


def test_stream_compact_preserves_order():
    E = 512
    payload = np.arange(E, dtype=np.float32)[:, None]
    mask = np.zeros(E, bool)
    mask[[3, 100, 101, 400]] = True
    packed, count = ops.stream_compact(payload, mask)
    np.testing.assert_array_equal(
        np.asarray(packed[:4, 0]), [3.0, 100.0, 101.0, 400.0]
    )
    assert np.all(np.asarray(packed[4:]) == 0)


@pytest.mark.parametrize(
    "dtype,gen",
    [
        (np.int32, lambda n: RNG.integers(-3000, 3000, n).astype(np.int32)),
        # smooth floats trigger the raw bail-out (kind 3, passthrough)
        (np.float32, lambda n: (RNG.exponential(30, n) + 1).astype(np.float32)),
        # discrete floats xor-compress -> exercises the KIND_FLOAT kernel path
        (
            np.float32,
            lambda n: RNG.choice(
                np.array([1.0, 1.25, 1.5, 1.75], np.float32), n
            ),
        ),
        (np.bool_, lambda n: RNG.random(n) < 0.2),
    ],
)
@pytest.mark.parametrize("sizes", [(64,), (100, 5000, 333), (4096, 4096)])
def test_basket_decode_sweep(dtype, gen, sizes):
    arrs = [gen(n) for n in sizes]
    parts = [bitpack_raw_parts(bitpack_encode(a)) for a in arrs]
    out_dtype = jnp.int32 if dtype == np.int32 else jnp.float32
    outs = ops.basket_decode_batch(parts, out_dtype)
    for a, o in zip(arrs, outs):
        np.testing.assert_array_equal(np.asarray(o), a.astype(np.asarray(o).dtype))


def test_basket_decode_matches_ref_kernel():
    arrs = [RNG.integers(-100, 100, 512).astype(np.int32) for _ in range(3)]
    parts = [bitpack_raw_parts(bitpack_encode(a)) for a in arrs]
    bits = max(p["bits"] for p in parts)
    W = max(p["n_pad"] for p in parts) // 32
    planes = np.zeros((3, bits, W), np.uint32)
    firsts = np.zeros(3, np.uint32)
    for i, p in enumerate(parts):
        pw = p["planes"].reshape(max(p["bits"], 1), -1)
        planes[i, : pw.shape[0], : pw.shape[1]] = pw
        firsts[i] = p["first"]
    want = ref.basket_decode_ref(
        jnp.asarray(planes), jnp.asarray(firsts), 0, 512, jnp.int32
    )
    for i, a in enumerate(arrs):
        np.testing.assert_array_equal(np.asarray(want[i]), a)


@pytest.mark.parametrize("B,H,S,D", [(1, 1, 128, 32), (2, 3, 256, 64), (1, 2, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, S, D, causal):
    q, k, v = (
        RNG.normal(size=(B, H, S, D)).astype(np.float32) for _ in range(3)
    )
    got = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal)
    want = ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_flash_attention_bf16():
    q, k, v = (
        jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16) for _ in range(3)
    )
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=0.05, atol=0.05
    )


@given(st.integers(1, 3), st.integers(1, 6), st.floats(0.05, 0.95))
@settings(max_examples=10, deadline=None)
def test_compact_count_property(d, seed, rate):
    rng = np.random.default_rng(seed)
    E = 256
    payload = rng.normal(size=(E, d)).astype(np.float32)
    mask = rng.random(E) < rate
    packed, count = ops.stream_compact(payload, mask)
    # survivor multiset preserved
    got = np.sort(np.asarray(packed[: int(count)]), axis=0)
    want = np.sort(payload[mask], axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-6)
