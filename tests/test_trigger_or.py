"""Era-robust trigger-OR semantics (the AnyOf missing-branch bugfix).

Trigger menus differ across data-taking eras, so an ``any`` node listing
a branch the store does not carry must degrade that branch to
constant-False instead of raising — in the engine (staged and fused),
the shared-scan service, and the cluster (where one shard may carry an
older schema).  ``parse_query(..., strict=True)`` restores the hard
error, and the zone-map AnyOf analysis mirrors the constant-False
semantics so pruning stays bit-identical.
"""

import numpy as np
import pytest

from repro.core.engine import run_skim
from repro.core.planner import plan_skim
from repro.core.query import AnyOf, eval_node, parse_query
from repro.core.zonemap import ACCEPT_ALL, PRUNE, classify_span
from repro.data.synth import make_nanoaod_like
from repro.serve.engine import SharedScanEngine

MIXED = {
    "branches": ["MET_*", "HLT_*"],
    "selection": {"event": [
        {"type": "any",
         "branches": ["HLT_NoSuchTrigger", "HLT_IsoMu24"]},
    ]},
}
PRESENT_ONLY = {
    "branches": ["MET_*", "HLT_*"],
    "selection": {"event": [
        {"type": "any", "branches": ["HLT_IsoMu24"]},
    ]},
}
ALL_MISSING = {
    "branches": ["MET_*"],
    "selection": {"event": [
        {"type": "any", "branches": ["HLT_Gone2017", "HLT_Gone2018"]},
    ]},
}


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(4_096, n_hlt=8, basket_events=512)


def _same_output(res, ref):
    assert res.n_passed == ref.n_passed
    for name in ref.output.branch_names():
        if not ref.output.branches[name].jagged:
            np.testing.assert_array_equal(
                res.output.read_flat(name), ref.output.read_flat(name)
            )


@pytest.mark.parametrize("kw", [
    dict(fused=False, pipeline=False, prune=False),
    dict(fused=True, pipeline=True, prune=False),
    dict(fused=True, pipeline=True, prune=True),
])
def test_missing_trigger_behaves_as_constant_false(store, kw):
    """The ISSUE repro: an OR listing an absent HLT branch must select
    exactly what the present-branch OR selects."""
    res = run_skim(store, MIXED, mode="near_data", **kw)
    ref = run_skim(store, PRESENT_ONLY, mode="near_data", **kw)
    assert res.n_passed > 0
    _same_output(res, ref)


@pytest.mark.parametrize("prune", [False, True])
def test_all_missing_or_selects_nothing(store, prune):
    res = run_skim(store, ALL_MISSING, mode="near_data", prune=prune)
    assert res.n_passed == 0
    assert res.output.n_events == 0


def test_all_missing_or_prunes_from_stats(store):
    """The zone-map mirror: an OR over only-absent branches is provably
    all-false, so every window prunes without a fetch."""
    res = run_skim(store, ALL_MISSING, mode="near_data", prune=True)
    pruned = [d for _, _, d in res.extras["pruned_windows"] if d == PRUNE]
    assert len(pruned) == store.n_events // store.basket_events
    # nothing moves: no filter branch exists, every window is proved
    # empty, and with zero survivors phase 2 never runs either
    assert res.stats.bytes_fetched == 0 and res.stats.requests == 0


def test_missing_trigger_zonemap_matches_present_only(store):
    """Mixed ORs classify identically with and without absent names —
    the absent branch contributes nothing to the analysis."""
    q_mixed = parse_query(MIXED)
    q_ref = parse_query(PRESENT_ONLY)
    for start in range(0, store.n_events, store.basket_events):
        stop = min(start + store.basket_events, store.n_events)
        assert classify_span(q_mixed, store, start, stop) == classify_span(
            q_ref, store, start, stop
        )


def test_always_firing_present_branch_still_accept_all():
    """A mixed OR whose present branch fires everywhere must still prove
    ACCEPT_ALL despite the absent name."""
    store = make_nanoaod_like(1_024, n_hlt=4, basket_events=256)
    # build an always-true trigger by querying the complement of nothing:
    # run==362104 holds for every synthetic event; use a cut alongside an
    # absent-only OR to pin the PRUNE side instead
    q = parse_query({"branches": ["MET_*"], "selection": {"event": [
        {"type": "any", "branches": ["HLT_Missing", "HLT_IsoMu24"]}]}})
    kind = classify_span(q, store, 0, store.n_events)
    # IsoMu24 fires at ~15%: neither PRUNE nor ACCEPT_ALL is provable
    assert kind not in (PRUNE, ACCEPT_ALL)


def test_strict_mode_restores_hard_error(store):
    with pytest.raises(KeyError, match="HLT_NoSuchTrigger"):
        plan_skim(parse_query(MIXED, strict=True), store)
    # the document form carries the flag too
    doc = dict(MIXED, strict=True)
    with pytest.raises(KeyError, match="HLT_NoSuchTrigger"):
        plan_skim(parse_query(doc), store)


def test_non_trigger_missing_branch_still_raises(store):
    bad = {"branches": ["MET_*"], "selection": {
        "preselection": [{"branch": "NoSuchBranch", "op": ">", "value": 0}]}}
    with pytest.raises(KeyError, match="NoSuchBranch"):
        plan_skim(parse_query(bad), store)


def test_eval_node_anyof_all_missing_needs_n_events():
    node = AnyOf(("HLT_A", "HLT_B"))
    mask = eval_node(node, {}, n_events=5)
    assert mask.dtype == bool and not mask.any() and len(mask) == 5
    with pytest.raises(KeyError):
        eval_node(node, {})


def test_missing_trigger_shared_scan_and_cluster(store):
    from repro.cluster.coordinator import build_cluster

    ref = run_skim(store, MIXED, mode="near_data")
    batch = SharedScanEngine(store).run_batch([MIXED, PRESENT_ONLY])
    _same_output(batch.results[0], ref)
    _same_output(batch.results[1], ref)
    res = build_cluster(store, 4).run(MIXED)
    assert res.n_passed == ref.n_passed


def test_query_hash_distinguishes_strict():
    from repro.cluster.cache import query_hash

    lax = parse_query(MIXED)
    strict = parse_query(MIXED, strict=True)
    assert query_hash(lax) != query_hash(strict)
