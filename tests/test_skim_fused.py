"""Fused predicate+compact kernel vs the two-kernel oracle composition."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.predicate_eval import Group, Program
from repro.kernels.ref import GROUP_ANY, GROUP_COUNT, GROUP_HT, OP_IDS

RNG = np.random.default_rng(3)


def _program():
    return Program(
        groups=(
            Group(GROUP_COUNT, (0, 1), (OP_IDS[">"], OP_IDS["abs<"]), (20.0, 25.0)),
            Group(GROUP_HT, (2,), (OP_IDS[">"],), (10.0,),
                  cmp_op=OP_IDS[">"], cmp_thr=100.0),
            Group(GROUP_ANY, (3,), (OP_IDS[">="],), (0.5,)),
        ),
        term_branches=("a", "b", "c", "d"),
        group_collections=("X", None, None),
        group_weights=(None, "w", None),
    )


@pytest.mark.parametrize("E,K,D", [(256, 4, 3), (1000, 8, 6), (2048, 1, 1)])
def test_fused_matches_two_pass(E, K, D):
    prog = _program()
    terms = RNG.normal(20, 15, (4, E, K)).astype(np.float32)
    valid = (RNG.random((3, E, K)) < 0.4).astype(np.float32)
    weights = np.abs(RNG.normal(30, 20, (3, E, K))).astype(np.float32)
    payload = RNG.normal(size=(E, D)).astype(np.float32)

    packed, count = ops.skim_fused(terms, valid, weights, payload, prog)
    mask = ref.predicate_eval_ref(
        jnp.asarray(terms), jnp.asarray(valid), jnp.asarray(weights), prog
    )
    want_packed, want_count = ref.stream_compact_ref(jnp.asarray(payload), mask)
    assert int(count) == int(want_count)
    np.testing.assert_allclose(
        np.asarray(packed), np.asarray(want_packed), rtol=1e-6
    )


def test_fused_empty_and_full():
    prog = Program(
        groups=(Group(GROUP_COUNT, (0,), (OP_IDS[">"],), (0.0,)),),
        term_branches=("x",),
        group_collections=(None,),
        group_weights=(None,),
    )
    E = 512
    valid = np.ones((1, E, 1), np.float32)
    weights = np.zeros((1, E, 1), np.float32)
    payload = RNG.normal(size=(E, 2)).astype(np.float32)
    # all pass
    terms = np.ones((1, E, 1), np.float32)
    packed, count = ops.skim_fused(terms, valid, weights, payload, prog)
    assert int(count) == E
    np.testing.assert_allclose(np.asarray(packed), payload, rtol=1e-6)
    # none pass
    terms = -np.ones((1, E, 1), np.float32)
    packed, count = ops.skim_fused(terms, valid, weights, payload, prog)
    assert int(count) == 0
    assert np.all(np.asarray(packed) == 0)
