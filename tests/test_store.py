import numpy as np
import pytest

from repro.data.store import EventStore, FetchStats
from repro.data.synth import make_nanoaod_like


@pytest.fixture(scope="module")
def store():
    return make_nanoaod_like(10_000, n_hlt=8, n_filler=2, basket_events=1024)


def test_structure(store):
    assert store.n_events == 10_000
    assert "Electron_pt" in store.branches
    assert store.branches["Electron_pt"].jagged
    assert store.branches["Electron_pt"].counts_branch == "nElectron"
    assert store.n_baskets("MET_pt") == 10  # 10k / 1024 -> 10 baskets


def test_first_event_index(store):
    fei = store.first_event_index("MET_pt")
    np.testing.assert_array_equal(fei, np.arange(10) * 1024)


def test_basket_range_selection(store):
    ids = store.basket_ids_for_range("MET_pt", 1500, 2100)
    assert ids == [1, 2]  # events 1024..2047 and 2048..3071


def test_flat_range_read(store):
    full = store.read_flat("MET_pt")
    part = store.read_flat("MET_pt", 1500, 2100)
    np.testing.assert_array_equal(part, full[1500:2100])


def test_jagged_range_read(store):
    v_full, c_full = store.read_jagged("Jet_pt")
    v, c = store.read_jagged("Jet_pt", 3000, 4000)
    np.testing.assert_array_equal(c, c_full[3000:4000])
    off = int(c_full[:3000].sum())
    np.testing.assert_array_equal(v, v_full[off : off + int(c.sum())])


def test_fetch_stats_accounting(store):
    stats = FetchStats()
    blobs = store.fetch_range("MET_pt", 0, 2048, stats=stats)
    assert stats.bytes_fetched == sum(len(b) for _, b in blobs)
    assert stats.requests == 1  # coalesced
    stats2 = FetchStats()
    store.fetch_range("MET_pt", 0, 2048, stats=stats2, coalesce=False)
    assert stats2.requests == 2  # per-basket


def test_save_load_roundtrip(tmp_path, store):
    p = str(tmp_path / "x.skim")
    store.save(p)
    st2 = EventStore.load(p)
    assert st2.n_events == store.n_events
    np.testing.assert_array_equal(st2.read_flat("MET_pt"), store.read_flat("MET_pt"))
    v1, c1 = store.read_jagged("Electron_pt")
    v2, c2 = st2.read_jagged("Electron_pt")
    np.testing.assert_array_equal(v1, v2)
    np.testing.assert_array_equal(c1, c2)


def test_compressed_smaller_than_raw(store):
    assert store.compressed_bytes() < store.raw_bytes()
