import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.codecs import (
    CODECS,
    bitpack_decode,
    bitpack_encode,
    bitpack_raw_parts,
    decode_basket,
    encode_basket,
)


@pytest.mark.parametrize("codec", ["bitpack", "zlib", "raw"])
@pytest.mark.parametrize(
    "dtype,gen",
    [
        ("int32", lambda rng, n: rng.integers(-10_000, 10_000, n).astype(np.int32)),
        ("float32", lambda rng, n: (rng.exponential(25, n) + 3).astype(np.float32)),
        ("bool", lambda rng, n: rng.random(n) < 0.15),
    ],
)
@pytest.mark.parametrize("n", [0, 1, 31, 32, 33, 1000, 4096])
def test_roundtrip(codec, dtype, gen, n):
    rng = np.random.default_rng(42 + n)
    arr = gen(rng, n)
    blob = encode_basket(arr, codec)
    out = decode_basket(blob, codec, arr.dtype)
    assert out.dtype == arr.dtype
    np.testing.assert_array_equal(out, arr)


def test_bitpack_compresses_monotone_ints():
    arr = np.cumsum(np.random.default_rng(0).integers(0, 8, 50_000)).astype(np.int32)
    blob = bitpack_encode(arr)
    assert len(blob) < arr.nbytes / 5  # small deltas pack tightly


def test_bitpack_bool_ratio():
    arr = np.zeros(10_000, dtype=bool)
    blob = bitpack_encode(arr)
    assert len(blob) < 2000


def test_raw_parts_consistent():
    arr = np.arange(-500, 500, dtype=np.int32)
    parts = bitpack_raw_parts(bitpack_encode(arr))
    assert parts["n"] == 1000
    assert parts["kind"] == 0
    assert parts["planes"].size == max(parts["bits"], 1) * parts["n_pad"] // 32


@given(
    st.lists(st.integers(min_value=-(2**30), max_value=2**30), max_size=200),
)
@settings(max_examples=50, deadline=None)
def test_bitpack_int_property(xs):
    arr = np.array(xs, dtype=np.int32)
    out = bitpack_decode(bitpack_encode(arr), np.int32)
    np.testing.assert_array_equal(out, arr)


@given(
    st.lists(
        st.floats(allow_nan=False, allow_infinity=False, width=32),
        max_size=200,
    )
)
@settings(max_examples=50, deadline=None)
def test_bitpack_float_property(xs):
    arr = np.array(xs, dtype=np.float32)
    out = bitpack_decode(bitpack_encode(arr), np.float32)
    np.testing.assert_array_equal(out, arr)  # bit-exact (xor transform)


def test_all_codecs_registered():
    assert set(CODECS) == {"bitpack", "zlib", "raw"}
