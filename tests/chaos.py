"""Deterministic chaos harness (ISSUE 8 / DESIGN.md §14).

One seed → one reproducible fault schedule → one run → one verdict.
:func:`draw_schedule` expands a seed into a :class:`FaultSchedule`
(which nodes fail / straggle / serve corrupt baskets, or where a
journaled service crashes mid-stream), and :func:`run_chaos` executes
it against a 3-shard replicated cluster (or a journaled service for
crash-restart schedules) and asserts the tentpole invariant:

  * every recovered result is **bit-identical** to the single-node
    reference, and
  * every degradation is **explicit** — a :class:`DegradedResult` whose
    error manifest names exactly the missing windows — with the fault
    ledger (retries, corrupt baskets, backoff) matching the schedule.

Nothing here sleeps: straggles are modeled seconds, crashes are
abandoned service objects, and the same seed replays the same schedule
forever.  ``pytest -m chaos`` sweeps the seeds (tests/test_chaos.py).
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

from repro.cluster import (
    ClusterCoordinator,
    DegradedResult,
    RetryPolicy,
    StorageNode,
    partition_store,
)
from repro.serve import DONE, JobJournal, SkimService
from tests.test_query import QUERY

#: schedule kinds a seed can draw
SCENARIOS = ("fail", "straggle", "corrupt", "mixed", "degraded", "crash")


@dataclass
class FaultSchedule:
    """One seed's reproducible fault plan."""

    seed: int
    scenario: str
    #: (node_index, kind, modeled delay_s) per injected fault
    faults: list[tuple[int, str, float]] = field(default_factory=list)
    #: crash scenario: windows streamed before each simulated crash
    crash_points: list[int] = field(default_factory=list)

    def describe(self) -> str:
        parts = [f"seed={self.seed}", self.scenario]
        parts += [f"node{n}:{k}" for n, k, _ in self.faults]
        parts += [f"crash@{w}" for w in self.crash_points]
        return " ".join(parts)


def draw_schedule(seed: int, n_nodes: int = 3, n_windows: int = 5) -> FaultSchedule:
    """Expand ``seed`` into a deterministic fault schedule."""
    rng = random.Random(seed)
    scenario = SCENARIOS[seed % len(SCENARIOS)]
    sched = FaultSchedule(seed=seed, scenario=scenario)
    if scenario == "crash":
        # one or two crashes at strictly increasing window watermarks
        first = rng.randrange(1, n_windows - 1)
        sched.crash_points.append(first)
        if rng.random() < 0.5 and first + 1 < n_windows:
            sched.crash_points.append(rng.randrange(1, n_windows - first))
        return sched
    n_faults = rng.randrange(1, n_nodes)  # never every node
    victims = rng.sample(range(n_nodes), n_faults)
    for v in victims:
        if scenario == "mixed":
            kind = rng.choice(("fail", "straggle", "corrupt"))
        elif scenario == "degraded":
            kind = "fail"
        else:
            kind = scenario
        delay = rng.uniform(10.0, 100.0) if kind == "straggle" else 0.0
        sched.faults.append((v, kind, delay))
    return sched


def build_chaos_cluster(store, schedule: FaultSchedule, n_nodes: int = 3):
    """A replicated (or, for degraded schedules, replica-less) cluster
    with the schedule's faults armed.  Pruning and cascading are off so
    every shard provably executes and every armed fault provably fires.
    """
    shards = partition_store(store, n_nodes)
    replicated = schedule.scenario != "degraded"
    nodes = [StorageNode(sh, prune=False, cascade=False) for sh in shards]
    replicas = (
        {
            sh.shard_id: StorageNode(
                sh, node_id=100 + sh.shard_id, prune=False, cascade=False
            )
            for sh in shards
        }
        if replicated
        else {}
    )
    coord = ClusterCoordinator(
        nodes,
        replicas=replicas,
        concurrency="serial",
        basket_events=store.basket_events,
        codec=store.codec,
        prune=False,
        retry_policy=RetryPolicy(seed=schedule.seed),
        allow_partial=not replicated,
    )
    for node_idx, kind, delay in schedule.faults:
        coord.nodes[node_idx].inject_fault(kind, delay_s=delay)
    return coord


def _assert_bit_identical(res, ref) -> None:
    assert res.n_passed == ref.n_passed
    assert res.n_input == ref.n_input
    assert res.output.manifest_hash() == ref.output.manifest_hash()


def _run_cluster_chaos(store, reference, schedule: FaultSchedule) -> dict:
    coord = build_chaos_cluster(store, schedule)
    res = coord.run(QUERY)
    recoverable = [f for f in schedule.faults if f[1] in ("fail", "corrupt")]
    n_corrupt = sum(1 for f in schedule.faults if f[1] == "corrupt")
    if schedule.scenario == "degraded":
        # no replicas: every failed shard is an EXPLICIT degradation
        assert isinstance(res, DegradedResult)
        failed = sorted(n for n, _, _ in schedule.faults)
        assert sorted(e.shard_id for e in res.errors) == failed
        expect_missing = sorted(
            w
            for n, _, _ in schedule.faults
            for w in coord.nodes[n].shard.window_ids
        )
        assert res.extras["missing_windows"] == expect_missing
        assert res.extras["degraded"] is True
    else:
        # replicas cover every fault: bit-identity, exact retry ledger
        assert not res.degraded
        _assert_bit_identical(res, reference)
        assert len(res.retries) == len(recoverable)
        assert {s for s, _, _ in res.retries} == {
            n for n, _, _ in recoverable
        }
        assert res.extras["corrupt_baskets"] == n_corrupt
        for node_idx, kind, _ in schedule.faults:
            q = coord.nodes[node_idx].quarantine
            assert (len(q) == 1) == (kind == "corrupt")
    return {
        "schedule": schedule.describe(),
        "degraded": bool(res.degraded),
        "retries": len(res.retries),
        "corrupt_baskets": res.extras.get("corrupt_baskets", 0),
    }


def _run_crash_chaos(store, schedule: FaultSchedule) -> dict:
    query = QUERY
    # uninterrupted journaled reference
    ref_svc = SkimService(store, journal=JobJournal())
    ref_job = ref_svc.submit(query, tenant="chaos")
    ref_svc.result(ref_job.job_id)

    journal = JobJournal()
    svc = SkimService(store, journal=journal)
    job = svc.submit(query, tenant="chaos")
    streamed = 0
    for point in schedule.crash_points:
        streamed += point
        while len(job.partials) < point:
            assert svc.step(), "service stalled before the crash point"
        # crash: abandon the service, recover a fresh one off the journal
        svc = SkimService.recover(journal, store)
        job = svc.jobs[job.job_id]
        assert job.resume_skip == streamed
    done = svc.result(job.job_id)
    assert done.state == DONE
    # post-recovery stream == the uninterrupted run's suffix
    assert done.windows_streamed() == ref_job.windows_streamed()[streamed:]
    assert [p.n_passed for p in done.partials] == [
        p.n_passed for p in ref_job.partials[streamed:]
    ]
    assert (
        done.result.output.manifest_hash()
        == ref_job.result.output.manifest_hash()
    )
    return {
        "schedule": schedule.describe(),
        "crashes": len(schedule.crash_points),
        "resumed_from": streamed,
    }


def run_chaos(store, reference, seed: int) -> dict:
    """Run one seed's schedule end-to-end; returns a ledger summary.

    Raises (AssertionError) on any silent corruption, missing ledger
    entry, or undeclared degradation — the chaos sweep's only passing
    outcomes are bit-identity and *explicit* degradation.
    """
    schedule = draw_schedule(seed)
    if schedule.scenario == "crash":
        return _run_crash_chaos(store, schedule)
    return _run_cluster_chaos(store, reference, schedule)


__all__ = [
    "SCENARIOS",
    "FaultSchedule",
    "build_chaos_cluster",
    "draw_schedule",
    "run_chaos",
]
