"""Pallas stream-compaction kernel — "return only the filtered data".

TPU adaptation: compaction is a data-dependent permutation, which the VPU
cannot scatter directly.  Instead each event tile builds a one-hot
permutation matrix from the exclusive prefix-sum of the survivor mask and
*matmuls* the payload through it — turning an irregular gather into an MXU
operation (DESIGN.md §7).  Tiles are then stitched by a small jnp scan
using the per-tile counts.

Two-pass structure:
  pass 1 (in-kernel): tile-local compaction + survivor count per tile,
  pass 2 (jnp):       place each tile's packed rows at the global offset.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EVENT_TILE = 512  # rows per tile; one-hot matmul is (512, 512) x (512, D)


def _compact_kernel(payload_ref, mask_ref, out_ref, count_ref):
    Eb = payload_ref.shape[0]
    mask = mask_ref[...] > 0  # (Eb,)
    maskf = mask.astype(jnp.float32)
    # exclusive prefix sum -> destination row for each surviving row
    pos = jnp.cumsum(maskf) - maskf  # (Eb,) float32, integral values
    rows = jax.lax.broadcasted_iota(jnp.float32, (Eb, Eb), 0)  # dest index j
    # one-hot permutation: P[j, i] = 1 iff row i survives and lands at j
    onehot = (rows == pos[None, :]) & mask[None, :]
    out_ref[...] = jnp.dot(
        onehot.astype(jnp.float32),
        payload_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)
    count_ref[0] = mask.astype(jnp.int32).sum()


@functools.partial(jax.jit, static_argnames=("interpret", "event_tile"))
def stream_compact(
    payload: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    interpret: bool = True,
    event_tile: int = EVENT_TILE,
):
    """Pack surviving rows of ``payload`` ((E, D), any float/int dtype) to the
    front; zero-fill the tail.  Returns (packed (E, D), count ()).
    """
    E, D = payload.shape
    assert E % event_tile == 0, (E, event_tile)
    n_tiles = E // event_tile

    packed_tiles, counts = pl.pallas_call(
        _compact_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((event_tile, D), lambda i: (i, 0)),
            pl.BlockSpec((event_tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((event_tile, D), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E, D), payload.dtype),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
        ],
        interpret=interpret,
    )(payload, mask.astype(jnp.int32))

    # pass 2: stitch tiles at global offsets (host-side jnp scan)
    tiles = packed_tiles.reshape(n_tiles, event_tile, D)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])

    def place(acc, inp):
        # rows beyond each tile's survivor count are zero, and tiles write
        # to disjoint [off, off+count) ranges — accumulate-add is exact.
        tile, off = inp
        cur = jax.lax.dynamic_slice(acc, (off, 0), (event_tile, D))
        acc = jax.lax.dynamic_update_slice(acc, cur + tile, (off, 0))
        return acc, None

    out0 = jnp.zeros((E + event_tile, D), payload.dtype)
    out, _ = jax.lax.scan(place, out0, (tiles, offsets))
    return out[:E], counts.sum()
