"""Pure-jnp oracles for every Pallas kernel.

These are the semantics of record: each kernel's test sweeps shapes/dtypes
and asserts ``assert_allclose`` against the function here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.expr import (
    RPN_ABS,
    RPN_ADD,
    RPN_BRANCH,
    RPN_CONST,
    RPN_DIV,
    RPN_MAX,
    RPN_MIN,
    RPN_MUL,
    RPN_NEG,
    RPN_SUB,
    RPN_SUM,
)

# ---------------------------------------------------------------------------
# predicate_eval
# ---------------------------------------------------------------------------

OP_GT, OP_GE, OP_LT, OP_LE, OP_EQ, OP_NE, OP_ABSLT, OP_ABSGT = range(8)

OP_IDS = {
    ">": OP_GT,
    ">=": OP_GE,
    "<": OP_LT,
    "<=": OP_LE,
    "==": OP_EQ,
    "!=": OP_NE,
    "abs<": OP_ABSLT,
    "abs>": OP_ABSGT,
}

GROUP_COUNT = 0  # count of objects passing all terms >= min_count
GROUP_HT = 1  # sum(weight * passing) cmp threshold
GROUP_ANY = 2  # OR over terms (flat boolean branches)
GROUP_MASS = 3  # leading-pair invariant mass inside [cmp_thr, cmp_thr2]
GROUP_DR = 4  # leading-pair ΔR cmp threshold
GROUP_EXPR = 5  # arithmetic stack program (Group.rpn) cmp threshold


def apply_op(x, op_id: int, thr: float):
    if op_id == OP_GT:
        return x > thr
    if op_id == OP_GE:
        return x >= thr
    if op_id == OP_LT:
        return x < thr
    if op_id == OP_LE:
        return x <= thr
    if op_id == OP_EQ:
        return x == thr
    if op_id == OP_NE:
        return x != thr
    if op_id == OP_ABSLT:
        return jnp.abs(x) < thr
    if op_id == OP_ABSGT:
        return jnp.abs(x) > thr
    raise ValueError(op_id)


def _lead_onehot(masked_pt: jnp.ndarray) -> jnp.ndarray:
    """(E, K) one-hot of each event's first maximal slot (ties -> lowest
    slot, i.e. storage order — the host lexsort tiebreak)."""
    i1 = jnp.argmax(masked_pt, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, masked_pt.shape, 1)
    return iota == i1[:, None]


def _pair_onehots(pt_a, va, pt_b, vb, same: bool):
    """Leading-pair selection: (oh1, oh2, ok).  Same-collection pairs take
    the two highest-pt objects of A; otherwise each collection's leading
    object.  ``ok`` marks events with a full pair (selection one-hots are
    garbage where it is False)."""
    neg = jnp.float32(-jnp.inf)
    ma = jnp.where(va, pt_a, neg)
    oh1 = _lead_onehot(ma)
    if same:
        oh2 = _lead_onehot(jnp.where(oh1, neg, ma))
        ok = va.astype(jnp.int32).sum(axis=-1) >= 2
    else:
        oh2 = _lead_onehot(jnp.where(vb, pt_b, neg))
        ok = (va.astype(jnp.int32).sum(axis=-1) >= 1) & (
            vb.astype(jnp.int32).sum(axis=-1) >= 1
        )
    return oh1, oh2, ok


def _sel(x: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """Select the one-hot slot of each event row: (E, K) -> (E,)."""
    return jnp.where(onehot, x, 0.0).sum(axis=-1)


def _unpack_validity(vg: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mass/ΔR groups pack two collections' validity planes into one float
    channel: bit0 = first collection, bit1 = second (values 0..3)."""
    return jnp.mod(vg, 2.0) >= 1.0, vg >= 2.0


def _p4(pt, eta, phi, mass):
    """(px, py, pz, E) from detector coordinates — mirrored term-for-term
    by the float64 host helper (repro.core.expr.leading_pair_mass)."""
    px = pt * jnp.cos(phi)
    py = pt * jnp.sin(phi)
    pz = pt * jnp.sinh(eta)
    ch = jnp.cosh(eta)
    e = jnp.sqrt(mass * mass + pt * pt * ch * ch)
    return px, py, pz, e


def _group_mass(grp, terms, vg, same: bool):
    ids = grp.term_ids  # (ptA, etaA, phiA, massA, ptB, etaB, phiB, massB)
    va, vb = _unpack_validity(vg)
    oh1, oh2, ok = _pair_onehots(terms[ids[0]], va, terms[ids[4]], vb, same)
    px1, py1, pz1, e1 = _p4(*(_sel(terms[i], oh1) for i in ids[:4]))
    px2, py2, pz2, e2 = _p4(*(_sel(terms[i], oh2) for i in ids[4:]))
    m2 = (
        (e1 + e2) * (e1 + e2)
        - (px1 + px2) * (px1 + px2)
        - (py1 + py2) * (py1 + py2)
        - (pz1 + pz2) * (pz1 + pz2)
    )
    mass = jnp.sqrt(jnp.maximum(m2, 0.0))
    return ok & (mass >= grp.cmp_thr) & (mass <= grp.cmp_thr2)


def _group_dr(grp, terms, vg, same: bool):
    ids = grp.term_ids  # (ptA, etaA, phiA, ptB, etaB, phiB)
    va, vb = _unpack_validity(vg)
    oh1, oh2, ok = _pair_onehots(terms[ids[0]], va, terms[ids[3]], vb, same)
    deta = _sel(terms[ids[1]], oh1) - _sel(terms[ids[4]], oh2)
    pi = jnp.float32(np.pi)
    dphi = jnp.mod(
        _sel(terms[ids[2]], oh1) - _sel(terms[ids[5]], oh2) + pi, 2.0 * pi
    ) - pi
    dr = jnp.sqrt(deta * deta + dphi * dphi)
    return ok & apply_op(dr, grp.cmp_op, grp.cmp_thr)


def _group_expr(grp, terms):
    """Stack-program evaluation over term slots: flat branches read slot 0,
    sum() reductions sum the zero-padded slots (invalid slots are exactly
    0.0 by the ingest contract, so no validity channel is needed)."""
    stack: list = []
    for op, arg in grp.rpn:
        if op == RPN_BRANCH:
            stack.append(terms[int(arg)][:, 0])
        elif op == RPN_SUM:
            stack.append(terms[int(arg)].sum(axis=-1))
        elif op == RPN_CONST:
            stack.append(jnp.float32(arg))
        elif op == RPN_NEG:
            stack.append(-stack.pop())
        elif op == RPN_ABS:
            stack.append(jnp.abs(stack.pop()))
        else:
            b = stack.pop()
            a = stack.pop()
            if op == RPN_ADD:
                stack.append(a + b)
            elif op == RPN_SUB:
                stack.append(a - b)
            elif op == RPN_MUL:
                stack.append(a * b)
            elif op == RPN_DIV:
                stack.append(a / b)
            elif op == RPN_MIN:
                stack.append(jnp.minimum(a, b))
            elif op == RPN_MAX:
                stack.append(jnp.maximum(a, b))
            else:
                raise ValueError(f"unknown RPN op {op}")
    return apply_op(stack[-1], grp.cmp_op, grp.cmp_thr)


def _coll2(program, g: int):
    c2 = getattr(program, "group_collections2", ())
    return c2[g] if c2 else None


def predicate_mask(program, terms, valid, weights) -> jnp.ndarray:
    """Evaluate a compiled predicate program (the single body shared by
    this oracle, the Pallas predicate kernel, and the fused kernel).

    Args:
      terms:   (T, E, K) float32 — per-term padded values.
      valid:   (G, E, K) float — per-group object validity (mass/ΔR groups
               carry two packed planes, see ``_unpack_validity``).
      weights: (G, E, K) float32 — per-group HT weights (zeros if unused).
      program: static description (see kernels.predicate_eval.Program).
    Returns: (E,) bool event mask.
    """
    E = terms.shape[1]
    mask = jnp.ones((E,), dtype=bool)
    for g, grp in enumerate(program.groups):
        if grp.kind == GROUP_ANY:
            gpass = jnp.zeros((E,), dtype=bool)
            for t, op, thr in zip(grp.term_ids, grp.ops, grp.thrs):
                gpass = gpass | apply_op(terms[t, :, 0], op, thr)
        elif grp.kind == GROUP_EXPR:
            gpass = _group_expr(grp, terms)
        elif grp.kind in (GROUP_MASS, GROUP_DR):
            same = program.group_collections[g] == _coll2(program, g)
            fn = _group_mass if grp.kind == GROUP_MASS else _group_dr
            gpass = fn(grp, terms, valid[g], same)
        else:
            obj = jnp.ones(terms.shape[1:], dtype=bool)  # (E, K)
            for t, op, thr in zip(grp.term_ids, grp.ops, grp.thrs):
                obj = obj & apply_op(terms[t], op, thr)
            obj = obj & (valid[g] > 0)
            if grp.kind == GROUP_COUNT:
                gpass = obj.astype(jnp.int32).sum(axis=-1) >= grp.min_count
            elif grp.kind == GROUP_HT:
                ht = (weights[g] * obj.astype(jnp.float32)).sum(axis=-1)
                gpass = apply_op(ht, grp.cmp_op, grp.cmp_thr)
            else:
                raise ValueError(grp.kind)
        mask = mask & gpass
    return mask


def predicate_eval_ref(terms, valid, weights, program) -> jnp.ndarray:
    """Oracle alias of :func:`predicate_mask` (the semantics of record)."""
    return predicate_mask(program, terms, valid, weights)


# ---------------------------------------------------------------------------
# stream_compact
# ---------------------------------------------------------------------------


def stream_compact_ref(payload: jnp.ndarray, mask: jnp.ndarray):
    """Pack rows of ``payload`` where ``mask`` is true to the front.

    Returns (packed (E, D) with survivors first then zeros, count ()).
    """
    E = payload.shape[0]
    mask = mask.astype(bool)
    order = jnp.argsort(~mask, stable=True)  # survivors first, stable
    packed = payload[order]
    count = mask.sum(dtype=jnp.int32)
    keep = jnp.arange(E) < count
    packed = jnp.where(keep[:, None], packed, 0)
    return packed, count


# ---------------------------------------------------------------------------
# basket_decode
# ---------------------------------------------------------------------------


def basket_decode_ref(planes, firsts, kind: int, n_values: int, out_dtype):
    """Decode a batch of bit-plane baskets.

    Args:
      planes: (N, B, W) uint32 — B bit-planes of W words per basket
              (planes above the basket's true bit width are zero).
      firsts: (N,) uint32 — first raw value (bit pattern).
      kind:   static int — 0 int-delta, 1 float-xor, 2 bool.
      n_values: static — values per basket (W*32 >= n_values).
    Returns: (N, n_values) array of ``out_dtype``.
    """
    N, B, W = planes.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    codes = jnp.zeros((N, W * 32), dtype=jnp.uint32)
    for j in range(B):
        bits = (planes[:, j, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
        codes = codes | (bits.reshape(N, W * 32) << jnp.uint32(j))
    codes = codes[:, :n_values]

    if kind == 2:  # bool
        return codes.astype(out_dtype)
    if kind == 0:  # zigzag delta + cumsum
        u = codes.astype(jnp.uint32)
        dec = (u >> 1).astype(jnp.int32) ^ -(u & 1).astype(jnp.int32)
        first = jax.lax.bitcast_convert_type(firsts.astype(jnp.uint32), jnp.int32)
        dec = dec.at[:, 0].set(first)
        return jnp.cumsum(dec, axis=1).astype(out_dtype)
    if kind == 1:  # xor prefix + bitcast
        codes = codes.at[:, 0].set(firsts)
        acc = jax.lax.associative_scan(jnp.bitwise_xor, codes, axis=1)
        return jax.lax.bitcast_convert_type(acc, jnp.float32).astype(out_dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


def flash_attention_ref(q, k, v, causal: bool = True, sm_scale: float | None = None):
    """(B, H, S, D) reference attention; fp32 accumulation."""
    B, H, S, D = q.shape
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(D)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs.astype(v.dtype), v)
