"""Pure-jnp oracles for every Pallas kernel.

These are the semantics of record: each kernel's test sweeps shapes/dtypes
and asserts ``assert_allclose`` against the function here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# predicate_eval
# ---------------------------------------------------------------------------

OP_GT, OP_GE, OP_LT, OP_LE, OP_EQ, OP_NE, OP_ABSLT, OP_ABSGT = range(8)

OP_IDS = {
    ">": OP_GT,
    ">=": OP_GE,
    "<": OP_LT,
    "<=": OP_LE,
    "==": OP_EQ,
    "!=": OP_NE,
    "abs<": OP_ABSLT,
    "abs>": OP_ABSGT,
}

GROUP_COUNT = 0  # count of objects passing all terms >= min_count
GROUP_HT = 1  # sum(weight * passing) cmp threshold
GROUP_ANY = 2  # OR over terms (flat boolean branches)


def apply_op(x, op_id: int, thr: float):
    if op_id == OP_GT:
        return x > thr
    if op_id == OP_GE:
        return x >= thr
    if op_id == OP_LT:
        return x < thr
    if op_id == OP_LE:
        return x <= thr
    if op_id == OP_EQ:
        return x == thr
    if op_id == OP_NE:
        return x != thr
    if op_id == OP_ABSLT:
        return jnp.abs(x) < thr
    if op_id == OP_ABSGT:
        return jnp.abs(x) > thr
    raise ValueError(op_id)


def predicate_eval_ref(terms, valid, weights, program) -> jnp.ndarray:
    """Evaluate a compiled predicate program.

    Args:
      terms:   (T, E, K) float32 — per-term padded values.
      valid:   (G, E, K) bool/float — per-group object validity.
      weights: (G, E, K) float32 — per-group HT weights (zeros if unused).
      program: static description (see kernels.predicate_eval.Program):
        groups: list of dicts with keys kind, term_ids, ops, thrs,
                min_count, cmp_op, cmp_thr.
    Returns: (E,) bool event mask.
    """
    E = terms.shape[1]
    mask = jnp.ones((E,), dtype=bool)
    for g, grp in enumerate(program.groups):
        if grp.kind == GROUP_ANY:
            gpass = jnp.zeros((E,), dtype=bool)
            for t, op, thr in zip(grp.term_ids, grp.ops, grp.thrs):
                gpass = gpass | apply_op(terms[t, :, 0], op, thr)
        else:
            obj = jnp.ones(terms.shape[1:], dtype=bool)  # (E, K)
            for t, op, thr in zip(grp.term_ids, grp.ops, grp.thrs):
                obj = obj & apply_op(terms[t], op, thr)
            obj = obj & (valid[g] > 0)
            if grp.kind == GROUP_COUNT:
                gpass = obj.sum(axis=-1) >= grp.min_count
            elif grp.kind == GROUP_HT:
                ht = (weights[g] * obj.astype(jnp.float32)).sum(axis=-1)
                gpass = apply_op(ht, grp.cmp_op, grp.cmp_thr)
            else:
                raise ValueError(grp.kind)
        mask = mask & gpass
    return mask


# ---------------------------------------------------------------------------
# stream_compact
# ---------------------------------------------------------------------------


def stream_compact_ref(payload: jnp.ndarray, mask: jnp.ndarray):
    """Pack rows of ``payload`` where ``mask`` is true to the front.

    Returns (packed (E, D) with survivors first then zeros, count ()).
    """
    E = payload.shape[0]
    mask = mask.astype(bool)
    order = jnp.argsort(~mask, stable=True)  # survivors first, stable
    packed = payload[order]
    count = mask.sum(dtype=jnp.int32)
    keep = jnp.arange(E) < count
    packed = jnp.where(keep[:, None], packed, 0)
    return packed, count


# ---------------------------------------------------------------------------
# basket_decode
# ---------------------------------------------------------------------------


def basket_decode_ref(planes, firsts, kind: int, n_values: int, out_dtype):
    """Decode a batch of bit-plane baskets.

    Args:
      planes: (N, B, W) uint32 — B bit-planes of W words per basket
              (planes above the basket's true bit width are zero).
      firsts: (N,) uint32 — first raw value (bit pattern).
      kind:   static int — 0 int-delta, 1 float-xor, 2 bool.
      n_values: static — values per basket (W*32 >= n_values).
    Returns: (N, n_values) array of ``out_dtype``.
    """
    N, B, W = planes.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    codes = jnp.zeros((N, W * 32), dtype=jnp.uint32)
    for j in range(B):
        bits = (planes[:, j, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
        codes = codes | (bits.reshape(N, W * 32) << jnp.uint32(j))
    codes = codes[:, :n_values]

    if kind == 2:  # bool
        return codes.astype(out_dtype)
    if kind == 0:  # zigzag delta + cumsum
        u = codes.astype(jnp.uint32)
        dec = (u >> 1).astype(jnp.int32) ^ -(u & 1).astype(jnp.int32)
        first = jax.lax.bitcast_convert_type(firsts.astype(jnp.uint32), jnp.int32)
        dec = dec.at[:, 0].set(first)
        return jnp.cumsum(dec, axis=1).astype(out_dtype)
    if kind == 1:  # xor prefix + bitcast
        codes = codes.at[:, 0].set(firsts)
        acc = jax.lax.associative_scan(jnp.bitwise_xor, codes, axis=1)
        return jax.lax.bitcast_convert_type(acc, jnp.float32).astype(out_dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


def flash_attention_ref(q, k, v, causal: bool = True, sm_scale: float | None = None):
    """(B, H, S, D) reference attention; fp32 accumulation."""
    B, H, S, D = q.shape
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(D)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs.astype(v.dtype), v)
