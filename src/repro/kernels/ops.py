"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False when a TPU
backend is present; callers can override.  Shape guards pad inputs to the
kernels' tile multiples and slice results back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import basket_decode as _bd
from repro.kernels import flash_attention as _fa
from repro.kernels import predicate_eval as _pe
from repro.kernels import stream_compact as _sc
from repro.kernels.predicate_eval import Program, compile_query  # re-export


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# dispatch / compile accounting (DESIGN.md §16)
#
# Every device entry point below notes one "dispatch" per real call and
# one "compile" per unique (entry, program, shape) signature — the
# compiled-program cache currency the recompile-regression test and
# benchmarks/bench_device.py pin.  "warmups" counts the zero-input
# warm-up dispatches the executors pay once per shape bucket, OUTSIDE
# their stage timers (satellite of DESIGN.md §16; same treatment the
# single-window path got in §4).
# ---------------------------------------------------------------------------

_DISPATCH_STATS = {"dispatches": 0, "compiles": 0, "warmups": 0}
_SEEN_SIGNATURES: set = set()


def reset_dispatch_stats() -> None:
    _DISPATCH_STATS.update(dispatches=0, compiles=0, warmups=0)
    _SEEN_SIGNATURES.clear()


def dispatch_stats() -> dict:
    return dict(_DISPATCH_STATS)


def _note_dispatch(sig, warm: bool = False) -> None:
    if sig not in _SEEN_SIGNATURES:
        _SEEN_SIGNATURES.add(sig)
        _DISPATCH_STATS["compiles"] += 1
    if warm:
        _DISPATCH_STATS["warmups"] += 1
    else:
        _DISPATCH_STATS["dispatches"] += 1


def donate_supported() -> bool:
    """Buffer donation is a no-op (with a warning) on CPU backends —
    gate the donated jit variants to accelerators."""
    return jax.default_backend() in ("tpu", "gpu")


# ---------------------------------------------------------------------------
# bit-packed survivor masks (host <-> device interchange format)
# ---------------------------------------------------------------------------


def pack_mask(mask: np.ndarray) -> np.ndarray:
    """Bool mask -> little-endian uint32 words over the last axis
    (bit ``j`` of word ``w`` is event ``w*32 + j``); pads to 32."""
    m = np.asarray(mask, dtype=np.uint8)
    pad = (-m.shape[-1]) % 32
    if pad:
        widths = [(0, 0)] * (m.ndim - 1) + [(0, pad)]
        m = np.pad(m, widths)
    packed = np.packbits(m, axis=-1, bitorder="little")
    return np.ascontiguousarray(packed).view("<u4")


def unpack_mask(words: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_mask`: uint32 words -> (..., n) bool."""
    b = np.ascontiguousarray(words).view(np.uint8)
    bits = np.unpackbits(b, axis=-1, bitorder="little")
    return bits[..., :n].astype(bool)


def _pack_bits_jnp(mask):
    """(B, E) bool -> (B, E//32) uint32 on device (E multiple of 32)."""
    Bn, E = mask.shape
    m = mask.reshape(Bn, E // 32, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(m << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def _unpack_bits_jnp(words, E: int):
    """(B, W) uint32 -> (B, E) bool on device (E == W*32)."""
    Bn = words.shape[0]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(Bn, -1)[:, :E].astype(bool)


def _pad_to(x: np.ndarray | jnp.ndarray, axis: int, multiple: int, value=0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


def predicate_eval(terms, valid, weights, program: Program, interpret=None):
    """(T,E,K),(G,E,K),(G,E,K) -> (E,) int32 mask; E padded internally."""
    interpret = default_interpret() if interpret is None else interpret
    tile = min(_pe.EVENT_TILE, max(128, terms.shape[1]))
    tile = 1 << (tile - 1).bit_length()  # pow2 for clean padding
    terms_p, E = _pad_to(jnp.asarray(terms, jnp.float32), 1, tile)
    valid_p, _ = _pad_to(jnp.asarray(valid, jnp.float32), 1, tile)
    weights_p, _ = _pad_to(jnp.asarray(weights, jnp.float32), 1, tile)
    out = _pe.predicate_eval(
        terms_p, valid_p, weights_p, program=program, interpret=interpret,
        event_tile=tile,
    )
    return out[:E]


def stream_compact(payload, mask, interpret=None):
    """(E,D),(E,) -> packed (E,D), count. E padded internally."""
    interpret = default_interpret() if interpret is None else interpret
    tile = min(_sc.EVENT_TILE, max(128, payload.shape[0]))
    tile = 1 << (tile - 1).bit_length()
    payload_p, E = _pad_to(jnp.asarray(payload), 0, tile)
    mask_p, _ = _pad_to(jnp.asarray(mask, jnp.int32), 0, tile)
    packed, count = _sc.stream_compact(
        payload_p, mask_p, interpret=interpret, event_tile=tile
    )
    return packed[:E], count


def basket_decode_batch(parts_list, out_dtype, interpret=None, use_pallas=None):
    """Decode a batch of ``bitpack_raw_parts`` dicts of the same kind.

    Pads plane counts/words to the batch max, runs the decode once on the
    device tier — the Pallas kernel on TPU, its jitted jnp mirror
    (:func:`repro.kernels.basket_decode.basket_decode_ref`) elsewhere —
    and returns a list of correctly-sized arrays, bit-identical to the
    host codec reference (``repro.data.codecs.bitpack_decode``).
    """
    interpret = default_interpret() if interpret is None else interpret
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    kind = parts_list[0]["kind"]
    assert all(p["kind"] == kind for p in parts_list)
    if kind == 3:  # KIND_RAW_F32: literals — passthrough, nothing to decode
        return [p["raw"].astype(np.dtype(out_dtype)) for p in parts_list]
    bits_max = max(p["bits"] for p in parts_list)
    wpp = [p["n_pad"] // 32 for p in parts_list]
    w_max = max(wpp)
    # lane-align word count (128-lane VPU)
    w_max = int(-(-w_max // 128) * 128)

    N = len(parts_list)
    planes = np.zeros((N, bits_max, w_max), dtype=np.uint32)
    firsts = np.zeros((N,), dtype=np.uint32)
    for i, p in enumerate(parts_list):
        pw = p["planes"].reshape(max(p["bits"], 1), -1)
        planes[i, : pw.shape[0], : pw.shape[1]] = pw
        firsts[i] = p["first"]

    _note_dispatch(("decode", kind, planes.shape, bool(use_pallas)))
    if use_pallas:
        out = _bd.basket_decode(
            jnp.asarray(planes),
            jnp.asarray(firsts),
            kind=kind,
            n_bits=bits_max,
            out_dtype=out_dtype,
            interpret=interpret,
        )
    else:
        out = _bd.basket_decode_ref(
            jnp.asarray(planes),
            jnp.asarray(firsts),
            kind=kind,
            n_bits=bits_max,
            out_dtype=out_dtype,
        )
    out = np.asarray(out)
    return [out[i, : p["n"]] for i, p in enumerate(parts_list)]


# ---------------------------------------------------------------------------
# window-batched cascade stage (DESIGN.md §16)
# ---------------------------------------------------------------------------


def _cascade_stage_impl(
    terms, valid, weights, packed, seg_ids, *, program, nb, use_pallas
):
    """One batched cascade stage, entirely on device.

    ``terms`` (B,T,E,K) / ``valid``+``weights`` (B,G,E,K) are the staged
    window inputs (zeros outside alive spans — dead events stay dead
    under the AND below, so the zero filler can never resurrect them);
    ``packed`` (B, E/32) uint32 is the device-resident survivor mask
    carried between stages; ``seg_ids`` (B, E) int32 maps each event
    slot to its window-local basket ordinal.

    Returns ``(new_packed, basket_alive (B, nb) int32, counts (B,))`` —
    only the basket bits and the per-window alive counts cross back to
    the host per stage; the event-level mask stays device-resident
    until the window-ledger boundary.
    """
    from repro.kernels import ref as _ref

    Bn, T, E, K = terms.shape
    if use_pallas:
        m = _pe.predicate_eval_batch(
            terms, valid, weights, program=program, interpret=False
        )
    else:
        m = jax.vmap(
            lambda t, v, w: _ref.predicate_eval_ref(t, v, w, program)
        )(terms, valid, weights)
    alive = _unpack_bits_jnp(packed, E) & (m > 0)
    new_packed = _pack_bits_jnp(alive)
    counts = jnp.sum(alive, axis=1, dtype=jnp.int32)

    def _baskets(ids, al):
        return jnp.zeros((nb,), jnp.int32).at[ids].max(al.astype(jnp.int32))

    basket_alive = jax.vmap(_baskets)(seg_ids, alive)
    return new_packed, basket_alive, counts


_cascade_stage_jit = jax.jit(
    _cascade_stage_impl, static_argnames=("program", "nb", "use_pallas")
)
# accelerator variant: the carried mask buffer is donated — stage k+1
# reuses stage k's words in place, so the masks never re-materialize
_cascade_stage_jit_donated = jax.jit(
    _cascade_stage_impl,
    static_argnames=("program", "nb", "use_pallas"),
    donate_argnums=(3,),
)


def _cascade_sig(program, shape, nb, use_pallas):
    return ("cascade_stage", program, tuple(shape), int(nb), bool(use_pallas))


def _resolve_cascade_flags(use_pallas, donate):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if donate is None:
        donate = donate_supported()
    return bool(use_pallas), bool(donate)


def warm_cascade_stage(
    program: Program, shape, nb: int, use_pallas=None, donate=None
) -> bool:
    """Warm the compiled cascade step for one shape bucket (zeros inputs).

    Called by the executor OUTSIDE its stage timers on the first sight of
    a ``(program, batch shape)`` signature, so measured filter time is
    steady-state dispatch, never compilation.  Returns True when a
    warm-up actually ran.  (Zeros inputs, not the real batch: the donated
    variant consumes its mask argument, so the real buffers cannot be
    dispatched twice.)
    """
    use_pallas, donate = _resolve_cascade_flags(use_pallas, donate)
    sig = _cascade_sig(program, shape, nb, use_pallas)
    if sig in _SEEN_SIGNATURES:
        return False
    Bn, T, E, K = shape
    G = program.n_groups
    zeros = functools.partial(jnp.zeros, dtype=jnp.float32)
    fn = _cascade_stage_jit_donated if donate else _cascade_stage_jit
    out = fn(
        zeros((Bn, T, E, K)),
        zeros((Bn, G, E, K)),
        zeros((Bn, G, E, K)),
        jnp.zeros((Bn, E // 32), jnp.uint32),
        jnp.zeros((Bn, E), jnp.int32),
        program=program,
        nb=nb,
        use_pallas=use_pallas,
    )
    jax.block_until_ready(out)
    _note_dispatch(sig, warm=True)
    return True


def cascade_stage_step(
    terms,
    valid,
    weights,
    packed,
    seg_ids,
    program: Program,
    nb: int,
    use_pallas=None,
    donate=None,
):
    """Public batched cascade stage: one device dispatch per (stage,
    window-batch).  See :func:`_cascade_stage_impl` for the contract.
    With ``donate`` (default on accelerators) the ``packed`` argument is
    consumed — callers must keep only the returned mask."""
    use_pallas, donate = _resolve_cascade_flags(use_pallas, donate)
    _note_dispatch(_cascade_sig(program, terms.shape, nb, use_pallas))
    fn = _cascade_stage_jit_donated if donate else _cascade_stage_jit
    return fn(
        jnp.asarray(terms, jnp.float32),
        jnp.asarray(valid, jnp.float32),
        jnp.asarray(weights, jnp.float32),
        packed,
        seg_ids,
        program=program,
        nb=nb,
        use_pallas=use_pallas,
    )


@functools.partial(jax.jit, static_argnames=("program",))
def _fused_ref_batch(terms, valid, weights, payload, *, program):
    """Vmapped jitted oracle: the one-dispatch batched fused skim on
    non-TPU backends (same semantics per window as ``_fused_ref``)."""
    from repro.kernels import ref

    def _one(t, v, w, p):
        mask = ref.predicate_eval_ref(t, v, w, program)
        return ref.stream_compact_ref(p, mask)

    return jax.vmap(_one)(terms, valid, weights, payload)


def fused_skim_batch(
    terms, valid, weights, payload, program: Program, use_pallas=None
):
    """Window-batched one-pass skim: ONE device dispatch for a batch.

    ``terms`` (B,T,E,K), ``valid``/``weights`` (B,G,E,K), ``payload``
    (B,E,D); E must be a multiple of the fused kernel tile (the batched
    staging pads to the window quantum).  Returns (packed (B,E,D) with
    each window's survivors front-packed, counts (B,)) — per-window
    bit-identical to :func:`fused_skim`.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    terms = jnp.asarray(terms, jnp.float32)
    valid = jnp.asarray(valid, jnp.float32)
    weights = jnp.asarray(weights, jnp.float32)
    payload = jnp.asarray(payload)
    _note_dispatch(("fused_batch", program, terms.shape, bool(use_pallas)))
    if use_pallas:
        from repro.kernels import skim_fused as _sf

        E = terms.shape[2]
        tile = min(_sf.EVENT_TILE, max(128, E))
        tile = 1 << (tile - 1).bit_length()
        assert E % tile == 0, (E, tile)
        tiles, counts = _sf.skim_fused_batch(
            terms, valid, weights, payload, program=program,
            interpret=default_interpret(), event_tile=tile,
        )
        out = jax.vmap(
            functools.partial(_sf.stitch_tiles, event_tile=tile)
        )(tiles, counts)
        return out, counts.sum(axis=1)
    return _fused_ref_batch(terms, valid, weights, payload, program=program)


def skim_fused(terms, valid, weights, payload, program: Program, interpret=None):
    """One-pass predicate+compact (beyond-paper fusion).  Returns
    (packed (E, D) with survivors front-packed globally, count)."""
    import jax.numpy as jnp  # local: keep module import graph light

    from repro.kernels import skim_fused as _sf

    interpret = default_interpret() if interpret is None else interpret
    tile = min(_sf.EVENT_TILE, max(128, terms.shape[1]))
    tile = 1 << (tile - 1).bit_length()
    terms_p, E = _pad_to(jnp.asarray(terms, jnp.float32), 1, tile)
    valid_p, _ = _pad_to(jnp.asarray(valid, jnp.float32), 1, tile)
    weights_p, _ = _pad_to(jnp.asarray(weights, jnp.float32), 1, tile)
    payload_p, _ = _pad_to(jnp.asarray(payload), 0, tile)
    packed_tiles, counts = _sf.skim_fused(
        terms_p, valid_p, weights_p, payload_p, program=program,
        interpret=interpret, event_tile=tile,
    )
    # stitch tiles at global offsets (same epilogue as stream_compact)
    out = _sf.stitch_tiles(packed_tiles, counts, event_tile=tile)
    return out[:E], counts.sum()


@functools.partial(jax.jit, static_argnames=("program",))
def _fused_ref(terms, valid, weights, payload, *, program):
    """Jitted oracle composition: same semantics as the fused Pallas kernel
    (one XLA program, no interpret-mode overhead on CPU backends)."""
    from repro.kernels import ref

    mask = ref.predicate_eval_ref(terms, valid, weights, program)
    return ref.stream_compact_ref(payload, mask)


def fused_skim(terms, valid, weights, payload, program: Program, use_pallas=None):
    """Backend-dispatched one-pass skim (the engine's device path).

    On TPU this is the fused Pallas kernel (predicate + compaction in one
    VMEM round trip); elsewhere the jitted jnp oracle with identical
    semantics — the equivalence is pinned by tests/test_skim_fused.py.
    Returns (packed (E, D) survivors-first, count).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    _note_dispatch(
        ("fused", program, tuple(terms.shape), bool(use_pallas))
    )
    if use_pallas:
        return skim_fused(
            terms, valid, weights, payload, program, interpret=default_interpret()
        )
    return _fused_ref(
        jnp.asarray(terms, jnp.float32),
        jnp.asarray(valid, jnp.float32),
        jnp.asarray(weights, jnp.float32),
        jnp.asarray(payload),
        program=program,
    )


def flash_attention(q, k, v, causal=True, sm_scale=None, block_q=None,
                    block_k=None, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    S = q.shape[2]
    bq = block_q or min(_fa.DEFAULT_BQ, S)
    bk = block_k or min(_fa.DEFAULT_BK, S)
    return _fa.flash_attention(
        q, k, v, causal=causal, sm_scale=sm_scale, block_q=bq, block_k=bk,
        interpret=interpret,
    )
