"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False when a TPU
backend is present; callers can override.  Shape guards pad inputs to the
kernels' tile multiples and slice results back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import basket_decode as _bd
from repro.kernels import flash_attention as _fa
from repro.kernels import predicate_eval as _pe
from repro.kernels import stream_compact as _sc
from repro.kernels.predicate_eval import Program, compile_query  # re-export


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: np.ndarray | jnp.ndarray, axis: int, multiple: int, value=0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


def predicate_eval(terms, valid, weights, program: Program, interpret=None):
    """(T,E,K),(G,E,K),(G,E,K) -> (E,) int32 mask; E padded internally."""
    interpret = default_interpret() if interpret is None else interpret
    tile = min(_pe.EVENT_TILE, max(128, terms.shape[1]))
    tile = 1 << (tile - 1).bit_length()  # pow2 for clean padding
    terms_p, E = _pad_to(jnp.asarray(terms, jnp.float32), 1, tile)
    valid_p, _ = _pad_to(jnp.asarray(valid, jnp.float32), 1, tile)
    weights_p, _ = _pad_to(jnp.asarray(weights, jnp.float32), 1, tile)
    out = _pe.predicate_eval(
        terms_p, valid_p, weights_p, program=program, interpret=interpret,
        event_tile=tile,
    )
    return out[:E]


def stream_compact(payload, mask, interpret=None):
    """(E,D),(E,) -> packed (E,D), count. E padded internally."""
    interpret = default_interpret() if interpret is None else interpret
    tile = min(_sc.EVENT_TILE, max(128, payload.shape[0]))
    tile = 1 << (tile - 1).bit_length()
    payload_p, E = _pad_to(jnp.asarray(payload), 0, tile)
    mask_p, _ = _pad_to(jnp.asarray(mask, jnp.int32), 0, tile)
    packed, count = _sc.stream_compact(
        payload_p, mask_p, interpret=interpret, event_tile=tile
    )
    return packed[:E], count


def basket_decode_batch(parts_list, out_dtype, interpret=None):
    """Decode a batch of ``bitpack_raw_parts`` dicts of the same kind.

    Pads plane counts/words to the batch max, runs the kernel once, and
    returns a list of correctly-sized arrays.
    """
    interpret = default_interpret() if interpret is None else interpret
    kind = parts_list[0]["kind"]
    assert all(p["kind"] == kind for p in parts_list)
    if kind == 3:  # KIND_RAW_F32: literals — passthrough, nothing to decode
        return [p["raw"].astype(np.dtype(out_dtype)) for p in parts_list]
    bits_max = max(p["bits"] for p in parts_list)
    wpp = [p["n_pad"] // 32 for p in parts_list]
    w_max = max(wpp)
    # lane-align word count (128-lane VPU)
    w_max = int(-(-w_max // 128) * 128)

    N = len(parts_list)
    planes = np.zeros((N, bits_max, w_max), dtype=np.uint32)
    firsts = np.zeros((N,), dtype=np.uint32)
    for i, p in enumerate(parts_list):
        pw = p["planes"].reshape(max(p["bits"], 1), -1)
        planes[i, : pw.shape[0], : pw.shape[1]] = pw
        firsts[i] = p["first"]

    out = _bd.basket_decode(
        jnp.asarray(planes),
        jnp.asarray(firsts),
        kind=kind,
        n_bits=bits_max,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    out = np.asarray(out)
    return [out[i, : p["n"]] for i, p in enumerate(parts_list)]


def skim_fused(terms, valid, weights, payload, program: Program, interpret=None):
    """One-pass predicate+compact (beyond-paper fusion).  Returns
    (packed (E, D) with survivors front-packed globally, count)."""
    import jax.numpy as jnp  # local: keep module import graph light

    from repro.kernels import skim_fused as _sf

    interpret = default_interpret() if interpret is None else interpret
    tile = min(_sf.EVENT_TILE, max(128, terms.shape[1]))
    tile = 1 << (tile - 1).bit_length()
    terms_p, E = _pad_to(jnp.asarray(terms, jnp.float32), 1, tile)
    valid_p, _ = _pad_to(jnp.asarray(valid, jnp.float32), 1, tile)
    weights_p, _ = _pad_to(jnp.asarray(weights, jnp.float32), 1, tile)
    payload_p, _ = _pad_to(jnp.asarray(payload), 0, tile)
    packed_tiles, counts = _sf.skim_fused(
        terms_p, valid_p, weights_p, payload_p, program=program,
        interpret=interpret, event_tile=tile,
    )
    # stitch tiles at global offsets (same epilogue as stream_compact)
    out = _sf.stitch_tiles(packed_tiles, counts, event_tile=tile)
    return out[:E], counts.sum()


@functools.partial(jax.jit, static_argnames=("program",))
def _fused_ref(terms, valid, weights, payload, *, program):
    """Jitted oracle composition: same semantics as the fused Pallas kernel
    (one XLA program, no interpret-mode overhead on CPU backends)."""
    from repro.kernels import ref

    mask = ref.predicate_eval_ref(terms, valid, weights, program)
    return ref.stream_compact_ref(payload, mask)


def fused_skim(terms, valid, weights, payload, program: Program, use_pallas=None):
    """Backend-dispatched one-pass skim (the engine's device path).

    On TPU this is the fused Pallas kernel (predicate + compaction in one
    VMEM round trip); elsewhere the jitted jnp oracle with identical
    semantics — the equivalence is pinned by tests/test_skim_fused.py.
    Returns (packed (E, D) survivors-first, count).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return skim_fused(
            terms, valid, weights, payload, program, interpret=default_interpret()
        )
    return _fused_ref(
        jnp.asarray(terms, jnp.float32),
        jnp.asarray(valid, jnp.float32),
        jnp.asarray(weights, jnp.float32),
        jnp.asarray(payload),
        program=program,
    )


def flash_attention(q, k, v, causal=True, sm_scale=None, block_q=None,
                    block_k=None, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    S = q.shape[2]
    bq = block_q or min(_fa.DEFAULT_BQ, S)
    bk = block_k or min(_fa.DEFAULT_BK, S)
    return _fa.flash_attention(
        q, k, v, causal=causal, sm_scale=sm_scale, block_q=bq, block_k=bk,
        interpret=interpret,
    )
