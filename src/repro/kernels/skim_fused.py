"""Fused predicate-eval + stream-compact kernel (beyond-paper; DESIGN.md §7).

The paper evaluates the predicate, then gathers survivors — two passes
over the event data.  On TPU both fit in one VMEM round trip: each event
tile evaluates the compiled program AND compacts its surviving payload
rows via the one-hot MXU permutation in the same kernel body, so the mask
never travels to HBM.  One pass, one output stream — exactly the "return
only the filtered data" contract, minus a full HBM round trip of the
payload + mask.

Data-layout contract (the engine's ``near_data`` fast path rides on it):
inputs are the padded window tensors from
``repro.core.neardata.build_padded_inputs`` — terms (T, E, K), validity /
HT weights (G, E, K), payload (E, D) — and by convention payload column 0
is the *local event index*, so the compacted output alone lets the host
recover the survivor mask without the mask ever leaving the device.
Tiles are stitched to a globally front-packed stream by
:func:`stitch_tiles`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.predicate_eval import Program
from repro.kernels.ref import predicate_mask

EVENT_TILE = 512


def _fused_kernel(terms_ref, valid_ref, weights_ref, payload_ref,
                  out_ref, count_ref, *, program: Program):
    Eb = payload_ref.shape[0]
    # --- predicate (shared body: repro.kernels.ref.predicate_mask) ---
    mask = predicate_mask(
        program, terms_ref[...], valid_ref[...], weights_ref[...]
    )

    # --- compact (same body as stream_compact) ---
    maskf = mask.astype(jnp.float32)
    pos = jnp.cumsum(maskf) - maskf
    rows = jax.lax.broadcasted_iota(jnp.float32, (Eb, Eb), 0)
    onehot = (rows == pos[None, :]) & mask[None, :]
    out_ref[...] = jnp.dot(
        onehot.astype(jnp.float32),
        payload_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)
    count_ref[0] = mask.astype(jnp.int32).sum()


@functools.partial(jax.jit, static_argnames=("event_tile",))
def stitch_tiles(packed_tiles, counts, *, event_tile: int):
    """Place each tile's front-packed rows at its global offset.

    Rows beyond a tile's survivor count are zero and tiles write to
    disjoint [off, off+count) ranges, so accumulate-add is exact.  Shared
    epilogue of the fused and two-pass compaction paths.
    """
    E, D = packed_tiles.shape
    n_tiles = E // event_tile
    tiles = packed_tiles.reshape(n_tiles, event_tile, D)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])

    def place(acc, inp):
        tile, off = inp
        cur = jax.lax.dynamic_slice(acc, (off, 0), (event_tile, D))
        return jax.lax.dynamic_update_slice(acc, cur + tile, (off, 0)), None

    out0 = jnp.zeros((E + event_tile, D), packed_tiles.dtype)
    out, _ = jax.lax.scan(place, out0, (tiles, offsets))
    return out[:E]


def _fused_kernel_batched(terms_ref, valid_ref, weights_ref, payload_ref,
                          out_ref, count_ref, *, program: Program):
    """Window-batched body: blocks carry a leading window dim of 1 (the
    outer grid axis); the evaluation is the same shared predicate +
    one-hot compaction as :func:`_fused_kernel`."""
    Eb = payload_ref.shape[1]
    mask = predicate_mask(
        program, terms_ref[0], valid_ref[0], weights_ref[0]
    )
    maskf = mask.astype(jnp.float32)
    pos = jnp.cumsum(maskf) - maskf
    rows = jax.lax.broadcasted_iota(jnp.float32, (Eb, Eb), 0)
    onehot = (rows == pos[None, :]) & mask[None, :]
    out_ref[0] = jnp.dot(
        onehot.astype(jnp.float32),
        payload_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)
    count_ref[0, 0] = mask.astype(jnp.int32).sum()


@functools.partial(jax.jit, static_argnames=("program", "interpret", "event_tile"))
def skim_fused_batch(terms, valid, weights, payload, *, program: Program,
                     interpret: bool = True, event_tile: int = EVENT_TILE):
    """Window-batched one-pass skim: ONE dispatch for a whole batch of
    padded windows (DESIGN.md §16).

    Inputs carry a leading window axis — terms (B,T,E,K), valid/weights
    (B,G,E,K), payload (B,E,D) — and the grid runs (B, E/tile): the same
    fused kernel body as :func:`skim_fused`, with the batch as the outer
    (slowest) grid dimension so each window's tiles stay VMEM-local.
    Returns per-window per-tile packed payload (B,E,D) + per-tile counts
    (B, E/tile); stitch per window with :func:`stitch_tiles`.
    """
    Bn, T, E, K = terms.shape
    G = valid.shape[1]
    D = payload.shape[2]
    assert E % event_tile == 0
    n_tiles = E // event_tile

    return pl.pallas_call(
        functools.partial(_fused_kernel_batched, program=program),
        grid=(Bn, n_tiles),
        in_specs=[
            pl.BlockSpec((1, T, event_tile, K), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, G, event_tile, K), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, G, event_tile, K), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, event_tile, D), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, event_tile, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bn, E, D), payload.dtype),
            jax.ShapeDtypeStruct((Bn, n_tiles), jnp.int32),
        ],
        interpret=interpret,
    )(terms, valid, weights, payload)


@functools.partial(jax.jit, static_argnames=("program", "interpret", "event_tile"))
def skim_fused(terms, valid, weights, payload, *, program: Program,
               interpret: bool = True, event_tile: int = EVENT_TILE):
    """One-pass skim: (T,E,K),(G,E,K),(G,E,K),(E,D) -> per-tile packed
    payload (E, D) + per-tile survivor counts (E/tile,)."""
    T, E, K = terms.shape
    G = valid.shape[0]
    D = payload.shape[1]
    assert E % event_tile == 0
    n_tiles = E // event_tile

    return pl.pallas_call(
        functools.partial(_fused_kernel, program=program),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((T, event_tile, K), lambda i: (0, i, 0)),
            pl.BlockSpec((G, event_tile, K), lambda i: (0, i, 0)),
            pl.BlockSpec((G, event_tile, K), lambda i: (0, i, 0)),
            pl.BlockSpec((event_tile, D), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((event_tile, D), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E, D), payload.dtype),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
        ],
        interpret=interpret,
    )(terms, valid, weights, payload)
