"""Pallas tiled causal attention (model-plane hot spot).

Standard online-softmax flash attention: grid over (batch*heads, q tiles),
inner ``fori_loop`` over k/v tiles with running (max, sum, acc) carries.
Block sizes keep q/k/v tiles and the (Bq, Bk) logits tile in VMEM, with
MXU-aligned (multiple-of-128) matmul dims.  Validated in interpret mode
against ``ref.flash_attention_ref``; on TPU it replaces the XLA attention
in the training path when ``use_pallas_attention`` is set.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale, causal, block_k):
    # blocks: q (1, Bq, D), k (1, S, D), v (1, S, D), o (1, Bq, D)
    q = q_ref[0].astype(jnp.float32) * sm_scale  # (Bq, D)
    Bq, D = q.shape
    S = k_ref.shape[1]
    qi = pl.program_id(1)
    q_off = qi * Bq

    n_kblocks = S // block_k

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[0, pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (Bq, Bk)
        if causal:
            rows = q_off + jax.lax.broadcasted_iota(jnp.int32, (Bq, block_k), 0)
            cols = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (Bq, block_k), 1
            )
            logits = jnp.where(rows >= cols, logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    acc0 = jnp.zeros((Bq, D), jnp.float32)
    m0 = jnp.full((Bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Bq,), jnp.float32)

    if causal:
        # only k blocks at or before this q block contribute
        last = (q_off + Bq + block_k - 1) // block_k
        n_iter = jnp.minimum(last, n_kblocks)
    else:
        n_iter = n_kblocks
    acc, _, l = jax.lax.fori_loop(0, n_iter, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = True,
) -> jnp.ndarray:
    """(B, H, S, D) attention. S must divide by block_q and block_k."""
    B, H, S, D = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = sm_scale if sm_scale is not None else float(1.0 / np.sqrt(D))

    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, sm_scale=scale, causal=causal, block_k=block_k
        ),
        grid=(B * H, S // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
