"""Pallas TPU kernels for the skim data plane + model-plane hot spots.

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper
in ``ops.py``; tests sweep shapes/dtypes and assert allclose.
"""

from repro.kernels import ops, ref
from repro.kernels.predicate_eval import Group, Program, compile_query

__all__ = ["ops", "ref", "Group", "Program", "compile_query"]
