"""Pallas basket-decode kernel — the DPU decompression-engine analogue.

Decodes the ``bitpack`` codec (repro.data.codecs): per basket, ``B``
bit-planes of ``W`` uint32 words reconstruct up to ``W*32`` codes, followed
by the inverse transform:

  kind 0 (int)   : zigzag^-1 then inclusive prefix *sum*,
  kind 1 (float) : inclusive prefix *xor* then bitcast to f32,
  kind 2 (bool)  : identity.

Everything is broadcast/shift vector arithmetic plus a log-step Hillis–
Steele scan — no gathers, no byte shuffles — so the body maps directly onto
the VPU.  Grid = one basket per step; a basket's planes ((B, W) uint32,
typically <= 32x128 words = 16 KiB) sit comfortably in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KIND_INT, KIND_FLOAT, KIND_BOOL = 0, 1, 2


def _log_scan(x: jnp.ndarray, combine) -> jnp.ndarray:
    """Hillis–Steele inclusive scan over the last axis (static log steps)."""
    n = x.shape[-1]
    shift = 1
    while shift < n:
        pad = [(0, 0)] * (x.ndim - 1)
        shifted = jnp.pad(x[..., :-shift], [*pad, (shift, 0)])
        x = combine(x, shifted)
        shift *= 2
    return x


def _decode_kernel(planes_ref, first_ref, out_ref, *, kind: int, n_bits: int):
    planes = planes_ref[0]  # block is (1, B, W) uint32
    _, W = planes.shape
    V = W * 32
    shifts = jnp.arange(32, dtype=jnp.uint32)
    codes = jnp.zeros((V,), dtype=jnp.uint32)
    for j in range(n_bits):
        bits = (planes[j, :, None] >> shifts[None, :]) & jnp.uint32(1)
        codes = codes | (bits.reshape(V) << jnp.uint32(j))

    if kind == KIND_BOOL:
        out_ref[0, :] = codes.astype(out_ref.dtype)
        return
    if kind == KIND_INT:
        dec = (codes >> 1).astype(jnp.int32) ^ -(codes & 1).astype(jnp.int32)
        first = jax.lax.bitcast_convert_type(first_ref[0], jnp.int32)
        pos = jax.lax.broadcasted_iota(jnp.int32, (V,), 0)
        dec = jnp.where(pos == 0, first, dec)
        out_ref[0, :] = _log_scan(dec[None, :], jnp.add)[0].astype(out_ref.dtype)
        return
    # KIND_FLOAT: prefix-xor then bitcast
    pos = jax.lax.broadcasted_iota(jnp.int32, (V,), 0)
    codes = jnp.where(pos == 0, first_ref[0], codes)
    acc = _log_scan(codes[None, :], jnp.bitwise_xor)[0]
    out_ref[0, :] = jax.lax.bitcast_convert_type(acc, jnp.float32).astype(
        out_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("kind", "n_bits", "out_dtype"))
def basket_decode_ref(
    planes: jnp.ndarray,
    firsts: jnp.ndarray,
    *,
    kind: int,
    n_bits: int,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """Jitted jnp mirror of the Pallas decode kernel (the XLA device tier).

    Same bit-extract + inverse-transform body as :func:`_decode_kernel`,
    vectorized over the basket axis — this is what backs the device
    decode path on hosts without a TPU (``repro.kernels.ops
    .basket_decode_batch``), and it is bit-identical to the host codec:
    the int path is a wrap-exact int32 prefix sum, the float path an
    exact prefix xor, bools an identity.
    """
    N, B, W = planes.shape
    V = W * 32
    shifts = jnp.arange(32, dtype=jnp.uint32)
    codes = jnp.zeros((N, V), dtype=jnp.uint32)
    for j in range(n_bits):
        bits = (planes[:, j, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
        codes = codes | (bits.reshape(N, V) << jnp.uint32(j))

    if kind == KIND_BOOL:
        return codes.astype(out_dtype)
    pos = jax.lax.broadcasted_iota(jnp.int32, (N, V), 1)
    if kind == KIND_INT:
        dec = (codes >> 1).astype(jnp.int32) ^ -(codes & 1).astype(jnp.int32)
        first = jax.lax.bitcast_convert_type(
            firsts.astype(jnp.uint32), jnp.int32
        )
        dec = jnp.where(pos == 0, first[:, None], dec)
        return _log_scan(dec, jnp.add).astype(out_dtype)
    # KIND_FLOAT: prefix-xor then bitcast
    codes = jnp.where(pos == 0, firsts.astype(jnp.uint32)[:, None], codes)
    acc = _log_scan(codes, jnp.bitwise_xor)
    return jax.lax.bitcast_convert_type(acc, jnp.float32).astype(out_dtype)


@functools.partial(
    jax.jit, static_argnames=("kind", "n_bits", "out_dtype", "interpret")
)
def basket_decode(
    planes: jnp.ndarray,
    firsts: jnp.ndarray,
    *,
    kind: int,
    n_bits: int,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jnp.ndarray:
    """Decode ``N`` same-shaped baskets.

    Args:
      planes: (N, B, W) uint32 bit-planes (planes >= the true bit width are
              zero-padded by the encoder batcher).
      firsts: (N,) uint32 first-value bit patterns.
      kind, n_bits: static codec parameters for the batch.
    Returns: (N, W*32) decoded values of ``out_dtype``.
    """
    N, B, W = planes.shape
    assert n_bits <= B

    return pl.pallas_call(
        functools.partial(_decode_kernel, kind=kind, n_bits=n_bits),
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, B, W), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, W * 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, W * 32), out_dtype),
        interpret=interpret,
    )(planes, firsts)
