"""Pallas predicate-evaluation kernel (TPU target, interpret-validated).

The TPU analogue of SkimROOT's on-DPU filtering loop: a query's selection
criteria are compiled to a static *program* (term comparisons + group
reductions) and evaluated over VMEM tiles of padded columnar event data.
All thresholds/ops are baked into the kernel closure, so the inner body is
pure vector compares + reductions on the VPU — one pass over each basket.

Data layout (device path): events are dense tiles, collections padded to a
static ``K`` objects/event with a validity mask — the jagged->padded
conversion happens once at ingest (``repro.core.neardata``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import (
    GROUP_ANY,
    GROUP_COUNT,
    GROUP_HT,
    OP_IDS,
    apply_op,
)

EVENT_TILE = 1024  # events per grid step; multiple of 8*128 lanes


@dataclass(frozen=True)
class Group:
    kind: int  # GROUP_COUNT / GROUP_HT / GROUP_ANY
    term_ids: tuple[int, ...]
    ops: tuple[int, ...]
    thrs: tuple[float, ...]
    min_count: int = 1
    cmp_op: int = 0
    cmp_thr: float = 0.0


@dataclass(frozen=True)
class Program:
    """Static predicate program: ``T`` terms over ``G`` AND-ed groups."""

    groups: tuple[Group, ...]
    term_branches: tuple[str, ...]  # branch feeding each term slot
    group_collections: tuple[str | None, ...]  # validity source per group
    group_weights: tuple[str | None, ...]  # HT weight branch per group

    @property
    def n_terms(self) -> int:
        return len(self.term_branches)

    @property
    def n_groups(self) -> int:
        return len(self.groups)


def compile_query(query) -> Program:
    """Lower a :class:`repro.core.query.Query` to a :class:`Program`."""
    from repro.core.query import AnyOf, Cut, HTCut, ObjectSelection

    term_branches: list[str] = []
    groups: list[Group] = []
    group_colls: list[str | None] = []
    group_weights: list[str | None] = []

    def add_term(branch: str) -> int:
        term_branches.append(branch)
        return len(term_branches) - 1

    for _, stage in query.stages():
        for node in stage:
            if isinstance(node, Cut):
                t = add_term(node.branch)
                groups.append(
                    Group(GROUP_COUNT, (t,), (OP_IDS[node.op],), (float(node.value),))
                )
                group_colls.append(None)
                group_weights.append(None)
            elif isinstance(node, AnyOf):
                ids = tuple(add_term(n) for n in node.names)
                groups.append(
                    Group(GROUP_ANY, ids, (OP_IDS[">="],) * len(ids), (0.5,) * len(ids))
                )
                group_colls.append(None)
                group_weights.append(None)
            elif isinstance(node, ObjectSelection):
                ids, ops, thrs = [], [], []
                for c in node.cuts:
                    ids.append(add_term(f"{node.collection}_{c.var}"))
                    ops.append(OP_IDS[c.op])
                    thrs.append(float(c.value))
                groups.append(
                    Group(
                        GROUP_COUNT,
                        tuple(ids),
                        tuple(ops),
                        tuple(thrs),
                        min_count=node.min_count,
                    )
                )
                group_colls.append(node.collection)
                group_weights.append(None)
            elif isinstance(node, HTCut):
                ids, ops, thrs = [], [], []
                for c in node.object_cuts:
                    ids.append(add_term(f"{node.collection}_{c.var}"))
                    ops.append(OP_IDS[c.op])
                    thrs.append(float(c.value))
                if not ids:  # unconditioned HT still needs a term for shape
                    ids.append(add_term(f"{node.collection}_{node.var}"))
                    ops.append(OP_IDS[">="])
                    thrs.append(-jnp.inf)
                groups.append(
                    Group(
                        GROUP_HT,
                        tuple(ids),
                        tuple(ops),
                        tuple(thrs),
                        cmp_op=OP_IDS[node.op],
                        cmp_thr=float(node.value),
                    )
                )
                group_colls.append(node.collection)
                group_weights.append(f"{node.collection}_{node.var}")
            else:
                raise TypeError(f"cannot compile node {type(node)}")

    return Program(
        tuple(groups), tuple(term_branches), tuple(group_colls), tuple(group_weights)
    )


# ---------------------------------------------------------------------------
# kernel
# ---------------------------------------------------------------------------


def _predicate_kernel(terms_ref, valid_ref, weights_ref, out_ref, *, program: Program):
    """One event tile: terms (T, Eb, K), valid (G, Eb, K), weights (G, Eb, K)."""
    mask = jnp.ones((terms_ref.shape[1],), dtype=jnp.bool_)
    for g, grp in enumerate(program.groups):
        if grp.kind == GROUP_ANY:
            gpass = jnp.zeros_like(mask)
            for t, op, thr in zip(grp.term_ids, grp.ops, grp.thrs):
                gpass = gpass | apply_op(terms_ref[t, :, 0], op, thr)
        else:
            obj = jnp.ones(terms_ref.shape[1:], dtype=jnp.bool_)  # (Eb, K)
            for t, op, thr in zip(grp.term_ids, grp.ops, grp.thrs):
                obj = obj & apply_op(terms_ref[t], op, thr)
            obj = obj & (valid_ref[g] > 0)
            if grp.kind == GROUP_COUNT:
                gpass = obj.astype(jnp.int32).sum(axis=-1) >= grp.min_count
            else:  # GROUP_HT
                ht = (weights_ref[g] * obj.astype(jnp.float32)).sum(axis=-1)
                gpass = apply_op(ht, grp.cmp_op, grp.cmp_thr)
        mask = mask & gpass
    out_ref[...] = mask.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("program", "interpret", "event_tile"))
def predicate_eval(
    terms: jnp.ndarray,
    valid: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    program: Program,
    interpret: bool = True,
    event_tile: int = EVENT_TILE,
) -> jnp.ndarray:
    """Evaluate the predicate program; returns (E,) int32 survivor mask.

    ``terms`` (T, E, K) float32, ``valid``/``weights`` (G, E, K).  ``E``
    must be a multiple of ``event_tile`` (the ingest path pads).
    """
    T, E, K = terms.shape
    G = valid.shape[0]
    assert E % event_tile == 0, (E, event_tile)
    grid = (E // event_tile,)

    return pl.pallas_call(
        functools.partial(_predicate_kernel, program=program),
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, event_tile, K), lambda i: (0, i, 0)),
            pl.BlockSpec((G, event_tile, K), lambda i: (0, i, 0)),
            pl.BlockSpec((G, event_tile, K), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((event_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((E,), jnp.int32),
        interpret=interpret,
    )(terms, valid, weights)
