"""Pallas predicate-evaluation kernel (TPU target, interpret-validated).

The TPU analogue of SkimROOT's on-DPU filtering loop: a query's selection
criteria are compiled to a static *program* (term comparisons + group
reductions) and evaluated over VMEM tiles of padded columnar event data.
All thresholds/ops are baked into the kernel closure, so the inner body is
pure vector compares + reductions on the VPU — one pass over each basket.

Data layout (device path): events are dense tiles, collections padded to a
static ``K`` objects/event with a validity mask — the jagged->padded
conversion happens once at ingest (``repro.core.neardata``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.expr import KINEMATIC_VARS, RPN_BRANCH, RPN_SUM
from repro.kernels.ref import (
    GROUP_ANY,
    GROUP_COUNT,
    GROUP_DR,
    GROUP_EXPR,
    GROUP_HT,
    GROUP_MASS,
    OP_IDS,
    predicate_mask,
)

EVENT_TILE = 1024  # events per grid step; multiple of 8*128 lanes


@dataclass(frozen=True)
class Group:
    kind: int  # GROUP_COUNT / GROUP_HT / GROUP_ANY / GROUP_MASS / ...
    term_ids: tuple[int, ...]
    ops: tuple[int, ...]
    thrs: tuple[float, ...]
    min_count: int = 1
    cmp_op: int = 0
    cmp_thr: float = 0.0
    cmp_thr2: float = 0.0  # mass window upper bound (GROUP_MASS)
    rpn: tuple = ()  # GROUP_EXPR stack program, term-slot operands


@dataclass(frozen=True)
class Program:
    """Static predicate program: ``T`` terms over ``G`` AND-ed groups."""

    groups: tuple[Group, ...]
    term_branches: tuple[str, ...]  # branch feeding each term slot
    group_collections: tuple[str | None, ...]  # validity source per group
    group_weights: tuple[str | None, ...]  # HT weight branch per group
    # second collection of mass/ΔR pair groups (None elsewhere); default ()
    # keeps hand-built three-field programs (tests, older callers) valid
    group_collections2: tuple = ()

    @property
    def n_terms(self) -> int:
        return len(self.term_branches)

    @property
    def n_groups(self) -> int:
        return len(self.groups)


def compile_query(query) -> Program:
    """Lower a :class:`repro.core.query.Query` to a :class:`Program`.

    Compilation is store-independent (the cluster coordinator compiles
    once and fans out to shards with possibly different schemas): trigger
    branches absent from a store evaluate as constant-False at ingest
    (zero term pages), not here.
    """
    from repro.core.query import (
        AnyOf,
        Cut,
        DeltaRCut,
        ExprCut,
        HTCut,
        MassWindow,
        ObjectSelection,
    )

    term_branches: list[str] = []
    groups: list[Group] = []
    group_colls: list[str | None] = []
    group_colls2: list[str | None] = []
    group_weights: list[str | None] = []

    def add_term(branch: str) -> int:
        term_branches.append(branch)
        return len(term_branches) - 1

    def add_group(group: Group, coll=None, coll2=None, weight=None) -> None:
        groups.append(group)
        group_colls.append(coll)
        group_colls2.append(coll2)
        group_weights.append(weight)

    for _, stage in query.stages():
        for node in stage:
            if isinstance(node, Cut):
                t = add_term(node.branch)
                add_group(
                    Group(GROUP_COUNT, (t,), (OP_IDS[node.op],), (float(node.value),))
                )
            elif isinstance(node, AnyOf):
                ids = tuple(add_term(n) for n in node.names)
                add_group(
                    Group(GROUP_ANY, ids, (OP_IDS[">="],) * len(ids), (0.5,) * len(ids))
                )
            elif isinstance(node, ObjectSelection):
                ids, ops, thrs = [], [], []
                for c in node.cuts:
                    ids.append(add_term(f"{node.collection}_{c.var}"))
                    ops.append(OP_IDS[c.op])
                    thrs.append(float(c.value))
                add_group(
                    Group(
                        GROUP_COUNT,
                        tuple(ids),
                        tuple(ops),
                        tuple(thrs),
                        min_count=node.min_count,
                    ),
                    coll=node.collection,
                )
            elif isinstance(node, HTCut):
                ids, ops, thrs = [], [], []
                for c in node.object_cuts:
                    ids.append(add_term(f"{node.collection}_{c.var}"))
                    ops.append(OP_IDS[c.op])
                    thrs.append(float(c.value))
                if not ids:  # unconditioned HT still needs a term for shape
                    ids.append(add_term(f"{node.collection}_{node.var}"))
                    ops.append(OP_IDS[">="])
                    thrs.append(-jnp.inf)
                add_group(
                    Group(
                        GROUP_HT,
                        tuple(ids),
                        tuple(ops),
                        tuple(thrs),
                        cmp_op=OP_IDS[node.op],
                        cmp_thr=float(node.value),
                    ),
                    coll=node.collection,
                    weight=f"{node.collection}_{node.var}",
                )
            elif isinstance(node, MassWindow):
                a, b = node.collections
                ids = tuple(
                    add_term(f"{c}_{v}")
                    for c in (a, b)
                    for v in KINEMATIC_VARS["mass"]
                )
                add_group(
                    Group(
                        GROUP_MASS, ids, (), (),
                        cmp_thr=float(node.lo), cmp_thr2=float(node.hi),
                    ),
                    coll=a, coll2=b,
                )
            elif isinstance(node, DeltaRCut):
                a, b = node.collections
                ids = tuple(
                    add_term(f"{c}_{v}")
                    for c in (a, b)
                    for v in KINEMATIC_VARS["deltaR"]
                )
                add_group(
                    Group(
                        GROUP_DR, ids, (), (),
                        cmp_op=OP_IDS[node.op], cmp_thr=float(node.value),
                    ),
                    coll=a, coll2=b,
                )
            elif isinstance(node, ExprCut):
                # rewrite branch-name operands to term slots; sums read the
                # zero-padded object slots, flat refs read slot 0
                rpn = []
                ids = []
                for op, arg in node.rpn:
                    if op in (RPN_BRANCH, RPN_SUM):
                        t = add_term(str(arg))
                        ids.append(t)
                        rpn.append((op, t))
                    else:
                        rpn.append((op, arg))
                add_group(
                    Group(
                        GROUP_EXPR, tuple(ids), (), (),
                        cmp_op=OP_IDS[node.op], cmp_thr=float(node.value),
                        rpn=tuple(rpn),
                    )
                )
            else:
                raise TypeError(f"cannot compile node {type(node)}")

    program = Program(
        tuple(groups),
        tuple(term_branches),
        tuple(group_colls),
        tuple(group_weights),
        tuple(group_colls2),
    )
    # static verification gate (REPRO_VERIFY=1): prove the compiled
    # program's structural invariants before anything evaluates it
    from repro.analysis.verify import maybe_verify_program

    maybe_verify_program(program)
    return program


# ---------------------------------------------------------------------------
# kernel
# ---------------------------------------------------------------------------


def _predicate_kernel(terms_ref, valid_ref, weights_ref, out_ref, *, program: Program):
    """One event tile: terms (T, Eb, K), valid (G, Eb, K), weights (G, Eb, K).

    The evaluation body is :func:`repro.kernels.ref.predicate_mask` — one
    implementation shared with the oracle and the fused kernel, so every
    group kind (count/HT/trigger-OR/mass/ΔR/expr) behaves identically
    across the three."""
    mask = predicate_mask(
        program, terms_ref[...], valid_ref[...], weights_ref[...]
    )
    out_ref[...] = mask.astype(jnp.int32)


def _predicate_kernel_batched(terms_ref, valid_ref, weights_ref, out_ref,
                              *, program: Program):
    """Window-batched body: blocks carry a leading window dim of 1."""
    mask = predicate_mask(
        program, terms_ref[0], valid_ref[0], weights_ref[0]
    )
    out_ref[0] = mask.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("program", "interpret", "event_tile"))
def predicate_eval_batch(
    terms: jnp.ndarray,
    valid: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    program: Program,
    interpret: bool = True,
    event_tile: int = EVENT_TILE,
) -> jnp.ndarray:
    """Window-batched predicate evaluation: ONE dispatch per batch.

    ``terms`` (B, T, E, K), ``valid``/``weights`` (B, G, E, K); the grid
    runs (B, E/tile) with the window axis outermost.  Returns (B, E)
    int32 survivor masks — the device-resident mask source of the
    batched cascade (DESIGN.md §16).
    """
    Bn, T, E, K = terms.shape
    G = valid.shape[1]
    assert E % event_tile == 0, (E, event_tile)
    grid = (Bn, E // event_tile)

    return pl.pallas_call(
        functools.partial(_predicate_kernel_batched, program=program),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, event_tile, K), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, G, event_tile, K), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, G, event_tile, K), lambda b, i: (b, 0, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, event_tile), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((Bn, E), jnp.int32),
        interpret=interpret,
    )(terms, valid, weights)


@functools.partial(jax.jit, static_argnames=("program", "interpret", "event_tile"))
def predicate_eval(
    terms: jnp.ndarray,
    valid: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    program: Program,
    interpret: bool = True,
    event_tile: int = EVENT_TILE,
) -> jnp.ndarray:
    """Evaluate the predicate program; returns (E,) int32 survivor mask.

    ``terms`` (T, E, K) float32, ``valid``/``weights`` (G, E, K).  ``E``
    must be a multiple of ``event_tile`` (the ingest path pads).
    """
    T, E, K = terms.shape
    G = valid.shape[0]
    assert E % event_tile == 0, (E, event_tile)
    grid = (E // event_tile,)

    return pl.pallas_call(
        functools.partial(_predicate_kernel, program=program),
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, event_tile, K), lambda i: (0, i, 0)),
            pl.BlockSpec((G, event_tile, K), lambda i: (0, i, 0)),
            pl.BlockSpec((G, event_tile, K), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((event_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((E,), jnp.int32),
        interpret=interpret,
    )(terms, valid, weights)
