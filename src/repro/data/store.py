"""Columnar event store — the ROOT-file analogue.

Mirrors the structures §2.1 of the paper describes:

  * branches (columns) of per-event values, flat or jagged,
  * baskets: fixed event-count chunks, the unit of compression and I/O,
  * a header with per-branch basket metadata including the
    "first event index array" used to locate the basket holding event *i*.

Access is basket-granular: readers ask for the baskets overlapping an event
range and get compressed blobs back; decompression and deserialization are
separate, *timed* stages in ``repro.core.engine`` (matching the paper's
operation breakdown).  A ``FetchStats`` object accounts every byte and
request so the network model (1/10/100 Gb/s tiers) stays honest.

Window-granular reading lives here too: :meth:`EventStore.fetch_window`
is the explicit TTreeCache round (all baskets a read round needs, bulk
request accounting — DESIGN.md §2b) and :class:`WindowPrefetcher` is the
double-buffered loader the pipelined near-data executor uses to overlap
fetch+decode of window *i+1* with filtering of window *i* (DESIGN.md §4b).
"""

from __future__ import annotations

import io
import json
from dataclasses import dataclass, field

import numpy as np

from repro.data.codecs import decode_basket, encode_basket

# Paper §4: "A 100 MB TTreeCache is used in all methods".  The coalesced
# window fetch aggregates every basket a read round needs into bulk
# requests of at most this size (DESIGN.md §2b).
TTREECACHE_BYTES = 100 * 1024 * 1024


@dataclass
class Branch:
    name: str
    dtype: str  # numpy dtype string, e.g. "float32"
    jagged: bool = False
    counts_branch: str | None = None  # e.g. "nElectron" for "Electron_pt"

    def np_dtype(self):
        return np.dtype(self.dtype)


@dataclass
class BasketMeta:
    first_entry: int  # first event index (the "first event index array")
    n_entries: int  # events covered
    n_values: int  # values stored (== n_entries for flat branches)
    comp_bytes: int
    raw_bytes: int


@dataclass
class FetchStats:
    bytes_fetched: int = 0
    requests: int = 0
    by_branch: dict = field(default_factory=dict)

    def record(self, branch: str, nbytes: int, n_requests: int = 1) -> None:
        self.bytes_fetched += nbytes
        self.requests += n_requests
        self.by_branch[branch] = self.by_branch.get(branch, 0) + nbytes

    def merge(self, other: "FetchStats") -> None:
        self.bytes_fetched += other.bytes_fetched
        self.requests += other.requests
        for k, v in other.by_branch.items():
            self.by_branch[k] = self.by_branch.get(k, 0) + v

    @classmethod
    def merged(cls, parts: "list[FetchStats]") -> "FetchStats":
        """Sum a sequence of stats into a fresh object (the scatter-gather
        coordinator's gather contract — inputs are left untouched)."""
        out = cls()
        for p in parts:
            out.merge(p)
        return out


class WindowPrefetcher:
    """Double-buffered basket-window loader (DESIGN.md §4).

    The paper's TTreeCache batching made explicit *and* asynchronous:
    while the consumer filters window *i*, one background worker fetches
    and decodes window *i+1*, so the pipeline bound per window is
    ``max(fetch+decode, filter)`` instead of their sum.

    ``load_fn(start, stop)`` runs in the worker thread and must touch only
    thread-local state; whatever it returns (decoded columns plus
    per-window ``FetchStats``/timing objects) is handed back to the
    consumer strictly in window order, so merging the accounting on the
    consumer side is deterministic and byte-identical to the serial
    schedule (pinned by tests/test_pipeline_executor.py).

    ``depth`` is the number of windows in flight (2 = classic double
    buffering); ``enabled=False`` degrades to the serial schedule with the
    same iteration contract, which is what the serial/pipelined
    invariance tests compare against.
    """

    def __init__(
        self,
        n_events: int,
        window_events: int,
        load_fn,
        depth: int = 2,
        enabled: bool = True,
    ):
        if window_events <= 0:
            raise ValueError("window_events must be positive")
        self.n_events = int(n_events)
        self.window_events = int(window_events)
        self.load_fn = load_fn
        self.depth = max(int(depth), 1)
        self.enabled = enabled

    def windows(self) -> list[tuple[int, int]]:
        return [
            (s, min(s + self.window_events, self.n_events))
            for s in range(0, self.n_events, self.window_events)
        ]

    def __iter__(self):
        spans = self.windows()
        if not self.enabled:
            for start, stop in spans:
                yield start, stop, self.load_fn(start, stop)
            return
        from collections import deque
        from concurrent.futures import ThreadPoolExecutor

        with ThreadPoolExecutor(max_workers=1) as ex:
            pending: deque = deque()
            it = iter(spans)
            for _ in range(self.depth):
                try:
                    s, e = next(it)
                except StopIteration:
                    break
                pending.append((s, e, ex.submit(self.load_fn, s, e)))
            while pending:
                start, stop, fut = pending.popleft()
                payload = fut.result()
                try:
                    s, e = next(it)
                    pending.append((s, e, ex.submit(self.load_fn, s, e)))
                except StopIteration:
                    pass
                # the next window is now decoding while the consumer works
                yield start, stop, payload


class EventStore:
    """Columnar store with basket-granular compressed access."""

    def __init__(self, basket_events: int = 4096, codec: str = "bitpack"):
        self.basket_events = int(basket_events)
        self.codec = codec
        self.branches: dict[str, Branch] = {}
        self.n_events = 0
        self._baskets: dict[str, list[BasketMeta]] = {}
        self._blobs: dict[str, list[bytes]] = {}

    # -- construction -------------------------------------------------------

    @classmethod
    def from_arrays(
        cls,
        columns: dict[str, np.ndarray],
        jagged: dict[str, str] | None = None,
        basket_events: int = 4096,
        codec: str = "bitpack",
    ) -> "EventStore":
        """Build a store.

        ``columns`` maps branch name -> values.  For jagged branches the
        entry holds the flattened values and ``jagged[name]`` names the
        counts branch (itself a flat integer column in ``columns``).
        """
        jagged = jagged or {}
        store = cls(basket_events=basket_events, codec=codec)

        flat_names = [n for n in columns if n not in jagged]
        if not flat_names:
            raise ValueError("need at least one flat branch to set n_events")
        store.n_events = len(columns[flat_names[0]])

        for name in flat_names:
            arr = np.asarray(columns[name])
            if len(arr) != store.n_events:
                raise ValueError(f"branch {name}: length mismatch")
            store._add_flat(name, arr)

        for name, counts_name in jagged.items():
            counts = np.asarray(columns[counts_name]).astype(np.int32)
            values = np.asarray(columns[name])
            if counts.sum() != len(values):
                raise ValueError(f"branch {name}: counts/values mismatch")
            store._add_jagged(name, values, counts, counts_name)
        return store

    def _add_flat(self, name: str, arr: np.ndarray) -> None:
        br = Branch(name, str(arr.dtype), jagged=False)
        metas, blobs = [], []
        for start in range(0, self.n_events, self.basket_events):
            stop = min(start + self.basket_events, self.n_events)
            chunk = arr[start:stop]
            blob = encode_basket(chunk, self.codec)
            metas.append(
                BasketMeta(start, stop - start, len(chunk), len(blob), chunk.nbytes)
            )
            blobs.append(blob)
        self.branches[name] = br
        self._baskets[name] = metas
        self._blobs[name] = blobs

    def _add_jagged(
        self, name: str, values: np.ndarray, counts: np.ndarray, counts_name: str
    ) -> None:
        br = Branch(name, str(values.dtype), jagged=True, counts_branch=counts_name)
        offsets = np.concatenate([[0], np.cumsum(counts)])
        metas, blobs = [], []
        for start in range(0, self.n_events, self.basket_events):
            stop = min(start + self.basket_events, self.n_events)
            v0, v1 = offsets[start], offsets[stop]
            chunk = values[v0:v1]
            blob = encode_basket(chunk, self.codec)
            metas.append(
                BasketMeta(start, stop - start, len(chunk), len(blob), chunk.nbytes)
            )
            blobs.append(blob)
        self.branches[name] = br
        self._baskets[name] = metas
        self._blobs[name] = blobs

    # -- metadata -----------------------------------------------------------

    def branch_names(self) -> list[str]:
        return list(self.branches)

    def first_event_index(self, name: str) -> np.ndarray:
        """The paper's per-branch "first event index array"."""
        return np.array([m.first_entry for m in self._baskets[name]], dtype=np.int64)

    def basket_ids_for_range(self, name: str, start: int, stop: int) -> list[int]:
        ids = []
        for i, m in enumerate(self._baskets[name]):
            if m.first_entry < stop and m.first_entry + m.n_entries > start:
                ids.append(i)
        return ids

    def basket_meta(self, name: str, basket_id: int) -> BasketMeta:
        return self._baskets[name][basket_id]

    def n_baskets(self, name: str) -> int:
        return len(self._baskets[name])

    def compressed_bytes(self, names=None) -> int:
        names = names if names is not None else self.branch_names()
        return sum(m.comp_bytes for n in names for m in self._baskets[n])

    def raw_bytes(self, names=None) -> int:
        names = names if names is not None else self.branch_names()
        return sum(m.raw_bytes for n in names for m in self._baskets[n])

    def manifest(self) -> dict:
        """Canonical description of the store's physical layout: branch
        schemas plus every basket's placement and size.  Two stores holding
        byte-identical baskets produce equal manifests, which is what makes
        the manifest hash usable as a content address for skim results
        (DESIGN.md §5)."""
        return {
            "n_events": self.n_events,
            "basket_events": self.basket_events,
            "codec": self.codec,
            "branches": {
                n: [b.dtype, b.jagged, b.counts_branch]
                for n, b in sorted(self.branches.items())
            },
            "baskets": {
                n: [
                    [m.first_entry, m.n_entries, m.n_values, m.comp_bytes, m.raw_bytes]
                    for m in self._baskets[n]
                ]
                for n in sorted(self._baskets)
            },
        }

    def manifest_hash(self) -> str:
        """SHA-256 of the canonical manifest (hex)."""
        import hashlib

        doc = json.dumps(self.manifest(), sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(doc.encode()).hexdigest()

    def slice_events(self, spans: "list[tuple[int, int]]") -> "EventStore":
        """Build a new store holding the concatenation of event ranges.

        ``spans`` is a list of half-open ``[start, stop)`` event ranges,
        taken in the given order.  The result re-baskets with this store's
        ``basket_events``/``codec``, so when every span is basket-aligned
        the sliced baskets are byte-identical to the originals — the
        property the cluster shard layer relies on (DESIGN.md §5).
        """
        columns: dict[str, np.ndarray] = {}
        jagged: dict[str, str] = {}
        for name, br in self.branches.items():
            if br.jagged:
                jagged[name] = br.counts_branch
                parts = [self.read_jagged(name, a, b)[0] for a, b in spans]
            else:
                parts = [self.read_flat(name, a, b) for a, b in spans]
            columns[name] = (
                np.concatenate(parts) if parts else np.empty(0, dtype=br.np_dtype())
            )
        store = EventStore(basket_events=self.basket_events, codec=self.codec)
        flat = [n for n in columns if n not in jagged]
        store.n_events = int(sum(b - a for a, b in spans))
        for name in flat:
            arr = np.asarray(columns[name])
            if len(arr) != store.n_events:
                raise ValueError(f"branch {name}: length mismatch in slice")
            store._add_flat(name, arr)
        for name, counts_name in jagged.items():
            counts = np.asarray(columns[counts_name]).astype(np.int32)
            store._add_jagged(name, np.asarray(columns[name]), counts, counts_name)
        return store

    # -- basket access ------------------------------------------------------

    def fetch_basket(
        self, name: str, basket_id: int, stats: FetchStats | None = None
    ) -> bytes:
        blob = self._blobs[name][basket_id]
        if stats is not None:
            stats.record(name, len(blob))
        return blob

    def fetch_range(
        self,
        name: str,
        start: int,
        stop: int,
        stats: FetchStats | None = None,
        coalesce: bool = True,
    ) -> list[tuple[BasketMeta, bytes]]:
        """Fetch all baskets overlapping [start, stop).

        ``coalesce=True`` models TTreeCache-style prefetching: one request
        for the whole contiguous run of baskets.  ``coalesce=False`` models
        the on-demand per-basket reads the paper observed for local
        server-side access (§4, "TTreeCache does not function for local
        ROOT file access").
        """
        ids = self.basket_ids_for_range(name, start, stop)
        out = []
        total = 0
        for i in ids:
            blob = self._blobs[name][i]
            total += len(blob)
            out.append((self._baskets[name][i], blob))
        if stats is not None:
            stats.record(name, total, n_requests=1 if coalesce else max(len(ids), 1))
        return out

    def fetch_window(
        self,
        names: list[str],
        start: int,
        stop: int,
        stats: FetchStats | None = None,
        coalesce: bool = True,
        cache_bytes: int = TTREECACHE_BYTES,
    ) -> dict[str, list[tuple[BasketMeta, bytes]]]:
        """Fetch every basket of ``names`` overlapping [start, stop) as one
        read round — the TTreeCache model made explicit.

        ``coalesce=True``: all baskets of the round are aggregated into
        bulk requests of at most ``cache_bytes`` (one request for typical
        windows), which is what the prefetcher overlaps with compute.
        ``coalesce=False``: one request (seek) per basket — the paper's
        on-demand local-read behavior for server-side filtering.
        """
        out: dict[str, list[tuple[BasketMeta, bytes]]] = {}
        local = FetchStats()
        for name in names:
            out[name] = self.fetch_range(
                name, start, stop, stats=local, coalesce=coalesce
            )
        if stats is not None:
            if coalesce:
                n_req = (
                    max(1, -(-local.bytes_fetched // cache_bytes))
                    if local.bytes_fetched
                    else 0
                )
                stats.bytes_fetched += local.bytes_fetched
                stats.requests += n_req
                for k, v in local.by_branch.items():
                    stats.by_branch[k] = stats.by_branch.get(k, 0) + v
            else:
                stats.merge(local)
        return out

    def decode_blob(self, name: str, blob: bytes) -> np.ndarray:
        return decode_basket(blob, self.codec, self.branches[name].np_dtype())

    # -- convenience full reads (not timed; for tests and writers) ----------

    def read_flat(self, name: str, start: int = 0, stop: int | None = None) -> np.ndarray:
        stop = self.n_events if stop is None else stop
        parts = []
        for meta, blob in self.fetch_range(name, start, stop):
            vals = self.decode_blob(name, blob)
            lo = max(start - meta.first_entry, 0)
            hi = min(stop - meta.first_entry, meta.n_entries)
            parts.append(vals[lo:hi])
        if not parts:
            return np.empty(0, dtype=self.branches[name].np_dtype())
        return np.concatenate(parts)

    def read_jagged(
        self, name: str, start: int = 0, stop: int | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        stop = self.n_events if stop is None else stop
        br = self.branches[name]
        counts = self.read_flat(br.counts_branch, start, stop).astype(np.int64)
        parts = []
        for meta, blob in self.fetch_range(name, start, stop):
            vals = self.decode_blob(name, blob)
            # per-basket event counts to slice values at event granularity
            bc = self.read_flat(
                br.counts_branch, meta.first_entry, meta.first_entry + meta.n_entries
            ).astype(np.int64)
            boff = np.concatenate([[0], np.cumsum(bc)])
            lo_e = max(start - meta.first_entry, 0)
            hi_e = min(stop - meta.first_entry, meta.n_entries)
            parts.append(vals[boff[lo_e] : boff[hi_e]])
        values = (
            np.concatenate(parts) if parts else np.empty(0, dtype=br.np_dtype())
        )
        return values, counts

    # -- serialization ------------------------------------------------------

    def save(self, path: str) -> None:
        header = {
            "basket_events": self.basket_events,
            "codec": self.codec,
            "n_events": self.n_events,
            "branches": {
                n: {
                    "dtype": b.dtype,
                    "jagged": b.jagged,
                    "counts_branch": b.counts_branch,
                }
                for n, b in self.branches.items()
            },
            "baskets": {
                n: [
                    [m.first_entry, m.n_entries, m.n_values, m.comp_bytes, m.raw_bytes]
                    for m in metas
                ]
                for n, metas in self._baskets.items()
            },
        }
        hbytes = json.dumps(header).encode()
        with open(path, "wb") as f:
            f.write(len(hbytes).to_bytes(8, "little"))
            f.write(hbytes)
            for n in self.branches:
                for blob in self._blobs[n]:
                    f.write(blob)

    @classmethod
    def load(cls, path: str) -> "EventStore":
        with open(path, "rb") as f:
            hlen = int.from_bytes(f.read(8), "little")
            header = json.loads(f.read(hlen).decode())
            store = cls(basket_events=header["basket_events"], codec=header["codec"])
            store.n_events = header["n_events"]
            for n, b in header["branches"].items():
                store.branches[n] = Branch(
                    n, b["dtype"], b["jagged"], b["counts_branch"]
                )
            for n, metas in header["baskets"].items():
                store._baskets[n] = [BasketMeta(*m) for m in metas]
            for n in store.branches:
                store._blobs[n] = [
                    f.read(m.comp_bytes) for m in store._baskets[n]
                ]
        return store

    # -- mutation used by the skim writer ------------------------------------

    @classmethod
    def from_selection(
        cls,
        columns: dict[str, np.ndarray],
        jagged: dict[str, str],
        basket_events: int,
        codec: str,
    ) -> "EventStore":
        return cls.from_arrays(
            columns, jagged=jagged, basket_events=basket_events, codec=codec
        )
