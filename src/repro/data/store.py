"""Columnar event store — the ROOT-file analogue.

Mirrors the structures §2.1 of the paper describes:

  * branches (columns) of per-event values, flat or jagged,
  * baskets: fixed event-count chunks, the unit of compression and I/O,
  * a header with per-branch basket metadata including the
    "first event index array" used to locate the basket holding event *i*.

Access is basket-granular: readers ask for the baskets overlapping an event
range and get compressed blobs back; decompression and deserialization are
separate, *timed* stages in ``repro.core.engine`` (matching the paper's
operation breakdown).  A ``FetchStats`` object accounts every byte and
request so the network model (1/10/100 Gb/s tiers) stays honest.

Window-granular reading lives here too: :meth:`EventStore.fetch_window`
is the explicit TTreeCache round (all baskets a read round needs, bulk
request accounting — DESIGN.md §2b) and :class:`WindowPrefetcher` is the
double-buffered loader the pipelined near-data executor uses to overlap
fetch+decode of window *i+1* with filtering of window *i* (DESIGN.md §4b).
"""

from __future__ import annotations

import io
import json
import threading
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from repro.data.codecs import (
    basket_digest,
    basket_stats,
    decode_basket,
    decode_basket_batch,
    encode_basket,
)

# Paper §4: "A 100 MB TTreeCache is used in all methods".  The coalesced
# window fetch aggregates every basket a read round needs into bulk
# requests of at most this size (DESIGN.md §2b).
TTREECACHE_BYTES = 100 * 1024 * 1024

# Version of the zone-map statistics schema carried by BasketMeta and the
# manifest.  Bumping this changes every manifest_hash (and therefore every
# cluster cache key), which is exactly the invalidation we want when the
# stat semantics change (DESIGN.md §9).
ZONEMAP_VERSION = 1

# Version of the basket integrity schema: since v1 every BasketMeta row
# carries a CRC-32 digest of its encoded blob, recomputed (and enforced)
# on every fetch.  Carried in the manifest like ZONEMAP_VERSION, so
# digest-bearing stores hash to different content addresses than legacy
# ones (DESIGN.md §14).
INTEGRITY_VERSION = 1

# Default capacity (in baskets) of the per-store decoded-basket LRU.
DECODE_CACHE_BASKETS = 64


class CorruptBasket(RuntimeError):
    """A fetched basket blob failed its integrity digest.

    Raised by the fetch path (:meth:`EventStore.fetch_basket` /
    :meth:`EventStore.fetch_range`, and therefore
    :meth:`EventStore.fetch_window`) before any decode — corrupt bytes
    never reach the filter.  The cluster layer treats this like a node
    fault: the shard is retried under the
    :class:`~repro.cluster.retry.RetryPolicy` (typically re-fetching
    from the replica) and the (shard, branch, basket) is quarantined on
    the node (DESIGN.md §14).
    """

    def __init__(self, branch: str, basket_id: int, expected: int, actual: int):
        super().__init__(
            f"basket {branch}[{basket_id}]: digest mismatch "
            f"(expected {expected:#010x}, got {actual:#010x})"
        )
        self.branch = branch
        self.basket_id = basket_id
        self.expected = expected
        self.actual = actual


@dataclass
class Branch:
    name: str
    dtype: str  # numpy dtype string, e.g. "float32"
    jagged: bool = False
    counts_branch: str | None = None  # e.g. "nElectron" for "Electron_pt"

    def np_dtype(self):
        return np.dtype(self.dtype)


@dataclass
class BasketMeta:
    first_entry: int  # first event index (the "first event index array")
    n_entries: int  # events covered
    n_values: int  # values stored (== n_entries for flat branches)
    comp_bytes: int
    raw_bytes: int
    # zone-map statistics (DESIGN.md §9): value bounds as exact float64
    # embeddings of the stored dtype, plus the true-count for bool
    # branches.  ``None`` means "unknown" (empty basket, non-finite data,
    # or a store written before ZONEMAP_VERSION) and always degrades to
    # "scan" in the pruning analysis — never to a wrong skip.
    vmin: float | None = None
    vmax: float | None = None
    n_true: int | None = None
    # CRC-32 of the encoded blob (INTEGRITY_VERSION).  ``None`` means
    # "unverifiable" (a store written before the digest upgrade) and
    # degrades to skipping the check — never to a false alarm.
    digest: int | None = None

    def stats_row(self) -> list:
        return [
            self.first_entry, self.n_entries, self.n_values,
            self.comp_bytes, self.raw_bytes,
            self.vmin, self.vmax, self.n_true, self.digest,
        ]


@dataclass(frozen=True)
class ZoneStats:
    """Aggregate zone-map statistics of one branch over an event range.

    ``lo``/``hi`` bound every value in the range (``None`` = unknown or no
    values); ``n_true`` sums bool true-counts (``None`` for non-bool or
    unknown).  ``n_entries``/``n_values`` count the covered events/values
    — for flat branches they coincide, for jagged value branches
    ``n_values`` is the object total the counts branch describes.
    """

    lo: float | None
    hi: float | None
    n_true: int | None
    n_entries: int
    n_values: int


@dataclass
class FetchStats:
    bytes_fetched: int = 0
    requests: int = 0
    by_branch: dict = field(default_factory=dict)
    # bytes/requests the zone-map pruning proved unnecessary and never
    # issued (DESIGN.md §9).  Not part of ``bytes_fetched`` — these are
    # the savings ledger, not traffic.
    bytes_skipped: int = 0
    requests_skipped: int = 0
    # bytes the cascaded executor never moved relative to the preloading
    # reference (DESIGN.md §11): filter-branch baskets that neither a
    # cascade stage nor phase 2 ever fetched, so
    # bytes_fetched + cascade_bytes_skipped == the preload run's
    # bytes_fetched, exactly.  A savings ledger like ``bytes_skipped``,
    # not traffic.
    cascade_bytes_skipped: int = 0

    def record(self, branch: str, nbytes: int, n_requests: int = 1) -> None:
        self.bytes_fetched += nbytes
        self.requests += n_requests
        self.by_branch[branch] = self.by_branch.get(branch, 0) + nbytes

    def skip(self, nbytes: int, n_requests: int = 0) -> None:
        """Account a fetch the pruning analysis proved away."""
        self.bytes_skipped += nbytes
        self.requests_skipped += n_requests

    def merge(self, other: "FetchStats") -> None:
        self.bytes_fetched += other.bytes_fetched
        self.requests += other.requests
        self.bytes_skipped += other.bytes_skipped
        self.requests_skipped += other.requests_skipped
        self.cascade_bytes_skipped += other.cascade_bytes_skipped
        for k, v in other.by_branch.items():
            self.by_branch[k] = self.by_branch.get(k, 0) + v

    @classmethod
    def merged(cls, parts: "list[FetchStats]") -> "FetchStats":
        """Sum a sequence of stats into a fresh object (the scatter-gather
        coordinator's gather contract — inputs are left untouched)."""
        out = cls()
        for p in parts:
            out.merge(p)
        return out


def coalesced_requests(
    nbytes: int, n_baskets: int, coalesce: bool,
    cache_bytes: int = TTREECACHE_BYTES,
) -> int:
    """Requests one fetch round issues under the TTreeCache model: bulk
    requests of at most ``cache_bytes`` when coalescing, one seek per
    basket otherwise.  The single source of truth — `fetch_window`, the
    engine's skip pricing, and the cascade's ledger all use it."""
    if coalesce:
        return max(1, -(-nbytes // cache_bytes)) if nbytes else 0
    return n_baskets


class WindowPrefetcher:
    """Double-buffered basket-window loader (DESIGN.md §4).

    The paper's TTreeCache batching made explicit *and* asynchronous:
    while the consumer filters window *i*, one background worker fetches
    and decodes window *i+1*, so the pipeline bound per window is
    ``max(fetch+decode, filter)`` instead of their sum.

    ``load_fn(start, stop)`` runs in the worker thread and must touch only
    thread-local state; whatever it returns (decoded columns plus
    per-window ``FetchStats``/timing objects) is handed back to the
    consumer strictly in window order, so merging the accounting on the
    consumer side is deterministic and byte-identical to the serial
    schedule (pinned by tests/test_pipeline_executor.py).

    ``depth`` is the number of windows in flight (2 = classic double
    buffering); ``enabled=False`` degrades to the serial schedule with the
    same iteration contract, which is what the serial/pipelined
    invariance tests compare against.
    """

    def __init__(
        self,
        n_events: int,
        window_events: int,
        load_fn,
        depth: int = 2,
        enabled: bool = True,
    ):
        if window_events <= 0:
            raise ValueError("window_events must be positive")
        self.n_events = int(n_events)
        self.window_events = int(window_events)
        self.load_fn = load_fn
        self.depth = max(int(depth), 1)
        self.enabled = enabled

    def windows(self) -> list[tuple[int, int]]:
        return [
            (s, min(s + self.window_events, self.n_events))
            for s in range(0, self.n_events, self.window_events)
        ]

    def __iter__(self):
        spans = self.windows()
        if not self.enabled:
            for start, stop in spans:
                yield start, stop, self.load_fn(start, stop)
            return
        from collections import deque
        from concurrent.futures import ThreadPoolExecutor

        ex = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="skim-prefetch"
        )
        try:
            pending: deque = deque()
            it = iter(spans)
            for _ in range(self.depth):
                try:
                    s, e = next(it)
                except StopIteration:
                    break
                pending.append((s, e, ex.submit(self.load_fn, s, e)))
            while pending:
                start, stop, fut = pending.popleft()
                payload = fut.result()
                try:
                    s, e = next(it)
                    pending.append((s, e, ex.submit(self.load_fn, s, e)))
                except StopIteration:
                    pass
                # the next window is now decoding while the consumer works
                yield start, stop, payload
        finally:
            # Cancellation-under-fault contract (pinned by
            # tests/test_faults.py): closing the generator — or a worker
            # exception surfacing through ``fut.result()`` — cancels
            # every queued-but-unstarted load and joins only the one in
            # flight.  Unconsumed payloads are dropped here without
            # touching the consumer's ledger, so ``FetchStats`` can
            # never double-account a window that was never yielded; an
            # in-flight worker that raises parks its exception in the
            # abandoned future (never re-raised).
            ex.shutdown(wait=True, cancel_futures=True)


class EventStore:
    """Columnar store with basket-granular compressed access."""

    def __init__(
        self,
        basket_events: int = 4096,
        codec: str = "bitpack",
        decode_cache_baskets: int = DECODE_CACHE_BASKETS,
        verify: bool = True,
        decode_backend: str | None = None,
    ):
        self.basket_events = int(basket_events)
        self.codec = codec
        # basket decode tier (DESIGN.md §16): "host" runs the numpy codec
        # reference, "device" ships compressed plane words to the kernel
        # tier (bitpack only; bit-identical by contract).  None resolves
        # lazily — device iff a TPU backend is present, host otherwise —
        # and any device failure falls back to host, counted in
        # ``decode_fallbacks`` so the degradation is test-visible.
        if decode_backend not in (None, "host", "device"):
            raise ValueError(f"unknown decode_backend {decode_backend!r}")
        self.decode_backend = decode_backend
        self._decode_backend_resolved: str | None = None
        self.decode_device_baskets = 0
        self.decode_host_baskets = 0
        self.decode_fallbacks = 0
        # enforce basket digests on every fetch (INTEGRITY_VERSION);
        # ``False`` restores the unverified fast path for A/B costing
        # (benchmarks/bench_faults.py pins the overhead under 2%)
        self.verify = bool(verify)
        self.branches: dict[str, Branch] = {}
        self.n_events = 0
        self._baskets: dict[str, list[BasketMeta]] = {}
        self._blobs: dict[str, list[bytes]] = {}
        # small decoded-basket LRU so windows that overlap between phase 1
        # and phase 2 (counts branches, shared-scan tenants) don't decode
        # the same basket twice.  Keyed by (branch, blob) — content, not
        # identity — so it can never serve stale data.  0 disables.
        self.decode_cache_baskets = int(decode_cache_baskets)
        self._decode_cache: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._decode_lock = threading.Lock()
        self.decode_cache_hits = 0
        self.decode_cache_misses = 0
        # byte-weighted savings: decoded bytes NOT re-decoded thanks to
        # a hit / decoded on a miss (same currency as the cluster result
        # cache's saved_fetch_bytes — see repro.obs.metrics)
        self.decode_cache_hit_bytes = 0
        self.decode_cache_miss_bytes = 0

    # -- construction -------------------------------------------------------

    @classmethod
    def from_arrays(
        cls,
        columns: dict[str, np.ndarray],
        jagged: dict[str, str] | None = None,
        basket_events: int = 4096,
        codec: str = "bitpack",
        decode_backend: str | None = None,
    ) -> "EventStore":
        """Build a store.

        ``columns`` maps branch name -> values.  For jagged branches the
        entry holds the flattened values and ``jagged[name]`` names the
        counts branch (itself a flat integer column in ``columns``).
        """
        jagged = jagged or {}
        store = cls(
            basket_events=basket_events,
            codec=codec,
            decode_backend=decode_backend,
        )

        flat_names = [n for n in columns if n not in jagged]
        if not flat_names:
            raise ValueError("need at least one flat branch to set n_events")
        store.n_events = len(columns[flat_names[0]])

        for name in flat_names:
            arr = np.asarray(columns[name])
            if len(arr) != store.n_events:
                raise ValueError(f"branch {name}: length mismatch")
            store._add_flat(name, arr)

        for name, counts_name in jagged.items():
            counts = np.asarray(columns[counts_name]).astype(np.int32)
            values = np.asarray(columns[name])
            if counts.sum() != len(values):
                raise ValueError(f"branch {name}: counts/values mismatch")
            store._add_jagged(name, values, counts, counts_name)
        return store

    def _add_flat(self, name: str, arr: np.ndarray) -> None:
        br = Branch(name, str(arr.dtype), jagged=False)
        metas, blobs = [], []
        for start in range(0, self.n_events, self.basket_events):
            stop = min(start + self.basket_events, self.n_events)
            chunk = arr[start:stop]
            blob = encode_basket(chunk, self.codec)
            vmin, vmax, n_true = basket_stats(chunk)
            metas.append(
                BasketMeta(
                    start, stop - start, len(chunk), len(blob), chunk.nbytes,
                    vmin=vmin, vmax=vmax, n_true=n_true,
                    digest=basket_digest(blob),
                )
            )
            blobs.append(blob)
        self.branches[name] = br
        self._baskets[name] = metas
        self._blobs[name] = blobs

    def _add_jagged(
        self, name: str, values: np.ndarray, counts: np.ndarray, counts_name: str
    ) -> None:
        br = Branch(name, str(values.dtype), jagged=True, counts_branch=counts_name)
        offsets = np.concatenate([[0], np.cumsum(counts)])
        metas, blobs = [], []
        for start in range(0, self.n_events, self.basket_events):
            stop = min(start + self.basket_events, self.n_events)
            v0, v1 = offsets[start], offsets[stop]
            chunk = values[v0:v1]
            blob = encode_basket(chunk, self.codec)
            vmin, vmax, n_true = basket_stats(chunk)
            metas.append(
                BasketMeta(
                    start, stop - start, len(chunk), len(blob), chunk.nbytes,
                    vmin=vmin, vmax=vmax, n_true=n_true,
                    digest=basket_digest(blob),
                )
            )
            blobs.append(blob)
        self.branches[name] = br
        self._baskets[name] = metas
        self._blobs[name] = blobs

    # -- metadata -----------------------------------------------------------

    def branch_names(self) -> list[str]:
        return list(self.branches)

    def first_event_index(self, name: str) -> np.ndarray:
        """The paper's per-branch "first event index array"."""
        return np.array([m.first_entry for m in self._baskets[name]], dtype=np.int64)

    def basket_ids_for_range(self, name: str, start: int, stop: int) -> list[int]:
        ids = []
        for i, m in enumerate(self._baskets[name]):
            if m.first_entry < stop and m.first_entry + m.n_entries > start:
                ids.append(i)
        return ids

    def basket_meta(self, name: str, basket_id: int) -> BasketMeta:
        return self._baskets[name][basket_id]

    def n_baskets(self, name: str) -> int:
        return len(self._baskets[name])

    def compressed_bytes(self, names=None) -> int:
        names = names if names is not None else self.branch_names()
        return sum(m.comp_bytes for n in names for m in self._baskets[n])

    def raw_bytes(self, names=None) -> int:
        names = names if names is not None else self.branch_names()
        return sum(m.raw_bytes for n in names for m in self._baskets[n])

    def range_comp_bytes(self, names, start: int, stop: int) -> tuple[int, int]:
        """``(compressed bytes, basket count)`` of ``names`` overlapping
        ``[start, stop)`` — what a fetch round for that window would move.
        Pure metadata; the pruning ledger prices skipped fetches with it."""
        total = baskets = 0
        for name in names:
            for i in self.basket_ids_for_range(name, start, stop):
                total += self._baskets[name][i].comp_bytes
                baskets += 1
        return total, baskets

    def window_stats(self, name: str, start: int, stop: int) -> ZoneStats | None:
        """Aggregate zone-map stats of one branch over ``[start, stop)``.

        Returns ``None`` when any overlapping basket lacks stats (legacy
        store, non-finite data) — the conservative "unknown" that the
        interval analysis maps to *scan*.  Baskets only partially inside
        the range contribute their full-basket bounds, which keeps the
        interval a superset of the range's true values (conservative in
        the safe direction for both prune and accept-all).
        """
        ids = self.basket_ids_for_range(name, start, stop)
        lo = hi = None
        n_true: int | None = 0
        n_entries = n_values = 0
        is_bool = self.branches[name].np_dtype() == np.bool_
        for i in ids:
            m = self._baskets[name][i]
            n_entries += m.n_entries
            n_values += m.n_values
            if m.n_values == 0:
                continue  # empty basket constrains nothing
            if m.vmin is None or m.vmax is None:
                return None  # unknown stats poison the whole range
            lo = m.vmin if lo is None else min(lo, m.vmin)
            hi = m.vmax if hi is None else max(hi, m.vmax)
            if is_bool:
                if m.n_true is None:
                    return None
                n_true += m.n_true
        return ZoneStats(
            lo=lo, hi=hi, n_true=n_true if is_bool else None,
            n_entries=n_entries, n_values=n_values,
        )

    def manifest(self) -> dict:
        """Canonical description of the store's physical layout: branch
        schemas plus every basket's placement and size.  Two stores holding
        byte-identical baskets produce equal manifests, which is what makes
        the manifest hash usable as a content address for skim results
        (DESIGN.md §5).  Since ZONEMAP_VERSION 1 every basket row also
        carries its zone-map stats, so shard manifests ship the pruning
        metadata for free and any stat change re-addresses the content.
        Since INTEGRITY_VERSION 1 each row also carries the blob's CRC-32
        digest — digest-bearing stores therefore hash differently from
        legacy ones, re-addressing every cluster cache key without a
        CACHE_KEY_VERSION bump (digests are deterministic functions of
        the basket contents, so re-encoding identical data still hits)."""
        return {
            "n_events": self.n_events,
            "basket_events": self.basket_events,
            "codec": self.codec,
            "zonemap_version": ZONEMAP_VERSION,
            "integrity_version": INTEGRITY_VERSION,
            "branches": {
                n: [b.dtype, b.jagged, b.counts_branch]
                for n, b in sorted(self.branches.items())
            },
            "baskets": {
                n: [m.stats_row() for m in self._baskets[n]]
                for n in sorted(self._baskets)
            },
        }

    def manifest_hash(self) -> str:
        """SHA-256 of the canonical manifest (hex)."""
        import hashlib

        doc = json.dumps(self.manifest(), sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(doc.encode()).hexdigest()

    def slice_events(self, spans: "list[tuple[int, int]]") -> "EventStore":
        """Build a new store holding the concatenation of event ranges.

        ``spans`` is a list of half-open ``[start, stop)`` event ranges,
        taken in the given order.  The result re-baskets with this store's
        ``basket_events``/``codec``, so when every span is basket-aligned
        the sliced baskets are byte-identical to the originals — the
        property the cluster shard layer relies on (DESIGN.md §5).
        """
        columns: dict[str, np.ndarray] = {}
        jagged: dict[str, str] = {}
        for name, br in self.branches.items():
            if br.jagged:
                jagged[name] = br.counts_branch
                parts = [self.read_jagged(name, a, b)[0] for a, b in spans]
            else:
                parts = [self.read_flat(name, a, b) for a, b in spans]
            columns[name] = (
                np.concatenate(parts) if parts else np.empty(0, dtype=br.np_dtype())
            )
        store = EventStore(basket_events=self.basket_events, codec=self.codec)
        flat = [n for n in columns if n not in jagged]
        store.n_events = int(sum(b - a for a, b in spans))
        for name in flat:
            arr = np.asarray(columns[name])
            if len(arr) != store.n_events:
                raise ValueError(f"branch {name}: length mismatch in slice")
            store._add_flat(name, arr)
        for name, counts_name in jagged.items():
            counts = np.asarray(columns[counts_name]).astype(np.int32)
            store._add_jagged(name, np.asarray(columns[name]), counts, counts_name)
        return store

    # -- basket access ------------------------------------------------------

    def _verify_blob(self, name: str, basket_id: int, blob: bytes) -> None:
        """Recompute and enforce one blob's digest (no-op for legacy
        metadata without one, or with ``verify=False``)."""
        meta = self._baskets[name][basket_id]
        if meta.digest is None:
            return
        actual = basket_digest(blob)
        if actual != meta.digest:
            raise CorruptBasket(name, basket_id, meta.digest, actual)

    def corrupt_blob(self, name: str, basket_id: int, xor: int = 0xFF):
        """Deterministically flip bits in one stored blob (fault
        injection for tests/chaos).  Returns a zero-arg ``restore()``
        callable that puts the original bytes back — the chaos harness
        models transient read-path corruption, not durable media loss."""
        blobs = self._blobs[name]
        original = blobs[basket_id]
        corrupted = bytes([original[0] ^ (xor & 0xFF)]) + original[1:]
        blobs[basket_id] = corrupted

        def restore():
            blobs[basket_id] = original

        return restore

    def fetch_basket(
        self, name: str, basket_id: int, stats: FetchStats | None = None
    ) -> bytes:
        blob = self._blobs[name][basket_id]
        if self.verify:
            self._verify_blob(name, basket_id, blob)
        if stats is not None:
            stats.record(name, len(blob))
        return blob

    def fetch_range(
        self,
        name: str,
        start: int,
        stop: int,
        stats: FetchStats | None = None,
        coalesce: bool = True,
    ) -> list[tuple[BasketMeta, bytes]]:
        """Fetch all baskets overlapping [start, stop).

        ``coalesce=True`` models TTreeCache-style prefetching: one request
        for the whole contiguous run of baskets.  ``coalesce=False`` models
        the on-demand per-basket reads the paper observed for local
        server-side access (§4, "TTreeCache does not function for local
        ROOT file access").
        """
        ids = self.basket_ids_for_range(name, start, stop)
        out = []
        total = 0
        for i in ids:
            blob = self._blobs[name][i]
            if self.verify:
                self._verify_blob(name, i, blob)
            total += len(blob)
            out.append((self._baskets[name][i], blob))
        if stats is not None:
            stats.record(name, total, n_requests=1 if coalesce else max(len(ids), 1))
        return out

    def fetch_window(
        self,
        names: list[str],
        start: int,
        stop: int,
        stats: FetchStats | None = None,
        coalesce: bool = True,
        cache_bytes: int = TTREECACHE_BYTES,
    ) -> dict[str, list[tuple[BasketMeta, bytes]]]:
        """Fetch every basket of ``names`` overlapping [start, stop) as one
        read round — the TTreeCache model made explicit.

        ``coalesce=True``: all baskets of the round are aggregated into
        bulk requests of at most ``cache_bytes`` (one request for typical
        windows), which is what the prefetcher overlaps with compute.
        ``coalesce=False``: one request (seek) per basket — the paper's
        on-demand local-read behavior for server-side filtering.
        """
        out: dict[str, list[tuple[BasketMeta, bytes]]] = {}
        local = FetchStats()
        for name in names:
            out[name] = self.fetch_range(
                name, start, stop, stats=local, coalesce=coalesce
            )
        if stats is not None:
            if coalesce:
                stats.bytes_fetched += local.bytes_fetched
                stats.requests += coalesced_requests(
                    local.bytes_fetched, 0, True, cache_bytes
                )
                for k, v in local.by_branch.items():
                    stats.by_branch[k] = stats.by_branch.get(k, 0) + v
            else:
                stats.merge(local)
        return out

    def resolved_decode_backend(self) -> str:
        """The decode tier actually in use: the configured backend, or
        (when unset) device iff an accelerator backend is present."""
        if self._decode_backend_resolved is None:
            backend = self.decode_backend
            if backend is None:
                try:
                    import jax

                    backend = (
                        "device" if jax.default_backend() == "tpu" else "host"
                    )
                except Exception:
                    backend = "host"
            self._decode_backend_resolved = backend
        return self._decode_backend_resolved

    def _decode_batch(self, name: str, blobs: list, dtype) -> list:
        """Backend-dispatched decode of one branch's blobs (no cache).

        The device tier covers the bitpack codec only; other codecs (and
        any device-path failure) fall back to the host reference, counted
        in ``decode_fallbacks``.  Both tiers are bit-identical by the
        codec contract (pinned in tests/test_device_batch.py)."""
        backend = self.resolved_decode_backend()
        if backend == "device" and blobs:
            if self.codec == "bitpack":
                try:
                    vals = decode_basket_batch(
                        blobs, self.codec, dtype, backend="device"
                    )
                except Exception:
                    with self._decode_lock:
                        self.decode_fallbacks += len(blobs)
                else:
                    with self._decode_lock:
                        self.decode_device_baskets += len(blobs)
                    return vals
            else:
                with self._decode_lock:
                    self.decode_fallbacks += len(blobs)
        with self._decode_lock:
            self.decode_host_baskets += len(blobs)
        return [decode_basket(blob, self.codec, dtype) for blob in blobs]

    def decode_blob(self, name: str, blob: bytes) -> np.ndarray:
        """Decode one basket blob, memoized through a small per-store LRU.

        The cache key is ``(branch, blob bytes)`` — content-addressed, so
        hits are always exact.  Cached arrays are frozen (read-only) to
        keep aliasing safe across phase 1 / phase 2 and across shared-scan
        tenants; every current consumer slices or copies.  Thread-safe:
        the :class:`WindowPrefetcher` worker decodes concurrently with the
        consumer's phase 2.
        """
        return self.decode_blobs(name, [blob])[0]

    def decode_blobs(self, name: str, blobs: list) -> list:
        """Decode a list of basket blobs for one branch in one round.

        The batch form of :meth:`decode_blob` (same LRU, same freezing):
        cache misses decode together through the backend-selected tier
        (:meth:`_decode_batch`), so a device-backed store pays one kernel
        dispatch per fetch round instead of one per basket.
        """
        dtype = self.branches[name].np_dtype()
        if self.decode_cache_baskets <= 0:
            return self._decode_batch(name, list(blobs), dtype)
        out: list = [None] * len(blobs)
        misses: list[int] = []
        with self._decode_lock:
            for i, blob in enumerate(blobs):
                cached = self._decode_cache.get((name, blob))
                if cached is not None:
                    self._decode_cache.move_to_end((name, blob))
                    self.decode_cache_hits += 1
                    self.decode_cache_hit_bytes += cached.nbytes
                    out[i] = cached
                else:
                    self.decode_cache_misses += 1
                    misses.append(i)
        if misses:
            decoded = self._decode_batch(
                name, [blobs[i] for i in misses], dtype
            )
            with self._decode_lock:
                for i, vals in zip(misses, decoded):
                    if vals.flags.writeable:
                        vals.flags.writeable = False
                    self.decode_cache_miss_bytes += vals.nbytes
                    self._decode_cache[(name, blobs[i])] = vals
                    self._decode_cache.move_to_end((name, blobs[i]))
                    out[i] = vals
                while len(self._decode_cache) > self.decode_cache_baskets:
                    self._decode_cache.popitem(last=False)
        return out

    def decode_backend_stats(self) -> dict:
        """Decode-tier ledger: which tier decoded how many baskets, and
        how many device requests degraded to the host reference."""
        with self._decode_lock:
            return {
                "backend": self.resolved_decode_backend(),
                "device_baskets": self.decode_device_baskets,
                "host_baskets": self.decode_host_baskets,
                "fallbacks": self.decode_fallbacks,
            }

    def decode_cache_stats(self) -> dict:
        with self._decode_lock:
            hits, misses = self.decode_cache_hits, self.decode_cache_misses
            return {
                "hits": hits,
                "misses": misses,
                "resident": len(self._decode_cache),
                "hit_bytes": self.decode_cache_hit_bytes,
                "miss_bytes": self.decode_cache_miss_bytes,
                # decoded bytes a hit avoided re-producing — the decode
                # cache's byte-weighted savings currency
                "saved_decode_bytes": self.decode_cache_hit_bytes,
                "hit_rate": hits / max(hits + misses, 1),
            }

    # -- convenience full reads (not timed; for tests and writers) ----------

    def read_flat(self, name: str, start: int = 0, stop: int | None = None) -> np.ndarray:
        stop = self.n_events if stop is None else stop
        parts = []
        for meta, blob in self.fetch_range(name, start, stop):
            vals = self.decode_blob(name, blob)
            lo = max(start - meta.first_entry, 0)
            hi = min(stop - meta.first_entry, meta.n_entries)
            parts.append(vals[lo:hi])
        if not parts:
            return np.empty(0, dtype=self.branches[name].np_dtype())
        return np.concatenate(parts)

    def read_jagged(
        self, name: str, start: int = 0, stop: int | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        stop = self.n_events if stop is None else stop
        br = self.branches[name]
        counts = self.read_flat(br.counts_branch, start, stop).astype(np.int64)
        parts = []
        for meta, blob in self.fetch_range(name, start, stop):
            vals = self.decode_blob(name, blob)
            # per-basket event counts to slice values at event granularity
            bc = self.read_flat(
                br.counts_branch, meta.first_entry, meta.first_entry + meta.n_entries
            ).astype(np.int64)
            boff = np.concatenate([[0], np.cumsum(bc)])
            lo_e = max(start - meta.first_entry, 0)
            hi_e = min(stop - meta.first_entry, meta.n_entries)
            parts.append(vals[boff[lo_e] : boff[hi_e]])
        values = (
            np.concatenate(parts) if parts else np.empty(0, dtype=br.np_dtype())
        )
        return values, counts

    # -- serialization ------------------------------------------------------

    def save(self, path: str) -> None:
        header = {
            "basket_events": self.basket_events,
            "codec": self.codec,
            "n_events": self.n_events,
            "zonemap_version": ZONEMAP_VERSION,
            "integrity_version": INTEGRITY_VERSION,
            "branches": {
                n: {
                    "dtype": b.dtype,
                    "jagged": b.jagged,
                    "counts_branch": b.counts_branch,
                }
                for n, b in self.branches.items()
            },
            "baskets": {
                n: [m.stats_row() for m in metas]
                for n, metas in self._baskets.items()
            },
        }
        # sort_keys makes the header — and with it the whole file —
        # deterministic in branch *content*, not dict insertion order;
        # the blob section must follow the same sorted order because
        # load() slurps blobs in header order
        hbytes = json.dumps(header, sort_keys=True).encode()
        with open(path, "wb") as f:
            f.write(len(hbytes).to_bytes(8, "little"))
            f.write(hbytes)
            for n in sorted(self.branches):
                for blob in self._blobs[n]:
                    f.write(blob)

    @classmethod
    def load(cls, path: str) -> "EventStore":
        with open(path, "rb") as f:
            hlen = int.from_bytes(f.read(8), "little")
            header = json.loads(f.read(hlen).decode())
            store = cls(basket_events=header["basket_events"], codec=header["codec"])
            store.n_events = header["n_events"]
            for n, b in header["branches"].items():
                store.branches[n] = Branch(
                    n, b["dtype"], b["jagged"], b["counts_branch"]
                )
            for n, metas in header["baskets"].items():
                store._baskets[n] = [BasketMeta(*m) for m in metas]
            for n in store.branches:
                store._blobs[n] = [
                    f.read(m.comp_bytes) for m in store._baskets[n]
                ]
        return store

    # -- mutation used by the skim writer ------------------------------------

    @classmethod
    def from_selection(
        cls,
        columns: dict[str, np.ndarray],
        jagged: dict[str, str],
        basket_events: int,
        codec: str,
    ) -> "EventStore":
        return cls.from_arrays(
            columns, jagged=jagged, basket_events=basket_events, codec=codec
        )
