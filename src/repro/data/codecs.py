"""Basket codecs.

The paper's storage layer compresses ROOT baskets with LZMA (small, slow) or
LZ4 (larger, fast) and offloads decompression to the BlueField-3 engine.

TPU adaptation (DESIGN.md §2/§7): LZ4's byte-granular match-copy loop is
serial and does not map onto the TPU VPU.  We keep the *role* of each codec:

  - ``zlib``    : the LZMA stand-in — high ratio, expensive CPU decode.
  - ``bitpack`` : the LZ4/DPU-engine stand-in — a zigzag-delta /
                  xor-transpose bit-plane codec whose decode is pure vector
                  arithmetic, implemented both in numpy (host) and as a
                  Pallas kernel (``repro.kernels.basket_decode``).
  - ``raw``     : identity (uncompressed baseline).

Bit-plane layout (``bitpack``)
------------------------------
Values are transformed to unsigned 32-bit "codes":

  * integers  : ``zigzag(delta(v))``  — first value stored relative to 0.
  * floats    : ``bitcast_u32(v) XOR bitcast_u32(v_prev)`` — exponent/sign
                bits of consecutive physics values repeat, so the xor stream
                has many leading zeros.
  * bools     : the 0/1 value itself (b == 1 plane).

With ``b = max bit-width`` of the codes, the basket stores ``b`` bit-planes,
each ``ceil(n/32)`` uint32 words: plane ``j`` holds bit ``j`` of every code.
Decoding plane words is a fully vectorized broadcast+shift — no gathers, no
byte shuffles — which is exactly what the VPU (8x128 lanes) wants.

Header per basket (little-endian uint32s):
  [0] magic, [1] kind (0=int delta, 1=float xor, 2=bool), [2] n values,
  [3] bit width b, [4] n padded values, [5] first raw value (bitcast).
"""

from __future__ import annotations

import zlib as _zlib

import numpy as np

_MAGIC = 0x534B4D52  # "SKMR"

KIND_INT = 0
KIND_FLOAT = 1
KIND_BOOL = 2
KIND_RAW_F32 = 3  # incompressible floats stored verbatim (LZ4-style bail-out)

# xor codes needing more than this many bit-planes don't compress enough to
# pay for the unpack — store raw instead, exactly like LZ4 emits literals
# for incompressible input.  Decode of raw mode is a memcpy.
_RAW_BAILOUT_BITS = 24

_HEADER_WORDS = 6


def _zigzag_encode(v: np.ndarray) -> np.ndarray:
    v = v.astype(np.int64)
    return ((v << 1) ^ (v >> 63)).astype(np.uint64).astype(np.uint32)


def _zigzag_decode(u: np.ndarray) -> np.ndarray:
    u = u.astype(np.uint64)
    return ((u >> 1) ^ (-(u & 1)).astype(np.uint64)).astype(np.int64)


def _pack_planes(codes: np.ndarray, bits: int) -> np.ndarray:
    """codes: uint32 (n,) -> uint32 planes (bits * ceil(n/32),).

    Layout: value ``i`` is bit ``i % 32`` of word ``i // 32`` of its plane
    (little-endian within words) — np.packbits(bitorder='little') produces
    exactly this when the bytes are viewed as LE uint32.
    """
    n = codes.shape[0]
    n_pad = ((n + 31) // 32) * 32
    padded = np.zeros(n_pad, dtype=np.uint32)
    padded[:n] = codes
    nb = max(bits, 1)
    planes = np.empty((nb, n_pad // 32), dtype=np.uint32)
    for j in range(nb):
        bits_j = ((padded >> np.uint32(j)) & np.uint32(1)).astype(np.uint8)
        planes[j] = np.packbits(bits_j, bitorder="little").view("<u4")
    return planes.reshape(-1)


def _unpack_planes(planes: np.ndarray, bits: int, n_pad: int) -> np.ndarray:
    """planes: uint32 (bits * n_pad/32,) -> uint32 codes (n_pad,)."""
    words_per_plane = n_pad // 32
    nb = max(bits, 1)
    planes = planes.reshape(nb, words_per_plane)
    byte_mat = np.ascontiguousarray(planes).view(np.uint8).reshape(nb, -1)
    bits_mat = np.unpackbits(byte_mat, axis=1, bitorder="little")  # (nb, n_pad)
    acc = np.zeros(n_pad, dtype=np.uint32)
    for j in range(nb):
        acc |= bits_mat[j].astype(np.uint32) << np.uint32(j)
    return acc


def _codes_for(values: np.ndarray) -> tuple[np.ndarray, int, int]:
    """Transform raw values to uint32 codes; returns (codes, kind, first_bits)."""
    if values.dtype == np.bool_:
        return values.astype(np.uint32), KIND_BOOL, 0
    if np.issubdtype(values.dtype, np.integer):
        v = values.astype(np.int64)
        first = int(v[0]) if v.size else 0
        deltas = np.diff(v, prepend=np.int64(first))
        deltas[0] = 0
        codes = _zigzag_encode(deltas)
        return codes, KIND_INT, np.uint32(np.int64(first) & 0xFFFFFFFF)
    if values.dtype == np.float32:
        u = values.view(np.uint32)
        first = int(u[0]) if u.size else 0
        prev = np.concatenate([[np.uint32(first)], u[:-1]]) if u.size else u
        codes = u ^ prev
        if codes.size:
            codes[0] = 0
        return codes, KIND_FLOAT, np.uint32(first)
    raise TypeError(f"unsupported dtype for bitpack: {values.dtype}")


def _values_from_codes(codes: np.ndarray, kind: int, first: int, dtype) -> np.ndarray:
    if kind == KIND_BOOL:
        return codes.astype(np.bool_)
    if kind == KIND_INT:
        # int32-wide zigzag + cumsum (sources are int32; wrap-exact)
        u = codes
        deltas = ((u >> np.uint32(1)) ^ (-(u & np.uint32(1)).astype(np.int32)).view(np.uint32)).view(np.int32)
        deltas = deltas.copy()
        deltas[0] = np.asarray(first, dtype=np.uint32).view(np.int32)
        return np.cumsum(deltas, dtype=np.int32).astype(dtype)
    if kind == KIND_FLOAT:
        acc = codes.copy()
        acc[0] = np.uint32(first)
        # cumulative xor
        out = np.bitwise_xor.accumulate(acc)
        return out.view(np.float32).astype(dtype)
    raise ValueError(f"bad kind {kind}")


def bitpack_encode(values: np.ndarray) -> bytes:
    values = np.ascontiguousarray(values)
    n = values.shape[0]
    if n == 0:
        kind = (
            KIND_BOOL
            if values.dtype == np.bool_
            else KIND_INT
            if np.issubdtype(values.dtype, np.integer)
            else KIND_FLOAT
        )
        header = np.array([_MAGIC, kind, 0, 1, 32, 0], dtype=np.uint32)
        return header.tobytes() + np.zeros(1, np.uint32).tobytes()
    codes, kind, first = _codes_for(values)
    bits = int(codes.max()).bit_length() if n and codes.max() > 0 else 1
    if kind == KIND_FLOAT and bits > _RAW_BAILOUT_BITS:
        # incompressible float stream: raw literals (decode == memcpy)
        header = np.array([_MAGIC, KIND_RAW_F32, n, 32, n, first], dtype=np.uint32)
        return header.tobytes() + values.astype(np.float32).tobytes()
    n_pad = ((n + 31) // 32) * 32 if n else 32
    planes = _pack_planes(codes if n else np.zeros(1, np.uint32), bits)
    header = np.array([_MAGIC, kind, n, bits, n_pad, first], dtype=np.uint32)
    return header.tobytes() + planes.tobytes()


def bitpack_decode(blob: bytes, dtype) -> np.ndarray:
    header = np.frombuffer(blob[: _HEADER_WORDS * 4], dtype=np.uint32)
    if int(header[0]) != _MAGIC:
        raise ValueError("bad bitpack magic")
    kind, n, bits, n_pad, first = (int(x) for x in header[1:6])
    if n == 0:
        return np.empty(0, dtype=dtype)
    if kind == KIND_RAW_F32:
        return np.frombuffer(blob[_HEADER_WORDS * 4 :], dtype=np.float32).astype(
            dtype, copy=False
        )
    planes = np.frombuffer(blob[_HEADER_WORDS * 4 :], dtype=np.uint32)
    codes = _unpack_planes(planes, bits, n_pad)[:n]
    return _values_from_codes(codes, kind, first, dtype)


def bitpack_raw_parts(blob: bytes) -> dict:
    """Expose header + plane words for the Pallas decode kernel.

    Raw-mode baskets (kind 3) carry ``raw`` float bytes instead of planes —
    the kernel wrapper passes them through (no decode needed).
    """
    header = np.frombuffer(blob[: _HEADER_WORDS * 4], dtype=np.uint32)
    kind = int(header[1])
    body = blob[_HEADER_WORDS * 4 :]
    out = {
        "kind": kind,
        "n": int(header[2]),
        "bits": int(header[3]),
        "n_pad": int(header[4]),
        "first": int(header[5]),
    }
    if kind == KIND_RAW_F32:
        out["raw"] = np.frombuffer(body, dtype=np.float32)
        out["planes"] = np.zeros(0, np.uint32)
    else:
        out["planes"] = np.frombuffer(body, dtype=np.uint32)
    return out


# ---------------------------------------------------------------------------
# zone-map statistics (computed at encode time, stored in BasketMeta)
# ---------------------------------------------------------------------------


def basket_stats(values: np.ndarray) -> tuple[float | None, float | None, int | None]:
    """Per-basket zone-map statistics: ``(vmin, vmax, n_true)``.

    ``vmin``/``vmax`` are the value bounds as exact float64 embeddings of
    the stored dtype (float32 -> float64 is exact; int32 fits float64
    exactly), so interval analysis over them reproduces the evaluator's
    comparison semantics bit-for-bit.  ``n_true`` is the true-count for
    boolean branches (``None`` otherwise).  Non-finite data (NaN/inf)
    yields ``(None, None, None)`` — unknown stats degrade to "scan", never
    to a wrong prune (DESIGN.md §9).
    """
    values = np.asarray(values)
    if values.size == 0:
        return None, None, None
    if values.dtype == np.bool_:
        n_true = int(values.sum())
        return float(values.min()), float(values.max()), n_true
    lo, hi = float(values.min()), float(values.max())
    if not (np.isfinite(lo) and np.isfinite(hi)):
        return None, None, None
    return lo, hi, None


# ---------------------------------------------------------------------------


def _zlib_encode(values: np.ndarray) -> bytes:
    return _zlib.compress(np.ascontiguousarray(values).tobytes(), level=9)


def _zlib_decode(blob: bytes, dtype) -> np.ndarray:
    return np.frombuffer(_zlib.decompress(blob), dtype=dtype)


def _raw_encode(values: np.ndarray) -> bytes:
    return np.ascontiguousarray(values).tobytes()


def _raw_decode(blob: bytes, dtype) -> np.ndarray:
    return np.frombuffer(blob, dtype=dtype)


CODECS = {
    "bitpack": (bitpack_encode, bitpack_decode),
    "zlib": (_zlib_encode, _zlib_decode),
    "raw": (_raw_encode, _raw_decode),
}


def encode_basket(values: np.ndarray, codec: str) -> bytes:
    return CODECS[codec][0](values)


def decode_basket(blob: bytes, codec: str, dtype) -> np.ndarray:
    return CODECS[codec][1](blob, dtype)


def decode_basket_batch(
    blobs: list, codec: str, dtype, backend: str = "host"
) -> list:
    """Decode a list of basket blobs in one round (DESIGN.md §16).

    ``backend="host"`` (or any codec without a device decode) loops the
    host reference decoder.  ``backend="device"`` with the ``bitpack``
    codec ships the compressed *plane words* — not decoded columns —
    across the host→device boundary and decodes them on the kernel tier
    (``repro.kernels.ops.basket_decode_batch``: the Pallas kernel on
    TPU, its jitted jnp mirror elsewhere), grouped by codec kind so each
    group is one dispatch.  Output order matches ``blobs`` and is
    bit-identical to the host reference for every kind (int zigzag-delta
    prefix sums are wrap-exact int32, float prefix-xor is exact, bools
    and raw literals are identity).
    """
    if backend != "device" or codec != "bitpack":
        decode = CODECS[codec][1]
        return [decode(blob, dtype) for blob in blobs]
    from repro.kernels import ops

    parts = [bitpack_raw_parts(blob) for blob in blobs]
    out: list = [None] * len(blobs)
    groups: dict[int, list[int]] = {}
    for i, p in enumerate(parts):
        if p["n"] == 0:
            out[i] = np.empty(0, dtype=dtype)
        else:
            groups.setdefault(p["kind"], []).append(i)
    for _kind, idxs in sorted(groups.items()):
        decoded = ops.basket_decode_batch([parts[i] for i in idxs], dtype)
        for i, vals in zip(idxs, decoded):
            out[i] = np.asarray(vals)
    return out


# ---------------------------------------------------------------------------
# integrity digests (computed at encode time, stored in BasketMeta)
# ---------------------------------------------------------------------------


def basket_digest(blob: bytes) -> int:
    """Integrity digest of one encoded basket blob (CRC-32, as an
    unsigned 32-bit int).

    Computed once at encode time and carried in
    :class:`~repro.data.store.BasketMeta` / the store manifest
    (``INTEGRITY_VERSION``); the fetch path recomputes it per blob and a
    mismatch raises :class:`~repro.data.store.CorruptBasket` — corrupt
    data is never silently decoded (DESIGN.md §14).  CRC-32 is orders of
    magnitude cheaper than any codec's decode, keeping verification
    overhead under the 2% budget benchmarked by
    ``benchmarks/bench_faults.py``.
    """
    return _zlib.crc32(blob) & 0xFFFFFFFF
