"""Synthetic NanoAOD-like event generator.

Produces a physically-shaped stand-in for the CMS NanoAOD files the paper
filters: jagged particle collections (Electron/Muon/Jet) with kinematic
variables, event-level MET, and a block of HLT trigger bits, plus optional
filler branches so the branch count can approach the paper's 1749-branch
file for the benchmarks.
"""

from __future__ import annotations

import numpy as np

from repro.data.store import EventStore

COLLECTIONS = {
    # name -> (poisson mean multiplicity, kinematic variables)
    "Electron": (0.4, ["pt", "eta", "phi", "mass", "charge", "mvaId"]),
    "Muon": (0.5, ["pt", "eta", "phi", "mass", "charge", "tightId"]),
    "Jet": (4.0, ["pt", "eta", "phi", "mass", "btagDeepB"]),
}

DEFAULT_TRIGGERS = [
    "HLT_IsoMu24",
    "HLT_Ele32_WPTight_Gsf",
    "HLT_PFMET120_PFMHT120_IDTight",
    "HLT_DoubleEle25_CaloIdL_MW",
    "HLT_Mu17_TrkIsoVVL_Mu8_TrkIsoVVL",
]


def _kinematic(rng: np.random.Generator, var: str, n: int) -> np.ndarray:
    if var == "pt":
        return (rng.exponential(25.0, n) + 3.0).astype(np.float32)
    if var == "eta":
        return rng.uniform(-2.5, 2.5, n).astype(np.float32)
    if var == "phi":
        return rng.uniform(-np.pi, np.pi, n).astype(np.float32)
    if var == "mass":
        return np.abs(rng.normal(5.0, 3.0, n)).astype(np.float32)
    if var == "charge":
        return rng.choice(np.array([-1, 1], dtype=np.int32), n)
    if var in ("mvaId", "tightId"):
        return (rng.random(n) > 0.3)
    if var == "btagDeepB":
        return rng.beta(0.5, 2.0, n).astype(np.float32)
    return rng.normal(0.0, 1.0, n).astype(np.float32)


def make_nanoaod_like(
    n_events: int = 20_000,
    n_hlt: int = 64,
    n_filler: int = 0,
    basket_events: int = 4096,
    codec: str = "bitpack",
    seed: int = 0,
) -> EventStore:
    """Build a synthetic NanoAOD-style :class:`EventStore`.

    ``n_hlt`` trigger-bit branches named ``HLT_*`` (the first few use the
    realistic names in :data:`DEFAULT_TRIGGERS`); ``n_filler`` extra flat
    float branches (``Filler_000`` ...) standing in for the long tail of
    NanoAOD branches that a skim carries to the output but never filters on.
    """
    rng = np.random.default_rng(seed)
    columns: dict[str, np.ndarray] = {}
    jagged: dict[str, str] = {}

    for coll, (mean_mult, variables) in COLLECTIONS.items():
        counts = rng.poisson(mean_mult, n_events).astype(np.int32)
        total = int(counts.sum())
        columns[f"n{coll}"] = counts
        for var in variables:
            name = f"{coll}_{var}"
            columns[name] = _kinematic(rng, var, total)
            jagged[name] = f"n{coll}"

    columns["MET_pt"] = (rng.exponential(30.0, n_events) + 1.0).astype(np.float32)
    columns["MET_phi"] = rng.uniform(-np.pi, np.pi, n_events).astype(np.float32)
    columns["PV_npvs"] = rng.poisson(35.0, n_events).astype(np.int32)
    columns["run"] = np.full(n_events, 362_104, dtype=np.int32)
    columns["event"] = np.arange(n_events, dtype=np.int64).astype(np.int32)
    columns["luminosityBlock"] = (np.arange(n_events) // 1000).astype(np.int32)

    for i in range(n_hlt):
        name = DEFAULT_TRIGGERS[i] if i < len(DEFAULT_TRIGGERS) else f"HLT_path{i:03d}"
        rate = 0.15 if i < len(DEFAULT_TRIGGERS) else 0.02
        columns[name] = rng.random(n_events) < rate

    for i in range(n_filler):
        columns[f"Filler_{i:03d}"] = rng.normal(0, 1, n_events).astype(np.float32)

    return EventStore.from_arrays(
        columns, jagged=jagged, basket_events=basket_events, codec=codec
    )
