from repro.data.codecs import CODECS, decode_basket, encode_basket
from repro.data.store import (
    TTREECACHE_BYTES,
    Branch,
    EventStore,
    FetchStats,
    WindowPrefetcher,
)
from repro.data.synth import make_nanoaod_like

__all__ = [
    "CODECS",
    "encode_basket",
    "decode_basket",
    "Branch",
    "EventStore",
    "FetchStats",
    "WindowPrefetcher",
    "TTREECACHE_BYTES",
    "make_nanoaod_like",
]
