from repro.data.codecs import CODECS, decode_basket, encode_basket
from repro.data.store import Branch, EventStore, FetchStats
from repro.data.synth import make_nanoaod_like

__all__ = [
    "CODECS",
    "encode_basket",
    "decode_basket",
    "Branch",
    "EventStore",
    "FetchStats",
    "make_nanoaod_like",
]
