"""Static verifier for compiled skim artifacts (DESIGN.md §15).

The lint half of skimlint proves *source-level* invariants; this module
proves what lints cannot see — properties of the compiled
:class:`~repro.kernels.predicate_eval.Program` and the lowered
:class:`~repro.core.planner.SkimPlan` that, if violated, break the
repo's signature bit-identity invariant or crash mid-scan after bytes
have already moved:

``verify_program``
    RPN stack-depth balance, term-slot bounds, valid group collection
    wiring, known opcodes — for every compiled Program.
``verify_plan``
    each cascade stage's fetch set covers **exactly** what its
    sub-Program reads (a missed branch is a KeyError after the prefetch
    already chose its load set; an extra branch is silent over-fetch
    that corrupts the byte ledger), the pinned-head invariant the
    double-buffered prefetcher relies on, sane prices, window-decision
    coverage, and the cache-key field coverage below.
``verify_cache_key_coverage``
    every :class:`~repro.core.query.Query` field is accounted for by the
    canonical query form recorded for the current ``CACHE_KEY_VERSION``
    — adding a query field without bumping the version is a *static*
    error here, not a silent stale-cache-hit in production.

Verification is hooked into ``compile_query`` and ``plan_skim`` behind
``REPRO_VERIFY=1`` (on in the test suite's conftest, off in benchmarks;
when off the hook costs one environment lookup).  Every rejection is a
typed :class:`VerifyError` carrying ``invariant``, the machine-readable
name of the broken invariant.
"""

from __future__ import annotations

import dataclasses
import math
import os

from repro.core.expr import (
    RPN_ABS,
    RPN_ADD,
    RPN_BRANCH,
    RPN_CONST,
    RPN_DIV,
    RPN_MAX,
    RPN_MIN,
    RPN_MUL,
    RPN_NEG,
    RPN_SUB,
    RPN_SUM,
    counts_name,
)
from repro.core.query import Query
from repro.kernels.ref import (
    GROUP_ANY,
    GROUP_COUNT,
    GROUP_DR,
    GROUP_EXPR,
    GROUP_HT,
    GROUP_MASS,
    OP_IDS,
)

_KNOWN_KINDS = frozenset(
    (GROUP_COUNT, GROUP_HT, GROUP_ANY, GROUP_MASS, GROUP_DR, GROUP_EXPR)
)
_KNOWN_OPS = frozenset(OP_IDS.values())
_RPN_PUSH = frozenset((RPN_BRANCH, RPN_SUM, RPN_CONST))
_RPN_UNARY = frozenset((RPN_NEG, RPN_ABS))
_RPN_BINARY = frozenset((RPN_ADD, RPN_SUB, RPN_MUL, RPN_DIV, RPN_MIN, RPN_MAX))

#: the Query dataclass fields accounted for by the canonical query form
#: (cluster/cache.canonical_query) at each CACHE_KEY_VERSION.  `input`,
#: `output`, and `meta` are deliberately excluded from the canonical
#: form (paths and free-form metadata cannot change a result); every
#: other field feeds it.  Adding a Query field requires bumping
#: CACHE_KEY_VERSION in cluster/cache.py AND recording the new field
#: set here — until both happen, verification fails statically.
CANONICAL_QUERY_FIELDS: dict[int, frozenset[str]] = {
    4: frozenset(
        {
            "input", "output", "branches", "force_all", "preselection",
            "object_stage", "event_stage", "strict", "cascade", "meta",
        }
    ),
}


class VerifyError(Exception):
    """A compiled artifact violates a static invariant.

    ``invariant`` is the machine-readable name (e.g.
    ``"rpn-stack-balance"``); the message says what and where.
    """

    def __init__(self, invariant: str, message: str):
        super().__init__(f"[{invariant}] {message}")
        self.invariant = invariant


def verify_enabled() -> bool:
    """True when ``REPRO_VERIFY`` asks for verification (default: off)."""
    return os.environ.get("REPRO_VERIFY", "0").lower() not in ("", "0", "false", "off")


# ---------------------------------------------------------------------------
# Program verification
# ---------------------------------------------------------------------------


def _check_terms(where: str, term_ids, n_terms: int) -> None:
    for t in term_ids:
        if not isinstance(t, int) or not 0 <= t < n_terms:
            raise VerifyError(
                "term-slot-bounds",
                f"{where}: term slot {t!r} outside [0, {n_terms})",
            )


def _check_rpn(where: str, rpn, n_terms: int) -> None:
    """Prove the stack program is balanced and reads only valid slots."""
    if not rpn:
        raise VerifyError("rpn-stack-balance", f"{where}: empty RPN program")
    depth = 0
    for i, (op, arg) in enumerate(rpn):
        if op in (RPN_BRANCH, RPN_SUM):
            _check_terms(f"{where} rpn[{i}]", (arg,), n_terms)
            depth += 1
        elif op == RPN_CONST:
            if not isinstance(arg, (int, float)) or not math.isfinite(float(arg)):
                raise VerifyError(
                    "rpn-constant", f"{where} rpn[{i}]: non-finite constant {arg!r}"
                )
            depth += 1
        elif op in _RPN_UNARY:
            if depth < 1:
                raise VerifyError(
                    "rpn-stack-balance",
                    f"{where} rpn[{i}]: unary op {op} on empty stack",
                )
        elif op in _RPN_BINARY:
            if depth < 2:
                raise VerifyError(
                    "rpn-stack-balance",
                    f"{where} rpn[{i}]: binary op {op} with stack depth {depth}",
                )
            depth -= 1
        else:
            raise VerifyError("rpn-opcode", f"{where} rpn[{i}]: unknown opcode {op!r}")
    if depth != 1:
        raise VerifyError(
            "rpn-stack-balance",
            f"{where}: program leaves stack depth {depth}, want exactly 1",
        )


def verify_program(program) -> None:
    """Prove a compiled :class:`Program`'s structural invariants.

    Raises :class:`VerifyError` naming the broken invariant; returns
    ``None`` on success.  Store-independent (compilation is too).
    """
    n_terms = program.n_terms
    n_groups = program.n_groups
    if len(program.group_collections) != n_groups or len(program.group_weights) != n_groups:
        raise VerifyError(
            "group-wiring",
            f"group_collections/group_weights length != {n_groups} groups",
        )
    colls2 = program.group_collections2
    if colls2 and len(colls2) != n_groups:
        raise VerifyError(
            "group-wiring",
            f"group_collections2 has {len(colls2)} entries for {n_groups} groups",
        )
    for name in program.term_branches:
        if not isinstance(name, str) or not name:
            raise VerifyError("term-branch", f"bad term branch name {name!r}")
    for g, grp in enumerate(program.groups):
        where = f"group[{g}]"
        if grp.kind not in _KNOWN_KINDS:
            raise VerifyError("group-opcode", f"{where}: unknown group kind {grp.kind!r}")
        _check_terms(where, grp.term_ids, n_terms)
        if grp.kind in (GROUP_COUNT, GROUP_HT, GROUP_ANY):
            if len(grp.ops) != len(grp.term_ids) or len(grp.thrs) != len(grp.term_ids):
                raise VerifyError(
                    "group-shape",
                    f"{where}: {len(grp.term_ids)} terms but {len(grp.ops)} ops / "
                    f"{len(grp.thrs)} thresholds",
                )
            for op in grp.ops:
                if op not in _KNOWN_OPS:
                    raise VerifyError("group-opcode", f"{where}: unknown term op {op!r}")
        if grp.kind in (GROUP_HT, GROUP_DR, GROUP_EXPR) and grp.cmp_op not in _KNOWN_OPS:
            raise VerifyError("group-opcode", f"{where}: unknown cmp op {grp.cmp_op!r}")
        if grp.kind == GROUP_COUNT and grp.min_count < 0:
            raise VerifyError("group-shape", f"{where}: negative min_count {grp.min_count}")
        if grp.kind == GROUP_HT:
            if not grp.term_ids:
                raise VerifyError("group-shape", f"{where}: HT group with no terms")
            if program.group_weights[g] is None or program.group_collections[g] is None:
                raise VerifyError(
                    "group-wiring", f"{where}: HT group needs a collection and a weight branch"
                )
        if grp.kind in (GROUP_MASS, GROUP_DR):
            want = 8 if grp.kind == GROUP_MASS else 6
            if len(grp.term_ids) != want:
                raise VerifyError(
                    "group-shape",
                    f"{where}: pair group wants {want} kinematic terms, "
                    f"has {len(grp.term_ids)}",
                )
            coll2 = colls2[g] if g < len(colls2) else None
            if program.group_collections[g] is None or coll2 is None:
                raise VerifyError(
                    "group-wiring", f"{where}: pair group needs both collections wired"
                )
        if grp.kind == GROUP_EXPR:
            _check_rpn(where, grp.rpn, n_terms)


# ---------------------------------------------------------------------------
# Plan verification
# ---------------------------------------------------------------------------


def program_reads(program, store) -> set[str]:
    """Branches a compiled sub-Program reads when evaluated over ``store``.

    Derived from the Program itself (NOT from the query node it was
    lowered from — that independence is what makes the coverage check a
    real cross-check): term branches present in the store, counts
    branches of every wired collection and jagged read, HT weight
    branches, and the counts feeding ``sum()`` RPN slots.
    """
    reads: set[str] = set()
    for name in program.term_branches:
        if name in store.branches:
            reads.add(name)
    colls2 = program.group_collections2
    for g, grp in enumerate(program.groups):
        coll = program.group_collections[g]
        if coll is not None:
            reads.add(f"n{coll}")
        coll2 = colls2[g] if g < len(colls2) else None
        if coll2 is not None:
            reads.add(f"n{coll2}")
        weight = program.group_weights[g]
        if weight is not None:
            reads.add(weight)
        for op, slot in grp.rpn:
            if op == RPN_SUM:
                reads.add(counts_name(program.term_branches[int(slot)]))
    for name in sorted(reads):
        br = store.branches.get(name)
        if br is not None and br.jagged:
            reads.add(br.counts_branch)
    return reads


def _verify_cascade(plan, store) -> None:
    cplan = plan.cascade
    n = cplan.n_stages
    order = list(cplan.static_order)
    if sorted(order) != list(range(n)):
        raise VerifyError(
            "pinned-head",
            f"static_order {order} is not a permutation of 0..{n - 1}",
        )
    for i, stage in enumerate(cplan.stages):
        where = f"stage[{i}]"
        if stage.index != i:
            raise VerifyError("stage-index", f"{where}: index {stage.index} != position {i}")
        if not (0.0 <= stage.est_selectivity <= 1.0) or not math.isfinite(
            stage.est_selectivity
        ):
            raise VerifyError(
                "stage-price",
                f"{where}: est_selectivity {stage.est_selectivity!r} outside [0, 1]",
            )
        if stage.est_bytes < 0:
            raise VerifyError(
                "stage-price", f"{where}: negative est_bytes {stage.est_bytes}"
            )
        if stage.program is None:
            raise VerifyError("stage-program", f"{where}: no compiled sub-Program")
        verify_program(stage.program)
        reads = program_reads(stage.program, store)
        fetch = set(stage.branches)
        missing = reads - fetch
        if missing:
            raise VerifyError(
                "stage-fetch-coverage",
                f"{where}: sub-Program reads {sorted(missing)} but the stage "
                f"fetch set {sorted(fetch)} does not include them — the "
                "cascade would KeyError mid-scan (or silently mis-evaluate)",
            )
        extra = fetch - reads
        if extra:
            raise VerifyError(
                "stage-fetch-coverage",
                f"{where}: fetch set includes {sorted(extra)} the sub-Program "
                "never reads — over-fetch corrupts the byte ledger",
            )
    # after the per-stage checks so a bad price reports as "stage-price",
    # not as the order drift it causes
    expected = sorted(range(n), key=lambda i: (cplan.stages[i].rank, i))
    if order != expected:
        raise VerifyError(
            "pinned-head",
            f"static_order {order} != cost-model order {expected} — the "
            "prefetcher's head load set would differ across pipeline modes",
        )


def verify_plan(plan, store) -> None:
    """Prove a lowered :class:`SkimPlan`'s invariants against its store.

    Raises :class:`VerifyError` naming the broken invariant.  Pure
    metadata — nothing is fetched, decoded, or evaluated.
    """
    available = set(store.branch_names())
    for kind, names in (
        ("filter", plan.filter_branches),
        ("output", plan.output_branches),
        ("phase2", plan.output_only_branches),
    ):
        if len(set(names)) != len(names):
            raise VerifyError("plan-branch-partition", f"duplicate {kind} branches")
        unknown = [b for b in names if b not in available]
        if unknown:
            raise VerifyError(
                "plan-branch-partition",
                f"{kind} set names branches the store lacks: {unknown}",
            )
    want_phase2 = [
        b for b in plan.output_branches if b not in set(plan.filter_branches)
    ]
    if plan.output_only_branches != want_phase2:
        raise VerifyError(
            "plan-branch-partition",
            "output_only_branches is not output minus filter — phase 2 "
            "would re-fetch or drop branches",
        )
    if plan.window_decisions is not None:
        pos = 0
        for i, d in enumerate(plan.window_decisions):
            if d.start != pos or d.stop <= d.start:
                raise VerifyError(
                    "window-decisions",
                    f"decision[{i}] spans [{d.start}, {d.stop}) but the scan "
                    f"cursor is at {pos} — windows must tile the store",
                )
            pos = d.stop
        if pos != store.n_events:
            raise VerifyError(
                "window-decisions",
                f"decisions end at event {pos}, store has {store.n_events}",
            )
    if plan.cascade is not None:
        _verify_cascade(plan, store)
    verify_cache_key_coverage()
    # every AST node in the query must render a canonical node doc — a
    # node type without one cannot be content-addressed
    from repro.cluster.cache import canonical_query

    try:
        canonical_query(plan.query)
    except TypeError as exc:
        raise VerifyError(
            "canonical-node-doc",
            f"query contains a node the canonical form cannot render: {exc}",
        ) from exc


def verify_cache_key_coverage() -> None:
    """Prove the canonical query form accounts for every Query field.

    The recorded field set for the current ``CACHE_KEY_VERSION`` must
    equal ``Query``'s actual dataclass fields: a new field that can
    change results MUST enter ``canonical_query`` with a version bump,
    and even a result-irrelevant field must be recorded as such here.
    """
    from repro.cluster.cache import CACHE_KEY_VERSION

    recorded = CANONICAL_QUERY_FIELDS.get(CACHE_KEY_VERSION)
    if recorded is None:
        raise VerifyError(
            "cache-key-version",
            f"CACHE_KEY_VERSION={CACHE_KEY_VERSION} has no recorded canonical "
            "field set in repro.analysis.verify.CANONICAL_QUERY_FIELDS — "
            "record it alongside the version bump",
        )
    actual = {f.name for f in dataclasses.fields(Query)}
    if actual != recorded:
        added = sorted(actual - recorded)
        removed = sorted(recorded - actual)
        raise VerifyError(
            "cache-key-coverage",
            f"Query fields changed without a cache-key version bump: "
            f"added={added} removed={removed} — update canonical_query, bump "
            "CACHE_KEY_VERSION in cluster/cache.py, and record the new field "
            "set in CANONICAL_QUERY_FIELDS",
        )


def verify_device_batch(
    spans,
    pad_E: int,
    pad_B: int,
    nb: int,
    basket_events: int,
    mask_words: int,
) -> None:
    """Prove one window-batch's tiling invariants (DESIGN.md §16).

    The batched cascade stages windows into a single (B, ..., pad_E, K)
    tensor and carries survivor masks as (B, pad_E/32) uint32 words; a
    pad shape that fails to cover a member window silently truncates its
    tail events, and a basket-axis (``nb``) too small for the window's
    global basket grid folds distinct baskets onto one alive bit —
    phase 2 would then re-fetch (or worse, skip) the wrong baskets.
    """
    if pad_E % 32 != 0:
        raise VerifyError(
            "batch-pad-alignment",
            f"pad_E={pad_E} is not a multiple of 32 — the bit-packed "
            "survivor words cannot tile the event axis",
        )
    if mask_words * 32 != pad_E:
        raise VerifyError(
            "batch-mask-width",
            f"packed mask carries {mask_words} words = {mask_words * 32} "
            f"events but the batch is padded to pad_E={pad_E}",
        )
    if len(spans) > pad_B:
        raise VerifyError(
            "batch-window-overflow",
            f"{len(spans)} member windows exceed the padded batch "
            f"size pad_B={pad_B}",
        )
    for start, stop in spans:
        m = stop - start
        if m > pad_E:
            raise VerifyError(
                "batch-pad-coverage",
                f"window [{start}, {stop}) has {m} events but the batch "
                f"is padded to pad_E={pad_E} — tail events would be "
                "silently truncated",
            )
        grid0 = start - start % basket_events
        last_id = (stop - 1 - grid0) // basket_events
        if last_id >= nb:
            raise VerifyError(
                "batch-basket-coverage",
                f"window [{start}, {stop}) spans basket ordinal "
                f"{last_id} on the global grid but the alive-bit axis "
                f"holds only nb={nb} baskets",
            )


# ---------------------------------------------------------------------------
# env-gated hooks (compile_query / plan_skim call these)
# ---------------------------------------------------------------------------


def maybe_verify_device_batch(
    spans, pad_E, pad_B, nb, basket_events, mask_words
) -> None:
    """``verify_device_batch`` iff ``REPRO_VERIFY`` is on."""
    if verify_enabled():
        verify_device_batch(spans, pad_E, pad_B, nb, basket_events, mask_words)


def maybe_verify_program(program) -> None:
    """``verify_program`` iff ``REPRO_VERIFY`` is on (one env lookup off)."""
    if verify_enabled():
        verify_program(program)


def maybe_verify_plan(plan, store) -> None:
    """``verify_plan`` iff ``REPRO_VERIFY`` is on (one env lookup off)."""
    if verify_enabled():
        verify_plan(plan, store)
