"""Static analysis of compiled artifacts (DESIGN.md §15).

``repro.analysis.verify`` proves invariants of compiled Programs and
SkimPlans *before anything runs* — the verifier half of the skimlint
suite (``tools/skimlint`` owns the source-level lint half).
"""

from repro.analysis.verify import (
    VerifyError,
    maybe_verify_plan,
    maybe_verify_program,
    program_reads,
    verify_cache_key_coverage,
    verify_enabled,
    verify_plan,
    verify_program,
)

__all__ = [
    "VerifyError",
    "maybe_verify_plan",
    "maybe_verify_program",
    "program_reads",
    "verify_cache_key_coverage",
    "verify_enabled",
    "verify_plan",
    "verify_program",
]
