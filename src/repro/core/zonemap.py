"""Zone-map predicate pushdown: interval analysis over the query AST.

The fastest byte is the one never moved — and with per-basket statistics
(:class:`~repro.data.store.BasketMeta` ``vmin``/``vmax``/``n_true``,
DESIGN.md §9) whole basket windows can be *proved* out before any fetch
or decode happens.  This module classifies each window against a parsed
:class:`~repro.core.query.Query`:

  * ``PRUNE``      — no event in the window can survive the selection:
    phase 1 *and* phase 2 are skipped entirely,
  * ``ACCEPT_ALL`` — every event provably survives: predicate evaluation
    is skipped and the window goes straight to phase 2,
  * ``SCAN``       — undecidable from stats; run the normal executor.

Correctness contract (pinned by tests/test_zonemap.py property tests):
a window classified PRUNE never contains a survivor and ACCEPT_ALL never
contains a failure, for every AST shape — so pruned runs are bit-identical
to the reference ``prune=False`` path.

Two semantics details make the analysis exact rather than merely
heuristic:

  * **float32 comparison semantics** — the evaluator compares float32
    branch data against the query threshold at float32 precision (NumPy
    weak promotion), so thresholds are rounded through float32 before the
    interval test whenever the branch stores float32.  Stats are exact
    float64 embeddings of the stored values, so interval endpoints compare
    exactly.
  * **HT accumulation slack** — HT sums are float64 accumulations whose
    rounding the interval bound cannot reproduce term-for-term; the HT
    interval is widened by a rigorous slack before claiming ALWAYS/NEVER.

Unknown statistics (legacy stores, non-finite data) always degrade to
SCAN.

The derived-expression tier (DESIGN.md §10) classifies through **interval
arithmetic over the expression tree**: +, −, ×, ÷ (nonzero divisor),
abs/neg/min/max propagate window bounds exactly (float64 endpoint ops are
monotone; one-ulp outward rounding is applied anyway as slack), ``sum()``
reductions reuse the HT accumulation-slack bound, and the nonlinear
leading-pair nodes (invariant mass, ΔR) degrade to SCAN.  Trigger-OR
branches *absent from the store* contribute constant-False — mirroring
the evaluator's era-robust ``AnyOf`` semantics bit-for-bit.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.expr import (
    RPN_ABS,
    RPN_ADD,
    RPN_BRANCH,
    RPN_CONST,
    RPN_DIV,
    RPN_MAX,
    RPN_MIN,
    RPN_MUL,
    RPN_NEG,
    RPN_SUB,
    RPN_SUM,
    counts_name,
)
from repro.core.query import (
    AnyOf,
    Cut,
    DeltaRCut,
    ExprCut,
    HTCut,
    MassWindow,
    ObjectSelection,
    Query,
)

# window decisions
PRUNE = "prune"
ACCEPT_ALL = "accept_all"
SCAN = "scan"

# node tri-states ("does an event pass this node?")
ALWAYS = 1
NEVER = -1
MAYBE = 0


@dataclass(frozen=True)
class WindowDecision:
    """One window's pruning decision plus the priced savings.

    ``p1_bytes``/``p1_baskets`` are the phase-1 filter-branch fetch a
    PRUNE avoids; ``extra_bytes``/``extra_baskets`` are the filter-only
    (non-output) branches an ACCEPT_ALL never moves at all.  SCAN windows
    carry zeros.

    Pricing model: savings are priced against the **preloading** executor
    (the default fused/pipelined path, which fetches the full filter set
    per window) — exact there, pinned by tests.  The staged ``fused=False``
    reference hierarchically early-discards, so for a window it would have
    killed at stage 1 it fetches less than ``p1_bytes``; against that
    path the ledger is an upper bound.
    """

    start: int
    stop: int
    decision: str  # PRUNE | ACCEPT_ALL | SCAN
    p1_bytes: int = 0
    p1_baskets: int = 0
    extra_bytes: int = 0
    extra_baskets: int = 0


def _effective_threshold(value: float, dtype: np.dtype) -> float:
    """The threshold as the evaluator actually compares it.

    float32 branch vs python-float threshold compares at float32 (NumPy
    weak promotion), so the threshold is rounded through float32 first;
    every other dtype promotes to float64, where the python float is
    exact.  The result is returned as float64 (the exact embedding), so
    comparisons against float64 stat endpoints reproduce the evaluator.
    """
    if dtype == np.float32:
        return float(np.float32(value))
    return float(value)


def _cmp_interval(lo: float, hi: float, op: str, value: float) -> int:
    """Tri-state of ``x <op> value`` for all x in ``[lo, hi]``."""
    if op == ">":
        return ALWAYS if lo > value else (NEVER if hi <= value else MAYBE)
    if op == ">=":
        return ALWAYS if lo >= value else (NEVER if hi < value else MAYBE)
    if op == "<":
        return ALWAYS if hi < value else (NEVER if lo >= value else MAYBE)
    if op == "<=":
        return ALWAYS if hi <= value else (NEVER if lo > value else MAYBE)
    if op == "==":
        if lo == hi == value:
            return ALWAYS
        return NEVER if (value < lo or value > hi) else MAYBE
    if op == "!=":
        if value < lo or value > hi:
            return ALWAYS
        return NEVER if lo == hi == value else MAYBE
    if op in ("abs<", "abs>"):
        alo, ahi = _abs_interval(lo, hi)
        return _cmp_interval(alo, ahi, op[3:], value)
    return MAYBE  # unknown op: never prune on guesswork


def _abs_interval(lo: float, hi: float) -> tuple[float, float]:
    if lo >= 0.0:
        return lo, hi
    if hi <= 0.0:
        return -hi, -lo
    return 0.0, max(-lo, hi)


# ---------------------------------------------------------------------------
# per-node classification
# ---------------------------------------------------------------------------


def _branch_interval(stats_of, branch: str, store):
    """(lo, hi, dtype) of a branch over the window, or None if unknown."""
    st = stats_of(branch)
    if st is None or st.lo is None or st.hi is None:
        return None
    return st.lo, st.hi, store.branches[branch].np_dtype()


def _classify_cut(node: Cut, stats_of, store) -> int:
    iv = _branch_interval(stats_of, node.branch, store)
    if iv is None:
        return MAYBE
    lo, hi, dt = iv
    return _cmp_interval(lo, hi, node.op, _effective_threshold(node.value, dt))


def _classify_anyof(node: AnyOf, stats_of, store) -> int:
    """OR of boolean branches: ALWAYS if some branch is all-true in the
    window, NEVER only if every branch is provably all-false.

    A branch *absent from the store* is constant-False by the evaluator's
    era-robust semantics — it contributes nothing and cannot block a
    NEVER.  A branch that is present but lacks stats might fire."""
    all_false = True
    for name in node.names:
        if name not in store.branches:
            continue  # absent trigger: definitively all-false
        st = stats_of(name)
        if st is None or st.n_true is None:
            all_false = False  # unknown stats might fire
            continue
        if st.n_values > 0 and st.n_true == st.n_values:
            return ALWAYS
        if st.n_true > 0:
            all_false = False
    return NEVER if all_false else MAYBE


def _object_cut_states(collection: str, cuts, stats_of, store) -> list[int]:
    """Tri-state of each object-level cut over ALL objects in the window."""
    states = []
    for c in cuts:
        iv = _branch_interval(stats_of, f"{collection}_{c.var}", store)
        if iv is None:
            states.append(MAYBE)
            continue
        lo, hi, dt = iv
        states.append(
            _cmp_interval(lo, hi, c.op, _effective_threshold(c.value, dt))
        )
    return states


def _counts_bounds(collection: str, stats_of) -> tuple[int | None, int | None]:
    st = stats_of(f"n{collection}")
    if st is None or st.lo is None or st.hi is None:
        return None, None
    return int(st.lo), int(st.hi)


def _classify_object(node: ObjectSelection, stats_of, store) -> int:
    if node.min_count <= 0:
        return ALWAYS  # count >= 0 holds vacuously
    cmin, cmax = _counts_bounds(node.collection, stats_of)
    if cmax is not None and cmax < node.min_count:
        return NEVER  # covers cmax == 0: no objects at all in the window
    states = _object_cut_states(node.collection, node.cuts, stats_of, store)
    if any(s == NEVER for s in states):
        # no object anywhere in the window passes that cut -> per-event
        # passing count is 0 < min_count, whatever the counts are
        return NEVER
    if all(s == ALWAYS for s in states) and cmin is not None:
        if cmin >= node.min_count:
            return ALWAYS
    return MAYBE


def _classify_ht(node: HTCut, stats_of, store) -> int:
    cmin, cmax = _counts_bounds(node.collection, stats_of)
    states = _object_cut_states(node.collection, node.object_cuts, stats_of, store)
    zero_ht = cmax == 0 or any(s == NEVER for s in states)
    if zero_ht:
        # HT is exactly 0.0 for every event in the window
        return _cmp_interval(0.0, 0.0, node.op, float(node.value))
    iv = _branch_interval(stats_of, f"{node.collection}_{node.var}", store)
    if iv is None or cmax is None:
        return MAYBE
    vlo, vhi, _ = iv
    if all(s == ALWAYS for s in states) and cmin is not None:
        # every object contributes: per-event count in [cmin, cmax]
        ht_lo = min(cmin * vlo, cmax * vlo)
        ht_hi = max(cmin * vhi, cmax * vhi)
    else:
        # passing subset unknown: anywhere from none to all objects
        ht_lo = min(0.0, cmax * vlo)
        ht_hi = max(0.0, cmax * vhi)
    # float64 accumulation slack: the evaluator's per-event sum of up to
    # cmax float64 terms carries rounding error bounded by
    # (n-1)*u*sum|x| <= cmax^2 * max|v| * u (u = 2^-52); widen by that
    # bound with a 32x safety factor plus an absolute floor
    maxabs = max(abs(vlo), abs(vhi))
    slack = max(1e-12, 32 * 1.11e-16 * cmax * cmax * maxabs)
    ht_lo, ht_hi = ht_lo - slack, ht_hi + slack
    if node.op in ("==", "!="):
        # interval endpoints carry slack; only the NEVER side is provable
        state = _cmp_interval(ht_lo, ht_hi, node.op, float(node.value))
        return state if state == NEVER else MAYBE
    return _cmp_interval(ht_lo, ht_hi, node.op, float(node.value))


# ---------------------------------------------------------------------------
# expression interval arithmetic (DESIGN.md §10)
# ---------------------------------------------------------------------------

# float64 unit roundoff; the HT/sum accumulation-slack constant
_ULP = 1.11e-16


def _outward(lo: float, hi: float) -> tuple[float, float]:
    """One-ulp outward rounding slack after an inexact float64 op.

    Endpoint arithmetic is already conservative (IEEE rounding is
    monotone, so pointwise float64 results stay inside the float64
    endpoint interval), but the extra ulp keeps the bound safe against
    any non-monotone refactor of the evaluator."""
    return float(np.nextafter(lo, -np.inf)), float(np.nextafter(hi, np.inf))


def _sum_interval(branch: str, stats_of, store):
    """Bounds of the per-event float64 ``sum(branch)`` reduction, or None.

    Mirrors the HT bound: per-event count in [cmin, cmax], every value in
    [vlo, vhi], widened by the rigorous float64 accumulation slack."""
    cst = stats_of(counts_name(branch))
    if cst is None or cst.lo is None or cst.hi is None:
        return None
    cmin, cmax = int(cst.lo), int(cst.hi)
    if cmax == 0:
        return 0.0, 0.0  # no objects anywhere: the sum is exactly 0.0
    iv = _branch_interval(stats_of, branch, store)
    if iv is None:
        return None
    vlo, vhi, _ = iv
    cands = (cmin * vlo, cmax * vlo, cmin * vhi, cmax * vhi)
    maxabs = max(abs(vlo), abs(vhi))
    slack = max(1e-12, 32 * _ULP * cmax * cmax * maxabs)
    return min(cands) - slack, max(cands) + slack


def _expr_interval(rpn, stats_of, store):
    """(lo, hi) bounds of a branch-name RPN over the window, or None.

    Any unknown input (missing stats, absent branch), a divisor interval
    straddling zero, or a non-finite endpoint poisons the whole
    expression — degrading to SCAN, never to a wrong skip."""
    stack: list[tuple[float, float]] = []
    for op, arg in rpn:
        if op == RPN_BRANCH:
            iv = _branch_interval(stats_of, str(arg), store)
            if iv is None:
                return None
            stack.append((iv[0], iv[1]))
        elif op == RPN_SUM:
            iv = _sum_interval(str(arg), stats_of, store)
            if iv is None:
                return None
            stack.append(iv)
        elif op == RPN_CONST:
            stack.append((float(arg), float(arg)))
        elif op == RPN_NEG:
            lo, hi = stack.pop()
            stack.append((-hi, -lo))
        elif op == RPN_ABS:
            stack.append(_abs_interval(*stack.pop()))
        else:
            blo, bhi = stack.pop()
            alo, ahi = stack.pop()
            if op == RPN_ADD:
                lo, hi = _outward(alo + blo, ahi + bhi)
            elif op == RPN_SUB:
                lo, hi = _outward(alo - bhi, ahi - blo)
            elif op == RPN_MUL:
                c = (alo * blo, alo * bhi, ahi * blo, ahi * bhi)
                lo, hi = _outward(min(c), max(c))
            elif op == RPN_DIV:
                if blo <= 0.0 <= bhi:
                    return None  # divisor may vanish: unbounded
                c = (alo / blo, alo / bhi, ahi / blo, ahi / bhi)
                lo, hi = _outward(min(c), max(c))
            elif op == RPN_MIN:
                lo, hi = min(alo, blo), min(ahi, bhi)
            elif op == RPN_MAX:
                lo, hi = max(alo, blo), max(ahi, bhi)
            else:
                return None  # unknown op: never skip on guesswork
            stack.append((lo, hi))
        lo, hi = stack[-1]
        if not (np.isfinite(lo) and np.isfinite(hi)):
            return None
    (result,) = stack
    return result


def _classify_expr(node: ExprCut, stats_of, store) -> int:
    iv = _expr_interval(node.rpn, stats_of, store)
    if iv is None:
        return MAYBE
    # the evaluator compares the float64 expression value against the
    # python-float threshold exactly — no float32 threshold rounding here
    return _cmp_interval(iv[0], iv[1], node.op, float(node.value))


def classify_node(node, stats_of, store) -> int:
    """Tri-state of one AST node over a window described by ``stats_of``
    (a callable ``branch -> ZoneStats | None``)."""
    if isinstance(node, Cut):
        return _classify_cut(node, stats_of, store)
    if isinstance(node, AnyOf):
        return _classify_anyof(node, stats_of, store)
    if isinstance(node, ObjectSelection):
        return _classify_object(node, stats_of, store)
    if isinstance(node, HTCut):
        return _classify_ht(node, stats_of, store)
    if isinstance(node, ExprCut):
        return _classify_expr(node, stats_of, store)
    if isinstance(node, (MassWindow, DeltaRCut)):
        # nonlinear leading-pair kinematics: window bounds on pt/eta/phi
        # do not bound the pair observable tightly enough to skip safely
        return MAYBE
    return MAYBE  # unknown node types never authorize a skip


# ---------------------------------------------------------------------------
# window classification
# ---------------------------------------------------------------------------


def classify_span(query: Query, store, start: int, stop: int) -> str:
    """Classify one event span.  Stages are AND-semantic, so one NEVER
    node prunes the span and the span is accept-all only when every node
    is ALWAYS (a selection-free query is accept-all by construction)."""
    cache: dict[str, object] = {}

    def stats_of(branch: str):
        if branch not in cache:
            cache[branch] = (
                store.window_stats(branch, start, stop)
                if branch in store.branches
                else None
            )
        return cache[branch]

    all_always = True
    for _, stage in query.stages():
        for node in stage:
            state = classify_node(node, stats_of, store)
            if state == NEVER:
                return PRUNE
            if state != ALWAYS:
                all_always = False
    return ACCEPT_ALL if all_always else SCAN


def classify_windows(
    query: Query, store, spans: "list[tuple[int, int]]"
) -> list[str]:
    """Per-window decisions for a list of ``[start, stop)`` spans."""
    return [classify_span(query, store, a, b) for a, b in spans]


__all__ = [
    "ACCEPT_ALL",
    "ALWAYS",
    "MAYBE",
    "NEVER",
    "PRUNE",
    "SCAN",
    "WindowDecision",
    "classify_node",
    "classify_span",
    "classify_windows",
]
