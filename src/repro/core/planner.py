"""Two-phase execution planning (paper §3.1–3.2).

Splits the branch universe into:

  * **filter-criteria branches** — read in phase 1 for every event
    (the paper's 27-of-1749 set), staged presel -> object -> event, and
  * **output-only branches** — read in phase 2 only for baskets that
    contain at least one passing event (the paper's 89-branch output set).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.branchmap import expand_branches, with_counts_branches
from repro.core.expr import validate_rpn
from repro.core.query import ExprCut, Query
from repro.core.zonemap import SCAN, WindowDecision, classify_windows


@dataclass
class SkimPlan:
    query: Query
    filter_branches: list[str]
    output_branches: list[str]  # full output set (includes filter branches kept)
    output_only_branches: list[str]  # phase-2 fetch set
    stage_order: list[str] = field(
        default_factory=lambda: ["preselection", "object", "event"]
    )
    excluded_by_optimization: list[str] = field(default_factory=list)
    # flat float32 branches in both the filter and output sets: the fused
    # device path compacts these alongside the survivor indices, so their
    # output columns come straight off the kernel (DESIGN.md §4).
    payload_branches: list[str] = field(default_factory=list)
    # zone-map pruning decisions, one per basket window of the executor's
    # chunking (DESIGN.md §9).  ``None`` when planning ran without
    # pruning; the engine then scans every window (the reference path).
    window_decisions: list[WindowDecision] | None = None
    # cascaded phase-1 physical plan (DESIGN.md §11): the cost-ordered
    # stage IR the cascade executor runs.  ``None`` when planning ran
    # without cascading (or there is nothing to cascade); the engines
    # then preload the full filter set per window (the PR-4 path).
    cascade: object = None  # repro.core.plan.CascadePlan | None
    _program: object = None

    def compiled_program(self):
        """Device predicate program, compiled once per skim (lazy — host-only
        paths never pull in the kernel stack).  A program attached to the
        query's ``meta`` (the cluster coordinator's compile-once fan-out,
        DESIGN.md §5b) short-circuits per-plan compilation."""
        if self._program is None:
            self._program = self.query.meta.get("_compiled_program")
        if self._program is None:
            from repro.kernels.predicate_eval import compile_query

            self._program = compile_query(self.query)
        return self._program

    def describe(self) -> str:
        """One-line physical-plan summary: branch sets, the zone-map
        window decisions (prune / accept-all / scan counts), and the
        cascade stage order — the three pushdown levers, together."""
        pruned = accept = scan = 0
        for d in self.window_decisions or ():
            pruned += d.decision == "prune"
            accept += d.decision == "accept_all"
            scan += d.decision == "scan"
        windows = (
            f"windows[prune={pruned}, accept_all={accept}, scan={scan}]"
            if self.window_decisions is not None
            else "windows=unpruned"
        )
        cascade = (
            f"cascade[{self.cascade.n_stages} stages: {self.cascade.describe()}]"
            if self.cascade is not None
            else "cascade=off"
        )
        return (
            f"SkimPlan(filter={len(self.filter_branches)} branches, "
            f"output={len(self.output_branches)}, "
            f"phase2={len(self.output_only_branches)}, "
            f"excluded={len(self.excluded_by_optimization)}, "
            f"{windows}, {cascade})"
        )


def _decide_windows(
    query: Query,
    store,
    window_events: int,
    filter_branches: list[str],
    output_branches: list[str],
) -> list[WindowDecision]:
    """Classify every basket window and price what each skip saves.

    PRUNE saves the whole phase-1 filter fetch for the window; ACCEPT_ALL
    saves only the filter branches the output does not keep (the rest
    still moves, just in the phase-2 round).  Pure metadata — nothing is
    fetched or decoded here.
    """
    spans = [
        (s, min(s + window_events, store.n_events))
        for s in range(0, store.n_events, window_events)
    ]
    kinds = classify_windows(query, store, spans)
    out_set = set(output_branches)
    extra_branches = [b for b in filter_branches if b not in out_set]
    decisions = []
    for (a, b), kind in zip(spans, kinds):
        p1_bytes = p1_baskets = extra_bytes = extra_baskets = 0
        if kind == "prune":
            p1_bytes, p1_baskets = store.range_comp_bytes(filter_branches, a, b)
        elif kind == "accept_all":
            extra_bytes, extra_baskets = store.range_comp_bytes(
                extra_branches, a, b
            )
        decisions.append(
            WindowDecision(a, b, kind, p1_bytes, p1_baskets,
                           extra_bytes, extra_baskets)
        )
    return decisions


def plan_skim(
    query: Query,
    store,
    window_events: int | None = None,
    prune: bool = False,
    cascade: bool = False,
) -> SkimPlan:
    available = store.branch_names()

    filter_set = {b for b in query.filter_branches() if b in available}
    missing = query.filter_branches() - filter_set
    # trigger-OR names are optional unless the query is strict: menus
    # differ across data-taking eras, and an absent HLT branch evaluates
    # as constant-False (mirrored by the zone-map AnyOf analysis)
    hard_missing = missing - query.optional_branches()
    # kind mismatches (bare jagged ref, sum() of a flat branch) first:
    # they subsume the missing-counts KeyError with a specific message
    for _, stage in query.stages():
        for node in stage:
            if isinstance(node, ExprCut):
                validate_rpn(node.rpn, store, node.source)
    if hard_missing:
        raise KeyError(
            f"selection references unknown branches: {sorted(hard_missing)}"
        )
    filter_branches = with_counts_branches(sorted(filter_set), store)

    selected, excluded = expand_branches(
        query.branches, available, force_all=query.force_all,
        extra_required=set(filter_branches),
    )
    output_branches = with_counts_branches(selected, store)
    output_only = [b for b in output_branches if b not in set(filter_branches)]

    payload = [
        b
        for b in output_branches
        if b in set(filter_branches)
        and not store.branches[b].jagged
        and store.branches[b].np_dtype() == "float32"
    ]

    decisions = None
    if prune and window_events:
        decisions = _decide_windows(
            query, store, window_events, filter_branches, output_branches
        )
        if all(d.decision == SCAN for d in decisions):
            decisions = None  # nothing provable: identical to no pruning

    cascade_plan = None
    if cascade and filter_branches:
        from repro.core.plan import build_cascade

        cascade_plan = build_cascade(query, store)

    plan = SkimPlan(
        query=query,
        filter_branches=filter_branches,
        output_branches=output_branches,
        output_only_branches=output_only,
        excluded_by_optimization=excluded,
        payload_branches=payload,
        window_decisions=decisions,
        cascade=cascade_plan,
    )
    # static verification gate (REPRO_VERIFY=1): prove the plan's
    # invariants (branch partition, stage fetch coverage, pinned head,
    # cache-key coverage) before any byte moves
    from repro.analysis.verify import maybe_verify_plan

    maybe_verify_plan(plan, store)
    return plan
