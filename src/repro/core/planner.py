"""Two-phase execution planning (paper §3.1–3.2).

Splits the branch universe into:

  * **filter-criteria branches** — read in phase 1 for every event
    (the paper's 27-of-1749 set), staged presel -> object -> event, and
  * **output-only branches** — read in phase 2 only for baskets that
    contain at least one passing event (the paper's 89-branch output set).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.branchmap import expand_branches, with_counts_branches
from repro.core.query import Query


@dataclass
class SkimPlan:
    query: Query
    filter_branches: list[str]
    output_branches: list[str]  # full output set (includes filter branches kept)
    output_only_branches: list[str]  # phase-2 fetch set
    stage_order: list[str] = field(
        default_factory=lambda: ["preselection", "object", "event"]
    )
    excluded_by_optimization: list[str] = field(default_factory=list)
    # flat float32 branches in both the filter and output sets: the fused
    # device path compacts these alongside the survivor indices, so their
    # output columns come straight off the kernel (DESIGN.md §4).
    payload_branches: list[str] = field(default_factory=list)
    _program: object = None

    def compiled_program(self):
        """Device predicate program, compiled once per skim (lazy — host-only
        paths never pull in the kernel stack).  A program attached to the
        query's ``meta`` (the cluster coordinator's compile-once fan-out,
        DESIGN.md §5b) short-circuits per-plan compilation."""
        if self._program is None:
            self._program = self.query.meta.get("_compiled_program")
        if self._program is None:
            from repro.kernels.predicate_eval import compile_query

            self._program = compile_query(self.query)
        return self._program

    def describe(self) -> str:
        return (
            f"SkimPlan(filter={len(self.filter_branches)} branches, "
            f"output={len(self.output_branches)}, "
            f"phase2={len(self.output_only_branches)}, "
            f"excluded={len(self.excluded_by_optimization)})"
        )


def plan_skim(query: Query, store) -> SkimPlan:
    available = store.branch_names()

    filter_set = {b for b in query.filter_branches() if b in available}
    missing = query.filter_branches() - filter_set
    if missing:
        raise KeyError(f"selection references unknown branches: {sorted(missing)}")
    filter_branches = with_counts_branches(sorted(filter_set), store)

    selected, excluded = expand_branches(
        query.branches, available, force_all=query.force_all,
        extra_required=set(filter_branches),
    )
    output_branches = with_counts_branches(selected, store)
    output_only = [b for b in output_branches if b not in set(filter_branches)]

    payload = [
        b
        for b in output_branches
        if b in set(filter_branches)
        and not store.branches[b].jagged
        and store.branches[b].np_dtype() == "float32"
    ]

    return SkimPlan(
        query=query,
        filter_branches=filter_branches,
        output_branches=output_branches,
        output_only_branches=output_only,
        excluded_by_optimization=excluded,
        payload_branches=payload,
    )
