"""Near-data skimming on the accelerator mesh (DESIGN.md §2, §6).

The paper's placement insight — filter where the bytes live, ship only
survivors — mapped to a JAX mesh: events are sharded over the ``data``
(and ``pod``) axes; each shard evaluates the compiled predicate and
compacts its survivors locally inside ``shard_map``; only compacted
survivor payloads ever cross the interconnect.

Device data layout: jagged collections are padded to a static ``K``
objects/event with a validity mask (built once at ingest by
:func:`build_padded_inputs`), so the device path is dense tiles — exactly
what the Pallas kernels want.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import expr as xpr
from repro.kernels import ref as kref
from repro.kernels.predicate_eval import Program, compile_query


@dataclass
class PaddedBatch:
    """Dense device-side event batch for predicate evaluation."""

    terms: jnp.ndarray  # (T, E, K) float32
    valid: jnp.ndarray  # (G, E, K) float32
    weights: jnp.ndarray  # (G, E, K) float32
    payload: jnp.ndarray  # (E, D) float32 — output columns to compact
    n_events: int


def _scatter_jagged(out: np.ndarray, values: np.ndarray, counts: np.ndarray) -> None:
    """Write jagged values into a preallocated (E, K) dense view (in place;
    fully vectorized — this runs per window on the skim hot path)."""
    E, K = out.shape
    take = np.minimum(counts, K).astype(np.int64)
    if not (E and take.sum()):
        return
    offsets = np.concatenate([[0], np.cumsum(counts)])
    idx_event = np.repeat(np.arange(E), take)
    # slot index within each event: global ramp minus each event's base
    bases = np.concatenate([[0], np.cumsum(take)])[:-1]
    idx_slot = np.arange(take.sum()) - np.repeat(bases, take)
    src_idx = np.repeat(offsets[:-1], take) + idx_slot
    out[idx_event, idx_slot] = values[src_idx].astype(np.float32)


def _collection_validity(counts: np.ndarray, K: int) -> np.ndarray:
    """(E, K) validity: slot k live iff k < counts[e]."""
    take = np.minimum(counts, K)
    return (np.arange(K)[None, :] < take[:, None]).astype(np.float32)


def build_padded_inputs(
    data: dict[str, np.ndarray],
    program: Program,
    store,
    K: int = 8,
    payload_branches: list[str] | None = None,
    include_index: bool = False,
    to_device: bool = True,
) -> PaddedBatch:
    """Build dense kernel inputs from columnar (host) data.

    ``data`` is the decoded columnar dict (flat arrays; jagged values with
    their ``n<Coll>`` counts).  ``K`` caps objects/event (overflow objects
    are dropped from *filtering only* — counts-based cuts use true counts
    via validity, see below).

    ``include_index=True`` prepends a local-event-index column to the
    payload: after stream compaction the survivor rows carry their own
    source indices, so the host can reconstruct the boolean mask from the
    compacted output alone — the mask itself never has to leave the device
    (DESIGN.md §7).  float32 holds indices exactly up to 2**24 events,
    far above any window size.
    """
    flat_names = [n for n in data if not (store.branches.get(n) and store.branches[n].jagged)]
    n_events = len(data[flat_names[0]])

    T = program.n_terms
    G = program.n_groups
    # preallocate and fill views in place: flat branches touch only slot 0
    # of their zero pages, jagged branches scatter exactly once — this is
    # the per-window hot path of the fused executor
    terms = np.zeros((T, n_events, K), np.float32)
    valid = np.zeros((G, n_events, K), np.float32)
    weights = np.zeros((G, n_events, K), np.float32)

    values_cache: dict[str, np.ndarray] = {}  # scatter each branch once

    def fill_values(target: np.ndarray, branch: str) -> None:
        if branch not in data:
            # absent trigger branch (menus differ across eras): the zero
            # page is constant-False under the ANY-group >= 0.5 test; the
            # planner guarantees every non-optional branch is present
            return
        br = store.branches.get(branch)
        if br is not None and br.jagged:
            if branch not in values_cache:
                _scatter_jagged(
                    target,
                    np.asarray(data[branch]),
                    np.asarray(data[br.counts_branch], dtype=np.int64),
                )
                values_cache[branch] = target
            else:
                np.copyto(target, values_cache[branch])
        else:
            target[:, 0] = np.asarray(data[branch], dtype=np.float32)

    validity_cache: dict[str, np.ndarray] = {}  # keyed by counts branch

    def validity_of(branch: str) -> np.ndarray:
        br = store.branches.get(branch)
        key = br.counts_branch if (br is not None and br.jagged) else ""
        if key not in validity_cache:
            if key:  # one validity per collection, shared by its branches
                validity_cache[key] = _collection_validity(
                    np.asarray(data[key], dtype=np.int64), K
                )
            else:  # flat branches live in slot 0 only
                v = np.zeros((n_events, K), np.float32)
                v[:, 0] = 1.0
                validity_cache[key] = v
        return validity_cache[key]

    for t, branch in enumerate(program.term_branches):
        fill_values(terms[t], branch)
    for g, grp in enumerate(program.groups):
        if grp.kind in (kref.GROUP_MASS, kref.GROUP_DR):
            # pair groups read two collections: pack both validity planes
            # into the one channel (bit0 = first, bit1 = second; a
            # same-collection pair encodes 3 everywhere it has objects)
            half = len(grp.term_ids) // 2
            first = program.term_branches[grp.term_ids[0]]
            second = program.term_branches[grp.term_ids[half]]
            valid[g] = validity_of(first) + 2.0 * validity_of(second)
            continue
        if grp.kind == kref.GROUP_EXPR:
            # sum() reductions read the zero-padded object slots directly
            # (invalid slots are exactly 0.0) — no validity channel
            continue
        if grp.term_ids:
            anchor = program.term_branches[grp.term_ids[0]]
            valid[g] = validity_of(anchor)
        wbranch = program.group_weights[g]
        if wbranch is not None:
            fill_values(weights[g], wbranch)

    payload_branches = payload_branches or []
    pay_cols = []
    if include_index:
        if n_events >= 1 << 24:
            raise ValueError("window too large for exact float32 index payload")
        pay_cols.append(np.arange(n_events, dtype=np.float32))
    pay_cols.extend(np.asarray(data[b], dtype=np.float32) for b in payload_branches)
    if pay_cols:
        payload = np.stack(pay_cols, axis=1)
    else:
        payload = np.zeros((n_events, 1), np.float32)

    if not to_device:
        # batched staging keeps host buffers: the caller places windows at
        # span offsets inside a batch tensor and ships the batch once
        return PaddedBatch(
            terms=terms, valid=valid, weights=weights,
            payload=payload, n_events=n_events,
        )
    return PaddedBatch(
        terms=jnp.asarray(terms),
        valid=jnp.asarray(valid),
        weights=jnp.asarray(weights),
        payload=jnp.asarray(payload),
        n_events=n_events,
    )


# ---------------------------------------------------------------------------
# device-side evaluation
# ---------------------------------------------------------------------------


def skim_mask(batch_terms, batch_valid, batch_weights, program: Program):
    """jnp predicate path (works on any backend; Pallas path in kernels.ops)."""
    return kref.predicate_eval_ref(batch_terms, batch_valid, batch_weights, program)


# numpy mirror of kernels.ref.apply_op, keyed by the compiled op ids
_NP_OPS = {
    kref.OP_GT: np.greater,
    kref.OP_GE: np.greater_equal,
    kref.OP_LT: np.less,
    kref.OP_LE: np.less_equal,
    kref.OP_EQ: np.equal,
    kref.OP_NE: np.not_equal,
    kref.OP_ABSLT: lambda x, v: np.abs(x) < v,
    kref.OP_ABSGT: lambda x, v: np.abs(x) > v,
}


def program_eval_np(
    data: dict[str, np.ndarray], program: Program, n_events: int
) -> np.ndarray:
    """Host interpreter for a compiled :class:`Program` over the *jagged*
    columnar layout (no padding).

    This is the fused executor's CPU fallback: one pass over the compiled
    groups, semantically identical to ``repro.core.query.eval_stage`` run
    over every stage (same float64 segment accumulation, so masks are
    bit-identical to the reference path) and to the device kernels modulo
    their float32 reductions.  On jagged data it skips the (T, E, K)
    densification entirely, which is what makes ``fused=True`` at least
    as fast as the staged evaluator on backends without a real
    accelerator.
    """
    mask = np.ones(n_events, dtype=bool)
    for g, grp in enumerate(program.groups):
        coll = program.group_collections[g]
        if grp.kind == kref.GROUP_ANY:
            gpass = np.zeros(n_events, dtype=bool)
            for t, op, thr in zip(grp.term_ids, grp.ops, grp.thrs):
                arr = data.get(program.term_branches[t])
                if arr is None:
                    continue  # absent trigger branch: constant-False
                gpass |= np.asarray(_NP_OPS[op](arr, thr), dtype=bool)
        elif grp.kind == kref.GROUP_MASS:
            m, ok = xpr.leading_pair_mass(
                data, coll, program.group_collections2[g]
            )
            gpass = ok & (m >= grp.cmp_thr) & (m <= grp.cmp_thr2)
        elif grp.kind == kref.GROUP_DR:
            dr, ok = xpr.leading_delta_r(
                data, coll, program.group_collections2[g]
            )
            gpass = ok & np.asarray(
                _NP_OPS[grp.cmp_op](dr, grp.cmp_thr), dtype=bool
            )
        elif grp.kind == kref.GROUP_EXPR:
            # same stack walk as the staged evaluator (expr.eval_rpn), with
            # term slots resolved back to branch names — bit-identical to
            # eval_node by construction
            def resolve(op, slot):
                name = program.term_branches[int(slot)]
                if op == xpr.RPN_BRANCH:
                    return np.asarray(data[name], dtype=np.float64)
                counts = np.asarray(
                    data[xpr.counts_name(name)], dtype=np.int64
                )
                return np.bincount(
                    np.repeat(np.arange(n_events), counts),
                    weights=np.asarray(data[name], dtype=np.float64),
                    minlength=n_events,
                )

            val = xpr.eval_rpn(grp.rpn, resolve)
            gpass = np.asarray(
                _NP_OPS[grp.cmp_op](val, grp.cmp_thr), dtype=bool
            )
        elif coll is None:
            # flat-branch cut compiled as a one-term COUNT group
            t, op, thr = grp.term_ids[0], grp.ops[0], grp.thrs[0]
            passing = np.asarray(
                _NP_OPS[op](data[program.term_branches[t]], thr), dtype=bool
            )
            gpass = passing.astype(np.int64) >= grp.min_count
        else:
            counts = np.asarray(data[f"n{coll}"], dtype=np.int64)
            ids = np.repeat(np.arange(n_events), counts)
            passing = np.ones(int(counts.sum()), dtype=bool)
            for t, op, thr in zip(grp.term_ids, grp.ops, grp.thrs):
                passing &= np.asarray(
                    _NP_OPS[op](data[program.term_branches[t]], thr), dtype=bool
                )
            if grp.kind == kref.GROUP_COUNT:
                # integer accumulation — exact counts, matching both the
                # staged evaluator and the device kernels' int32 path
                per_event = np.bincount(ids[passing], minlength=n_events)
                gpass = per_event >= grp.min_count
            else:  # GROUP_HT
                w = np.asarray(data[program.group_weights[g]], dtype=np.float64)
                ht = np.bincount(ids, weights=w * passing, minlength=n_events)
                gpass = np.asarray(
                    _NP_OPS[grp.cmp_op](ht, grp.cmp_thr), dtype=bool
                )
        mask &= gpass
    return mask


def compact_jnp(payload: jnp.ndarray, mask: jnp.ndarray):
    return kref.stream_compact_ref(payload, mask)


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def window_pad_K(data: dict[str, np.ndarray], program: Program, store) -> int:
    """Smallest pow2 object capacity that loses no object of any jagged
    branch the program reads — guarantees the padded device evaluation is
    bit-identical to the host evaluator (no overflow truncation)."""
    K = 1
    seen: set[str] = set()
    branches = set(program.term_branches) | {
        w for w in program.group_weights if w is not None
    }
    for name in branches:
        br = store.branches.get(name)
        if br is None or not br.jagged or br.counts_branch in seen:
            continue
        seen.add(br.counts_branch)
        counts = np.asarray(data[br.counts_branch])
        if len(counts):
            K = max(K, int(counts.max()))
    return _next_pow2(K)


_WINDOW_QUANTUM = 512  # event-axis padding multiple (fused kernel tile)


def fused_window_skim(
    data: dict[str, np.ndarray],
    program: Program,
    store,
    payload_branches: list[str] | tuple[str, ...] = (),
    K: int | None = None,
    pad_to: int | None = None,
    backend: str | None = None,
    decision: str = "scan",
) -> tuple[np.ndarray, dict[str, np.ndarray]]:
    """One-pass skim of a decoded window (the engine's fused path).

    Evaluates the compiled predicate AND compacts the survivor payload in
    a single pass over the window, on the best executor for the backend:

      * ``"pallas"`` — the fused VMEM kernel (``kernels.skim_fused``):
        pad the window once, then predicate + one-hot MXU compaction per
        event tile.  Default on TPU.
      * ``"xla"``    — the kernel's jitted jnp oracle over the same
        padded layout (validation / non-TPU accelerators).
      * ``"host"``   — the compiled-program interpreter over the native
        jagged layout (:func:`program_eval_np`); skips densification,
        which is what makes ``fused=True`` fast on plain CPUs.  Default
        off-TPU.

    All three produce bit-identical survivor sets on the repo fixtures
    (pinned by tests/test_pipeline_executor.py).  Returns the boolean
    survivor mask and the compacted payload columns (survivor-only, event
    order).

    ``pad_to`` fixes the padded event-axis shape (e.g. to the engine's
    window size) so every window of a skim hits the same compiled kernel.
    Padding events get index >= n_events in the payload index column and
    are dropped after compaction, so a predicate that happens to accept
    an all-zero event (e.g. ``HT < x``) cannot leak phantom survivors.

    ``decision`` is the window's zone-map classification (DESIGN.md §9):
    ``"accept_all"`` skips predicate evaluation entirely — every event
    provably survives, so the payload columns pass through whole (payload
    branches are flat float32 by the planner's contract, hence identical
    to ``arr[all-true mask]``).  ``"scan"`` (default) runs the normal
    fused evaluation.  Pruned windows never reach this function: their
    data is never fetched, let alone decoded.
    """
    flat = next(
        n for n in data if not (store.branches.get(n) and store.branches[n].jagged)
    )
    E = len(data[flat])

    if decision == "accept_all":
        mask = np.ones(E, dtype=bool)
        return mask, {n: np.asarray(data[n]) for n in payload_branches}

    import jax

    from repro.kernels import ops

    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "host"

    if backend == "host":
        mask = program_eval_np(data, program, E)
        cols = {
            name: np.asarray(data[name])[mask] for name in payload_branches
        }
        return mask, cols
    if backend not in ("pallas", "xla"):
        raise ValueError(f"unknown fused backend {backend!r}")

    if K is None:
        K = window_pad_K(data, program, store)
    pb = build_padded_inputs(
        data, program, store, K=K,
        payload_branches=list(payload_branches), include_index=True,
    )
    target = -(-max(E, pad_to or E) // _WINDOW_QUANTUM) * _WINDOW_QUANTUM
    terms, valid, weights, payload = pb.terms, pb.valid, pb.weights, pb.payload
    if target > E:
        pad = target - E
        terms = jnp.pad(terms, ((0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad), (0, 0)))
        weights = jnp.pad(weights, ((0, 0), (0, pad), (0, 0)))
        payload = jnp.pad(payload, ((0, pad), (0, 0)))
        payload = payload.at[E:, 0].set(jnp.arange(E, target, dtype=jnp.float32))

    packed, count = ops.fused_skim(
        terms, valid, weights, payload, program, use_pallas=(backend == "pallas")
    )
    k = int(count)
    packed = np.asarray(packed[:k])
    idx = packed[:, 0].astype(np.int64)
    real = idx < E  # drop phantom survivors from event-axis padding
    packed, idx = packed[real], idx[real]
    mask = np.zeros(E, dtype=bool)
    mask[idx] = True
    cols = {
        name: packed[:, 1 + j].astype(
            store.branches[name].np_dtype() if name in store.branches else np.float32
        )
        for j, name in enumerate(payload_branches)
    }
    return mask, cols


def sharded_skim(mesh, program: Program, data_axes=("pod", "data")):
    """Build the sharded near-data skim step.

    Returns a jitted fn: (terms, valid, weights, payload) sharded over the
    event axis -> (packed survivors per shard, global survivor count).
    The compaction happens *inside* the shard — only packed survivors and a
    scalar count are exposed to cross-shard collectives, which is the
    paper's "return only the filtered data" on the mesh.
    """
    from jax.experimental.shard_map import shard_map

    axes = tuple(a for a in data_axes if a in mesh.axis_names)

    def _local(terms, valid, weights, payload):
        mask = kref.predicate_eval_ref(terms, valid, weights, program)
        packed, count = kref.stream_compact_ref(payload, mask)
        total = jax.lax.psum(count, axes)
        return packed, mask.astype(jnp.int32), total

    spec_e1 = P(None, axes, None)  # (T/G, E, K)
    spec_pay = P(axes, None)  # (E, D)

    return jax.jit(
        shard_map(
            _local,
            mesh=mesh,
            in_specs=(spec_e1, spec_e1, spec_e1, spec_pay),
            out_specs=(spec_pay, P(axes), P()),
            check_rep=False,
        )
    )


__all__ = [
    "PaddedBatch",
    "Program",
    "compile_query",
    "build_padded_inputs",
    "skim_mask",
    "compact_jnp",
    "program_eval_np",
    "fused_window_skim",
    "window_pad_K",
    "sharded_skim",
]
