"""Near-data skimming on the accelerator mesh (DESIGN.md §2, §5).

The paper's placement insight — filter where the bytes live, ship only
survivors — mapped to a JAX mesh: events are sharded over the ``data``
(and ``pod``) axes; each shard evaluates the compiled predicate and
compacts its survivors locally inside ``shard_map``; only compacted
survivor payloads ever cross the interconnect.

Device data layout: jagged collections are padded to a static ``K``
objects/event with a validity mask (built once at ingest by
:func:`build_padded_inputs`), so the device path is dense tiles — exactly
what the Pallas kernels want.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import ref as kref
from repro.kernels.predicate_eval import Program, compile_query


@dataclass
class PaddedBatch:
    """Dense device-side event batch for predicate evaluation."""

    terms: jnp.ndarray  # (T, E, K) float32
    valid: jnp.ndarray  # (G, E, K) float32
    weights: jnp.ndarray  # (G, E, K) float32
    payload: jnp.ndarray  # (E, D) float32 — output columns to compact
    n_events: int


def _padded_collection(values: np.ndarray, counts: np.ndarray, K: int):
    """Jagged -> (E, K) dense + validity."""
    E = len(counts)
    out = np.zeros((E, K), dtype=np.float32)
    validity = np.zeros((E, K), dtype=np.float32)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    cols = np.arange(K)
    take = np.minimum(counts[:, None], K)
    validity[cols[None, :] < take] = 1.0
    # scatter values row-wise
    idx_event = np.repeat(np.arange(E), np.minimum(counts, K))
    idx_slot = np.concatenate([np.arange(min(c, K)) for c in counts]) if E else np.empty(0, int)
    src = np.concatenate(
        [values[offsets[i] : offsets[i] + min(counts[i], K)] for i in range(E)]
    ) if E else np.empty(0, values.dtype)
    out[idx_event, idx_slot] = src.astype(np.float32)
    return out, validity


def build_padded_inputs(
    data: dict[str, np.ndarray],
    program: Program,
    store,
    K: int = 8,
    payload_branches: list[str] | None = None,
) -> PaddedBatch:
    """Build dense kernel inputs from columnar (host) data.

    ``data`` is the decoded columnar dict (flat arrays; jagged values with
    their ``n<Coll>`` counts).  ``K`` caps objects/event (overflow objects
    are dropped from *filtering only* — counts-based cuts use true counts
    via validity, see below).
    """
    flat_names = [n for n in data if not (store.branches.get(n) and store.branches[n].jagged)]
    n_events = len(data[flat_names[0]])

    dense_cache: dict[str, tuple[np.ndarray, np.ndarray]] = {}

    def dense(branch: str) -> tuple[np.ndarray, np.ndarray]:
        if branch in dense_cache:
            return dense_cache[branch]
        br = store.branches.get(branch)
        if br is not None and br.jagged:
            counts = data[br.counts_branch].astype(np.int64)
            out = _padded_collection(np.asarray(data[branch]), counts, K)
        else:
            col = np.asarray(data[branch], dtype=np.float32).reshape(-1, 1)
            v = np.zeros((n_events, K), np.float32)
            v[:, 0] = 1.0
            x = np.zeros((n_events, K), np.float32)
            x[:, 0] = col[:, 0]
            out = (x, v)
        dense_cache[branch] = out
        return out

    T = program.n_terms
    G = program.n_groups
    terms = np.zeros((T, n_events, K), np.float32)
    valid = np.zeros((G, n_events, K), np.float32)
    weights = np.zeros((G, n_events, K), np.float32)

    for t, branch in enumerate(program.term_branches):
        terms[t] = dense(branch)[0]
    for g, (coll, wbranch) in enumerate(
        zip(program.group_collections, program.group_weights)
    ):
        if coll is not None:
            ref_branch = next(
                program.term_branches[t] for t in program.groups[g].term_ids
            )
            valid[g] = dense(ref_branch)[1]
        else:
            anchor = program.term_branches[program.groups[g].term_ids[0]]
            valid[g] = dense(anchor)[1]
        if wbranch is not None:
            weights[g] = dense(wbranch)[0]

    payload_branches = payload_branches or []
    if payload_branches:
        payload = np.stack(
            [np.asarray(data[b], dtype=np.float32) for b in payload_branches], axis=1
        )
    else:
        payload = np.zeros((n_events, 1), np.float32)

    return PaddedBatch(
        terms=jnp.asarray(terms),
        valid=jnp.asarray(valid),
        weights=jnp.asarray(weights),
        payload=jnp.asarray(payload),
        n_events=n_events,
    )


# ---------------------------------------------------------------------------
# device-side evaluation
# ---------------------------------------------------------------------------


def skim_mask(batch_terms, batch_valid, batch_weights, program: Program):
    """jnp predicate path (works on any backend; Pallas path in kernels.ops)."""
    return kref.predicate_eval_ref(batch_terms, batch_valid, batch_weights, program)


def compact_jnp(payload: jnp.ndarray, mask: jnp.ndarray):
    return kref.stream_compact_ref(payload, mask)


def sharded_skim(mesh, program: Program, data_axes=("pod", "data")):
    """Build the sharded near-data skim step.

    Returns a jitted fn: (terms, valid, weights, payload) sharded over the
    event axis -> (packed survivors per shard, global survivor count).
    The compaction happens *inside* the shard — only packed survivors and a
    scalar count are exposed to cross-shard collectives, which is the
    paper's "return only the filtered data" on the mesh.
    """
    from jax.experimental.shard_map import shard_map

    axes = tuple(a for a in data_axes if a in mesh.axis_names)

    def _local(terms, valid, weights, payload):
        mask = kref.predicate_eval_ref(terms, valid, weights, program)
        packed, count = kref.stream_compact_ref(payload, mask)
        total = jax.lax.psum(count, axes)
        return packed, mask.astype(jnp.int32), total

    spec_e1 = P(None, axes, None)  # (T/G, E, K)
    spec_pay = P(axes, None)  # (E, D)

    return jax.jit(
        shard_map(
            _local,
            mesh=mesh,
            in_specs=(spec_e1, spec_e1, spec_e1, spec_pay),
            out_specs=(spec_pay, P(axes), P()),
            check_rep=False,
        )
    )


__all__ = [
    "PaddedBatch",
    "Program",
    "compile_query",
    "build_padded_inputs",
    "skim_mask",
    "compact_jnp",
    "sharded_skim",
]
