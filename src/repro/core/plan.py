"""Cascaded phase-1 physical plan: cost-based stage IR + executor (DESIGN.md §11).

The two-phase model moves only the bytes a skim needs — but phase 1
still paid the *full* filter-branch set for every scanned window, even
when the first cheap scalar cut kills 99% of the events.  This module
lowers a compiled :class:`~repro.core.query.Query` into an ordered
**cascade** of phase-1 stages:

  * each :class:`CascadeStage` names one predicate node's branch set, its
    compiled sub-program (``kernels.predicate_eval.compile_query`` over a
    single-node query, so the fused kernel path evaluates per-stage
    sub-programs exactly like the monolithic program), and a cost
    estimate;
  * a **cost model seeded from zone-map basket stats** prices each stage:
    ``vmin``/``vmax``/``n_true`` give an estimated selectivity (uniform
    density over the observed interval; trigger true-rates are exact),
    ``range_comp_bytes`` gives the fetch cost; stages run
    cheapest-and-most-selective-first (rank = bytes / (1 − selectivity),
    the classic predicate-ordering rule);
  * **per-window observed selectivities adapt the order** as the scan
    progresses (:class:`CascadeState`): once a stage has seen events, its
    observed pass rate replaces the estimate in the rank.  The *head*
    stage is pinned to the static cost-model choice so the double-buffered
    prefetcher's load set is identical across ``pipeline`` modes
    (serial == threaded accounting invariance, DESIGN.md §4b).

The executor (:class:`CascadeExecutor`) evaluates stage *k* **only over
the basket spans still alive** after stage *k−1*'s mask — dead baskets
are never fetched, dead windows stop the cascade, and a per-window
basket ledger guarantees every ``(branch, basket)`` pair is paid at most
once per window across phase 1 *and* phase 2 (the decoded-basket LRU
absorbs the decode side of stage overlap).  The final mask is
bit-identical to the single-pass reference for ANY stage order, because
every predicate node is a per-event function of its own branches and
stages combine with logical AND.

``cascade=False`` on the engines keeps the PR-4 preload path, exactly
like ``prune=False`` keeps the unpruned reference.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.branchmap import with_counts_branches
from repro.core.query import (
    AnyOf,
    Cut,
    HTCut,
    ObjectSelection,
    Query,
)
from repro.core.zonemap import ACCEPT_ALL, PRUNE, SCAN
from repro.data.store import FetchStats, coalesced_requests

# selectivity the cost model assumes when statistics prove nothing
# (HT / mass / ΔR / expression nodes, unknown stats)
DEFAULT_SELECTIVITY = 0.5
# rank = est_bytes / max(1 - selectivity, _MIN_KILL): bounds the rank of
# near-accept-all stages instead of dividing by zero
_MIN_KILL = 1e-3


@dataclass(frozen=True)
class CascadeStage:
    """One phase-1 stage: a predicate node, its fetch set, and its price."""

    index: int  # position in the reference (query-order) cascade
    tier: str  # originating stage name (preselection/object/event)
    nodes: tuple  # AST nodes this stage evaluates (currently one)
    branches: tuple[str, ...]  # fetch set, counts branches included
    est_selectivity: float  # cost-model pass-rate estimate in [0, 1]
    est_bytes: int  # whole-store compressed fetch cost of `branches`
    program: object = None  # compiled sub-Program (lazy, see CascadePlan)

    @property
    def rank(self) -> float:
        """Static cost-model rank: cheaper and more selective is smaller."""
        return self.est_bytes / max(1.0 - self.est_selectivity, _MIN_KILL)


# predicate-node class -> stage kind label.  The calibration loop keys
# priced-vs-observed byte ratios by this (DESIGN.md §13): pricing errors
# are systematic per node *kind* (trigger true-rates are exact, ΔR/mass
# selectivities are guesses), not per individual stage.
_NODE_KIND = {
    "Cut": "cut",
    "AnyOf": "trigger",
    "ObjectSelection": "object",
    "HTCut": "ht",
    "MassWindow": "mass",
    "DeltaRCut": "deltaR",
    "ExprCut": "expr",
}


def stage_kind(stage: CascadeStage) -> str:
    """Stable kind label for a cascade stage (its predicate-node class)."""
    if not stage.nodes:
        return "const"
    return _NODE_KIND.get(type(stage.nodes[0]).__name__, "other")


@dataclass
class CascadePlan:
    """Ordered cascade IR for one (query, store) pair.

    ``static_order`` is the cost model's execution order (stage indices
    into ``stages``); ``static_order[0]`` is the pinned head stage the
    prefetcher loads.  The runtime order may permute the tail
    (:class:`CascadeState`) — any permutation is bit-identical on
    survivors, only the byte ledger changes.
    """

    stages: list[CascadeStage]
    static_order: list[int]

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def head(self) -> CascadeStage:
        return self.stages[self.static_order[0]]

    def describe(self) -> str:
        parts = []
        for i in self.static_order:
            s = self.stages[i]
            parts.append(
                f"{'/'.join(sorted(b for b in s.branches)[:2]) or '<const>'}"
                f"(sel~{s.est_selectivity:.2f},{s.est_bytes / 1e3:.0f}kB)"
            )
        return " -> ".join(parts)


# ---------------------------------------------------------------------------
# cost model: zone-map statistics -> estimated selectivity
# ---------------------------------------------------------------------------


def _uniform_frac(lo: float, hi: float, op: str, value: float) -> float:
    """Pass fraction of ``x <op> value`` assuming x uniform on [lo, hi].

    Estimation only — never used for correctness decisions (that is the
    zone-map's exact interval analysis).  Degenerate intervals evaluate
    the comparison at the point.
    """
    if hi <= lo:
        from repro.core.query import OPS

        try:
            return 1.0 if bool(OPS[op](lo, value)) else 0.0
        except KeyError:
            return DEFAULT_SELECTIVITY
    w = hi - lo
    if op in (">", ">="):
        return min(max((hi - value) / w, 0.0), 1.0)
    if op in ("<", "<="):
        return min(max((value - lo) / w, 0.0), 1.0)
    if op == "==":
        return 0.05 if lo <= value <= hi else 0.0
    if op == "!=":
        return 0.95 if lo <= value <= hi else 1.0
    if op in ("abs<", "abs>"):
        a = max(lo, -abs(value))
        b = min(hi, abs(value))
        inside = max(b - a, 0.0) / w
        return inside if op == "abs<" else 1.0 - inside
    return DEFAULT_SELECTIVITY


def _poisson_tail(lam: float, min_count: int) -> float:
    """P(N >= min_count) for N ~ Poisson(lam)."""
    if min_count <= 0:
        return 1.0
    if lam <= 0.0:
        return 0.0
    cdf = 0.0
    term = math.exp(-lam)
    for k in range(min_count):
        cdf += term
        term *= lam / (k + 1)
    return min(max(1.0 - cdf, 0.0), 1.0)


def estimate_node_selectivity(node, stats_of, store) -> float:
    """Estimated pass rate of one AST node from zone-map statistics.

    ``stats_of`` maps branch -> :class:`~repro.data.store.ZoneStats` or
    ``None``.  Unknown statistics and nodes the stats cannot speak about
    (HT, mass, ΔR, expressions) fall back to ``DEFAULT_SELECTIVITY``.
    """
    if isinstance(node, Cut):
        st = stats_of(node.branch)
        if st is None or st.lo is None or st.hi is None:
            return DEFAULT_SELECTIVITY
        if st.n_true is not None and st.n_values:
            # boolean branch: the true-rate is exact
            frac_true = st.n_true / st.n_values
            passes_true = _uniform_frac(1.0, 1.0, node.op, float(node.value))
            passes_false = _uniform_frac(0.0, 0.0, node.op, float(node.value))
            return frac_true * passes_true + (1.0 - frac_true) * passes_false
        return _uniform_frac(st.lo, st.hi, node.op, float(node.value))
    if isinstance(node, AnyOf):
        miss_all = 1.0
        any_present = False
        for name in node.names:
            if name not in store.branches:
                continue  # absent trigger: constant-False, contributes 0
            any_present = True
            st = stats_of(name)
            rate = (
                st.n_true / st.n_values
                if st is not None and st.n_true is not None and st.n_values
                else 0.3
            )
            miss_all *= 1.0 - rate
        return 1.0 - miss_all if any_present else 0.0
    if isinstance(node, ObjectSelection):
        if node.min_count <= 0:
            return 1.0
        p_obj = 1.0
        mean_count = None
        for c in node.cuts:
            st = stats_of(f"{node.collection}_{c.var}")
            if st is None or st.lo is None or st.hi is None:
                p_obj *= DEFAULT_SELECTIVITY
                continue
            if st.n_entries:
                mean_count = st.n_values / st.n_entries
            p_obj *= _uniform_frac(st.lo, st.hi, c.op, float(c.value))
        if mean_count is None:
            cst = stats_of(f"n{node.collection}")
            if cst is None or cst.lo is None or cst.hi is None:
                return DEFAULT_SELECTIVITY
            mean_count = (cst.lo + cst.hi) / 2.0
        return _poisson_tail(mean_count * p_obj, node.min_count)
    if isinstance(node, HTCut):
        return DEFAULT_SELECTIVITY
    return DEFAULT_SELECTIVITY  # mass / ΔR / expr: stats say nothing


# ---------------------------------------------------------------------------
# lowering: Query -> CascadePlan
# ---------------------------------------------------------------------------


def _stage_query(tier: str, node) -> Query:
    """Single-node query wrapping one AST node (the compile_query input
    for a per-stage sub-program; the tier placement is semantic only)."""
    kw = {"preselection": (), "object_stage": (), "event_stage": ()}
    key = {
        "preselection": "preselection",
        "object": "object_stage",
        "event": "event_stage",
    }[tier]
    kw[key] = (node,)
    return Query(input="", output="", branches=(), force_all=False, **kw)


def _stage_branches(node, store) -> tuple[str, ...]:
    """Fetch set of one node: its branches (present-only for trigger ORs,
    whose absent names are constant-False) plus the counts branches any
    jagged member needs."""
    names = node.branches()
    if isinstance(node, AnyOf):
        names = {n for n in names if n in store.branches}
    return tuple(with_counts_branches(sorted(names), store))


def build_cascade(query: Query, store) -> CascadePlan | None:
    """Lower a query to a :class:`CascadePlan`, or ``None`` when there is
    nothing to cascade (no predicate nodes — constant programs keep the
    engines' dedicated constant path).
    """
    from repro.kernels.predicate_eval import compile_query

    cache: dict[str, object] = {}

    def stats_of(branch: str):
        if branch not in cache:
            cache[branch] = (
                store.window_stats(branch, 0, store.n_events)
                if branch in store.branches
                else None
            )
        return cache[branch]

    stages: list[CascadeStage] = []
    for tier, stage in query.stages():
        for node in stage:
            branches = _stage_branches(node, store)
            stages.append(
                CascadeStage(
                    index=len(stages),
                    tier=tier,
                    nodes=(node,),
                    branches=branches,
                    est_selectivity=float(
                        min(max(estimate_node_selectivity(node, stats_of, store), 0.0), 1.0)
                    ),
                    est_bytes=store.compressed_bytes(branches),
                    program=compile_query(_stage_query(tier, node)),
                )
            )
    if not stages:
        return None
    static_order = sorted(range(len(stages)), key=lambda i: (stages[i].rank, i))
    return CascadePlan(stages=stages, static_order=static_order)


# ---------------------------------------------------------------------------
# admission pricing: whole-plan byte estimate BEFORE anything runs
# ---------------------------------------------------------------------------


def estimate_plan_bytes(
    plan, store, window_events: int, calibration: dict | None = None
) -> dict:
    """Price a :class:`~repro.core.planner.SkimPlan`'s fetch bytes before
    executing it — the admission-control currency (DESIGN.md §12).

    Pure metadata: basket sizes come from ``range_comp_bytes``, pass
    rates from the cascade stages' zone-map-seeded selectivity estimates
    (stage independence assumed), window skips from the plan's zone-map
    decisions.  **Nothing is fetched or decoded** — a service can reject
    a query on this price with zero bytes moved.

    Per window: PRUNE windows cost nothing; ACCEPT_ALL windows pay the
    one phase-2 output round; scanned windows pay the head stage in
    full, each later cascade stage scaled by the estimated alive
    fraction after its predecessors, and the phase-2 output-only set
    scaled by the probability the window keeps a survivor.  Without a
    cascade the full filter set is priced per window (the preload path).

    ``calibration`` is an optional ``{stage_kind: ratio}`` prior of
    observed/priced byte ratios (from
    :meth:`repro.obs.metrics.MetricsRegistry.calibration_priors` — the
    admission feedback loop): each stage's priced bytes scale by its
    kind's ratio, phase 2 by the ``"phase2"`` ratio.  Ratios clamp to
    [0.05, 20] so a few anomalous jobs cannot collapse or explode the
    price; ``None`` (the default) prices exactly as before.

    Returns ``{"phase1", "phase2", "total", "requests", "per_stage",
    "per_stage_kinds", "est_selectivity", "n_windows",
    "n_windows_pruned"}`` — bytes as ints, ``per_stage`` keyed by
    cascade stage index in static order, ``per_stage_kinds`` mapping
    those indices to kind labels.
    """

    def _scale(kind: str) -> float:
        if not calibration:
            return 1.0
        ratio = calibration.get(kind)
        if ratio is None:
            return 1.0
        return min(max(float(ratio), 0.05), 20.0)

    n = store.n_events
    spans = [
        (s, min(s + window_events, n)) for s in range(0, n, window_events)
    ]
    decisions = plan.window_decisions
    cplan = plan.cascade
    per_stage: dict[int, float] = (
        {s.index: 0.0 for s in cplan.stages} if cplan is not None else {}
    )
    stage_kinds: dict[int, str] = (
        {s.index: stage_kind(s) for s in cplan.stages}
        if cplan is not None
        else {}
    )
    phase1 = phase2 = 0.0
    requests = 0
    pruned = 0
    passed_est = 0.0
    for wi, (a, b) in enumerate(spans):
        kind = decisions[wi].decision if decisions is not None else SCAN
        m = b - a
        if kind == PRUNE:
            pruned += 1
            continue
        if kind == ACCEPT_ALL:
            nbytes, nb = store.range_comp_bytes(plan.output_branches, a, b)
            phase2 += nbytes * _scale("phase2")
            requests += coalesced_requests(nbytes, nb, True)
            passed_est += m
            continue
        if cplan is not None:
            # the alive fraction prices later stages in the *correlated*
            # limit (whole baskets live or die together) — the right
            # prior for era-correlated HEP data, where conditions are
            # constant within a basket; the independent limit would
            # price every stage at its full preload cost
            alive = 1.0
            for si in cplan.static_order:
                stage = cplan.stages[si]
                nbytes, _ = store.range_comp_bytes(stage.branches, a, b)
                # truncate per window so per_stage sums exactly to phase1
                est = int(nbytes * alive * _scale(stage_kinds[si]))
                per_stage[si] += est
                phase1 += est
                if est:
                    requests += coalesced_requests(est, 0, True)
                alive *= stage.est_selectivity
            sel = alive
        else:
            nbytes, _ = store.range_comp_bytes(plan.filter_branches, a, b)
            phase1 += nbytes
            if nbytes:
                requests += coalesced_requests(nbytes, 0, True)
            sel = DEFAULT_SELECTIVITY ** max(
                sum(len(stage) for _, stage in plan.query.stages()), 1
            )
        sel = min(max(sel, 0.0), 1.0)
        passed_est += sel * m
        # phase 2 moves the output-only set iff >= 1 event survives
        p_alive = 1.0 - (1.0 - sel) ** max(m, 1)
        nbytes, _ = store.range_comp_bytes(plan.output_only_branches, a, b)
        phase2 += nbytes * p_alive * _scale("phase2")
        if nbytes and p_alive > 0.5:
            requests += coalesced_requests(nbytes, 0, True)
    return {
        "phase1": int(phase1),
        "phase2": int(phase2),
        "total": int(phase1 + phase2),
        "requests": int(requests),
        "per_stage": {si: int(v) for si, v in per_stage.items()},
        "per_stage_kinds": stage_kinds,
        "est_selectivity": passed_est / max(n, 1),
        "n_windows": len(spans),
        "n_windows_pruned": pruned,
    }


# ---------------------------------------------------------------------------
# runtime state: observed selectivities adapt the order
# ---------------------------------------------------------------------------


@dataclass
class _StageLedger:
    events_in: int = 0
    events_out: int = 0
    bytes_fetched: int = 0
    windows: int = 0
    windows_skipped: int = 0  # windows dead before this stage ran


class CascadeState:
    """Per-run mutable cascade state: observed pass rates + byte ledger.

    ``order()`` returns the execution order for the next window: the head
    stage is pinned (static cost model), the tail re-ranks with observed
    selectivities once a stage has seen events.  Updates happen strictly
    in window order on the consumer side, so the order sequence — and
    with it the byte accounting — is identical across ``pipeline`` modes.
    """

    def __init__(self, cplan: CascadePlan, adaptive: bool = True):
        self.cplan = cplan
        self.adaptive = adaptive
        self.ledgers = [_StageLedger() for _ in cplan.stages]

    def observed_selectivity(self, i: int) -> float | None:
        led = self.ledgers[i]
        if led.events_in <= 0:
            return None
        return led.events_out / led.events_in

    def _blended(self, i: int) -> float:
        obs = self.observed_selectivity(i)
        return obs if obs is not None else self.cplan.stages[i].est_selectivity

    def order(self) -> list[int]:
        head, *tail = self.cplan.static_order
        if self.adaptive and tail:
            tail = sorted(
                tail,
                key=lambda i: (
                    self.cplan.stages[i].est_bytes
                    / max(1.0 - self._blended(i), _MIN_KILL),
                    i,
                ),
            )
        return [head, *tail]

    def observe(self, i: int, n_in: int, n_out: int, nbytes: int) -> None:
        led = self.ledgers[i]
        led.events_in += int(n_in)
        led.events_out += int(n_out)
        led.bytes_fetched += int(nbytes)
        led.windows += 1

    def skip(self, i: int) -> None:
        self.ledgers[i].windows_skipped += 1

    def report(self) -> list[dict]:
        """Per-stage extras ledger, in current execution order."""
        out = []
        for i in self.order():
            s, led = self.cplan.stages[i], self.ledgers[i]
            out.append(
                {
                    "stage": i,
                    "tier": s.tier,
                    "kind": stage_kind(s),
                    "branches": list(s.branches),
                    "est_selectivity": s.est_selectivity,
                    "observed_selectivity": self.observed_selectivity(i),
                    "bytes_fetched": led.bytes_fetched,
                    "windows": led.windows,
                    "windows_skipped": led.windows_skipped,
                    "events_in": led.events_in,
                    "events_out": led.events_out,
                }
            )
        return out


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------


def _alive_spans(
    mask: np.ndarray, start: int, stop: int, basket_events: int
) -> list[tuple[int, int]]:
    """Maximal contiguous event spans of baskets with >= 1 alive event.

    The basket grid is global (multiples of ``basket_events``); spans are
    clipped to the window.  Baskets whose events are all dead never
    appear — they are exactly the baskets the next stage must not fetch.
    """
    spans: list[list[int]] = []
    grid0 = start - start % basket_events
    for gb in range(grid0, stop, basket_events):
        a, b = max(gb, start), min(gb + basket_events, stop)
        if not mask[a - start : b - start].any():
            continue
        if spans and spans[-1][1] == a:
            spans[-1][1] = b
        else:
            spans.append([a, b])
    return [(a, b) for a, b in spans]


def account_fetch(
    store,
    names,
    start: int,
    stop: int,
    ledger: dict[str, set],
    stats: FetchStats | None,
    coalesce: bool = True,
) -> int:
    """Account one fetch round for ``names`` over ``[start, stop)``,
    charging only baskets not yet in ``ledger`` (and marking them).

    Mirrors :meth:`EventStore.fetch_window`'s request model on the *new*
    bytes: bulk requests of at most the TTreeCache size when coalescing,
    one seek per basket otherwise.  Returns the newly accounted bytes.
    """
    new_bytes = new_baskets = 0
    per_branch: dict[str, int] = {}
    for name in names:
        seen = ledger.setdefault(name, set())
        for i in store.basket_ids_for_range(name, start, stop):
            if i in seen:
                continue
            seen.add(i)
            nb = store.basket_meta(name, i).comp_bytes
            per_branch[name] = per_branch.get(name, 0) + nb
            new_bytes += nb
            new_baskets += 1
    if stats is not None and new_bytes:
        stats.bytes_fetched += new_bytes
        stats.requests += coalesced_requests(new_bytes, new_baskets, coalesce)
        for k, v in per_branch.items():
            stats.by_branch[k] = stats.by_branch.get(k, 0) + v
    return new_bytes


def mark_fetched(store, names, start: int, stop: int, ledger: dict[str, set]) -> None:
    """Mark baskets as already accounted (no stats) — the caller fetched
    them through another path (e.g. the prefetcher's load stage)."""
    for name in names:
        seen = ledger.setdefault(name, set())
        seen.update(store.basket_ids_for_range(name, start, stop))


def unfetched_bytes(
    store, names, start: int, stop: int, ledger: dict[str, set]
) -> int:
    """Bytes of ``names``' window baskets the ledger never saw — the
    exact cascade savings once BOTH phases have run (a basket phase 2
    re-fetched is in the ledger and does not count as skipped)."""
    skipped = 0
    for name in names:
        seen = ledger.get(name, ())
        for i in store.basket_ids_for_range(name, start, stop):
            if i not in seen:
                skipped += store.basket_meta(name, i).comp_bytes
    return skipped


@dataclass
class WindowOutcome:
    """One window's cascade result: the survivor mask plus ledgers."""

    mask: np.ndarray
    full_loaded: dict  # branch -> full-window decoded array
    stage_bytes: int  # on-demand phase-1 bytes (beyond the head preload)
    stages_run: int


class CascadeExecutor:
    """Shared cascaded phase-1 executor (engine / shared-scan / cluster).

    One instance per skim run; holds the adaptive :class:`CascadeState`.
    The caller owns window iteration, zone-map decisions, phase 2, and
    output assembly — the executor owns stage ordering, alive-span
    fetch/decode, sub-program evaluation, and the basket ledger.
    """

    def __init__(
        self,
        plan,  # SkimPlan with .cascade set
        store,
        coalesce: bool = True,
        adaptive: bool = True,
        order: list[int] | None = None,
        tracer=None,
        backend: str | None = None,
    ):
        if plan.cascade is None:
            raise ValueError("plan has no cascade (plan_skim(cascade=True))")
        from repro.obs.trace import NULL_TRACER

        self.plan = plan
        self.cplan: CascadePlan = plan.cascade
        self.store = store
        self.coalesce = coalesce
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._forced_order = list(order) if order is not None else None
        self.state = CascadeState(self.cplan, adaptive=adaptive and order is None)
        self._backend: str | None = backend  # resolved on first evaluation
        # batched-dispatch shape buckets (DESIGN.md §16): grow-only so a
        # late large window re-buckets once instead of recompiling per batch
        self._pad_E: int = 0
        self._stage_K: dict[int, int] = {}

    # -- plan queries --------------------------------------------------------

    def order(self) -> list[int]:
        return self._forced_order or self.state.order()

    @property
    def head_branches(self) -> list[str]:
        """The pinned head stage's fetch set — what the prefetcher loads.

        Reads only immutable plan state (never the adaptive ledgers): the
        prefetch worker calls this concurrently with consumer-side
        ``observe`` updates, and the load set must be identical across
        pipeline modes anyway (DESIGN.md §4b)."""
        head = (self._forced_order or self.cplan.static_order)[0]
        return list(self.cplan.stages[head].branches)

    # -- stage evaluation ----------------------------------------------------

    def _eval_stage(self, stage: CascadeStage, data: dict, n: int) -> np.ndarray:
        """Evaluate one sub-program over a decoded span (fused path):
        the Pallas kernel route on TPU, the compiled-program interpreter
        on plain CPUs — resolved once per run (this is the per-span hot
        path)."""
        from repro.core.neardata import fused_window_skim, program_eval_np

        if not stage.branches:
            # constant sub-program (trigger OR over absent-era branches)
            return program_eval_np({}, stage.program, n)
        if self._backend is None:
            import jax

            self._backend = (
                "pallas" if jax.default_backend() == "tpu" else "host"
            )
        if self._backend == "host":
            return program_eval_np(data, stage.program, n)
        mask, _ = fused_window_skim(
            data, stage.program, self.store, backend=self._backend
        )
        return mask

    # -- the per-window cascade ---------------------------------------------

    def run_window(
        self,
        start: int,
        stop: int,
        head_data: dict | None,
        breakdown,
        stats: FetchStats,
        ledger: dict[str, set] | None = None,
        timer_breakdown=None,
    ) -> WindowOutcome:
        """Run the cascade over one window; returns the survivor mask.

        ``head_data`` holds the head stage's branches decoded over the
        full window (the prefetcher's load payload) — its fetch must
        already be accounted and marked in ``ledger`` by the caller (or
        pass ``None`` to let the executor fetch it here).  Later stages
        fetch **only alive basket spans**, charging ``stats`` through the
        dedup ledger.  ``breakdown`` receives decode timings,
        ``timer_breakdown`` (default: same) the filter timings.
        """
        from repro.core.engine import _decode_branches, _Timer

        store = self.store
        timer_breakdown = timer_breakdown if timer_breakdown is not None else breakdown
        m = stop - start
        mask = np.ones(m, dtype=bool)
        ledger = {} if ledger is None else ledger
        full_loaded: dict = {}
        order = self.order()
        stage_bytes_total = 0
        stages_run = 0

        for pos, si in enumerate(order):
            stage = self.cplan.stages[si]
            alive_in = int(mask.sum())
            if alive_in == 0:
                # dead window: remaining stages never fetch a byte
                for rest in order[pos:]:
                    self.state.skip(rest)
                break
            stages_run += 1
            ssid = self.tracer.begin(
                f"stage[{si}]", kind="cascade_stage", stage=si,
                node=stage_kind(stage), tier=stage.tier,
            )
            stage_bytes = 0
            if pos == 0 and head_data is not None:
                spans = [(start, stop)]
            else:
                spans = _alive_spans(mask, start, stop, store.basket_events)
            for a, b in spans:
                if pos == 0 and head_data is not None:
                    sdata, n_local, off = head_data, m, 0
                else:
                    stage_bytes += account_fetch(
                        store, stage.branches, a, b, ledger, stats, self.coalesce
                    )
                    sdata = _decode_branches(
                        store, list(stage.branches), a, b, breakdown,
                        FetchStats(), self.coalesce, tracer=self.tracer,
                    )
                    n_local, off = b - a, a - start
                with _Timer(timer_breakdown, "filter"):
                    smask = self._eval_stage(stage, sdata, n_local)
                mask[off : off + n_local] &= smask
                if n_local == m:
                    # full-window decode: reusable by phase 2 as-is
                    full_loaded.update(sdata)
            stage_bytes_total += stage_bytes
            alive_out = int(mask.sum())
            self.tracer.end(
                ssid, alive_in=alive_in, alive_out=alive_out, bytes=stage_bytes
            )
            self.state.observe(si, alive_in, alive_out, stage_bytes)
        return WindowOutcome(
            mask=mask,
            full_loaded=full_loaded,
            stage_bytes=stage_bytes_total,
            stages_run=stages_run,
        )

    # -- the batched cascade (one device dispatch per stage per batch) -------

    def _resolve_backend(self) -> str:
        if self._backend is None:
            import jax

            self._backend = (
                "pallas" if jax.default_backend() == "tpu" else "host"
            )
        return self._backend

    @staticmethod
    def _bits_to_spans(
        bits, start: int, stop: int, basket_events: int
    ) -> list[tuple[int, int]]:
        """Alive-basket bits (window-local ordinals on the global basket
        grid) -> merged contiguous event spans, clipped to the window.
        The batched mirror of :func:`_alive_spans`, driven by the (B, nb)
        basket-alive planes the device step returns instead of the full
        event mask (which stays device-resident)."""
        grid0 = start - start % basket_events
        spans: list[list[int]] = []
        for j, bit in enumerate(bits):
            if not bit:
                continue
            a = max(grid0 + j * basket_events, start)
            b = min(grid0 + (j + 1) * basket_events, stop)
            if a >= b:
                continue
            if spans and spans[-1][1] == a:
                spans[-1][1] = b
            else:
                spans.append([a, b])
        return [(a, b) for a, b in spans]

    def run_window_batch(
        self,
        entries: list[tuple],
        pad_B: int | None = None,
    ) -> list[WindowOutcome]:
        """Run the cascade over a batch of windows with ONE device
        dispatch per stage (DESIGN.md §16).

        ``entries`` is a list of ``(start, stop, head_data, breakdown,
        stats, ledger)`` tuples — the same per-window arguments as
        :meth:`run_window`; returns one :class:`WindowOutcome` per entry,
        in order, bit-identical to running each window through
        :meth:`run_window` with the batch's (frozen) stage order.

        Mechanics: windows are staged into stable-shaped batch tensors
        (event axis padded to a grow-only ``pad_E`` bucket, batch axis to
        ``pad_B`` with dead windows, per-stage object capacity ``K`` in
        grow-only pow2 buckets), so a late-growing window re-buckets the
        compiled step once instead of recompiling per batch.  The
        survivor masks live on device as bit-packed uint32 words between
        stages; per stage only the (B, nb) basket-alive bits and (B,)
        counts return to the host — they drive the *next* stage's
        alive-span fetch, so dead baskets are never re-staged.  The full
        event masks cross back exactly once, at the window-ledger
        boundary (batch end).  Fetch accounting is per window through
        each entry's own stats + ledger, identical to the per-window
        path.
        """
        import time as _time

        from repro.analysis.verify import maybe_verify_device_batch
        from repro.core import neardata as nd
        from repro.core.engine import _decode_branches
        from repro.kernels import ops

        if not entries:
            return []
        import jax.numpy as jnp

        store = self.store
        be = store.basket_events
        B_real = len(entries)
        Bn = max(int(pad_B or 0), B_real)
        sizes = [stop - start for (start, stop, *_r) in entries]
        quantum = nd._WINDOW_QUANTUM
        self._pad_E = max(
            self._pad_E, -(-max(sizes) // quantum) * quantum
        )
        pad_E = self._pad_E
        nb = pad_E // be + 2
        use_pallas = self._resolve_backend() == "pallas"

        # initial masks: real events alive, batch/event padding dead —
        # phantom events can never surface in a survivor set
        init = np.zeros((Bn, pad_E), dtype=bool)
        seg = np.zeros((Bn, pad_E), dtype=np.int32)
        for b, (start, stop, *_r) in enumerate(entries):
            init[b, : stop - start] = True
            grid0 = start - start % be
            ids = (start + np.arange(pad_E, dtype=np.int64) - grid0) // be
            seg[b] = np.clip(ids, 0, nb - 1).astype(np.int32)
        packed = jnp.asarray(ops.pack_mask(init))
        seg_ids = jnp.asarray(seg)
        maybe_verify_device_batch(
            [(s, t) for (s, t, *_r) in entries],
            pad_E, Bn, nb, be, int(packed.shape[1]),
        )

        order = self.order()  # frozen for the batch (any order is
        # bit-identical on survivors; the adaptive re-rank applies
        # between batches, exactly as it applies between windows)
        bsid = self.tracer.begin(
            "device_batch", kind="device_batch",
            windows=B_real, pad_windows=Bn, pad_events=pad_E,
        )

        counts_host = np.array(sizes + [0] * (Bn - B_real), dtype=np.int64)
        basket_bits: np.ndarray | None = None  # (Bn, nb) after a stage
        full_loaded: list[dict] = [{} for _ in entries]
        stage_bytes_total = [0] * B_real
        stages_run = [0] * B_real

        for pos, si in enumerate(order):
            stage = self.cplan.stages[si]
            alive = [b for b in range(B_real) if counts_host[b] > 0]
            for b in range(B_real):
                if counts_host[b] == 0:
                    self.state.skip(si)
            if not alive:
                continue  # whole batch dead: no staging, no dispatch
            for b in alive:
                stages_run[b] += 1
            ssid = self.tracer.begin(
                f"stage[{si}]", kind="cascade_stage", stage=si,
                node=stage_kind(stage), tier=stage.tier, batch=len(alive),
            )

            # -- fetch + decode alive spans (host side, per window) ------
            staged: list[list[tuple[int, int, dict]]] = [
                [] for _ in range(B_real)
            ]
            stage_bytes = [0] * B_real
            K_req = 1
            for b in alive:
                start, stop, head_data, breakdown, stats, ledger = entries[b]
                if not stage.branches:
                    continue  # constant sub-program: zero staging pages
                    # evaluate it exactly (absent-trigger ANY is
                    # constant-False on zeros, as on the host)
                if pos == 0 and head_data is not None:
                    spans = [(start, stop)]
                elif basket_bits is None:
                    spans = [(start, stop)]
                else:
                    spans = self._bits_to_spans(
                        basket_bits[b], start, stop, be
                    )
                for a, z in spans:
                    if pos == 0 and head_data is not None:
                        sdata = head_data
                    else:
                        stage_bytes[b] += account_fetch(
                            store, stage.branches, a, z, ledger, stats,
                            self.coalesce,
                        )
                        sdata = _decode_branches(
                            store, list(stage.branches), a, z, breakdown,
                            FetchStats(), self.coalesce, tracer=self.tracer,
                        )
                    staged[b].append((a - start, z - a, sdata))
                    if z - a == stop - start:
                        full_loaded[b].update(sdata)
                    K_req = max(
                        K_req, nd.window_pad_K(sdata, stage.program, store)
                    )
            K_b = max(self._stage_K.get(si, 1), K_req)
            self._stage_K[si] = K_b

            # -- stage the batch tensors (zeros outside alive spans) -----
            T, G = stage.program.n_terms, stage.program.n_groups
            terms = np.zeros((Bn, T, pad_E, K_b), np.float32)
            valid = np.zeros((Bn, G, pad_E, K_b), np.float32)
            weights = np.zeros((Bn, G, pad_E, K_b), np.float32)
            for b in alive:
                for off, n, sdata in staged[b]:
                    pb = nd.build_padded_inputs(
                        sdata, stage.program, store, K=K_b, to_device=False
                    )
                    terms[b, :, off : off + n, :] = pb.terms
                    valid[b, :, off : off + n, :] = pb.valid
                    weights[b, :, off : off + n, :] = pb.weights

            # warm the compiled step per shape bucket OUTSIDE the stage
            # timers: measured filter time is steady-state dispatch
            ops.warm_cascade_stage(
                stage.program, (Bn, T, pad_E, K_b), nb,
                use_pallas=use_pallas,
            )

            t0 = _time.perf_counter()
            packed, basket_dev, counts_dev = ops.cascade_stage_step(
                terms, valid, weights, packed, seg_ids,
                stage.program, nb, use_pallas=use_pallas,
            )
            basket_bits = np.asarray(basket_dev).astype(bool)
            counts_new = np.asarray(counts_dev).astype(np.int64)
            elapsed = _time.perf_counter() - t0
            share = elapsed / len(alive)

            batch_in = batch_out = 0
            for b in alive:
                _s, _t, _h, breakdown, _st, _l = entries[b]
                breakdown.filter += share
                alive_in = int(counts_host[b])
                alive_out = int(counts_new[b])
                self.state.observe(si, alive_in, alive_out, stage_bytes[b])
                stage_bytes_total[b] += stage_bytes[b]
                batch_in += alive_in
                batch_out += alive_out
            counts_host = counts_new
            self.tracer.end(
                ssid, alive_in=batch_in, alive_out=batch_out,
                bytes=sum(stage_bytes),
            )

        # the one host round trip for event-level masks: batch boundary
        words = np.asarray(packed)
        outcomes = []
        for b, (start, stop, *_r) in enumerate(entries):
            mask = ops.unpack_mask(words[b], pad_E)[: stop - start].copy()
            outcomes.append(
                WindowOutcome(
                    mask=mask,
                    full_loaded=full_loaded[b],
                    stage_bytes=stage_bytes_total[b],
                    stages_run=stages_run[b],
                )
            )
        self.tracer.end(bsid, stages=len(order))
        return outcomes

    # -- phase 2 through the same ledger -------------------------------------

    def fetch_full(
        self,
        names,
        start: int,
        stop: int,
        breakdown,
        stats: FetchStats,
        ledger: dict[str, set],
        known: dict | None = None,
    ) -> dict:
        """Full-window columnar data for ``names``, charging only baskets
        the ledger has not seen (phase 2 of a cascaded window: branches a
        stage already moved are not paid again; the decoded-basket LRU
        absorbs the re-decode).  ``known`` supplies branches already
        decoded over the full window (head data, full-window stages)."""
        from repro.core.engine import _decode_branches

        known = known or {}
        need = [n for n in names if n not in known]
        account_fetch(
            self.store, need, start, stop, ledger, stats, self.coalesce
        )
        data = _decode_branches(
            self.store, need, start, stop, breakdown, FetchStats(),
            self.coalesce, preloaded=dict(known), tracer=self.tracer,
        )
        return data


__all__ = [
    "DEFAULT_SELECTIVITY",
    "CascadeExecutor",
    "CascadePlan",
    "CascadeStage",
    "CascadeState",
    "WindowOutcome",
    "account_fetch",
    "build_cascade",
    "estimate_node_selectivity",
    "estimate_plan_bytes",
    "mark_fetched",
    "stage_kind",
]
