"""Derived-kinematics expression tier (DESIGN.md §10).

Real LHC skims cut on *derived* quantities — dilepton invariant-mass
windows, ΔR isolation, arithmetic over event scalars — not just raw
branches against constants.  This module is the host half of that tier:

  * a tiny arithmetic language over flat branches and ``sum(...)``
    reductions (``"MET_pt + 0.5*sum(Jet_pt)"``), parsed to an AST and
    lowered to a stack (RPN) program that both the NumPy reference
    evaluator and the compiled device :class:`~repro.kernels.predicate_eval.Program`
    execute — same post-order, same op sequence, so the two host paths
    are bit-identical by construction;
  * leading-pair kinematics (invariant mass, ΔR) shared by the query
    evaluator (``repro.core.query.eval_node``) and the fused program
    interpreter (``repro.core.neardata.program_eval_np``).

Everything here is float64 NumPy; the device kernels mirror the same
formulas in float32 (the HT precedent: bit-identical on the repo
fixtures, where no value sits within float32 noise of a threshold).

Conventions:

  * bare identifiers name **flat** branches;
  * ``sum(X)`` sums a **jagged** branch per event (float64 accumulation,
    exactly like HT); ``X`` must follow the NanoAOD ``Coll_var`` naming so
    its counts branch is ``nColl`` (:func:`counts_name`) — the same
    convention the ``object``/``ht`` nodes already rely on;
  * "leading" objects are highest-``pt`` first, ties broken by storage
    order (what ``argmax`` picks on device).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# ---------------------------------------------------------------------------
# RPN opcodes (shared with the device compiler/kernels)
# ---------------------------------------------------------------------------

(
    RPN_BRANCH,  # push a flat branch      (arg: branch name / term slot)
    RPN_SUM,  # push per-event sum of a jagged branch (arg: name / slot)
    RPN_CONST,  # push a constant          (arg: float)
    RPN_ADD,
    RPN_SUB,
    RPN_MUL,
    RPN_DIV,
    RPN_NEG,
    RPN_ABS,
    RPN_MIN,
    RPN_MAX,
) = range(11)

_BINARY = {RPN_ADD, RPN_SUB, RPN_MUL, RPN_DIV, RPN_MIN, RPN_MAX}
_UNARY = {RPN_NEG, RPN_ABS}

_FUNCTIONS = {"abs": (1, RPN_ABS), "min": (2, RPN_MIN), "max": (2, RPN_MAX)}


def counts_name(branch: str) -> str:
    """``Coll_var`` -> ``nColl`` (the NanoAOD counts-branch convention)."""
    return "n" + branch.split("_", 1)[0]


# ---------------------------------------------------------------------------
# AST + parser
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Num:
    value: float


@dataclass(frozen=True)
class Ref:
    name: str  # flat branch


@dataclass(frozen=True)
class SumRef:
    name: str  # jagged branch, summed per event


@dataclass(frozen=True)
class Un:
    op: int  # RPN_NEG / RPN_ABS
    arg: object


@dataclass(frozen=True)
class Bin:
    op: int  # RPN_ADD / RPN_SUB / RPN_MUL / RPN_DIV / RPN_MIN / RPN_MAX
    lhs: object
    rhs: object


class ExprError(ValueError):
    """Malformed expression text."""


def _tokenize(text: str) -> list[tuple[str, object]]:
    toks: list[tuple[str, object]] = []
    i, n = 0, len(text)
    while i < n:
        c = text[i]
        if c.isspace():
            i += 1
        elif c in "+-*/(),":
            toks.append((c, None))
            i += 1
        elif c.isdigit() or c == ".":
            j = i
            while j < n and (text[j].isdigit() or text[j] in ".eE" or
                             (text[j] in "+-" and text[j - 1] in "eE")):
                j += 1
            try:
                toks.append(("num", float(text[i:j])))
            except ValueError as exc:
                raise ExprError(f"bad number {text[i:j]!r} in {text!r}") from exc
            i = j
        elif c.isalpha() or c == "_":
            j = i
            while j < n and (text[j].isalnum() or text[j] == "_"):
                j += 1
            toks.append(("ident", text[i:j]))
            i = j
        else:
            raise ExprError(f"unexpected character {c!r} in {text!r}")
    toks.append(("end", None))
    return toks


class _Parser:
    """Recursive descent: expr -> term -> unary -> primary."""

    def __init__(self, text: str):
        self.text = text
        self.toks = _tokenize(text)
        self.pos = 0

    def peek(self) -> str:
        return self.toks[self.pos][0]

    def next(self) -> tuple[str, object]:
        tok = self.toks[self.pos]
        self.pos += 1
        return tok

    def expect(self, kind: str) -> object:
        k, v = self.next()
        if k != kind:
            raise ExprError(f"expected {kind!r}, got {k!r} in {self.text!r}")
        return v

    def parse(self):
        node = self.expr()
        if self.peek() != "end":
            raise ExprError(f"trailing input after expression in {self.text!r}")
        return node

    def expr(self):
        node = self.term()
        while self.peek() in "+-":
            op, _ = self.next()
            node = Bin(RPN_ADD if op == "+" else RPN_SUB, node, self.term())
        return node

    def term(self):
        node = self.unary()
        while self.peek() in "*/":
            op, _ = self.next()
            node = Bin(RPN_MUL if op == "*" else RPN_DIV, node, self.unary())
        return node

    def unary(self):
        if self.peek() == "-":
            self.next()
            return Un(RPN_NEG, self.unary())
        if self.peek() == "+":
            self.next()
            return self.unary()
        return self.primary()

    def primary(self):
        kind, val = self.next()
        if kind == "num":
            return Num(float(val))
        if kind == "(":
            node = self.expr()
            self.expect(")")
            return node
        if kind == "ident":
            if self.peek() != "(":
                return Ref(str(val))
            self.next()  # '('
            name = str(val)
            if name == "sum":
                arg = self.expect("ident")
                self.expect(")")
                return SumRef(str(arg))
            if name not in _FUNCTIONS:
                raise ExprError(f"unknown function {name!r} in {self.text!r}")
            arity, op = _FUNCTIONS[name]
            args = [self.expr()]
            while self.peek() == ",":
                self.next()
                args.append(self.expr())
            self.expect(")")
            if len(args) != arity:
                raise ExprError(
                    f"{name}() takes {arity} argument(s), got {len(args)}"
                )
            return Un(op, args[0]) if arity == 1 else Bin(op, args[0], args[1])
        raise ExprError(f"unexpected token {kind!r} in {self.text!r}")


def parse_expr(text: str):
    """Parse expression text -> AST."""
    return _Parser(text).parse()


def to_rpn(node) -> tuple[tuple[int, object], ...]:
    """Post-order lowering of the AST to a stack program.

    Operands are branch *names* here; the device compiler rewrites them to
    term-slot indices.  Both host evaluators walk this exact sequence, so
    their float64 op order is identical.
    """
    out: list[tuple[int, object]] = []

    def walk(n) -> None:
        if isinstance(n, Num):
            out.append((RPN_CONST, float(n.value)))
        elif isinstance(n, Ref):
            out.append((RPN_BRANCH, n.name))
        elif isinstance(n, SumRef):
            out.append((RPN_SUM, n.name))
        elif isinstance(n, Un):
            walk(n.arg)
            out.append((n.op, None))
        elif isinstance(n, Bin):
            walk(n.lhs)
            walk(n.rhs)
            out.append((n.op, None))
        else:  # pragma: no cover - parser never builds other nodes
            raise TypeError(f"unknown expression node {type(n)}")

    walk(node)
    return tuple(out)


def compile_expr(text: str) -> tuple[tuple[int, object], ...]:
    """Text -> RPN; rejects expressions that read no branch (a constant
    predicate would silently defeat the engine's selection-free fast path)."""
    rpn = to_rpn(parse_expr(text))
    if not any(op in (RPN_BRANCH, RPN_SUM) for op, _ in rpn):
        raise ExprError(f"expression references no branches: {text!r}")
    return rpn


def rpn_branches(rpn) -> set[str]:
    """Branches the program reads (sum reductions include their counts)."""
    out: set[str] = set()
    for op, arg in rpn:
        if op == RPN_BRANCH:
            out.add(str(arg))
        elif op == RPN_SUM:
            out.add(str(arg))
            out.add(counts_name(str(arg)))
    return out


def validate_rpn(rpn, store, source: str = "") -> None:
    """Check branch kinds against a store: bare refs must be flat, sums
    jagged with the conventional counts branch (missing branches are the
    planner's generic error)."""
    for op, arg in rpn:
        br = store.branches.get(arg) if op in (RPN_BRANCH, RPN_SUM) else None
        if br is None:
            continue
        if op == RPN_BRANCH and br.jagged:
            raise ValueError(
                f"expression {source!r}: {arg!r} is jagged — "
                f"use sum({arg}) or an object/ht node"
            )
        if op == RPN_SUM:
            if not br.jagged:
                raise ValueError(
                    f"expression {source!r}: sum() needs a jagged branch, "
                    f"{arg!r} is flat"
                )
            if br.counts_branch != counts_name(str(arg)):
                raise ValueError(
                    f"expression {source!r}: sum({arg}) expects counts "
                    f"branch {counts_name(str(arg))!r}, store has "
                    f"{br.counts_branch!r}"
                )


# ---------------------------------------------------------------------------
# NumPy evaluation (the semantics of record for the host paths)
# ---------------------------------------------------------------------------


def _event_ids(counts: np.ndarray) -> np.ndarray:
    return np.repeat(np.arange(len(counts)), counts)


def eval_rpn(rpn, resolve) -> np.ndarray:
    """Run a stack program; ``resolve(op, arg)`` supplies RPN_BRANCH /
    RPN_SUM operands as float64 ``(n_events,)`` arrays.

    Both ``eval_node`` (branch-name operands) and ``program_eval_np``
    (term-slot operands) call this exact walk, which is what makes the
    staged and fused host evaluations bit-identical for expressions.
    """
    stack: list = []
    for op, arg in rpn:
        if op in (RPN_BRANCH, RPN_SUM):
            stack.append(resolve(op, arg))
        elif op == RPN_CONST:
            stack.append(np.float64(arg))
        elif op in _UNARY:
            x = stack.pop()
            stack.append(-x if op == RPN_NEG else np.abs(x))
        else:
            b = stack.pop()
            a = stack.pop()
            if op == RPN_ADD:
                stack.append(a + b)
            elif op == RPN_SUB:
                stack.append(a - b)
            elif op == RPN_MUL:
                stack.append(a * b)
            elif op == RPN_DIV:
                with np.errstate(divide="ignore", invalid="ignore"):
                    stack.append(a / b)
            elif op == RPN_MIN:
                stack.append(np.minimum(a, b))
            elif op == RPN_MAX:
                stack.append(np.maximum(a, b))
            else:  # pragma: no cover - compile_expr never emits others
                raise ValueError(f"unknown RPN op {op}")
    (result,) = stack
    return result


def eval_expr_np(rpn, data: dict) -> np.ndarray:
    """Evaluate a branch-name RPN over decoded columnar ``data``.

    Flat branches promote exactly to float64; ``sum(X)`` is a float64
    ``bincount`` segment sum (the HT accumulation, kept float64 per the
    count/sum semantics split).  Branch-name operands missing from
    ``data`` raise ``KeyError`` — expressions are never optional the way
    trigger ORs are.
    """

    def resolve(op, name):
        if op == RPN_BRANCH:
            return np.asarray(data[name], dtype=np.float64)
        counts = np.asarray(data[counts_name(name)], dtype=np.int64)
        vals = np.asarray(data[name], dtype=np.float64)
        return np.bincount(
            _event_ids(counts), weights=vals, minlength=len(counts)
        )

    return eval_rpn(rpn, resolve)


# ---------------------------------------------------------------------------
# leading-pair kinematics (invariant mass, ΔR)
# ---------------------------------------------------------------------------


def _leading_indices(pt: np.ndarray, counts: np.ndarray, k: int):
    """Global value-array indices of the ``k`` highest-``pt`` objects per
    event (ties -> storage order, matching device ``argmax``).  Returns a
    list of ``k`` index arrays plus the per-event "has >= j objects"
    masks; indices are clamped safe where the mask is False.
    """
    n = len(counts)
    counts = np.asarray(counts, dtype=np.int64)
    if len(pt) == 0:
        zeros = np.zeros(n, dtype=np.int64)
        return [zeros] * k, [np.zeros(n, dtype=bool)] * k
    order = np.lexsort(
        (np.arange(len(pt)), -np.asarray(pt, dtype=np.float64),
         _event_ids(counts))
    )
    starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
    idxs, masks = [], []
    for j in range(k):
        has = counts >= j + 1
        pos = np.minimum(starts + j, len(order) - 1)
        idxs.append(np.where(has, order[pos], 0))
        masks.append(has)
    return idxs, masks


def _pair_kinematics(data: dict, coll_a: str, coll_b: str, variables):
    """Kinematic columns of the leading pair: for a same-collection pair
    the two highest-``pt`` objects, otherwise each collection's leading
    object.  Returns ``(cols_a, cols_b, ok)`` with float64 columns keyed
    by variable name and ``ok`` the events that have a full pair."""
    if coll_a == coll_b:
        counts = np.asarray(data[f"n{coll_a}"], dtype=np.int64)
        (i1, i2), (has1, has2) = _leading_indices(
            np.asarray(data[f"{coll_a}_pt"]), counts, 2
        )
        ok = has2
        idx_a, idx_b = i1, i2
        src_a = src_b = coll_a
    else:
        ca = np.asarray(data[f"n{coll_a}"], dtype=np.int64)
        cb = np.asarray(data[f"n{coll_b}"], dtype=np.int64)
        (ia,), (ha,) = _leading_indices(
            np.asarray(data[f"{coll_a}_pt"]), ca, 1
        )
        (ib,), (hb,) = _leading_indices(
            np.asarray(data[f"{coll_b}_pt"]), cb, 1
        )
        ok = ha & hb
        idx_a, idx_b = ia, ib
        src_a, src_b = coll_a, coll_b

    def gather(coll, idx):
        out = {}
        for var in variables:
            vals = np.asarray(data[f"{coll}_{var}"], dtype=np.float64)
            out[var] = vals[idx] if len(vals) else np.zeros(len(idx))
        return out

    return gather(src_a, idx_a), gather(src_b, idx_b), ok


def wrap_dphi(dphi: np.ndarray) -> np.ndarray:
    """Wrap an azimuthal difference into (-pi, pi]."""
    return (dphi + np.pi) % (2.0 * np.pi) - np.pi


def leading_pair_mass(
    data: dict, coll_a: str, coll_b: str
) -> tuple[np.ndarray, np.ndarray]:
    """Invariant mass of the leading pair -> ``(m (n,), ok (n,))``.

    ``m`` is garbage (zeros) where ``ok`` is False — callers gate on
    ``ok``.  Formula mirrored term-for-term by the float32 device kernel
    (kernels/ref.py)."""
    a, b, ok = _pair_kinematics(data, coll_a, coll_b,
                                ("pt", "eta", "phi", "mass"))

    def p4(c):
        px = c["pt"] * np.cos(c["phi"])
        py = c["pt"] * np.sin(c["phi"])
        pz = c["pt"] * np.sinh(c["eta"])
        ch = np.cosh(c["eta"])
        e = np.sqrt(c["mass"] * c["mass"] + c["pt"] * c["pt"] * ch * ch)
        return px, py, pz, e

    pxa, pya, pza, ea = p4(a)
    pxb, pyb, pzb, eb = p4(b)
    m2 = (
        (ea + eb) * (ea + eb)
        - (pxa + pxb) * (pxa + pxb)
        - (pya + pyb) * (pya + pyb)
        - (pza + pzb) * (pza + pzb)
    )
    return np.sqrt(np.maximum(m2, 0.0)), ok


def leading_delta_r(
    data: dict, coll_a: str, coll_b: str
) -> tuple[np.ndarray, np.ndarray]:
    """ΔR between the leading pair -> ``(dr (n,), ok (n,))``."""
    a, b, ok = _pair_kinematics(data, coll_a, coll_b, ("pt", "eta", "phi"))
    deta = a["eta"] - b["eta"]
    dphi = wrap_dphi(a["phi"] - b["phi"])
    return np.sqrt(deta * deta + dphi * dphi), ok


KINEMATIC_VARS = {"mass": ("pt", "eta", "phi", "mass"),
                  "deltaR": ("pt", "eta", "phi")}


__all__ = [
    "RPN_BRANCH", "RPN_SUM", "RPN_CONST", "RPN_ADD", "RPN_SUB", "RPN_MUL",
    "RPN_DIV", "RPN_NEG", "RPN_ABS", "RPN_MIN", "RPN_MAX",
    "ExprError", "parse_expr", "to_rpn", "compile_expr", "rpn_branches",
    "validate_rpn", "counts_name", "eval_rpn", "eval_expr_np",
    "leading_pair_mass", "leading_delta_r", "wrap_dphi", "KINEMATIC_VARS",
]
