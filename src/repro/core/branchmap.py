"""Wildcard -> minimal branch-set mapping (paper §3.1).

``HLT_*`` expands to >650 trigger branches in NanoAOD, but "most physics
studies typically rely on fewer than 23 specific triggers".  SkimROOT maps
wildcard selections to a minimal predefined set based on usage statistics,
logs a warning for excluded branches, and honors a ``force_all`` override.
"""

from __future__ import annotations

import fnmatch
import logging

logger = logging.getLogger("repro.branchmap")

# "Usage statistics" table: wildcard prefix -> the minimal branch set that
# common analyses actually read.  Extend via ``register_minimal_set``.
_MINIMAL_SETS: dict[str, tuple[str, ...]] = {
    "HLT_*": (
        "HLT_IsoMu24",
        "HLT_Ele32_WPTight_Gsf",
        "HLT_PFMET120_PFMHT120_IDTight",
        "HLT_DoubleEle25_CaloIdL_MW",
        "HLT_Mu17_TrkIsoVVL_Mu8_TrkIsoVVL",
    ),
}


def register_minimal_set(pattern: str, names: tuple[str, ...]) -> None:
    _MINIMAL_SETS[pattern] = tuple(names)


def expand_branches(
    patterns,
    available: list[str],
    force_all: bool = False,
    extra_required: set[str] | None = None,
) -> tuple[list[str], list[str]]:
    """Expand output-branch patterns against the store's branch list.

    Returns ``(selected, excluded_by_optimization)``.  Wildcards with a
    registered minimal set expand to that set unless ``force_all``; a
    warning is logged naming every excluded branch (paper: "SkimROOT logs a
    warning for any missing branches that were excluded due to
    optimization").  ``extra_required`` (e.g. filter branches) are always
    kept.
    """
    selected: list[str] = []
    excluded: list[str] = []
    seen: set[str] = set()

    def add(name: str) -> None:
        if name not in seen:
            seen.add(name)
            selected.append(name)

    for pat in patterns:
        full = fnmatch.filter(available, pat)
        if not full and pat in available:
            full = [pat]
        if not force_all and pat in _MINIMAL_SETS:
            minimal = [n for n in _MINIMAL_SETS[pat] if n in available]
            dropped = sorted(set(full) - set(minimal))
            if dropped:
                logger.warning(
                    "branchmap: pattern %r reduced to %d-branch minimal set; "
                    "%d branches excluded by optimization: %s%s",
                    pat,
                    len(minimal),
                    len(dropped),
                    ", ".join(dropped[:8]),
                    " ..." if len(dropped) > 8 else "",
                )
            excluded.extend(dropped)
            for n in minimal:
                add(n)
        else:
            for n in sorted(full):
                add(n)

    for n in sorted(extra_required or ()):
        if n in available:
            add(n)

    # jagged branches need their counts branch in the output
    return selected, excluded


def with_counts_branches(names: list[str], store) -> list[str]:
    """Ensure every jagged branch's counts branch rides along."""
    out = list(names)
    present = set(out)
    for n in names:
        br = store.branches.get(n)
        if br is not None and br.jagged and br.counts_branch not in present:
            present.add(br.counts_branch)
            out.append(br.counts_branch)
    return out
