"""Skim execution engine — reproduces the paper's four compared systems.

Modes (Fig. 4/5 of the paper):

  * ``client_plain``    — legacy client-side filtering: every selected
    branch's baskets cross the network for every event; everything is
    decompressed and deserialized before filtering (Fig. 2b).
  * ``client_opt``      — client-side with SkimROOT's two-phase model
    ("Client Opt"): phase 1 moves only filter branches; phase 2 moves
    output-only baskets for surviving ranges.
  * ``server_side``     — two-phase filtering on the storage server
    itself: no network for input baskets, but local reads are
    per-basket/on-demand (no TTreeCache batching — paper §4), adding
    request latency and stalling the decode pipeline.
  * ``near_data``       — SkimROOT: two-phase filtering next to storage
    (DPU analogue), coalesced high-bandwidth fetches, hardware-class
    (vectorized bitplane) decode, survivor-only output over the WAN.

Compute stages (decompress / deserialize / filter / write) are *measured*
on this host; link stages are *modeled* from accounted bytes via
:class:`NetworkModel` — the container has no real 1/10/100 Gb/s WAN, so the
byte accounting is exact and the time model is explicit (DESIGN.md §2c).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.planner import SkimPlan, plan_skim
from repro.core.query import Query, eval_stage, parse_query
from repro.data.store import EventStore, FetchStats


@dataclass
class NetworkModel:
    """Analytic link-time model: serialization + per-request round trips."""

    bandwidth_gbps: float = 1.0
    rtt_s: float = 0.001

    def transfer_time(self, nbytes: int, n_requests: int = 1) -> float:
        return nbytes * 8.0 / (self.bandwidth_gbps * 1e9) + n_requests * self.rtt_s


# Paper §4: "A 100 MB TTreeCache is used in all methods".
TTREECACHE_BYTES = 100 * 1024 * 1024

# Link tiers used throughout the evaluation (paper §4).
WAN_1G = NetworkModel(1.0, rtt_s=0.010)
LAN_10G = NetworkModel(10.0, rtt_s=0.001)
LAN_100G = NetworkModel(100.0, rtt_s=0.0005)
PCIE_128G = NetworkModel(128.0, rtt_s=0.00002)  # DPU<->host PCIe Gen3 x16
LOCAL_DISK = NetworkModel(16.0, rtt_s=0.0005)  # on-demand local reads, seek-y


@dataclass
class Breakdown:
    """Per-operation seconds; mirrors Fig. 4b / 5a."""

    fetch: float = 0.0  # input basket movement (modeled link / disk time)
    decompress: float = 0.0  # measured
    deserialize: float = 0.0  # measured
    filter: float = 0.0  # measured
    write: float = 0.0  # measured
    output_transfer: float = 0.0  # modeled (filtered file -> client)

    def total(self) -> float:
        return (
            self.fetch
            + self.decompress
            + self.deserialize
            + self.filter
            + self.write
            + self.output_transfer
        )

    def as_dict(self) -> dict:
        return {
            "fetch": self.fetch,
            "decompress": self.decompress,
            "deserialize": self.deserialize,
            "filter": self.filter,
            "write": self.write,
            "output_transfer": self.output_transfer,
            "total": self.total(),
        }


@dataclass
class SkimResult:
    mode: str
    output: EventStore
    n_input: int
    n_passed: int
    breakdown: Breakdown
    stats: FetchStats
    plan: SkimPlan
    busy_fraction: float = 1.0  # compute_time / total -> Fig. 5b proxy
    extras: dict = field(default_factory=dict)

    @property
    def selectivity(self) -> float:
        return self.n_passed / max(self.n_input, 1)


class _Timer:
    def __init__(self, breakdown: Breakdown, key: str):
        self.b, self.k = breakdown, key

    def __enter__(self):
        self.t0 = time.perf_counter()

    def __exit__(self, *exc):
        setattr(self.b, self.k, getattr(self.b, self.k) + time.perf_counter() - self.t0)


def _decode_branches(
    store: EventStore,
    names: list[str],
    start: int,
    stop: int,
    breakdown: Breakdown,
    stats: FetchStats,
    coalesce: bool,
    preloaded: dict[str, np.ndarray] | None = None,
) -> dict[str, np.ndarray]:
    """Fetch+decode a branch set for an event range; returns columnar data.

    Jagged branches come back as flat value arrays; counts branches carry
    the structure (the evaluator uses ``n<Coll>``).  ``preloaded`` supplies
    counts branches already decoded in an earlier stage.
    """
    data: dict[str, np.ndarray] = dict(preloaded or {})
    local = FetchStats()
    # counts branches must decode before jagged values they describe
    order = sorted(names, key=lambda n: 0 if not store.branches[n].jagged else 1)
    for name in order:
        blobs = store.fetch_range(name, start, stop, stats=local, coalesce=coalesce)
        parts = []
        with _Timer(breakdown, "decompress"):
            decoded = [store.decode_blob(name, blob) for _, blob in blobs]
        with _Timer(breakdown, "deserialize"):
            br = store.branches[name]
            for (meta, _), vals in zip(blobs, decoded):
                if not br.jagged:
                    lo = max(start - meta.first_entry, 0)
                    hi = min(stop - meta.first_entry, meta.n_entries)
                    parts.append(vals[lo:hi])
                else:
                    counts = data[br.counts_branch]
                    # basket-local event slice using already-decoded counts
                    b0 = max(start, meta.first_entry)
                    b1 = min(stop, meta.first_entry + meta.n_entries)
                    gc = counts[b0 - start : b1 - start].astype(np.int64)
                    # leading events of this basket that precede `start`
                    if meta.first_entry < start:
                        lead = store.read_flat(
                            br.counts_branch, meta.first_entry, start
                        ).astype(np.int64).sum()
                    else:
                        lead = 0
                    parts.append(vals[lead : lead + gc.sum()])
            data[name] = (
                np.concatenate(parts)
                if parts
                else np.empty(0, dtype=store.branches[name].np_dtype())
            )
    if coalesce:
        # TTreeCache model (paper §4: "a 100 MB TTreeCache is used in all
        # methods"): all baskets needed by this read round are aggregated
        # into bulk requests of up to the cache window.
        n_req = (
            max(1, -(-local.bytes_fetched // TTREECACHE_BYTES))
            if local.bytes_fetched
            else 0
        )
        stats.bytes_fetched += local.bytes_fetched
        stats.requests += n_req
        for k, v in local.by_branch.items():
            stats.by_branch[k] = stats.by_branch.get(k, 0) + v
    else:
        # on-demand local reads: one request (seek) per basket
        stats.merge(local)
    return data


def _rows_materialize(data: dict[str, np.ndarray], store, n: int) -> list:
    """Legacy deserialization: per-event row objects (the C++-object analogue).

    This is what makes unoptimized client-side filtering CPU-bound: every
    branch of every event becomes a Python-level object before the filter
    runs (paper: 240.4 s deserialize for LZ4 client-side).
    """
    offsets = {}
    for name, arr in data.items():
        br = store.branches.get(name)
        if br is not None and br.jagged:
            counts = data[br.counts_branch].astype(np.int64)
            offsets[name] = np.concatenate([[0], np.cumsum(counts)])
    rows = []
    for i in range(n):
        row = {}
        for name, arr in data.items():
            br = store.branches.get(name)
            if br is not None and br.jagged:
                off = offsets[name]
                row[name] = arr[off[i] : off[i + 1]]
            else:
                row[name] = arr[i]
        rows.append(row)
    return rows


def _select_columns(
    data: dict[str, np.ndarray], mask: np.ndarray, store
) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Apply an event mask to columnar data -> (columns, jagged map)."""
    cols: dict[str, np.ndarray] = {}
    jagged: dict[str, str] = {}
    for name, arr in data.items():
        br = store.branches.get(name)
        if br is not None and br.jagged:
            counts = data[br.counts_branch].astype(np.int64)
            obj_mask = np.repeat(mask, counts)
            cols[name] = arr[obj_mask]
            jagged[name] = br.counts_branch
        else:
            cols[name] = arr[mask]
    return cols, jagged


def _write_output(
    cols: dict, jagged: dict, store: EventStore, breakdown: Breakdown
) -> EventStore:
    with _Timer(breakdown, "write"):
        out = EventStore.from_arrays(
            cols, jagged=jagged, basket_events=store.basket_events, codec=store.codec
        )
    return out


class SkimEngine:
    """Runs a :class:`Query` against an :class:`EventStore` in one of the
    paper's four execution modes."""

    def __init__(
        self,
        store: EventStore,
        input_link: NetworkModel = WAN_1G,
        output_link: NetworkModel | None = None,
        chunk_events: int | None = None,
        decode_fn=None,
    ):
        self.store = store
        self.input_link = input_link
        self.output_link = output_link or input_link
        self.chunk_events = chunk_events or store.basket_events
        # near-data mode may plug in the Pallas/vectorized decoder
        self.decode_fn = decode_fn

    # -- public API ----------------------------------------------------------

    def run(self, query: Query | dict | str, mode: str = "near_data") -> SkimResult:
        if not isinstance(query, Query):
            query = parse_query(query)
        plan = plan_skim(query, self.store)
        if mode == "client_plain":
            return self._run_client_plain(plan)
        if mode == "client_opt":
            return self._run_two_phase(plan, mode, self.input_link, coalesce=True)
        if mode == "server_side":
            return self._run_two_phase(plan, mode, LOCAL_DISK, coalesce=False)
        if mode == "near_data":
            return self._run_two_phase(plan, mode, PCIE_128G, coalesce=True)
        raise ValueError(f"unknown mode {mode}")

    # -- legacy client-side (Fig. 2b) -----------------------------------------

    def _run_client_plain(self, plan: SkimPlan) -> SkimResult:
        store, b, stats = self.store, Breakdown(), FetchStats()
        n = store.n_events

        data = _decode_branches(
            store, plan.output_branches, 0, n, b, stats, coalesce=True
        )
        # legacy deserialization: build per-event rows for EVERY branch
        with _Timer(b, "deserialize"):
            rows = _rows_materialize(data, store, n)

        with _Timer(b, "filter"):
            mask = np.ones(n, dtype=bool)
            for _, stage in plan.query.stages():
                mask &= eval_stage(stage, data, n)
            del rows

        cols, jagged = _select_columns(data, mask, store)
        out = _write_output(cols, jagged, store, b)

        b.fetch = self.input_link.transfer_time(stats.bytes_fetched, stats.requests)
        b.output_transfer = 0.0  # filtering ran at the client already
        compute = b.decompress + b.deserialize + b.filter + b.write
        return SkimResult(
            "client_plain", out, n, int(mask.sum()), b, stats, plan,
            busy_fraction=compute / max(b.total(), 1e-12),
        )

    # -- two-phase model (client_opt / server_side / near_data) ---------------

    def _run_two_phase(
        self, plan: SkimPlan, mode: str, link: NetworkModel, coalesce: bool
    ) -> SkimResult:
        store, b, stats = self.store, Breakdown(), FetchStats()
        n = store.n_events
        chunk = self.chunk_events

        out_cols: dict[str, list] = {k: [] for k in plan.output_branches}
        jagged_map: dict[str, str] = {}
        n_passed = 0
        phase2_stats = FetchStats()

        for start in range(0, n, chunk):
            stop = min(start + chunk, n)
            m = stop - start
            # ---- phase 1: staged filter over filter-criteria branches ----
            mask = np.ones(m, dtype=bool)
            loaded: dict[str, np.ndarray] = {}
            for stage_name, stage in plan.query.stages():
                if not stage:
                    continue
                if not mask.any():
                    break  # hierarchical early discard: skip later stages
                need = [
                    x
                    for x in sorted(plan.query.stage_branches(stage_name))
                    if x not in loaded and x in store.branches
                ]
                from repro.core.branchmap import with_counts_branches

                need = [
                    x for x in with_counts_branches(need, store) if x not in loaded
                ]
                loaded.update(
                    _decode_branches(
                        store, need, start, stop, b, stats, coalesce, preloaded=loaded
                    )
                )
                with _Timer(b, "filter"):
                    mask &= eval_stage(stage, loaded, m)

            k = int(mask.sum())
            if k == 0:
                continue
            n_passed += k

            # ---- phase 2: output-only branches, survivors only ----
            need2 = [x for x in plan.output_only_branches if x not in loaded]
            data2 = _decode_branches(
                store, need2, start, stop, b, phase2_stats, coalesce, preloaded=loaded
            )
            full = {**loaded, **data2}
            with _Timer(b, "deserialize"):
                cols, jagged = _select_columns(
                    {k2: full[k2] for k2 in plan.output_branches}, mask, store
                )
            jagged_map.update(jagged)
            for k2, v in cols.items():
                out_cols[k2].append(v)

        stats.merge(phase2_stats)

        with _Timer(b, "write"):
            if n_passed:
                cat = {
                    k2: np.concatenate(v) if v else np.empty(0)
                    for k2, v in out_cols.items()
                }
            else:
                cat = {
                    k2: np.empty(0, dtype=store.branches[k2].np_dtype())
                    for k2 in plan.output_branches
                }
        out = _write_output(cat, jagged_map, store, b)

        b.fetch = link.transfer_time(stats.bytes_fetched, stats.requests)
        out_bytes = out.compressed_bytes()
        if mode in ("server_side", "near_data"):
            # the filtered file crosses the WAN back to the client
            b.output_transfer = self.output_link.transfer_time(out_bytes, 1)
        compute = b.decompress + b.deserialize + b.filter + b.write
        # beyond-paper: double-buffered basket prefetch (the paper's
        # "advanced data prefetching" future work) — with fetch of chunk
        # i+1 overlapping compute of chunk i, the pipeline bound is
        # max(fetch, compute) instead of their sum.
        overlap_total = (
            max(b.fetch, b.decompress + b.deserialize + b.filter)
            + b.write
            + b.output_transfer
        )
        return SkimResult(
            mode, out, n, n_passed, b, stats, plan,
            busy_fraction=compute / max(b.total(), 1e-12),
            extras={"output_bytes": out_bytes, "overlap_total": overlap_total},
        )


def run_skim(
    store: EventStore,
    query: Query | dict | str,
    mode: str = "near_data",
    input_link: NetworkModel = WAN_1G,
    output_link: NetworkModel | None = None,
) -> SkimResult:
    return SkimEngine(store, input_link, output_link).run(query, mode)
