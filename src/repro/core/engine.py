"""Skim execution engine — reproduces the paper's four compared systems.

Modes (Fig. 4/5 of the paper):

  * ``client_plain``    — legacy client-side filtering: every selected
    branch's baskets cross the network for every event; everything is
    decompressed and deserialized before filtering (Fig. 2b).
  * ``client_opt``      — client-side with SkimROOT's two-phase model
    ("Client Opt"): phase 1 moves only filter branches; phase 2 moves
    output-only baskets for surviving ranges.
  * ``server_side``     — two-phase filtering on the storage server
    itself: no network for input baskets, but local reads are
    per-basket/on-demand (no TTreeCache batching — paper §4), adding
    request latency and stalling the decode pipeline.
  * ``near_data``       — SkimROOT: two-phase filtering next to storage
    (DPU analogue), coalesced high-bandwidth fetches, hardware-class
    (vectorized bitplane) decode, survivor-only output over the WAN.

``near_data`` additionally runs the **pipelined fused executor** by
default (DESIGN.md §4): the coalesced fetch + decode of basket window
*i+1* overlaps filtering of window *i* (double-buffered; modeled from
exact per-window records by default, realized by the
:class:`repro.data.store.WindowPrefetcher` worker thread with
``pipeline="threads"``), and phase 1 evaluates the query as a compiled
predicate program fused with stream compaction — the Pallas VMEM kernel
``repro.kernels.skim_fused`` on TPU, the jagged-layout program
interpreter on plain CPUs.  ``fused=False`` / ``pipeline=False`` select
the reference two-pass serial path; all paths produce bit-identical
survivor sets and outputs.

Compute stages (decompress / deserialize / filter / write) are *measured*
on this host; link stages are *modeled* from accounted bytes via
:class:`NetworkModel` — the container has no real 1/10/100 Gb/s WAN, so the
byte accounting is exact and the time model is explicit (DESIGN.md §2c).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.planner import SkimPlan, plan_skim
from repro.core.query import Query, eval_stage, parse_query
from repro.core.zonemap import ACCEPT_ALL, PRUNE, SCAN
from repro.data.store import (
    TTREECACHE_BYTES,  # noqa: F401  (re-export; serve + tests import via engine)
    EventStore,
    FetchStats,
    WindowPrefetcher,
    coalesced_requests,
)
from repro.obs.schema import SkimReport
from repro.obs.trace import NULL_TRACER


@dataclass
class NetworkModel:
    """Analytic link-time model: serialization + per-request round trips."""

    bandwidth_gbps: float = 1.0
    rtt_s: float = 0.001

    def transfer_time(self, nbytes: int, n_requests: int = 1) -> float:
        return nbytes * 8.0 / (self.bandwidth_gbps * 1e9) + n_requests * self.rtt_s


# Link tiers used throughout the evaluation (paper §4; DESIGN.md §2c).
WAN_1G = NetworkModel(1.0, rtt_s=0.010)
LAN_10G = NetworkModel(10.0, rtt_s=0.001)
LAN_100G = NetworkModel(100.0, rtt_s=0.0005)
PCIE_128G = NetworkModel(128.0, rtt_s=0.00002)  # DPU<->host PCIe Gen3 x16
LOCAL_DISK = NetworkModel(16.0, rtt_s=0.0005)  # on-demand local reads, seek-y


@dataclass
class Breakdown:
    """Per-operation seconds; mirrors Fig. 4b / 5a."""

    fetch: float = 0.0  # input basket movement (modeled link / disk time)
    decompress: float = 0.0  # measured
    deserialize: float = 0.0  # measured
    filter: float = 0.0  # measured
    write: float = 0.0  # measured
    output_transfer: float = 0.0  # modeled (filtered file -> client)

    def total(self) -> float:
        return (
            self.fetch
            + self.decompress
            + self.deserialize
            + self.filter
            + self.write
            + self.output_transfer
        )

    def as_dict(self) -> dict:
        return {
            "fetch": self.fetch,
            "decompress": self.decompress,
            "deserialize": self.deserialize,
            "filter": self.filter,
            "write": self.write,
            "output_transfer": self.output_transfer,
            "total": self.total(),
        }

    def merge(self, other: "Breakdown") -> None:
        """Accumulate another breakdown (per-window accounting merge)."""
        self.fetch += other.fetch
        self.decompress += other.decompress
        self.deserialize += other.deserialize
        self.filter += other.filter
        self.write += other.write
        self.output_transfer += other.output_transfer

    @classmethod
    def merged(cls, parts: "list[Breakdown]") -> "Breakdown":
        """Sum a sequence of breakdowns into a fresh object (the cluster
        coordinator's gather contract — inputs are left untouched)."""
        out = cls()
        for p in parts:
            out.merge(p)
        return out


@dataclass
class SkimResult:
    mode: str
    output: EventStore
    n_input: int
    n_passed: int
    breakdown: Breakdown
    stats: FetchStats
    plan: SkimPlan
    busy_fraction: float = 1.0  # compute_time / total -> Fig. 5b proxy
    extras: dict = field(default_factory=dict)
    # structured form of `extras` (repro.obs.schema.SkimReport); extras
    # is rendered FROM it via the compatibility shim and stays the
    # read-side contract for existing callers
    report: object = None

    @property
    def selectivity(self) -> float:
        return self.n_passed / max(self.n_input, 1)


@dataclass
class WindowPartial:
    """One basket window's completed ledger entry, streamed mid-skim.

    The executor yields one of these per window, in window order, as soon
    as that window's phase 2 finishes (DESIGN.md §12).  ``cols`` holds the
    window's survivor columns exactly as they will be concatenated into
    the final output — so the union of a run's partials is bit-identical
    to the synchronous result by construction.  ``n_passed == 0`` windows
    still stream (empty ``cols``): the ledger entry is the progress
    signal.
    """

    index: int  # window ordinal (0-based, ascending)
    start: int
    stop: int
    n_passed: int
    cols: dict  # branch -> survivor array ({} when nothing passed)
    jagged: dict  # jagged branch -> counts branch, for `cols`
    decision: str = SCAN  # zone-map kind this window resolved as


def drain(gen):
    """Drive a partial-yielding executor generator to its final result.

    The streaming executors are generators that yield
    :class:`WindowPartial` (or the shared-scan batch equivalent) per
    window and *return* the final result object — ``drain`` is the
    synchronous caller's one-liner to discard the stream and keep the
    result.
    """
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


class _Timer:
    def __init__(self, breakdown: Breakdown, key: str):
        self.b, self.k = breakdown, key

    def __enter__(self):
        self.t0 = time.perf_counter()

    def __exit__(self, *exc):
        setattr(self.b, self.k, getattr(self.b, self.k) + time.perf_counter() - self.t0)


def _decode_branches(
    store: EventStore,
    names: list[str],
    start: int,
    stop: int,
    breakdown: Breakdown,
    stats: FetchStats,
    coalesce: bool,
    preloaded: dict[str, np.ndarray] | None = None,
    tracer=None,
) -> dict[str, np.ndarray]:
    """Fetch+decode a branch set for an event range; returns columnar data.

    Jagged branches come back as flat value arrays; counts branches carry
    the structure (the evaluator uses ``n<Coll>``).  ``preloaded`` supplies
    counts branches already decoded in an earlier stage.
    """
    tr = tracer if tracer is not None else NULL_TRACER
    data: dict[str, np.ndarray] = dict(preloaded or {})
    # counts branches must decode before jagged values they describe
    order = sorted(names, key=lambda n: 0 if not store.branches[n].jagged else 1)
    # one coalesced read round for the whole branch set (TTreeCache model;
    # the store owns the request accounting — DESIGN.md §2b)
    fsid = tr.begin("fetch", kind="fetch", branches=len(order))
    window = store.fetch_window(order, start, stop, stats=stats, coalesce=coalesce)
    tr.end(fsid, bytes=stats.bytes_fetched)
    # decode spans name their tier: "decode_device" when the store's
    # backend-selected batch decode runs on the accelerator (bitpack
    # planes crossing the host->device boundary compressed, DESIGN.md §16)
    dkind = (
        "decode_device"
        if store.resolved_decode_backend() == "device"
        and store.codec == "bitpack"
        else "decode"
    )
    dsid = tr.begin("decode", kind=dkind)
    for name in order:
        blobs = window[name]
        parts = []
        with _Timer(breakdown, "decompress"):
            decoded = store.decode_blobs(name, [blob for _, blob in blobs])
        with _Timer(breakdown, "deserialize"):
            br = store.branches[name]
            for (meta, _), vals in zip(blobs, decoded):
                if not br.jagged:
                    lo = max(start - meta.first_entry, 0)
                    hi = min(stop - meta.first_entry, meta.n_entries)
                    parts.append(vals[lo:hi])
                else:
                    counts = data[br.counts_branch]
                    # basket-local event slice using already-decoded counts
                    b0 = max(start, meta.first_entry)
                    b1 = min(stop, meta.first_entry + meta.n_entries)
                    gc = counts[b0 - start : b1 - start].astype(np.int64)
                    # leading events of this basket that precede `start`
                    if meta.first_entry < start:
                        lead = store.read_flat(
                            br.counts_branch, meta.first_entry, start
                        ).astype(np.int64).sum()
                    else:
                        lead = 0
                    parts.append(vals[lead : lead + gc.sum()])
            data[name] = (
                np.concatenate(parts)
                if parts
                else np.empty(0, dtype=store.branches[name].np_dtype())
            )
    tr.end(dsid)
    return data


def _skipped_requests(nbytes: int, n_baskets: int, coalesce: bool) -> int:
    """Requests a skipped fetch round would have issued — the store's
    TTreeCache request model (:func:`repro.data.store.coalesced_requests`),
    re-exported under the pricing-side name."""
    return coalesced_requests(nbytes, n_baskets, coalesce)


def _pipeline_schedule(
    records: list[dict], link: NetworkModel, depth: int = 2
) -> float:
    """Exact event-driven schedule of the double-buffered executor.

    One load worker (modeled link fetch + measured decode per window)
    runs ahead of one process worker (measured filter + phase-2 fetch and
    compute), with at most ``depth`` windows in flight — load of window
    ``i`` cannot start before window ``i - depth`` finished processing.
    Returns the makespan of the window loop; the serial equivalent is the
    plain sum of all stage terms (DESIGN.md §4b).
    """
    load_free = 0.0  # when the load worker is next available
    proc_free = 0.0  # when the process worker is next available
    done: list[float] = []  # per-window processing completion times
    for i, r in enumerate(records):
        load_t = (
            link.transfer_time(r["load_bytes"], r["load_requests"])
            + r["load_compute"]
        )
        start = load_free if i < depth else max(load_free, done[i - depth])
        load_done = start + load_t
        proc_t = r.get("proc_compute", 0.0) + link.transfer_time(
            r.get("p2_bytes", 0), r.get("p2_requests", 0)
        )
        proc_free = max(proc_free, load_done) + proc_t
        done.append(proc_free)
        load_free = load_done
    return proc_free


def _window_phase2(
    store,
    plan: SkimPlan,
    start: int,
    stop: int,
    mask: np.ndarray,
    dev_cols: dict,
    loaded: dict,
    breakdown: Breakdown,
    stats: FetchStats,
    coalesce: bool,
    tracer=None,
) -> tuple[dict, dict]:
    """Phase 2 for one surviving window: fetch the output-only branches and
    select survivor columns (shared by the single-query executor and the
    shared-scan service — the two must stay bit-identical).

    The fetch set is every output branch not already decoded: for scanned
    windows ``loaded`` holds the filter branches, so this is exactly the
    output-only set; for zone-map *accept-all* windows nothing was loaded
    in phase 1 and the whole output set moves here in one round
    (DESIGN.md §9)."""
    need2 = [x for x in plan.output_branches if x not in loaded]
    data2 = _decode_branches(
        store, need2, start, stop, breakdown, stats, coalesce, preloaded=loaded,
        tracer=tracer,
    )
    full = {**loaded, **data2}
    with _Timer(breakdown, "deserialize"):
        cols, jagged = _select_columns(
            {k2: full[k2] for k2 in plan.output_branches if k2 not in dev_cols},
            mask,
            store,
        )
        # payload columns come straight off the fused kernel, already
        # survivor-compacted (bit-identical to arr[mask])
        cols.update(dev_cols)
    return cols, jagged


def _concat_output(out_cols: dict, n_passed: int, plan: SkimPlan, store) -> dict:
    """Concatenate per-window survivor columns (empty-output dtype fallback
    included)."""
    if n_passed:
        return {
            k2: np.concatenate(v) if v else np.empty(0)
            for k2, v in out_cols.items()
        }
    return {
        k2: np.empty(0, dtype=store.branches[k2].np_dtype())
        for k2 in plan.output_branches
    }


def _rows_materialize(data: dict[str, np.ndarray], store, n: int) -> list:
    """Legacy deserialization: per-event row objects (the C++-object analogue).

    This is what makes unoptimized client-side filtering CPU-bound: every
    branch of every event becomes a Python-level object before the filter
    runs (paper: 240.4 s deserialize for LZ4 client-side).
    """
    offsets = {}
    for name in data:
        br = store.branches.get(name)
        if br is not None and br.jagged:
            counts = data[br.counts_branch].astype(np.int64)
            offsets[name] = np.concatenate([[0], np.cumsum(counts)])
    rows = []
    for i in range(n):
        row = {}
        for name, arr in data.items():
            br = store.branches.get(name)
            if br is not None and br.jagged:
                off = offsets[name]
                row[name] = arr[off[i] : off[i + 1]]
            else:
                row[name] = arr[i]
        rows.append(row)
    return rows


def _select_columns(
    data: dict[str, np.ndarray], mask: np.ndarray, store
) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Apply an event mask to columnar data -> (columns, jagged map)."""
    cols: dict[str, np.ndarray] = {}
    jagged: dict[str, str] = {}
    for name, arr in data.items():
        br = store.branches.get(name)
        if br is not None and br.jagged:
            counts = data[br.counts_branch].astype(np.int64)
            obj_mask = np.repeat(mask, counts)
            cols[name] = arr[obj_mask]
            jagged[name] = br.counts_branch
        else:
            cols[name] = arr[mask]
    return cols, jagged


def _write_output(
    cols: dict, jagged: dict, store: EventStore, breakdown: Breakdown
) -> EventStore:
    with _Timer(breakdown, "write"):
        out = EventStore.from_arrays(
            cols, jagged=jagged, basket_events=store.basket_events, codec=store.codec
        )
    return out


class SkimEngine:
    """Runs a :class:`Query` against an :class:`EventStore` in one of the
    paper's four execution modes.

    ``fused`` / ``pipeline`` control the ``near_data`` executor only (the
    DPU analogue is where the fast path lives): ``fused=True`` evaluates
    the compiled predicate + stream compaction as one pass per window on
    the backend's best executor, and ``pipeline`` double-buffers window
    fetch+decode behind filtering — ``True`` runs the serial schedule and
    computes the exact double-buffered makespan from per-window records
    (``extras["pipeline_total"]``; compute stages stay cleanly
    measurable), ``"threads"`` additionally runs the real
    :class:`~repro.data.store.WindowPrefetcher` worker (wall-clock
    overlap on hosts with spare cores; stage timings then include
    contention).  The other three modes always run the reference serial
    paths so the paper comparison stays honest.

    Note: any fused or pipelined configuration preloads *all* filter
    branches per window (one coalesced TTreeCache round), trading the
    staged evaluator's early-discard byte savings for batched I/O — so
    byte accounting differs slightly from the lazy staged path when
    whole windows die at an early stage.  The seed-exact reference for
    accounting comparisons is ``fused=False, pipeline=False``.
    """

    def __init__(
        self,
        store: EventStore,
        input_link: NetworkModel = WAN_1G,
        output_link: NetworkModel | None = None,
        chunk_events: int | None = None,
        decode_fn=None,
        fused: bool = True,
        pipeline: bool | str = True,
        near_input_link: NetworkModel = PCIE_128G,
        prune: bool = True,
        cascade: bool = True,
        tracer=None,
        device_batch: int | None = None,
        fused_backend: str | None = None,
    ):
        self.store = store
        self.input_link = input_link
        self.output_link = output_link or input_link
        self.chunk_events = chunk_events or store.basket_events
        # near-data mode may plug in the Pallas/vectorized decoder
        self.decode_fn = decode_fn
        self.fused = fused
        self.pipeline = pipeline
        # what the DPU analogue reads its input baskets over: PCIe Gen3
        # x16 by default, or an SSD-class tier (e.g. LOCAL_DISK) to model
        # near-storage fetch that the prefetcher actually has to hide
        self.near_input_link = near_input_link
        # zone-map predicate pushdown (DESIGN.md §9): classify each basket
        # window from encode-time stats and skip fetch+decode for windows
        # provably empty (or provably all-surviving).  ``False`` is the
        # reference path every pruned run must stay bit-identical to.
        self.prune = prune
        # cascaded phase-1 execution (DESIGN.md §11): run the fused
        # near-data phase 1 as a cost-ordered cascade of per-node stages,
        # fetching each stage's branches only for baskets still alive.
        # ``False`` restores the PR-4 full-preload path (the accounting
        # reference), bit-identical on survivors either way.
        self.cascade = cascade
        # default span sink (repro.obs.trace); the no-op tracer unless a
        # caller opts in — per-call ``tracer=`` overrides take precedence
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # device-resident batched cascade (DESIGN.md §16): group this many
        # cascaded SCAN windows per device dispatch — O(windows/B) stage
        # dispatches instead of O(windows), with survivor masks living on
        # device between stages.  ``None``/1 keeps the per-window path.
        if device_batch is not None and int(device_batch) < 1:
            raise ValueError(f"device_batch must be >= 1, got {device_batch}")
        self.device_batch = int(device_batch) if device_batch else None
        # forced fused-evaluator backend ("pallas"/"xla"/"host"); ``None``
        # resolves per backend (pallas on TPU, host interpreter elsewhere)
        if fused_backend not in (None, "pallas", "xla", "host"):
            raise ValueError(f"unknown fused backend {fused_backend!r}")
        self.fused_backend = fused_backend

    # -- public API ----------------------------------------------------------

    def run(
        self,
        query: Query | dict | str,
        mode: str = "near_data",
        fused: bool | None = None,
        pipeline: bool | str | None = None,
        prune: bool | None = None,
        cascade: bool | None = None,
        tracer=None,
    ) -> SkimResult:
        plan, args = self._prepare(
            query, mode, fused, pipeline, prune, cascade, tracer
        )
        if args is None:  # client_plain: the one-pass legacy path
            return self._run_client_plain(plan)
        return drain(self._iter_two_phase(plan, **args))

    def iter_run(
        self,
        query: Query | dict | str,
        mode: str = "near_data",
        fused: bool | None = None,
        pipeline: bool | str | None = None,
        prune: bool | None = None,
        cascade: bool | None = None,
        tracer=None,
    ):
        """Streaming form of :meth:`run`: a generator yielding one
        :class:`WindowPartial` per basket window as its ledger entry
        completes, and *returning* the final :class:`SkimResult` (via
        ``StopIteration.value``; :func:`drain` recovers it).

        This is the cooperative execution surface the async job service
        schedules on (DESIGN.md §12): window boundaries are the
        cancellation points, and the stream of partials is the partial-
        result feed.  Identical accounting and output to :meth:`run` by
        construction — ``run`` is ``drain(iter_run(...))``.
        ``client_plain`` has no window loop and cannot stream.
        """
        plan, args = self._prepare(
            query, mode, fused, pipeline, prune, cascade, tracer
        )
        if args is None:
            raise ValueError("client_plain is a one-pass mode; nothing to stream")
        return self._iter_two_phase(plan, **args)

    def _prepare(
        self,
        query: Query | dict | str,
        mode: str,
        fused: bool | None,
        pipeline: bool | str | None,
        prune: bool | None,
        cascade: bool | None,
        tracer=None,
    ) -> tuple[SkimPlan, dict | None]:
        """Shared argument resolution + planning for run / iter_run.

        Returns ``(plan, two_phase_kwargs)``; ``None`` kwargs means
        client_plain (the legacy one-pass path)."""
        tr = tracer if tracer is not None else self.tracer
        if not isinstance(query, Query):
            query = parse_query(query)
        do_prune = (self.prune if prune is None else bool(prune)) and (
            mode != "client_plain"  # full-scan legacy mode: nothing to push down
        )
        use_fused = self.fused if fused is None else fused
        # cascade resolution: explicit call arg > query flag > engine
        # default; the cascade lives on the near-data fused fast path
        # only (the other modes are the paper's fixed comparison points)
        if cascade is None:
            cascade = query.cascade if query.cascade is not None else self.cascade
        do_cascade = bool(cascade) and mode == "near_data" and use_fused
        plan_t0 = tr.now()
        plan = plan_skim(
            query, self.store, window_events=self.chunk_events, prune=do_prune,
            cascade=do_cascade,
        )
        plan_t = (plan_t0, tr.now())
        if mode == "client_plain":
            return plan, None
        if mode == "client_opt":
            return plan, dict(
                mode=mode, link=self.input_link, coalesce=True,
                tracer=tr, plan_t=plan_t,
            )
        if mode == "server_side":
            return plan, dict(
                mode=mode, link=LOCAL_DISK, coalesce=False,
                tracer=tr, plan_t=plan_t,
            )
        if mode == "near_data":
            prefetch = self.pipeline if pipeline is None else pipeline
            if prefetch not in (False, True, "threads"):
                raise ValueError(
                    f"pipeline must be False, True, or 'threads', got {prefetch!r}"
                )
            return plan, dict(
                mode=mode, link=self.near_input_link, coalesce=True,
                fused=use_fused, prefetch=prefetch,
                tracer=tr, plan_t=plan_t,
            )
        raise ValueError(f"unknown mode {mode}")

    # -- legacy client-side (Fig. 2b) -----------------------------------------

    def _run_client_plain(self, plan: SkimPlan) -> SkimResult:
        store, b, stats = self.store, Breakdown(), FetchStats()
        n = store.n_events

        data = _decode_branches(
            store, plan.output_branches, 0, n, b, stats, coalesce=True
        )
        # legacy deserialization: build per-event rows for EVERY branch
        with _Timer(b, "deserialize"):
            rows = _rows_materialize(data, store, n)

        with _Timer(b, "filter"):
            mask = np.ones(n, dtype=bool)
            for _, stage in plan.query.stages():
                mask &= eval_stage(stage, data, n)
            del rows

        cols, jagged = _select_columns(data, mask, store)
        out = _write_output(cols, jagged, store, b)

        b.fetch = self.input_link.transfer_time(stats.bytes_fetched, stats.requests)
        b.output_transfer = 0.0  # filtering ran at the client already
        compute = b.decompress + b.deserialize + b.filter + b.write
        return SkimResult(
            "client_plain", out, n, int(mask.sum()), b, stats, plan,
            busy_fraction=compute / max(b.total(), 1e-12),
        )

    # -- two-phase model (client_opt / server_side / near_data) ---------------

    def _iter_two_phase(
        self,
        plan: SkimPlan,
        mode: str,
        link: NetworkModel,
        coalesce: bool,
        fused: bool = False,
        prefetch: bool | str = False,
        tracer=None,
        plan_t: tuple | None = None,
    ):
        """Generator core of the two-phase executor: yields a
        :class:`WindowPartial` per window, returns the :class:`SkimResult`."""
        tracer = tracer if tracer is not None else NULL_TRACER
        store, b, stats = self.store, Breakdown(), FetchStats()
        n = store.n_events
        chunk = self.chunk_events

        # the query root span stays open across the whole generator; each
        # child span closes before the window's partial yields, so a
        # consumer observing the stream never sees a half-open child
        qsid = tracer.begin(
            "query", kind="query", mode=mode, n_events=n, fused=fused
        )
        if plan_t is not None:
            tracer.add_span("plan", kind="plan", t0=plan_t[0], t1=plan_t[1])

        out_cols: dict[str, list] = {k: [] for k in plan.output_branches}
        jagged_map: dict[str, str] = {}
        n_passed = 0
        phase2_stats = FetchStats()

        program = plan.compiled_program() if fused else None
        if fused:
            # one-time executor warm-up (module imports + backend init)
            # outside the stage timers: measured stages report steady-state
            # compute, not interpreter start-up (DESIGN.md §2c)
            import jax

            from repro.kernels import ops  # noqa: F401

            jax.default_backend()
        # cascaded phase 1 (DESIGN.md §11): one executor per run owns the
        # adaptive stage order; the prefetcher loads only the pinned head
        # stage, later stages fetch alive baskets on demand
        cascade_exec = None
        dispatches0 = None
        if fused:
            from repro.kernels.ops import dispatch_stats

            dispatches0 = dispatch_stats()["dispatches"]
        if fused and plan.cascade is not None:
            from repro.core.plan import CascadeExecutor, mark_fetched

            cascade_exec = CascadeExecutor(
                plan, store, coalesce=coalesce, tracer=tracer,
                backend=self.fused_backend,
            )
        use_threads = prefetch == "threads"
        preload = fused or bool(prefetch)
        # zone-map decisions (DESIGN.md §9): one per chunk window, or None
        # when pruning is off / nothing was provable
        decisions = plan.window_decisions
        # per-window load/process records feeding the explicit pipeline
        # schedule model (DESIGN.md §4b)
        win_records: list[dict] = []

        def load_window(start: int, stop: int):
            """Fetch + decode one window's filter branches (in "threads"
            mode this runs in the prefetch worker; all accounting is
            window-local and merged in window order by the consumer, so
            pipelined byte/request stats are identical to the serial
            schedule).  Zone-map decided windows (DESIGN.md §9): *prune*
            never touches the store at all; *accept-all* loads the full
            output set instead — every event survives, so the one
            coalesced round that phase 2 would pay moves into the load
            stage and keeps the double-buffered overlap."""
            kind = (
                decisions[start // chunk].decision
                if decisions is not None
                else SCAN
            )
            if kind == PRUNE:
                return None, Breakdown(), FetchStats()
            if kind != SCAN:
                names = plan.output_branches
            elif cascade_exec is not None:
                # cascaded phase 1: prefetch ONLY the pinned head stage;
                # the remaining stages fetch alive baskets on demand in
                # the process step (DESIGN.md §11)
                names = cascade_exec.head_branches
            else:
                names = plan.filter_branches
            lb, ls = Breakdown(), FetchStats()
            # the prefetch worker thread must not touch the consumer's
            # span stack; its loads go untraced in "threads" mode (the
            # serial schedules trace them as load_window spans)
            ltr = NULL_TRACER if use_threads else tracer
            lsid = ltr.begin("load_window", kind="fetch", window=start // chunk)
            data = _decode_branches(
                store, names, start, stop, lb, ls, coalesce, tracer=ltr
            )
            ltr.end(lsid, bytes=ls.bytes_fetched)
            return data, lb, ls

        def windows():
            if preload:
                # all filter branches move in one coalesced round per
                # window (the paper's TTreeCache batching); in "threads"
                # mode the prefetcher decodes window i+1 while window i
                # filters
                src = WindowPrefetcher(n, chunk, load_window, enabled=use_threads)
                for start, stop, (data, lb, ls) in src:
                    b.merge(lb)
                    stats.merge(ls)
                    win_records.append(
                        {
                            "load_bytes": ls.bytes_fetched,
                            "load_requests": ls.requests,
                            "load_compute": lb.decompress + lb.deserialize,
                        }
                    )
                    yield start, stop, data
            else:
                for start in range(0, n, chunk):
                    yield start, min(start + chunk, n), None

        # device-batched cascade grouping (DESIGN.md §16): consume SCAN
        # windows in groups of ``device_batch``, run the cascade ONCE per
        # group (one device dispatch per stage per group, survivor masks
        # device-resident between stages), then replay the precomputed
        # outcomes through the unchanged per-window ledger loop below.
        # Zone-map decided windows pass through unbatched — they never
        # evaluate the cascade at all.
        batch_n = self.device_batch if cascade_exec is not None else None
        pending: dict[int, tuple] = {}

        def window_items():
            src = enumerate(windows())
            if not batch_n or batch_n <= 1:
                yield from src
                return
            buf: list = []

            def flush():
                if not buf:
                    return
                entries, metas = [], []
                for _wi, (start_, stop_, preloaded_) in buf:
                    wb_, w1s_, ledger_ = Breakdown(), FetchStats(), {}
                    mark_fetched(
                        store, cascade_exec.head_branches, start_, stop_,
                        ledger_,
                    )
                    entries.append(
                        (start_, stop_, preloaded_, wb_, w1s_, ledger_)
                    )
                    metas.append((wb_, w1s_, ledger_))
                outs = cascade_exec.run_window_batch(entries, pad_B=batch_n)
                for (_wi, _win), out, meta in zip(buf, outs, metas):
                    pending[_wi] = (out, *meta)
                items = list(buf)
                buf.clear()
                yield from items

            for item in src:
                kind_ = (
                    decisions[item[0]].decision
                    if decisions is not None
                    else SCAN
                )
                if kind_ == SCAN:
                    buf.append(item)
                    if len(buf) == batch_n:
                        yield from flush()
                else:
                    yield from flush()
                    yield item
            yield from flush()

        # per-window survivor ledger: (start, stop, n_passed) for EVERY
        # window, survivors or not — the mergeable-result contract the
        # cluster coordinator splits shard outputs with (DESIGN.md §5)
        window_rows: list[tuple[int, int, int]] = []
        t_phase = time.perf_counter()
        pad_K = 0  # grows monotonically so padded shapes (and compiled
        # kernels) stay stable across windows once the max multiplicity
        # has been seen
        for wi, (start, stop, preloaded) in window_items():
            m = stop - start
            dec = decisions[wi] if decisions is not None else None
            kind = dec.decision if dec is not None else SCAN
            wsid = tracer.begin(
                f"window[{wi}]", kind="window", index=wi, decision=kind
            )
            dev_cols: dict[str, np.ndarray] = {}
            # window-local processing breakdown/stats (merged into the
            # run totals below; also feeds the pipeline schedule model)
            wb, w2s = Breakdown(), FetchStats()
            # cascade per-window state: the basket dedup ledger and the
            # window outcome (None on the non-cascaded paths)
            ledger: dict[str, set] = {}
            outcome = None
            w1s = FetchStats()
            if kind == PRUNE:
                # provably no survivor: phase 1 AND phase 2 never happen;
                # account what the skipped fetch round would have moved
                stats.skip(
                    dec.p1_bytes,
                    _skipped_requests(dec.p1_bytes, dec.p1_baskets, coalesce),
                )
                loaded = {}
                mask = np.zeros(m, dtype=bool)
            elif kind == ACCEPT_ALL:
                # provably all survive: skip predicate fetch+eval — the
                # output set moves in ONE round (preloaded in the load
                # stage when pipelining, fetched by phase 2 below
                # otherwise); filter-only branches never move at all
                stats.skip(
                    dec.extra_bytes,
                    0 if coalesce else dec.extra_baskets,
                )
                loaded = preloaded if preloaded is not None else {}
                mask = np.ones(m, dtype=bool)
            elif cascade_exec is not None:
                # ---- phase 1 (cascaded path, DESIGN.md §11): stages run
                # cheapest-and-most-selective-first; stage k fetches its
                # branches only for baskets still alive after stage k-1 ----
                loaded = {}
                if wi in pending:
                    # batched path: the cascade already ran for this
                    # window's group — adopt its outcome and per-window
                    # ledgers (byte/time accounting is window-local in
                    # the batch too, so totals match the per-window path)
                    outcome, cwb, w1s, ledger = pending.pop(wi)
                    wb.merge(cwb)
                else:
                    mark_fetched(
                        store, cascade_exec.head_branches, start, stop, ledger
                    )
                    outcome = cascade_exec.run_window(
                        start, stop, preloaded, wb, w1s, ledger=ledger
                    )
                mask = outcome.mask
                stats.merge(w1s)
            elif fused:
                # ---- phase 1 (fused path): one pass evaluates the
                # compiled predicate AND compacts [index]+payload rows ----
                from repro.core.neardata import (
                    fused_window_skim,
                    program_eval_np,
                    window_pad_K,
                )

                loaded = preloaded
                if not plan.filter_branches:
                    # no present branch feeds the predicate: the program is
                    # constant — all-true for a selection-free projection,
                    # all-false when only absent-era trigger ORs remain
                    mask = program_eval_np(loaded or {}, program, m)
                else:
                    pad_K = max(pad_K, window_pad_K(loaded, program, store))
                    ksid = tracer.begin("kernel", kind="kernel", window=wi)
                    with _Timer(wb, "filter"):
                        mask, dev_cols = fused_window_skim(
                            loaded, program, store,
                            payload_branches=plan.payload_branches,
                            K=pad_K,
                            pad_to=chunk,
                            backend=self.fused_backend,
                        )
                    tracer.end(ksid)
            else:
                # ---- phase 1: staged filter over filter-criteria branches ----
                mask = np.ones(m, dtype=bool)
                loaded = dict(preloaded) if preloaded is not None else {}
                for stage_name, stage in plan.query.stages():
                    if not stage:
                        continue
                    if not mask.any():
                        break  # hierarchical early discard: skip later stages
                    need = [
                        x
                        for x in sorted(plan.query.stage_branches(stage_name))
                        if x not in loaded and x in store.branches
                    ]
                    from repro.core.branchmap import with_counts_branches

                    need = [
                        x for x in with_counts_branches(need, store) if x not in loaded
                    ]
                    loaded.update(
                        _decode_branches(
                            store, need, start, stop, wb, stats, coalesce,
                            preloaded=loaded,
                        )
                    )
                    with _Timer(wb, "filter"):
                        mask &= eval_stage(stage, loaded, m)

            k = int(mask.sum())
            window_rows.append((start, stop, k))
            part_cols: dict = {}
            part_jagged: dict = {}
            if k:
                n_passed += k
                p2sid = tracer.begin("phase2", kind="fetch", window=wi)
                if outcome is not None:
                    # ---- phase 2 (cascaded window): the basket ledger
                    # dedups against phase 1, so filter∩output branches a
                    # stage already moved are not paid again ----
                    known = {**(preloaded or {}), **outcome.full_loaded}
                    full = cascade_exec.fetch_full(
                        plan.output_branches, start, stop, wb, w2s, ledger,
                        known=known,
                    )
                    with _Timer(wb, "deserialize"):
                        cols, jagged = _select_columns(
                            {k2: full[k2] for k2 in plan.output_branches},
                            mask, store,
                        )
                else:
                    # ---- phase 2: output-only branches, survivors only ----
                    cols, jagged = _window_phase2(
                        store, plan, start, stop, mask, dev_cols, loaded, wb,
                        w2s, coalesce, tracer=tracer,
                    )
                tracer.end(p2sid, bytes=w2s.bytes_fetched)
                jagged_map.update(jagged)
                for k2, v in cols.items():
                    out_cols[k2].append(v)
                part_cols, part_jagged = cols, jagged
            if outcome is not None:
                # savings vs the preloading reference, ledgered AFTER both
                # phases: a filter-branch basket counts as skipped only if
                # neither a cascade stage nor phase 2 ever moved it (phase
                # 2 re-fetches dead baskets of filter∩output branches for
                # surviving windows, which must not be credited)
                from repro.core.plan import unfetched_bytes

                stats.cascade_bytes_skipped += unfetched_bytes(
                    store, plan.filter_branches, start, stop, ledger
                )
            b.merge(wb)
            phase2_stats.merge(w2s)
            if win_records:
                # indexed by window (not [-1]): batched grouping consumes
                # load records ahead of the processing loop
                win_records[wi].update(
                    {
                        "proc_compute": wb.decompress + wb.deserialize + wb.filter,
                        # cascaded stage fetches are non-overlapped fetch in
                        # the schedule, same currency as phase 2
                        "p2_bytes": w2s.bytes_fetched + w1s.bytes_fetched,
                        "p2_requests": w2s.requests + w1s.requests,
                    }
                )
            # the window's ledger entry is complete: stream it.  A caller
            # that stops consuming here (cancellation) has paid exactly
            # the windows it saw — the accounting above is window-local.
            tracer.end(wsid, n_passed=k)
            try:
                yield WindowPartial(
                    index=wi, start=start, stop=stop, n_passed=k,
                    cols=part_cols, jagged=part_jagged, decision=kind,
                )
            except GeneratorExit:
                # cancelled mid-stream: close the root so the partial
                # trace still exports as a well-formed tree
                tracer.end(qsid, cancelled=True, n_passed=n_passed)
                raise
        phase_wall = time.perf_counter() - t_phase

        phase1_bytes = stats.bytes_fetched  # pre-merge: phase-1 only
        stats.merge(phase2_stats)

        osid = tracer.begin("write", kind="write")
        with _Timer(b, "write"):
            cat = _concat_output(out_cols, n_passed, plan, store)
        out = _write_output(cat, jagged_map, store, b)
        tracer.end(osid)

        b.fetch = link.transfer_time(stats.bytes_fetched, stats.requests)
        out_bytes = out.compressed_bytes()
        if mode in ("server_side", "near_data"):
            # the filtered file crosses the WAN back to the client
            b.output_transfer = self.output_link.transfer_time(out_bytes, 1)
        compute = b.decompress + b.deserialize + b.filter + b.write
        # double-buffered basket prefetch (the paper's "advanced data
        # prefetching" future work, implemented for near_data): with fetch
        # of window i+1 overlapping compute of window i, the pipeline
        # bound is max(fetch, compute) instead of their sum.
        overlap_total = (
            max(b.fetch, b.decompress + b.deserialize + b.filter)
            + b.write
            + b.output_transfer
        )
        report = SkimReport(
            mode=mode,
            fused=fused,
            pipelined=bool(prefetch),
            prune=decisions is not None,
            # cascaded phase-1 ledger (DESIGN.md §11)
            cascade=cascade_exec is not None,
            output_bytes=out_bytes,
            window_rows=window_rows,
            # zone-map pruning ledger (DESIGN.md §9): every window the
            # analysis decided without fetching, plus the priced savings
            # mirrored in stats.bytes_skipped / requests_skipped
            pruned_windows=[
                (d.start, d.stop, d.decision)
                for d in decisions or ()
                if d.decision != SCAN
            ],
            overlap_total_s=overlap_total,
            phase_wall_s=phase_wall,
            # phase split of stats.bytes_fetched (accept-all windows fold
            # their single output round into phase 1 when preloading)
            phase1_bytes=phase1_bytes,
            phase2_bytes=phase2_stats.bytes_fetched,
        )
        if cascade_exec is not None:
            report.cascade_order = cascade_exec.order()
            report.cascade_stages = cascade_exec.state.report()
            report.cascade_bytes_skipped = stats.cascade_bytes_skipped
        if dispatches0 is not None:
            from repro.kernels.ops import dispatch_stats

            report.device_dispatches = (
                dispatch_stats()["dispatches"] - dispatches0
            )
            report.decode_backend = store.resolved_decode_backend()
            if batch_n:
                report.device_batch = batch_n
        if win_records:
            # exact double-buffered schedule from the per-window records
            # (what the threaded prefetcher realizes on capable hosts)
            report.pipeline_total_s = (
                _pipeline_schedule(win_records, link)
                + b.write
                + b.output_transfer
            )
        tracer.end(qsid, n_passed=n_passed, bytes=stats.bytes_fetched)
        return SkimResult(
            mode, out, n, n_passed, b, stats, plan,
            busy_fraction=compute / max(b.total(), 1e-12),
            extras=report.legacy_extras(),
            report=report,
        )


def run_skim(
    store: EventStore,
    query: Query | dict | str,
    mode: str = "near_data",
    input_link: NetworkModel = WAN_1G,
    output_link: NetworkModel | None = None,
    fused: bool | None = None,
    pipeline: bool | str | None = None,
    prune: bool | None = None,
    cascade: bool | None = None,
    device_batch: int | None = None,
    fused_backend: str | None = None,
) -> SkimResult:
    return SkimEngine(
        store, input_link, output_link,
        device_batch=device_batch, fused_backend=fused_backend,
    ).run(
        query, mode, fused=fused, pipeline=pipeline, prune=prune,
        cascade=cascade,
    )
