# The paper's primary contribution: JSON-query-driven, two-phase,
# near-data skimming (SkimROOT) as a composable library.
from repro.core.branchmap import expand_branches, register_minimal_set
from repro.core.engine import (
    LAN_10G,
    LAN_100G,
    LOCAL_DISK,
    PCIE_128G,
    WAN_1G,
    Breakdown,
    NetworkModel,
    SkimEngine,
    SkimResult,
    run_skim,
)
from repro.core.planner import SkimPlan, plan_skim
from repro.core.query import Query, eval_node, eval_stage, parse_query

__all__ = [
    "expand_branches",
    "register_minimal_set",
    "Breakdown",
    "NetworkModel",
    "SkimEngine",
    "SkimResult",
    "run_skim",
    "WAN_1G",
    "LAN_10G",
    "LAN_100G",
    "PCIE_128G",
    "LOCAL_DISK",
    "SkimPlan",
    "plan_skim",
    "Query",
    "parse_query",
    "eval_node",
    "eval_stage",
]
