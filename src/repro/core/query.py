"""JSON query format -> predicate AST (paper §3.1, Fig. 2c).

A query replaces the hand-written C++/Python filtering script with a
declarative JSON document::

    {
      "input":  "events.skim",
      "output": "skimmed.skim",
      "branches": ["Electron_*", "Jet_pt", "HLT_*", "MET_*"],
      "force_all": false,
      "selection": {
        "preselection": [
          {"branch": "nElectron", "op": ">=", "value": 1}
        ],
        "object": [
          {"collection": "Electron",
           "cuts": [{"var": "pt",  "op": ">",    "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4}],
           "min_count": 1}
        ],
        "event": [
          {"type": "ht", "collection": "Jet", "var": "pt",
           "object_cuts": [{"var": "pt", "op": ">", "value": 30.0}],
           "op": ">", "value": 200.0},
          {"type": "any", "branches": ["HLT_IsoMu24"]},
          {"type": "cut", "branch": "MET_pt", "op": ">", "value": 40.0}
        ]
      }
    }

The three selection tiers map to the paper's hierarchical model:
*preselection* (cheap single-branch cuts), *object-level* (per-particle
kinematic cuts over jagged collections), *event-level* (composite derived
variables such as HT, trigger ORs).  Stages run in order and events are
discarded as early as possible (basket-granular short-circuiting in the
engine).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

OPS = {
    ">": lambda x, v: x > v,
    ">=": lambda x, v: x >= v,
    "<": lambda x, v: x < v,
    "<=": lambda x, v: x <= v,
    "==": lambda x, v: x == v,
    "!=": lambda x, v: x != v,
    "abs<": lambda x, v: abs(x) < v,
    "abs>": lambda x, v: abs(x) > v,
}


@dataclass(frozen=True)
class Cut:
    """Flat-branch comparison (preselection / event tier)."""

    branch: str
    op: str
    value: float

    def branches(self) -> set[str]:
        return {self.branch}


@dataclass(frozen=True)
class VarCut:
    """Comparison on one variable of a collection member."""

    var: str
    op: str
    value: float


@dataclass(frozen=True)
class ObjectSelection:
    """Object tier: count collection members passing all cuts >= min_count."""

    collection: str
    cuts: tuple[VarCut, ...]
    min_count: int = 1

    def branches(self) -> set[str]:
        out = {f"n{self.collection}"}
        for c in self.cuts:
            out.add(f"{self.collection}_{c.var}")
        return out


@dataclass(frozen=True)
class HTCut:
    """Event tier: scalar sum of ``var`` over passing objects, compared."""

    collection: str
    var: str
    object_cuts: tuple[VarCut, ...]
    op: str
    value: float

    def branches(self) -> set[str]:
        out = {f"n{self.collection}", f"{self.collection}_{self.var}"}
        for c in self.object_cuts:
            out.add(f"{self.collection}_{c.var}")
        return out


@dataclass(frozen=True)
class AnyOf:
    """Event tier: OR of boolean branches (trigger conditions)."""

    names: tuple[str, ...]

    def branches(self) -> set[str]:
        return set(self.names)


Stage = tuple  # tuple of AST nodes evaluated with logical AND


@dataclass
class Query:
    input: str
    output: str
    branches: tuple[str, ...]  # output branch patterns (wildcards allowed)
    force_all: bool
    preselection: tuple = ()
    object_stage: tuple = ()
    event_stage: tuple = ()
    meta: dict = field(default_factory=dict)

    def stages(self) -> list[tuple[str, tuple]]:
        return [
            ("preselection", self.preselection),
            ("object", self.object_stage),
            ("event", self.event_stage),
        ]

    def filter_branches(self) -> set[str]:
        """Branches the selection criteria read (the paper's O(10) set)."""
        out: set[str] = set()
        for _, stage in self.stages():
            for node in stage:
                out |= node.branches()
        return out

    def stage_branches(self, stage_name: str) -> set[str]:
        for name, stage in self.stages():
            if name == stage_name:
                out: set[str] = set()
                for node in stage:
                    out |= node.branches()
                return out
        raise KeyError(stage_name)


def _parse_varcuts(items) -> tuple[VarCut, ...]:
    return tuple(VarCut(c["var"], c["op"], c["value"]) for c in items)


def parse_query(doc: dict | str) -> Query:
    """Parse a JSON query document (dict or JSON string) into a :class:`Query`."""
    if isinstance(doc, str):
        doc = json.loads(doc)
    sel = doc.get("selection", {})

    presel = tuple(
        Cut(c["branch"], c["op"], c["value"]) for c in sel.get("preselection", [])
    )
    objs = tuple(
        ObjectSelection(
            o["collection"], _parse_varcuts(o.get("cuts", [])), o.get("min_count", 1)
        )
        for o in sel.get("object", [])
    )
    events: list = []
    for e in sel.get("event", []):
        kind = e.get("type", "cut")
        if kind == "cut":
            events.append(Cut(e["branch"], e["op"], e["value"]))
        elif kind == "any":
            events.append(AnyOf(tuple(e["branches"])))
        elif kind == "ht":
            events.append(
                HTCut(
                    e["collection"],
                    e.get("var", "pt"),
                    _parse_varcuts(e.get("object_cuts", [])),
                    e["op"],
                    e["value"],
                )
            )
        else:
            raise ValueError(f"unknown event-cut type: {kind}")

    for op_node in presel + tuple(events):
        if isinstance(op_node, Cut) and op_node.op not in OPS:
            raise ValueError(f"unknown op {op_node.op}")

    return Query(
        input=doc.get("input", ""),
        output=doc.get("output", ""),
        branches=tuple(doc.get("branches", [])),
        force_all=bool(doc.get("force_all", False)),
        preselection=presel,
        object_stage=objs,
        event_stage=tuple(events),
        meta={k: v for k, v in doc.items() if k not in
              ("input", "output", "branches", "force_all", "selection")},
    )


# ---------------------------------------------------------------------------
# numpy evaluator (host path; the jnp/Pallas path lives in repro.kernels)
# ---------------------------------------------------------------------------


def _event_ids(counts: np.ndarray) -> np.ndarray:
    return np.repeat(np.arange(len(counts)), counts)


def eval_node(node, data: dict) -> np.ndarray:
    """Evaluate one AST node -> boolean mask over events.

    ``data`` maps flat branch name -> (n_events,) array and jagged branch
    name -> values array, with counts available under the ``n<Collection>``
    name.
    """
    if isinstance(node, Cut):
        return np.asarray(OPS[node.op](data[node.branch], node.value), dtype=bool)
    if isinstance(node, AnyOf):
        mask = np.zeros_like(np.asarray(data[node.names[0]], dtype=bool))
        for n in node.names:
            mask |= np.asarray(data[n], dtype=bool)
        return mask
    if isinstance(node, ObjectSelection):
        counts = np.asarray(data[f"n{node.collection}"], dtype=np.int64)
        passing = None
        for c in node.cuts:
            vals = data[f"{node.collection}_{c.var}"]
            m = np.asarray(OPS[c.op](vals, c.value), dtype=bool)
            passing = m if passing is None else (passing & m)
        if passing is None:
            passing = np.ones(int(counts.sum()), dtype=bool)
        per_event = np.bincount(
            _event_ids(counts), weights=passing.astype(np.float64), minlength=len(counts)
        )
        return per_event >= node.min_count
    if isinstance(node, HTCut):
        counts = np.asarray(data[f"n{node.collection}"], dtype=np.int64)
        vals = np.asarray(data[f"{node.collection}_{node.var}"], dtype=np.float64)
        passing = np.ones(len(vals), dtype=bool)
        for c in node.object_cuts:
            v = data[f"{node.collection}_{c.var}"]
            passing &= np.asarray(OPS[c.op](v, c.value), dtype=bool)
        ht = np.bincount(
            _event_ids(counts), weights=vals * passing, minlength=len(counts)
        )
        return np.asarray(OPS[node.op](ht, node.value), dtype=bool)
    raise TypeError(f"unknown node {type(node)}")


def eval_stage(stage: tuple, data: dict, n_events: int) -> np.ndarray:
    mask = np.ones(n_events, dtype=bool)
    for node in stage:
        mask &= eval_node(node, data)
    return mask
