"""JSON query format -> predicate AST (paper §3.1, Fig. 2c).

A query replaces the hand-written C++/Python filtering script with a
declarative JSON document::

    {
      "input":  "events.skim",
      "output": "skimmed.skim",
      "branches": ["Electron_*", "Jet_pt", "HLT_*", "MET_*"],
      "force_all": false,
      "selection": {
        "preselection": [
          {"branch": "nElectron", "op": ">=", "value": 1}
        ],
        "object": [
          {"collection": "Electron",
           "cuts": [{"var": "pt",  "op": ">",    "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4}],
           "min_count": 1}
        ],
        "event": [
          {"type": "ht", "collection": "Jet", "var": "pt",
           "object_cuts": [{"var": "pt", "op": ">", "value": 30.0}],
           "op": ">", "value": 200.0},
          {"type": "any", "branches": ["HLT_IsoMu24"]},
          {"type": "cut", "branch": "MET_pt", "op": ">", "value": 40.0},
          {"type": "mass", "collections": ["Electron", "Electron"],
           "window": [80.0, 100.0]},
          {"type": "deltaR", "collections": ["Electron", "Jet"],
           "op": ">", "value": 0.4},
          {"type": "expr", "expr": "MET_pt + 0.5*sum(Jet_pt)",
           "op": ">", "value": 150.0}
        ]
      }
    }

The three selection tiers map to the paper's hierarchical model:
*preselection* (cheap single-branch cuts), *object-level* (per-particle
kinematic cuts over jagged collections), *event-level* (composite derived
variables such as HT, trigger ORs, and the derived-kinematics tier:
leading-pair invariant-mass windows, ΔR, and arithmetic expressions over
flat branches and ``sum()`` reductions — DESIGN.md §10).  Stages run in
order and events are discarded as early as possible (basket-granular
short-circuiting in the engine).

Trigger menus differ across data-taking eras, so ``any`` nodes treat
branches absent from a store as constant-False by default;
``parse_query(..., strict=True)`` restores hard validation.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from repro.core.expr import (
    KINEMATIC_VARS,
    compile_expr,
    eval_expr_np,
    leading_delta_r,
    leading_pair_mass,
    rpn_branches,
)

OPS = {
    ">": lambda x, v: x > v,
    ">=": lambda x, v: x >= v,
    "<": lambda x, v: x < v,
    "<=": lambda x, v: x <= v,
    "==": lambda x, v: x == v,
    "!=": lambda x, v: x != v,
    "abs<": lambda x, v: abs(x) < v,
    "abs>": lambda x, v: abs(x) > v,
}


@dataclass(frozen=True)
class Cut:
    """Flat-branch comparison (preselection / event tier)."""

    branch: str
    op: str
    value: float

    def branches(self) -> set[str]:
        return {self.branch}


@dataclass(frozen=True)
class VarCut:
    """Comparison on one variable of a collection member."""

    var: str
    op: str
    value: float


@dataclass(frozen=True)
class ObjectSelection:
    """Object tier: count collection members passing all cuts >= min_count."""

    collection: str
    cuts: tuple[VarCut, ...]
    min_count: int = 1

    def branches(self) -> set[str]:
        out = {f"n{self.collection}"}
        for c in self.cuts:
            out.add(f"{self.collection}_{c.var}")
        return out


@dataclass(frozen=True)
class HTCut:
    """Event tier: scalar sum of ``var`` over passing objects, compared."""

    collection: str
    var: str
    object_cuts: tuple[VarCut, ...]
    op: str
    value: float

    def branches(self) -> set[str]:
        out = {f"n{self.collection}", f"{self.collection}_{self.var}"}
        for c in self.object_cuts:
            out.add(f"{self.collection}_{c.var}")
        return out


@dataclass(frozen=True)
class AnyOf:
    """Event tier: OR of boolean branches (trigger conditions).

    Branches absent from the store under evaluation contribute
    constant-False (menus differ across eras); ``Query.strict`` restores
    the hard ``KeyError``.  The zone-map analysis mirrors the same
    semantics so pruning stays bit-identical.
    """

    names: tuple[str, ...]

    def branches(self) -> set[str]:
        return set(self.names)


@dataclass(frozen=True)
class MassWindow:
    """Event tier: leading-pair invariant mass inside ``[lo, hi]``.

    The pair is the two highest-``pt`` objects of a same-collection pair,
    or each collection's leading object otherwise; events without a full
    pair fail.  Bounds are inclusive."""

    collections: tuple[str, str]
    lo: float
    hi: float

    def branches(self) -> set[str]:
        out: set[str] = set()
        for c in set(self.collections):
            out.add(f"n{c}")
            out |= {f"{c}_{v}" for v in KINEMATIC_VARS["mass"]}
        return out


@dataclass(frozen=True)
class DeltaRCut:
    """Event tier: ΔR between the leading pair, compared to a threshold.

    Events without a full pair fail regardless of the operator."""

    collections: tuple[str, str]
    op: str
    value: float

    def branches(self) -> set[str]:
        out: set[str] = set()
        for c in set(self.collections):
            out.add(f"n{c}")
            out |= {f"{c}_{v}" for v in KINEMATIC_VARS["deltaR"]}
        return out


@dataclass(frozen=True)
class ExprCut:
    """Event tier: arithmetic expression over flat branches and ``sum()``
    reductions, compared to a threshold (float64 host semantics;
    ``repro.core.expr``)."""

    source: str  # original expression text (repr / error messages)
    rpn: tuple  # branch-name stack program from expr.compile_expr
    op: str
    value: float

    def branches(self) -> set[str]:
        return rpn_branches(self.rpn)


Stage = tuple  # tuple of AST nodes evaluated with logical AND


@dataclass
class Query:
    input: str
    output: str
    branches: tuple[str, ...]  # output branch patterns (wildcards allowed)
    force_all: bool
    preselection: tuple = ()
    object_stage: tuple = ()
    event_stage: tuple = ()
    # strict=True restores the hard KeyError for trigger-OR branches the
    # store does not carry (the pre-era-robustness behavior)
    strict: bool = False
    # cascaded phase-1 execution (DESIGN.md §11): ``True``/``False``
    # forces the cascade on or off for this query, ``None`` defers to the
    # executing engine's default.  Part of the canonical query form (the
    # executor flag changes a cached result's accounting payload).
    cascade: bool | None = None
    meta: dict = field(default_factory=dict)

    def stages(self) -> list[tuple[str, tuple]]:
        return [
            ("preselection", self.preselection),
            ("object", self.object_stage),
            ("event", self.event_stage),
        ]

    def filter_branches(self) -> set[str]:
        """Branches the selection criteria read (the paper's O(10) set)."""
        out: set[str] = set()
        for _, stage in self.stages():
            for node in stage:
                out |= node.branches()
        return out

    def stage_branches(self, stage_name: str) -> set[str]:
        for name, stage in self.stages():
            if name == stage_name:
                out: set[str] = set()
                for node in stage:
                    out |= node.branches()
                return out
        raise KeyError(stage_name)

    def optional_branches(self) -> set[str]:
        """Branches a store may legitimately lack: trigger-OR names, which
        evaluate as constant-False when absent (unless ``strict``)."""
        if self.strict:
            return set()
        out: set[str] = set()
        for _, stage in self.stages():
            for node in stage:
                if isinstance(node, AnyOf):
                    out |= set(node.names)
        return out


def _parse_varcuts(items) -> tuple[VarCut, ...]:
    return tuple(VarCut(c["var"], c["op"], c["value"]) for c in items)


def parse_query(doc: dict | str, strict: bool = False) -> Query:
    """Parse a JSON query document (dict or JSON string) into a :class:`Query`.

    ``strict=True`` (or ``"strict": true`` in the document) makes trigger
    branches listed in ``any`` nodes but absent from the target store a
    hard planning error instead of constant-False.
    """
    if isinstance(doc, str):
        doc = json.loads(doc)
    sel = doc.get("selection", {})

    presel = tuple(
        Cut(c["branch"], c["op"], c["value"]) for c in sel.get("preselection", [])
    )
    objs = tuple(
        ObjectSelection(
            o["collection"], _parse_varcuts(o.get("cuts", [])), o.get("min_count", 1)
        )
        for o in sel.get("object", [])
    )
    events: list = []
    for e in sel.get("event", []):
        kind = e.get("type", "cut")
        if kind == "cut":
            events.append(Cut(e["branch"], e["op"], e["value"]))
        elif kind == "any":
            events.append(AnyOf(tuple(e["branches"])))
        elif kind == "ht":
            events.append(
                HTCut(
                    e["collection"],
                    e.get("var", "pt"),
                    _parse_varcuts(e.get("object_cuts", [])),
                    e["op"],
                    e["value"],
                )
            )
        elif kind == "mass":
            colls = tuple(e["collections"])
            if len(colls) != 2:
                raise ValueError("mass node needs exactly two collections")
            lo, hi = e["window"]
            events.append(MassWindow(colls, float(lo), float(hi)))
        elif kind == "deltaR":
            colls = tuple(e["collections"])
            if len(colls) != 2:
                raise ValueError("deltaR node needs exactly two collections")
            events.append(DeltaRCut(colls, e.get("op", ">"), float(e["value"])))
        elif kind == "expr":
            events.append(
                ExprCut(e["expr"], compile_expr(e["expr"]), e["op"],
                        float(e["value"]))
            )
        else:
            raise ValueError(f"unknown event-cut type: {kind}")

    for op_node in presel + tuple(events):
        if isinstance(op_node, (Cut, DeltaRCut, ExprCut)) and op_node.op not in OPS:
            raise ValueError(f"unknown op {op_node.op}")

    return Query(
        input=doc.get("input", ""),
        output=doc.get("output", ""),
        branches=tuple(doc.get("branches", [])),
        force_all=bool(doc.get("force_all", False)),
        preselection=presel,
        object_stage=objs,
        event_stage=tuple(events),
        strict=bool(doc.get("strict", strict)),
        cascade=(None if doc.get("cascade") is None else bool(doc["cascade"])),
        meta={k: v for k, v in doc.items() if k not in
              ("input", "output", "branches", "force_all", "selection",
               "strict", "cascade")},
    )


# ---------------------------------------------------------------------------
# numpy evaluator (host path; the jnp/Pallas path lives in repro.kernels)
# ---------------------------------------------------------------------------


def _event_ids(counts: np.ndarray) -> np.ndarray:
    return np.repeat(np.arange(len(counts)), counts)


def eval_node(node, data: dict, n_events: int | None = None) -> np.ndarray:
    """Evaluate one AST node -> boolean mask over events.

    ``data`` maps flat branch name -> (n_events,) array and jagged branch
    name -> values array, with counts available under the ``n<Collection>``
    name.  ``any`` names missing from ``data`` contribute constant-False
    (absent-era triggers); ``n_events`` sizes the mask when *every* name
    is missing (``eval_stage`` always passes it).
    """
    if isinstance(node, Cut):
        return np.asarray(OPS[node.op](data[node.branch], node.value), dtype=bool)
    if isinstance(node, AnyOf):
        present = [n for n in node.names if n in data]
        if not present:
            if n_events is None:
                raise KeyError(
                    f"AnyOf{node.names}: no branch present and n_events unknown"
                )
            return np.zeros(n_events, dtype=bool)
        mask = np.zeros_like(np.asarray(data[present[0]], dtype=bool))
        for n in present:
            mask |= np.asarray(data[n], dtype=bool)
        return mask
    if isinstance(node, MassWindow):
        m, ok = leading_pair_mass(data, *node.collections)
        return ok & (m >= node.lo) & (m <= node.hi)
    if isinstance(node, DeltaRCut):
        dr, ok = leading_delta_r(data, *node.collections)
        return ok & np.asarray(OPS[node.op](dr, node.value), dtype=bool)
    if isinstance(node, ExprCut):
        val = eval_expr_np(node.rpn, data)
        return np.asarray(OPS[node.op](val, node.value), dtype=bool)
    if isinstance(node, ObjectSelection):
        counts = np.asarray(data[f"n{node.collection}"], dtype=np.int64)
        passing = None
        for c in node.cuts:
            vals = data[f"{node.collection}_{c.var}"]
            m = np.asarray(OPS[c.op](vals, c.value), dtype=bool)
            passing = m if passing is None else (passing & m)
        if passing is None:
            passing = np.ones(int(counts.sum()), dtype=bool)
        # integer accumulation: count semantics are exact and match the
        # fused kernel's int32 path (float64 counting was exact too, but
        # only incidentally — the comparison belongs in integers)
        per_event = np.bincount(
            _event_ids(counts)[passing], minlength=len(counts)
        )
        return per_event >= node.min_count
    if isinstance(node, HTCut):
        counts = np.asarray(data[f"n{node.collection}"], dtype=np.int64)
        vals = np.asarray(data[f"{node.collection}_{node.var}"], dtype=np.float64)
        passing = np.ones(len(vals), dtype=bool)
        for c in node.object_cuts:
            v = data[f"{node.collection}_{c.var}"]
            passing &= np.asarray(OPS[c.op](v, c.value), dtype=bool)
        ht = np.bincount(
            _event_ids(counts), weights=vals * passing, minlength=len(counts)
        )
        return np.asarray(OPS[node.op](ht, node.value), dtype=bool)
    raise TypeError(f"unknown node {type(node)}")


def eval_stage(stage: tuple, data: dict, n_events: int) -> np.ndarray:
    mask = np.ones(n_events, dtype=bool)
    for node in stage:
        mask &= eval_node(node, data, n_events)
    return mask
