"""Per-query span trees + Chrome-trace export (DESIGN.md §13).

A :class:`Tracer` records a tree of timed **spans** for one logical unit
of work (one query, one service job, one shard execution).  The design
constraints, in order:

  * **Zero cost when off.**  The module-level :data:`NULL_TRACER` is the
    default everywhere; its ``span``/``begin``/``end`` are empty method
    calls returning shared singletons, so the engines' hot window loop
    pays a few attribute lookups per window, never an allocation.
  * **Byte-deterministic under an injected clock.**  The clock is
    injectable (any object with ``.now()`` — reuse the service's
    :class:`~repro.serve.jobs.ManualClock`); span ids are a per-tracer
    counter; :func:`trace_json` serializes with sorted keys and fixed
    separators.  Same seed ⇒ byte-identical export (pinned by
    tests/test_obs.py).
  * **Trees compose across processes.**  A storage node traces into its
    own tracer; the coordinator *adopts* the node's spans — re-ids them
    and re-parents the node's roots under a coordinator span — so a
    cluster query exports as ONE tree (every node span adopted exactly
    once).
  * **Opens in ``chrome://tracing``.**  :func:`chrome_trace` emits the
    Trace Event Format (``ph: "X"`` complete events, microsecond
    timestamps, one ``pid`` per traced process/job).

Span taxonomy (the ``kind`` field): ``query``, ``plan``, ``window``,
``cascade_stage``, ``fetch``, ``decode``, ``decode_device`` (the
backend-selected on-device basket decode, DESIGN.md §16), ``kernel``,
``device_batch`` (one per window-batched cascade dispatch group, attrs:
windows/pad_windows/pad_events), ``write``, ``shard``, ``merge``,
``job``, ``admission``, ``queue``, ``settle``, ``tenant``, and the
fault-tolerance kinds ``retry`` (one per re-issued shard, attrs:
failed/used node), ``hedge`` (one per hedged shard, attrs: outcome
won/lost/cancelled), ``recover`` (one per journal-recovered job, attrs:
resume_skip).  See DESIGN.md §13–14, §16.
"""

from __future__ import annotations

import itertools
import json
import threading
import time


class Span:
    """One timed node of the trace tree.  ``t1 is None`` while open."""

    __slots__ = ("sid", "parent", "name", "kind", "t0", "t1", "attrs")

    def __init__(self, sid, parent, name, kind, t0, t1=None, attrs=None):
        self.sid = sid
        self.parent = parent  # sid of the parent span, or None for roots
        self.name = name
        self.kind = kind
        self.t0 = t0
        self.t1 = t1
        self.attrs = attrs if attrs is not None else {}

    def __setitem__(self, key, value):
        self.attrs[key] = value

    @property
    def duration(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def as_dict(self) -> dict:
        return {
            "sid": self.sid,
            "parent": self.parent,
            "name": self.name,
            "kind": self.kind,
            "t0": self.t0,
            "t1": self.t1,
            "attrs": dict(self.attrs),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (
            f"Span({self.sid}<-{self.parent} {self.kind}:{self.name} "
            f"{self.duration * 1e3:.3f}ms)"
        )


class _SpanCM:
    """Context-manager wrapper around begin/end (the ``with`` form)."""

    __slots__ = ("_tr", "_name", "_kind", "_parent", "_attrs", "_span")

    def __init__(self, tracer, name, kind, parent, attrs):
        self._tr, self._name, self._kind = tracer, name, kind
        self._parent, self._attrs = parent, attrs

    def __enter__(self) -> Span:
        tr = self._tr
        st = tr._stack()
        pid = self._parent if self._parent is not None else (st[-1] if st else None)
        self._span = tr._new(self._name, self._kind, pid, tr.now(), None, self._attrs)
        st.append(self._span.sid)
        return self._span

    def __exit__(self, *exc) -> bool:
        tr, sp = self._tr, self._span
        sp.t1 = tr.now()
        st = tr._stack()
        if sp.sid in st:
            del st[st.index(sp.sid) :]
        return False


class Tracer:
    """Records one span tree.  Parenting is implicit (the innermost open
    span on the *calling thread*) unless ``parent=`` is given — worker
    threads that must attach to a specific span pass it explicitly.

    ``clock`` is any object with a ``.now() -> float`` (seconds), a bare
    callable, or ``None`` for ``time.perf_counter``.
    """

    enabled = True

    def __init__(self, clock=None, name: str = "trace"):
        self.name = name
        self.clock = clock
        if hasattr(clock, "now"):
            self._now = clock.now
        else:
            self._now = clock if callable(clock) else time.perf_counter
        self._spans: list[Span] = []
        self._by_id: dict[int, Span] = {}
        self._counter = itertools.count(1)
        self._lock = threading.Lock()
        self._local = threading.local()

    # -- internals -----------------------------------------------------------

    def now(self) -> float:
        return float(self._now())

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = []
            self._local.stack = st
        return st

    def _new(self, name, kind, parent, t0, t1, attrs) -> Span:
        with self._lock:
            sid = next(self._counter)
            sp = Span(sid, parent, name, kind, t0, t1, dict(attrs) if attrs else {})
            self._spans.append(sp)
            self._by_id[sid] = sp
        return sp

    # -- recording -----------------------------------------------------------

    def span(self, name: str, kind: str = "span", parent: int | None = None, **attrs):
        """``with tracer.span("window", kind="window") as sp: ...``"""
        return _SpanCM(self, name, kind, parent, attrs)

    def begin(self, name: str, kind: str = "span", parent: int | None = None, **attrs) -> int:
        """Open a span without a ``with`` block; returns its sid for
        :meth:`end`.  The generator-shaped executors use this to keep a
        span open across ``yield`` boundaries of *inner* code without
        re-indenting their bodies."""
        st = self._stack()
        pid = parent if parent is not None else (st[-1] if st else None)
        sp = self._new(name, kind, pid, self.now(), None, attrs)
        st.append(sp.sid)
        return sp.sid

    def end(self, sid: int, **attrs) -> None:
        """Close a span opened with :meth:`begin`; late attrs merge in.
        Pops the stack through ``sid`` so a dangling child (error paths)
        cannot mis-parent later spans."""
        sp = self._by_id.get(sid)
        if sp is None:
            return
        if sp.t1 is None:
            sp.t1 = self.now()
        if attrs:
            sp.attrs.update(attrs)
        st = self._stack()
        if sid in st:
            del st[st.index(sid) :]

    def add_span(
        self,
        name: str,
        kind: str = "span",
        t0: float = 0.0,
        t1: float | None = None,
        parent: int | None = None,
        **attrs,
    ) -> Span:
        """Record an already-completed span with explicit timestamps
        (admission decided at submit time, queue-wait measured between
        two clock readings, ...)."""
        st = self._stack()
        pid = parent if parent is not None else (st[-1] if st else None)
        return self._new(
            name, kind, pid, float(t0), float(t1 if t1 is not None else t0), attrs
        )

    def adopt(self, spans, parent: int | None = None) -> int:
        """Graft a foreign span list (e.g. a :class:`NodeResponse`'s
        node-local trace) into this tree: every span is re-id'd exactly
        once, internal parent links are remapped, and the foreign roots
        re-parent under ``parent``.  Spans must arrive parents-first
        (tracers append at open time, so ``spans()`` already is).
        Returns the number of spans adopted."""
        mapping: dict[int, int] = {}
        n = 0
        for sp in spans or ():
            pid = mapping.get(sp.parent, parent)
            new = self._new(
                sp.name, sp.kind, pid, sp.t0,
                sp.t1 if sp.t1 is not None else sp.t0, dict(sp.attrs),
            )
            mapping[sp.sid] = new.sid
            n += 1
        return n

    # -- reading -------------------------------------------------------------

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)

    def get(self, sid: int) -> Span | None:
        return self._by_id.get(sid)

    def roots(self) -> list[Span]:
        return [s for s in self.spans() if s.parent is None]

    def children(self, sid: int | None) -> list[Span]:
        return [s for s in self.spans() if s.parent == sid]

    def chrome_trace(self, pid: int = 0) -> dict:
        return chrome_trace([(pid, self.name, self)])


class _NullSpan:
    """Shared do-nothing span; also its own context manager."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def __setitem__(self, key, value) -> None:
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The default tracer: every operation is a no-op returning shared
    singletons.  The hot path's only cost is the call itself."""

    enabled = False
    name = "null"
    clock = None

    def now(self) -> float:
        return 0.0

    def span(self, *args, **attrs):
        return _NULL_SPAN

    def begin(self, *args, **attrs) -> int:
        return 0

    def end(self, sid, **attrs) -> None:
        pass

    def add_span(self, *args, **attrs):
        return _NULL_SPAN

    def adopt(self, spans, parent=None) -> int:
        return 0

    def spans(self) -> list:
        return []

    def roots(self) -> list:
        return []


#: the process-wide shared no-op tracer (default everywhere)
NULL_TRACER = NullTracer()


# ---------------------------------------------------------------------------
# Chrome Trace Event Format export
# ---------------------------------------------------------------------------


def _json_safe(value):
    """Coerce attrs to plain JSON types (numpy scalars via ``.item()``)
    without importing numpy — obs stays dependency-free."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, (list, tuple)):
        return [_json_safe(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _json_safe(v) for k, v in value.items()}
    if hasattr(value, "item"):
        try:
            return _json_safe(value.item())
        except Exception:
            pass
    return str(value)


def chrome_events(spans, pid: int = 0, tid: int = 0) -> list[dict]:
    """Spans -> Trace Event Format complete (``ph: "X"``) events.
    Timestamps are microseconds; open spans export with zero duration."""
    events = []
    for sp in spans:
        t1 = sp.t1 if sp.t1 is not None else sp.t0
        events.append(
            {
                "name": sp.name,
                "cat": sp.kind,
                "ph": "X",
                "ts": round(sp.t0 * 1e6, 3),
                "dur": round((t1 - sp.t0) * 1e6, 3),
                "pid": int(pid),
                "tid": int(tid),
                "args": {
                    "sid": sp.sid,
                    "parent": sp.parent,
                    **_json_safe(sp.attrs),
                },
            }
        )
    return events


def chrome_trace(groups) -> dict:
    """Assemble one Chrome-trace document from many traced processes.

    ``groups`` is an iterable of ``(pid, display_name, tracer_or_spans)``
    — one per traced unit (the service exports one pid per job).  The
    result opens directly in ``chrome://tracing`` / Perfetto.
    """
    events: list[dict] = []
    for pid, name, src in groups:
        spans = src.spans() if hasattr(src, "spans") else list(src)
        events.append(
            {
                "name": "process_name",
                "ph": "M",
                "pid": int(pid),
                "tid": 0,
                "args": {"name": str(name)},
            }
        )
        events.extend(chrome_events(spans, pid=pid))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def trace_json(doc: dict) -> str:
    """Canonical serialization: sorted keys, fixed separators — the
    byte-determinism contract (same spans ⇒ same bytes)."""
    return json.dumps(_json_safe(doc), sort_keys=True, separators=(",", ":"))


def dump_chrome_trace(path: str, groups) -> dict:
    doc = chrome_trace(groups)
    with open(path, "w") as fh:
        fh.write(trace_json(doc))
    return doc


__all__ = [
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "chrome_events",
    "chrome_trace",
    "dump_chrome_trace",
    "trace_json",
]
