"""Observability: span tracing, metrics, and the versioned result
report schema (DESIGN.md §13).  Zero external dependencies; everything
is off (no-op tracer) unless a caller opts in."""

from repro.obs.metrics import (
    MetricsRegistry,
    collect_cache_metrics,
    observed_phase2_bytes,
    observed_stage_bytes,
    priced_stage_bytes,
    unified_cache_report,
)
from repro.obs.schema import KNOWN_EXTRAS, SCHEMA_VERSION, SkimReport, make_extras
from repro.obs.trace import (
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    chrome_trace,
    dump_chrome_trace,
    trace_json,
)

__all__ = [
    "KNOWN_EXTRAS",
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "SCHEMA_VERSION",
    "SkimReport",
    "Span",
    "Tracer",
    "chrome_trace",
    "collect_cache_metrics",
    "dump_chrome_trace",
    "make_extras",
    "observed_phase2_bytes",
    "observed_stage_bytes",
    "priced_stage_bytes",
    "trace_json",
    "unified_cache_report",
]
