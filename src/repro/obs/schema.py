"""Versioned result-report schema (DESIGN.md §13).

Result accounting used to accrete as ad-hoc ``extras["..."]`` writes
scattered across the engines — no common shape, no versioning, and every
consumer guessing which keys a given execution path produces.  This
module is now the single place result metadata is defined:

  * :class:`SkimReport` — the structured, versioned record attached to
    every :class:`~repro.core.engine.SkimResult` as ``result.report``.
  * :meth:`SkimReport.legacy_extras` — the compatibility shim: it
    renders the report back into exactly the historical ``extras`` dict
    (same keys, same conditional presence), so every existing
    ``result.extras["..."]`` / ``"key" in extras`` consumer keeps
    working unchanged.
  * :func:`make_extras` — the validating constructor for the few extras
    dicts that are not per-engine reports (cluster merge metadata).

A CI checker (tools/check_extras.py) forbids new bare ``extras[...]``
writes outside this module, so the schema can only grow here — bump
:data:`SCHEMA_VERSION` when a field changes meaning or disappears
(adding optional fields is backward-compatible and needs no bump).
"""

from __future__ import annotations

from dataclasses import dataclass, field

#: version stamped into every SkimReport (and its exports)
SCHEMA_VERSION = 1

#: every extras key any execution path may produce — the closed set the
#: lint checker and :func:`make_extras` validate against
KNOWN_EXTRAS = frozenset(
    {
        # per-engine report keys (SkimReport.legacy_extras)
        "output_bytes",
        "overlap_total",
        "fused",
        "pipelined",
        "phase_wall_s",
        "window_rows",
        "phase1_bytes",
        "phase2_bytes",
        "pruned_windows",
        "prune",
        "cascade",
        "cascade_order",
        "cascade_stages",
        "cascade_bytes_skipped",
        "pipeline_total",
        "shared_scan",
        "shard_pruned",
        # device-resident batched cascade (DESIGN.md §16)
        "device_batch",
        "device_dispatches",
        "decode_backend",
        # cluster merge metadata (coordinator-level, make_extras)
        "n_nodes",
        "concurrency",
        "query_hash",
        "pruned_shards",
        "prune_saved_bytes",
        "tenant",
        # fault-tolerance ledger (coordinator / DESIGN.md §14)
        "retry_attempts",
        "retry_backoff_s",
        "corrupt_baskets",
        "hedges_won",
        "hedges_lost",
        "hedges_cancelled",
        "degraded",
        "missing_windows",
    }
)


def make_extras(**kv) -> dict:
    """Build an extras dict restricted to the known schema; the one
    sanctioned way to produce extras outside :class:`SkimReport`."""
    unknown = set(kv) - KNOWN_EXTRAS
    if unknown:
        raise KeyError(
            f"extras keys {sorted(unknown)} are not in the obs schema "
            f"(add them to repro.obs.schema.KNOWN_EXTRAS deliberately)"
        )
    return kv


@dataclass
class SkimReport:
    """Structured per-execution report.

    Optional fields are ``None`` when the execution path doesn't produce
    them (shared-scan tenants have no phase split; only pipelined runs
    have a schedule total) — :meth:`legacy_extras` omits ``None`` fields
    so the emitted key set matches each path's historical extras dict
    exactly.
    """

    mode: str = ""
    version: int = SCHEMA_VERSION
    # flags (always emitted)
    fused: bool = False
    pipelined: bool = False
    prune: bool = False
    # emitted only when the path reports it (pruned shard responses
    # predate the cascade and never carried the key)
    cascade: bool | None = None
    # ledgers (always emitted)
    output_bytes: int = 0
    window_rows: list = field(default_factory=list)
    pruned_windows: list = field(default_factory=list)
    # modeled/measured times (single-engine two-phase runs only)
    overlap_total_s: float | None = None
    phase_wall_s: float | None = None
    pipeline_total_s: float | None = None
    # phase byte split (single-engine two-phase runs only)
    phase1_bytes: int | None = None
    phase2_bytes: int | None = None
    # cascaded phase-1 ledger (cascade runs only)
    cascade_order: list | None = None
    cascade_stages: list | None = None
    cascade_bytes_skipped: int | None = None
    # device-resident batched cascade (DESIGN.md §16): the configured
    # window-batch size, the run's device dispatch count, and the
    # store's resolved decode tier — emitted only on batched runs
    device_batch: int | None = None
    device_dispatches: int | None = None
    decode_backend: str | None = None
    # path markers (emitted only when True)
    shared_scan: bool = False
    shard_pruned: bool = False

    def as_dict(self) -> dict:
        """Full versioned record (``None`` fields included) — the
        machine-readable export shape."""
        return {
            "version": self.version,
            "mode": self.mode,
            "fused": self.fused,
            "pipelined": self.pipelined,
            "prune": self.prune,
            "cascade": self.cascade,
            "output_bytes": self.output_bytes,
            "window_rows": list(self.window_rows),
            "pruned_windows": list(self.pruned_windows),
            "overlap_total_s": self.overlap_total_s,
            "phase_wall_s": self.phase_wall_s,
            "pipeline_total_s": self.pipeline_total_s,
            "phase1_bytes": self.phase1_bytes,
            "phase2_bytes": self.phase2_bytes,
            "cascade_order": self.cascade_order,
            "cascade_stages": self.cascade_stages,
            "cascade_bytes_skipped": self.cascade_bytes_skipped,
            "device_batch": self.device_batch,
            "device_dispatches": self.device_dispatches,
            "decode_backend": self.decode_backend,
            "shared_scan": self.shared_scan,
            "shard_pruned": self.shard_pruned,
        }

    def legacy_extras(self) -> dict:
        """Render the historical ``extras`` dict: same keys, same
        conditional presence, per execution path."""
        extras = {"output_bytes": self.output_bytes}
        if self.overlap_total_s is not None:
            extras["overlap_total"] = self.overlap_total_s
        extras["fused"] = self.fused
        extras["pipelined"] = self.pipelined
        if self.phase_wall_s is not None:
            extras["phase_wall_s"] = self.phase_wall_s
        if self.shared_scan:
            extras["shared_scan"] = True
        extras["window_rows"] = self.window_rows
        if self.phase1_bytes is not None:
            extras["phase1_bytes"] = self.phase1_bytes
        if self.phase2_bytes is not None:
            extras["phase2_bytes"] = self.phase2_bytes
        extras["pruned_windows"] = self.pruned_windows
        extras["prune"] = self.prune
        if self.shard_pruned:
            extras["shard_pruned"] = True
        if self.cascade is not None:
            extras["cascade"] = self.cascade
        if self.cascade_order is not None:
            extras["cascade_order"] = self.cascade_order
        if self.cascade_stages is not None:
            extras["cascade_stages"] = self.cascade_stages
        if self.cascade_bytes_skipped is not None:
            extras["cascade_bytes_skipped"] = self.cascade_bytes_skipped
        if self.pipeline_total_s is not None:
            extras["pipeline_total"] = self.pipeline_total_s
        if self.device_batch is not None:
            extras["device_batch"] = self.device_batch
        if self.device_dispatches is not None:
            extras["device_dispatches"] = self.device_dispatches
        if self.decode_backend is not None:
            extras["decode_backend"] = self.decode_backend
        return extras


__all__ = ["KNOWN_EXTRAS", "SCHEMA_VERSION", "SkimReport", "make_extras"]
