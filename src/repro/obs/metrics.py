"""Counters/gauges/histograms + priced-vs-observed calibration store.

:class:`MetricsRegistry` is the single sink for operational numbers that
used to live in scattered per-component counters: bytes fetched/skipped,
cache hit rates (decode cache and cluster result cache, unified behind
one gauge family), stage pass rates, queue waits, time-to-first-partial,
per-tenant quota spend.  Zero dependencies, deterministic snapshots
(keys are sorted), safe under the cluster's thread-pool gather.

The **calibration store** closes ROADMAP item 1's feedback loop: the
service records ``observed_bytes / priced_bytes`` per cascade-stage kind
at settle time (:meth:`MetricsRegistry.record_price_ratio`), and
:meth:`MetricsRegistry.calibration_priors` turns the accumulated ratios
into the ``calibration`` mapping that
:func:`repro.core.plan.estimate_plan_bytes` consumes as a prior.
"""

from __future__ import annotations

import threading


def _label_key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


def _render_key(key: tuple) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class _Hist:
    """Count/sum/min/max plus deterministic power-of-4 buckets (upper
    bounds 4**k); enough for queue-wait / first-partial distributions
    without pulling in a real histogram library."""

    __slots__ = ("count", "sum", "min", "max", "buckets")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self.buckets: dict[float, int] = {}

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)
        le = 0.0
        if value > 0:
            le = 1.0
            while value > le:
                le *= 4.0
        self.buckets[le] = self.buckets.get(le, 0) + 1

    def as_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "mean": (self.sum / self.count) if self.count else None,
            "buckets": {str(k): self.buckets[k] for k in sorted(self.buckets)},
        }


class MetricsRegistry:
    """Label-aware counters, gauges and histograms.

    Metric identity is ``(name, sorted(labels))`` so
    ``inc("cache_hits", cache="decode")`` and ``cache="result"`` stay
    distinct series under one name.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[tuple, float] = {}
        self._gauges: dict[tuple, float] = {}
        self._hists: dict[tuple, _Hist] = {}
        self._calib: dict[str, dict] = {}

    # -- counters ------------------------------------------------------------

    def inc(self, name: str, value: float = 1, **labels) -> None:
        key = _label_key(name, labels)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + value

    def counter(self, name: str, **labels) -> float:
        return self._counters.get(_label_key(name, labels), 0)

    # -- gauges --------------------------------------------------------------

    def set_gauge(self, name: str, value: float, **labels) -> None:
        with self._lock:
            self._gauges[_label_key(name, labels)] = value

    def gauge(self, name: str, **labels):
        return self._gauges.get(_label_key(name, labels))

    # -- histograms ----------------------------------------------------------

    def observe(self, name: str, value: float, **labels) -> None:
        key = _label_key(name, labels)
        with self._lock:
            hist = self._hists.get(key)
            if hist is None:
                hist = self._hists[key] = _Hist()
            hist.observe(float(value))

    def histogram(self, name: str, **labels) -> dict | None:
        hist = self._hists.get(_label_key(name, labels))
        return hist.as_dict() if hist is not None else None

    # -- calibration (priced vs observed bytes per stage kind) ---------------

    def record_price_ratio(self, kind: str, priced_bytes, observed_bytes) -> None:
        """Accumulate one settled job's priced/observed byte pair for a
        cascade-stage kind (``"cut"``, ``"trigger"``, ``"phase2"``,
        ``"total"``, ...)."""
        with self._lock:
            cell = self._calib.get(kind)
            if cell is None:
                cell = self._calib[kind] = {"n": 0, "priced": 0, "observed": 0}
            cell["n"] += 1
            cell["priced"] += int(priced_bytes)
            cell["observed"] += int(observed_bytes)

    def calibration_summary(self) -> dict:
        """Per-kind totals and the observed/priced ratio (None until a
        kind has priced bytes to divide by)."""
        out = {}
        with self._lock:
            for kind in sorted(self._calib):
                cell = self._calib[kind]
                ratio = (cell["observed"] / cell["priced"]) if cell["priced"] > 0 else None
                out[kind] = {
                    "n": cell["n"],
                    "priced_bytes": cell["priced"],
                    "observed_bytes": cell["observed"],
                    "ratio": ratio,
                }
        return out

    def calibration_priors(self, min_samples: int = 1) -> dict:
        """The ``{stage_kind: ratio}`` mapping `estimate_plan_bytes`
        accepts as its ``calibration`` argument.  Kinds with fewer than
        ``min_samples`` settled jobs (or zero priced bytes) are omitted
        — the estimator falls back to its uncalibrated prior for them."""
        return {
            kind: cell["ratio"]
            for kind, cell in self.calibration_summary().items()
            if cell["ratio"] is not None and cell["n"] >= min_samples
        }

    # -- export --------------------------------------------------------------

    def snapshot(self) -> dict:
        """Deterministic flat view: ``{"counters": {...}, "gauges":
        {...}, "histograms": {...}, "calibration": {...}}`` with
        ``name{label=value}`` keys, sorted."""
        with self._lock:
            counters = {_render_key(k): v for k, v in self._counters.items()}
            gauges = {_render_key(k): v for k, v in self._gauges.items()}
            hists = {_render_key(k): h.as_dict() for k, h in self._hists.items()}
        return {
            "counters": dict(sorted(counters.items())),
            "gauges": dict(sorted(gauges.items())),
            "histograms": dict(sorted(hists.items())),
            "calibration": self.calibration_summary(),
        }


# ---------------------------------------------------------------------------
# Unified cache accounting (decode cache + cluster result cache)
# ---------------------------------------------------------------------------


def unified_cache_report(store=None, result_cache=None) -> dict:
    """One shape for both caches: ``hits``/``misses``/``hit_rate``/
    ``saved_bytes``/``resident``.  ``saved_bytes`` is the byte-weighted
    savings — decoded bytes not re-decoded for the decode cache, fetch
    bytes not re-fetched for the cluster result cache."""
    report = {}
    if store is not None:
        st = store.decode_cache_stats()
        report["decode"] = {
            "hits": st["hits"],
            "misses": st["misses"],
            "hit_rate": st["hit_rate"],
            "saved_bytes": st["saved_decode_bytes"],
            "resident": st["resident"],
        }
    if result_cache is not None:
        cs = result_cache.stats
        report["result"] = {
            "hits": cs.hits,
            "misses": cs.misses,
            "hit_rate": cs.hit_rate,
            "saved_bytes": cs.saved_fetch_bytes,
            "resident": len(result_cache),
        }
    return report


def collect_cache_metrics(registry: MetricsRegistry, store=None, result_cache=None) -> dict:
    """Publish both caches into the registry as one gauge family
    (``cache_hits{cache=decode}``, ``cache_saved_bytes{cache=result}``,
    ...) and return the unified report."""
    report = unified_cache_report(store=store, result_cache=result_cache)
    for cache_name, row in report.items():
        for field, value in row.items():
            registry.set_gauge(f"cache_{field}", value, cache=cache_name)
    return report


# ---------------------------------------------------------------------------
# Priced-vs-observed helpers (consumed by SkimService._settle)
# ---------------------------------------------------------------------------


def priced_stage_bytes(estimate) -> dict:
    """Fold a CostEstimate's per-stage priced bytes by stage kind."""
    kinds = getattr(estimate, "per_stage_kinds", None) or {}
    out: dict[str, int] = {}
    for si, priced in (getattr(estimate, "per_stage", None) or {}).items():
        kind = kinds.get(si, "other")
        out[kind] = out.get(kind, 0) + int(priced)
    return out


def observed_stage_bytes(result) -> dict:
    """Fold a result's observed per-stage bytes by stage kind.  Works on
    a single-engine SkimResult (reads the ``cascade_stages`` report
    rows) and on a ClusterSkimResult (sums over shard responses)."""
    responses = getattr(result, "responses", None)
    if responses is not None:
        out: dict[str, int] = {}
        for resp in responses:
            for kind, nbytes in observed_stage_bytes(resp.result).items():
                out[kind] = out.get(kind, 0) + nbytes
        return out
    out = {}
    for row in (getattr(result, "extras", None) or {}).get("cascade_stages") or ():
        kind = row.get("kind", "other")
        out[kind] = out.get(kind, 0) + int(row.get("bytes_fetched", 0))
    return out


def observed_phase2_bytes(result):
    """Observed phase-2 bytes, or None when the result doesn't report a
    phase split (shared-scan tenants, pruned shards)."""
    responses = getattr(result, "responses", None)
    if responses is not None:
        vals = [observed_phase2_bytes(r.result) for r in responses]
        vals = [v for v in vals if v is not None]
        return sum(vals) if vals else None
    value = (getattr(result, "extras", None) or {}).get("phase2_bytes")
    return int(value) if value is not None else None


__all__ = [
    "MetricsRegistry",
    "collect_cache_metrics",
    "observed_phase2_bytes",
    "observed_stage_bytes",
    "priced_stage_bytes",
    "unified_cache_report",
]
