"""Content-addressed skim-result cache (DESIGN.md §5c).

Repeat and overlapping tenant queries are the norm in the paper's
multi-user regime: the same Higgs-style selection runs against the same
striped dataset over and over.  The cluster caches **per-shard** skim
results under a content address::

    key = sha256(canonical_query_form) . sha256(shard_manifest)

The canonical query form normalizes everything that cannot change the
result — AND-stage ordering, trigger-OR ordering, object-cut ordering —
and keeps everything that can (output branch patterns in order,
``force_all``, every threshold).  The shard side is the store's basket
manifest hash, so the address names *content*, not placement: two
clusters striping byte-identical shards share cache entries, and any
mutation of the underlying baskets changes the address.

Entries are whole :class:`NodeResponse` payloads (shard output store +
window ledger + accounting), budgeted by the output's compressed bytes
under LRU eviction.  ``CacheStats`` accounts hits/misses and the two byte
currencies: output bytes served from cache and phase-1/2 fetch bytes the
hit avoided.
"""

from __future__ import annotations

import hashlib
import json
import threading
from collections import OrderedDict
from dataclasses import dataclass, field

from repro.core.query import (
    AnyOf,
    Cut,
    DeltaRCut,
    ExprCut,
    HTCut,
    MassWindow,
    ObjectSelection,
    Query,
    parse_query,
)

# ---------------------------------------------------------------------------
# canonical query form
# ---------------------------------------------------------------------------


def _varcuts_doc(cuts) -> list:
    return sorted([c.var, c.op, float(c.value)] for c in cuts)


def _node_doc(node) -> list:
    if isinstance(node, Cut):
        return ["cut", node.branch, node.op, float(node.value)]
    if isinstance(node, AnyOf):
        return ["any", sorted(node.names)]
    if isinstance(node, ObjectSelection):
        return [
            "object", node.collection, _varcuts_doc(node.cuts), int(node.min_count)
        ]
    if isinstance(node, HTCut):
        return [
            "ht", node.collection, node.var,
            _varcuts_doc(node.object_cuts), node.op, float(node.value),
        ]
    if isinstance(node, MassWindow):
        # the leading-pair observables are symmetric in the two
        # collections (mass and ΔR of (leading A, leading B)), so the
        # canonical form sorts the pair and reordered queries share a key
        return ["mass", sorted(node.collections), float(node.lo), float(node.hi)]
    if isinstance(node, DeltaRCut):
        return ["deltaR", sorted(node.collections), node.op, float(node.value)]
    if isinstance(node, ExprCut):
        # the lowered stack program, not the source text: whitespace and
        # redundant parens normalize away, every op and constant stays
        return ["expr", [[op, arg] for op, arg in node.rpn],
                node.op, float(node.value)]
    raise TypeError(f"unknown AST node {type(node)}")


def canonical_query(query: Query | dict | str) -> str:
    """Deterministic JSON form of a query's *semantics*.

    Stages are AND-semantic, so node order inside a stage is sorted away;
    output branch patterns keep their order (pattern order is part of the
    output contract).  ``input``/``output`` paths and free-form ``meta``
    do not affect the result and are excluded.
    """
    q = query if isinstance(query, Query) else parse_query(query)
    doc = {
        "branches": list(q.branches),
        "force_all": bool(q.force_all),
        # strict changes what a store with missing trigger branches
        # produces (error vs constant-False), so it addresses content
        "strict": bool(q.strict),
        # the query-level cascade override (DESIGN.md §11): survivors are
        # bit-identical either way, but a cached NodeResponse carries the
        # executor's byte/request ledger, which the cascade changes —
        # None (engine decides) / True / False address differently
        "cascade": q.cascade,
        "stages": {
            name: sorted(
                (_node_doc(n) for n in stage),
                key=lambda d: json.dumps(d, sort_keys=True),
            )
            for name, stage in q.stages()
        },
    }
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


def query_hash(query: Query | dict | str) -> str:
    return hashlib.sha256(canonical_query(query).encode()).hexdigest()


# Version prefix of the cache address format.  v2: shard manifests carry
# zone-map basket statistics (store.ZONEMAP_VERSION), so stores written
# before the stats upgrade hash differently — the version prefix makes
# that an explicit, debuggable namespace instead of a silent miss, and
# re-encoding identical data keeps hitting (stats are deterministic
# functions of the basket contents).  v3: the canonical query form grew
# the ``strict`` flag and the derived-expression node docs, changing
# query hashes for every query.  v4: the canonical form grew the
# ``cascade`` flag (cascaded phase-1 execution, DESIGN.md §11) — results
# are bit-identical across the upgrade, but cached responses carry the
# executor's accounting ledger, which the cascade changes.
CACHE_KEY_VERSION = 4


def versioned_key(query_hash_hex: str, manifest_hash: str) -> str:
    """Assemble the content address from precomputed hashes (the
    coordinator hashes the query once per fan-out)."""
    return f"v{CACHE_KEY_VERSION}.{query_hash_hex}.{manifest_hash}"


def cache_key(query: Query | dict | str, manifest_hash: str) -> str:
    """(query canonical form, shard manifest hash) -> content address."""
    return versioned_key(query_hash(query), manifest_hash)


# ---------------------------------------------------------------------------
# LRU byte-budgeted cache
# ---------------------------------------------------------------------------


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    replacements: int = 0  # same-key re-puts (racing primary vs fallback)
    evictions: int = 0
    stored_bytes: int = 0  # current resident output bytes
    hit_bytes: int = 0  # output bytes served from cache
    miss_bytes: int = 0  # output bytes inserted after misses
    saved_fetch_bytes: int = 0  # phase-1/2 fetch bytes hits avoided

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.hits + self.misses, 1)

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "insertions": self.insertions,
            "replacements": self.replacements,
            "evictions": self.evictions,
            "stored_bytes": self.stored_bytes,
            "hit_bytes": self.hit_bytes,
            "miss_bytes": self.miss_bytes,
            "saved_fetch_bytes": self.saved_fetch_bytes,
            "hit_rate": self.hit_rate,
        }


@dataclass
class _Entry:
    value: object
    nbytes: int
    fetch_bytes: int  # accounted fetch bytes a hit short-circuits


@dataclass
class SkimResultCache:
    """Thread-safe LRU cache of per-shard skim results, byte-budgeted.

    ``budget_bytes`` bounds the sum of entry sizes (the shard outputs'
    compressed bytes).  An entry larger than the whole budget is refused
    rather than flushing the cache for one tenant.
    """

    budget_bytes: int = 256 * 1024 * 1024
    stats: CacheStats = field(default_factory=CacheStats)

    def __post_init__(self):
        self._entries: OrderedDict[str, _Entry] = OrderedDict()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._entries)

    def contains(self, key: str) -> bool:
        """Membership peek — no LRU touch, no hit/miss accounting."""
        with self._lock:
            return key in self._entries

    def get(self, key: str):
        """Return the cached value or ``None``; accounts the hit/miss."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            self.stats.hit_bytes += entry.nbytes
            self.stats.saved_fetch_bytes += entry.fetch_bytes
            return entry.value

    def get_many(self, keys: "list[str]"):
        """All-or-nothing multi-get under ONE lock acquisition (no
        check-then-get race): returns the values in key order iff every
        key is resident (each accounted as a hit), else ``None`` (one
        miss per absent key)."""
        with self._lock:
            entries = [self._entries.get(k) for k in keys]
            if any(e is None for e in entries):
                self.stats.misses += sum(1 for e in entries if e is None)
                return None
            out = []
            for k, e in zip(keys, entries):
                self._entries.move_to_end(k)
                self.stats.hits += 1
                self.stats.hit_bytes += e.nbytes
                self.stats.saved_fetch_bytes += e.fetch_bytes
                out.append(e.value)
            return out

    def put(self, key: str, value, nbytes: int, fetch_bytes: int = 0) -> bool:
        """Insert under LRU eviction; returns False if over-budget."""
        with self._lock:
            if nbytes > self.budget_bytes:
                return False
            old = self._entries.pop(key, None)
            if old is not None:
                self.stats.stored_bytes -= old.nbytes
            while (
                self._entries
                and self.stats.stored_bytes + nbytes > self.budget_bytes
            ):
                _, victim = self._entries.popitem(last=False)
                self.stats.stored_bytes -= victim.nbytes
                self.stats.evictions += 1
            self._entries[key] = _Entry(value, nbytes, fetch_bytes)
            self.stats.stored_bytes += nbytes
            if old is None:
                self.stats.insertions += 1
                self.stats.miss_bytes += nbytes
            else:
                # re-putting the same content address (a timed-out
                # primary completing after its replica already won the
                # race) used to double-count insertions and miss_bytes
                self.stats.replacements += 1
            return True

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.stats.stored_bytes = 0
