"""Retry budgets, deterministic backoff, and hedge policy (DESIGN.md §14).

The coordinator's original fault policy was hard-coded: exactly one
replica retry on :class:`~repro.cluster.node.NodeFailure`, nothing else.
This module replaces it with explicit, per-query policy objects:

  * :class:`RetryPolicy` — how many times a failing shard may be
    re-issued (``budget``), to which targets (replica first;
    ``retry_primary=True`` alternates back to the primary for transient
    faults), and how long each attempt backs off.  Backoff is
    *modeled*, never slept: the exponential delay (plus jitter from a
    seeded RNG, so tests replay exactly) is added to the shard's modeled
    seconds and ledgered in a :class:`RetryEvent` — the same
    two-currency discipline as the rest of the repo (DESIGN.md §2c).
    One policy covers every fault kind uniformly: ``NodeFailure``,
    ``NodeTimeout``, and :class:`~repro.data.store.CorruptBasket`.

  * :class:`HedgePolicy` — when a completed shard's modeled time sits in
    the straggler tail, the coordinator re-issues it to the replica and
    takes the faster *bit-identical* response (mismatch is
    ``IntegrityError``, never a silent pick).  The hedge delay is either
    fixed (``delay_s``) or quantile-based: ``multiplier`` times the
    ``quantile`` of the modeled times observed so far in the gather,
    which is the classic "hedge after the p95" tail-latency policy.
    Hedging operates on the **modeled clock** — a node that is modeled
    slow (straggle injection, cold links) gets hedged deterministically;
    real wall-clock hangs are the job of ``shard_timeout_s``.
"""

from __future__ import annotations

import random
from dataclasses import dataclass


@dataclass(frozen=True)
class RetryPolicy:
    """Per-query retry budget + deterministic exponential backoff.

    ``budget`` is the number of *re-issues* per shard per query (the
    primary's first attempt is free).  Attempt ``k`` (1-based) backs off
    ``base_delay_s * multiplier**(k-1)`` seconds, capped at
    ``max_delay_s``, with ±``jitter`` relative noise drawn from an RNG
    seeded by ``(seed, shard_id, k)`` — two runs with the same policy
    replay byte-identical delays.  The defaults reproduce the historical
    policy: one replica retry, primaries never retried.
    """

    budget: int = 1
    base_delay_s: float = 0.05
    multiplier: float = 2.0
    max_delay_s: float = 5.0
    jitter: float = 0.1
    seed: int = 0
    # retry the primary itself when no replica exists (or alternate
    # replica/primary when one does) — off by default: a primary that
    # just failed is assumed bad for the rest of the query
    retry_primary: bool = False

    def __post_init__(self):
        if self.budget < 0:
            raise ValueError("retry budget must be >= 0")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError("backoff delays must be >= 0")
        if not 0 <= self.jitter <= 1:
            raise ValueError("jitter must be in [0, 1]")

    def backoff_s(self, attempt: int, shard_id: int = 0) -> float:
        """Modeled backoff before re-issue ``attempt`` (1-based) of one
        shard.  Deterministic: seeded by (policy seed, shard, attempt)."""
        if attempt < 1:
            raise ValueError("attempts are 1-based")
        delay = min(
            self.base_delay_s * self.multiplier ** (attempt - 1),
            self.max_delay_s,
        )
        if self.jitter == 0 or delay == 0:
            return delay
        # mixed int seed (tuple seeds are deprecated): same inputs, same draw
        rng = random.Random(
            (self.seed * 1_000_003 + shard_id) * 1_000_003 + attempt
        )
        return delay * (1.0 + self.jitter * (2.0 * rng.random() - 1.0))

    def targets(self, primary, replica) -> list:
        """The node to use for each re-issue, in order — length
        ``budget``.  Replica first when one exists; ``retry_primary``
        alternates back to the primary (or, with no replica, retries the
        primary itself).  Without either, the list is empty and the
        first fault is terminal."""
        if replica is not None:
            if self.retry_primary:
                pair = [replica, primary]
                return [pair[i % 2] for i in range(self.budget)]
            return [replica] * self.budget
        if self.retry_primary:
            return [primary] * self.budget
        return []


#: the historical coordinator policy: one replica retry, no primary retry
DEFAULT_RETRY_POLICY = RetryPolicy()


@dataclass(frozen=True)
class HedgePolicy:
    """When (and whether) to hedge a straggling shard onto its replica.

    ``delay_s`` fixes the hedge delay outright; when ``None`` the delay
    is ``multiplier`` x the ``quantile`` of the modeled shard times
    completed so far in this gather (``min_delay_s`` floors the cold
    start before enough samples exist).  A shard whose modeled time
    exceeds the delay is re-issued to its replica; the coordinator keeps
    whichever response finishes the modeled race first — primary at its
    own modeled time, replica at ``delay + replica modeled`` — after
    verifying the two are bit-identical.

    ``jitter_guard`` makes the race decision deterministic: modeled
    shard times carry measured decode/filter components that jitter a
    few percent between two runs of identical work, so the replica only
    *wins* when it beats the primary by more than this relative margin
    (``delay + replica < primary * (1 - jitter_guard)``).  Flapping
    between two bit-identical responses on sub-jitter differences buys
    nothing and makes the reported modeled time (and the hedge ledger)
    nondeterministic; a genuine straggler rescue clears the guard by
    orders of magnitude.
    """

    delay_s: float | None = None
    quantile: float = 0.95
    multiplier: float = 2.0
    min_delay_s: float = 0.05
    min_samples: int = 2
    jitter_guard: float = 0.25

    def __post_init__(self):
        if self.delay_s is not None and self.delay_s < 0:
            raise ValueError("hedge delay_s must be >= 0")
        if not 0 < self.quantile <= 1:
            raise ValueError("hedge quantile must be in (0, 1]")
        if self.min_delay_s < 0:
            raise ValueError("min_delay_s must be >= 0")
        if not 0 <= self.jitter_guard < 1:
            raise ValueError("jitter_guard must be in [0, 1)")

    def delay(self, samples_modeled_s: list[float]) -> float:
        """The hedge delay given the modeled times gathered so far."""
        if self.delay_s is not None:
            return self.delay_s
        done = sorted(samples_modeled_s)
        if len(done) < max(self.min_samples, 1):
            return self.min_delay_s
        idx = min(int(self.quantile * len(done)), len(done) - 1)
        return max(self.multiplier * done[idx], self.min_delay_s)


@dataclass(frozen=True)
class RetryEvent:
    """One re-issue of one shard, with its modeled backoff — the
    detailed ledger behind ``ClusterSkimResult.retries``."""

    shard_id: int
    attempt: int  # 1-based re-issue ordinal
    error: str  # "fail" | "timeout" | "corrupt"
    failed_node: int
    next_node: int
    backoff_s: float


def classify_fault(exc: BaseException) -> str:
    """Map a shard-serving exception onto the fault taxonomy
    (DESIGN.md §14): ``corrupt`` | ``timeout`` | ``fail``."""
    from repro.data.store import CorruptBasket

    if isinstance(exc, CorruptBasket):
        return "corrupt"
    name = type(exc).__name__
    if "Timeout" in name:
        return "timeout"
    return "fail"


__all__ = [
    "DEFAULT_RETRY_POLICY",
    "HedgePolicy",
    "RetryEvent",
    "RetryPolicy",
    "classify_fault",
]
