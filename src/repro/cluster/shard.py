"""Dataset sharding for the distributed skim cluster (DESIGN.md §5a).

A fleet of storage nodes stripes one logical dataset: the partitioner
cuts the event axis into **basket windows** (the engine's unit of
fetch/filter work) and assigns whole windows to shards, so every shard
is a self-contained :class:`~repro.data.store.EventStore` whose basket
boundaries coincide with the parent's.  Window-aligned shards are what
make the scatter-gather merge bit-identical: each shard's baskets are
byte-identical to the parent's baskets for the same events, and the
coordinator can reassemble per-window survivor chunks in global window
order (coordinator.py).

Two assignment policies:

  * ``round_robin``    — window *i* → shard ``i % n`` (striping; even
    window counts, oblivious to size skew),
  * ``size_balanced``  — greedy longest-processing-time: windows sorted
    by compressed size, each assigned to the currently lightest shard
    (balances bytes when basket sizes are skewed).

Each shard carries a per-shard manifest (every branch's
:class:`~repro.data.store.BasketMeta` rows) and its SHA-256
``manifest_hash`` — the content address the skim-result cache keys on
(cache.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.data.store import BasketMeta, EventStore

POLICIES = ("round_robin", "size_balanced")


def window_spans(n_events: int, window_events: int) -> list[tuple[int, int]]:
    """Global basket-window spans: ``[start, stop)`` per window."""
    if window_events <= 0:
        raise ValueError("window_events must be positive")
    return [
        (s, min(s + window_events, n_events))
        for s in range(0, n_events, window_events)
    ]


@dataclass
class Shard:
    """One node's slice of the dataset: whole basket windows, ascending."""

    shard_id: int
    window_ids: list[int]  # global window indices, ascending
    spans: list[tuple[int, int]]  # global [start, stop) per window
    window_events: int
    store: EventStore  # the shard-local re-basketed store
    manifest_hash: str = ""
    comp_bytes: int = 0  # compressed payload this shard holds

    def __post_init__(self):
        if not self.manifest_hash:
            self.manifest_hash = self.store.manifest_hash()
        if not self.comp_bytes:
            self.comp_bytes = self.store.compressed_bytes()

    @property
    def n_events(self) -> int:
        return self.store.n_events

    def manifest(self) -> dict[str, list[BasketMeta]]:
        """Per-branch basket metadata of the shard-local store."""
        return {
            name: [
                self.store.basket_meta(name, i)
                for i in range(self.store.n_baskets(name))
            ]
            for name in self.store.branch_names()
        }

    def zone_stats(self, branch: str):
        """Shard-level aggregate zone-map stats of one branch — every
        basket of the shard folded into one
        :class:`~repro.data.store.ZoneStats` interval.  This is what the
        coordinator consults to skip a whole node before any RPC
        (DESIGN.md §9); per-window stats stay on the node for the finer
        in-engine pruning."""
        return self.store.window_stats(branch, 0, self.store.n_events)


def _window_comp_bytes(
    store: EventStore, spans: list[tuple[int, int]]
) -> list[int]:
    """Compressed bytes per window, summed over every branch's baskets."""
    sizes = [0] * len(spans)
    for name in store.branch_names():
        for w, (a, b) in enumerate(spans):
            for i in store.basket_ids_for_range(name, a, b):
                sizes[w] += store.basket_meta(name, i).comp_bytes
    return sizes


def assign_windows(
    n_windows: int,
    n_shards: int,
    policy: str = "round_robin",
    sizes: list[int] | None = None,
) -> list[list[int]]:
    """Window → shard assignment; returns ascending window ids per shard."""
    if policy not in POLICIES:
        raise ValueError(f"unknown shard policy {policy!r} (want {POLICIES})")
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    out: list[list[int]] = [[] for _ in range(n_shards)]
    if policy == "round_robin":
        for w in range(n_windows):
            out[w % n_shards].append(w)
        return out
    if sizes is None or len(sizes) != n_windows:
        raise ValueError("size_balanced needs one size per window")
    load = [0] * n_shards
    # LPT greedy; ties broken by shard id for determinism
    for w in sorted(range(n_windows), key=lambda i: (-sizes[i], i)):
        s = min(range(n_shards), key=lambda j: (load[j], j))
        out[s].append(w)
        load[s] += sizes[w]
    for shard in out:
        shard.sort()
    return out


def partition_store(
    store: EventStore,
    n_shards: int,
    policy: str = "round_robin",
    window_events: int | None = None,
) -> list[Shard]:
    """Partition ``store`` into ``n_shards`` window-aligned shards.

    ``window_events`` defaults to the store's ``basket_events`` and must
    be a multiple of it — otherwise shard-local basket boundaries drift
    from the parent's and the byte accounting / bit-identity contracts
    break.  Shards may be empty when there are fewer windows than shards.
    """
    window_events = window_events or store.basket_events
    if window_events % store.basket_events:
        raise ValueError(
            f"window_events={window_events} must be a multiple of "
            f"basket_events={store.basket_events} for basket-aligned shards"
        )
    spans = window_spans(store.n_events, window_events)
    sizes = (
        _window_comp_bytes(store, spans) if policy == "size_balanced" else None
    )
    assignment = assign_windows(len(spans), n_shards, policy, sizes)
    shards = []
    for sid, wids in enumerate(assignment):
        sh_spans = [spans[w] for w in wids]
        shards.append(
            Shard(
                shard_id=sid,
                window_ids=wids,
                spans=sh_spans,
                window_events=window_events,
                store=store.slice_events(sh_spans),
            )
        )
    return shards


@dataclass
class ShardMap:
    """Cluster-wide view: which shard owns each global window."""

    shards: list[Shard]
    window_events: int
    n_events: int
    owner: dict[int, int] = field(default_factory=dict)  # window -> shard

    @classmethod
    def build(cls, shards: list[Shard], n_events: int) -> "ShardMap":
        if not shards:
            raise ValueError("need at least one shard")
        owner: dict[int, int] = {}
        for sh in shards:
            for w in sh.window_ids:
                if w in owner:
                    raise ValueError(f"window {w} owned by two shards")
                owner[w] = sh.shard_id
        n_windows = len(window_spans(n_events, shards[0].window_events))
        missing = set(range(n_windows)) - set(owner)
        if missing:
            raise ValueError(f"windows not owned by any shard: {sorted(missing)}")
        return cls(
            shards=shards,
            window_events=shards[0].window_events,
            n_events=n_events,
            owner=owner,
        )
