"""Distributed skim cluster (DESIGN.md §5).

Sharded storage nodes + scatter-gather coordinator + content-addressed
skim-result cache: the multi-node layer over the PR-1 single-node fast
path.  ``build_cluster`` wires the whole stack in one call; merged
cluster output is bit-identical to the single-node ``run_skim`` result
for any node count, shard policy, replica retry, or cache state.
"""

from repro.cluster.cache import (
    CacheStats,
    SkimResultCache,
    cache_key,
    canonical_query,
    query_hash,
    versioned_key,
)
from repro.cluster.coordinator import (
    ClusterBatchResult,
    ClusterCoordinator,
    ClusterError,
    ClusterSkimResult,
    DegradedResult,
    IntegrityError,
    NodeTimeout,
    ShardError,
    build_cluster,
    merge_responses,
)
from repro.cluster.retry import (
    DEFAULT_RETRY_POLICY,
    HedgePolicy,
    RetryEvent,
    RetryPolicy,
    classify_fault,
)
from repro.cluster.node import (
    BatchResponse,
    NodeFailure,
    NodeResponse,
    StorageNode,
)
from repro.cluster.shard import Shard, ShardMap, partition_store, window_spans

__all__ = [
    "BatchResponse",
    "CacheStats",
    "ClusterBatchResult",
    "ClusterCoordinator",
    "ClusterError",
    "ClusterSkimResult",
    "DEFAULT_RETRY_POLICY",
    "DegradedResult",
    "HedgePolicy",
    "IntegrityError",
    "NodeFailure",
    "NodeResponse",
    "NodeTimeout",
    "RetryEvent",
    "RetryPolicy",
    "Shard",
    "ShardError",
    "classify_fault",
    "ShardMap",
    "SkimResultCache",
    "StorageNode",
    "build_cluster",
    "cache_key",
    "canonical_query",
    "merge_responses",
    "partition_store",
    "query_hash",
    "versioned_key",
    "window_spans",
]
