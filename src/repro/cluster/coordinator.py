"""Scatter-gather skim coordinator (DESIGN.md §5b).

One logical dataset, striped over N storage nodes: the coordinator
parses and compiles a query **once**, fans it out to every node (a
serially-deterministic loop or a thread pool), and gathers the per-shard
results back into ONE skim result that is bit-identical to running the
query on the unsharded store — same survivor rows in the same order,
same counts, same output bytes.

The merge works at basket-window granularity.  Every node reports its
per-window survivor ledger (``extras["window_rows"]``, the mergeable
result contract from ``core/engine.py``); the coordinator splits each
shard's concatenated output back into per-window column chunks and
reassembles them in **global window order**, which is exactly the order
the single-node executor produced them in.  Accounting merges with
``FetchStats.merged`` / ``Breakdown.merged`` — for aligned shards the
cluster's fetched bytes and request counts equal the single-node run's.

Failures (DESIGN.md §14): a shard that raises :class:`NodeFailure`,
:class:`~repro.data.store.CorruptBasket`, or blows its deadline is
re-issued under the per-query :class:`~repro.cluster.retry.RetryPolicy`
(replica first, deterministic modeled backoff); stragglers stretch the
modeled makespan unless a :class:`~repro.cluster.retry.HedgePolicy`
hedges them onto the replica — the coordinator takes the faster
*bit-identical* response (mismatch raises :class:`IntegrityError`,
never a silent pick).  ``allow_partial=True`` turns shards that exhaust
their budget into an explicit :class:`DegradedResult` whose error
manifest accounts every missing window; the default refuses.  Repeat
queries: the coordinator consults the content-addressed
:class:`~repro.cluster.cache.SkimResultCache` per (query, shard) before
scattering, so warm shards skip phase 1 (and everything else) entirely.
Before either, zone-map pushdown (DESIGN.md §9): shard-level aggregate
stats that prove a shard empty let the coordinator answer it without
any RPC at all (single-query path; batches rely on the nodes'
window-level pruning).

Time is reported in both currencies (DESIGN.md §2c): modeled cluster
wall-clock = ``max`` over nodes of the node-local modeled pipeline bound
(+ injected straggle) plus the measured merge, next to the realized
wall-clock on this host.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from dataclasses import dataclass, field, replace

import numpy as np

from repro.cluster.cache import SkimResultCache, query_hash, versioned_key
from repro.cluster.node import BatchResponse, NodeFailure, NodeResponse, StorageNode
from repro.cluster.retry import (
    DEFAULT_RETRY_POLICY,
    HedgePolicy,
    RetryEvent,
    RetryPolicy,
    classify_fault,
)
from repro.core.engine import Breakdown, SkimResult, _skipped_requests, drain
from repro.core.planner import plan_skim
from repro.core.query import Query, parse_query
from repro.core.zonemap import PRUNE, classify_span
from repro.data.store import CorruptBasket, EventStore, FetchStats
from repro.obs.schema import SkimReport, make_extras
from repro.obs.trace import NULL_TRACER, Tracer

CONCURRENCY_MODES = ("serial", "threads")

#: exceptions the retry policy covers — one more attempt, not an abort
RETRYABLE = (NodeFailure, CorruptBasket)


class ClusterError(RuntimeError):
    """A shard could not be served within its retry budget."""


class NodeTimeout(ClusterError):
    """A shard blew its per-shard deadline and no retry target could
    cover for it.  Without a deadline a straggling node without a
    replica hangs the whole gather forever — ``shard_timeout_s`` turns
    that into this error (or a replica retry) instead.  In threads mode
    the deadline is wall-clock (``Future.result(timeout=...)``); in
    serial mode it is enforced against the *modeled* clock
    (``NodeResponse.modeled_s``), since a serial in-process gather
    cannot be preempted by wall time.

    Leak semantics (threads mode): the worker thread that timed out is
    deliberately NOT joined — it still holds the hung node's request and
    parks its eventual result (or exception) in an abandoned future.
    The pool is shut down with ``wait=False``, gather threads are named
    ``skim-gather-*`` so leaked workers are identifiable in thread
    dumps, and a fresh pool per gather means a subsequent query on the
    same coordinator is unaffected (pinned by tests/test_faults.py)."""


class IntegrityError(RuntimeError):
    """Two executions of the same shard disagreed bit-for-bit.

    Raised when a hedged replica response does not match the primary's
    (output manifest hash, survivor counts, or window ledger) — the one
    fault the coordinator must never paper over, because picking either
    side silently would be exactly the corruption this layer exists to
    prevent.  Deliberately NOT a :class:`ClusterError`: ``allow_partial``
    degrades budget-exhausted shards, never integrity violations."""


@dataclass
class ClusterSkimResult:
    """Merged scatter-gather result; the cluster-level ``SkimResult``."""

    output: EventStore
    n_input: int
    n_passed: int
    breakdown: Breakdown  # cluster-wide work: sum over shards
    stats: FetchStats  # cluster-wide bytes/requests: sum over shards
    responses: list[NodeResponse]  # per shard, shard order
    retries: list[tuple[int, int, int]]  # (shard_id, failed_node, used_node)
    modeled_total_s: float  # max-over-nodes pipeline bound + merge
    merge_s: float
    wall_s: float
    extras: dict = field(default_factory=dict)

    @property
    def selectivity(self) -> float:
        return self.n_passed / max(self.n_input, 1)

    @property
    def cache_hits(self) -> int:
        return sum(1 for r in self.responses if r.cached)

    @property
    def pruned_shards(self) -> list[int]:
        """Shards answered from zone-map stats without any RPC."""
        return [r.shard_id for r in self.responses if r.pruned]

    @property
    def degraded(self) -> bool:
        return False


@dataclass(frozen=True)
class ShardError:
    """One shard's terminal failure inside a degraded gather: which
    windows are missing and why (DESIGN.md §14)."""

    shard_id: int
    node_id: int
    kind: str  # "fail" | "timeout" | "corrupt"
    message: str
    window_ids: list[int]
    # global event spans of the missing windows, [start, stop)
    spans: list[tuple[int, int]]

    @property
    def missing_events(self) -> int:
        return sum(b - a for a, b in self.spans)


@dataclass
class DegradedResult(ClusterSkimResult):
    """A partial cluster result: every surviving window bit-identical to
    the reference, every missing window explicitly accounted.

    Only produced under ``allow_partial=True`` after a shard exhausts
    its retry budget; ``errors`` is the per-shard error manifest.  A
    degraded result is **never cached** — the per-shard result cache
    only ever stores complete shard responses, and the merged object
    carries no cache entry of its own.
    """

    errors: list[ShardError] = field(default_factory=list)

    @property
    def degraded(self) -> bool:
        return True

    @property
    def missing_windows(self) -> list[int]:
        return sorted(w for e in self.errors for w in e.window_ids)


@dataclass
class _Gather:
    """Per-gather fault ledger (one per ``iter_run`` invocation; list
    appends are atomic under the GIL, so the threads gather shares it
    without a lock)."""

    retries: list[tuple[int, int, int]] = field(default_factory=list)
    events: list[RetryEvent] = field(default_factory=list)
    hedges: list[tuple[int, str]] = field(default_factory=list)  # (shard, outcome)
    samples: list[float] = field(default_factory=list)  # modeled_s, hedge input
    errors: list[ShardError] = field(default_factory=list)
    corrupts: list[int] = field(default_factory=list)  # shard per CorruptBasket

    @property
    def backoff_s(self) -> float:
        return sum(e.backoff_s for e in self.events)

    def hedge_count(self, outcome: str) -> int:
        return sum(1 for _, o in self.hedges if o == outcome)


@dataclass
class ClusterBatchResult:
    """Scatter-gather over a shared-scan tenant batch."""

    results: list[ClusterSkimResult]  # per tenant, request order
    shared_phase1_bytes: int  # sum of the nodes' shared passes
    naive_phase1_bytes: int  # N independent cluster scans
    modeled_total_s: float
    wall_s: float
    cached_tenants: list[int] = field(default_factory=list)

    @property
    def amortization(self) -> float:
        return self.naive_phase1_bytes / max(self.shared_phase1_bytes, 1)


# ---------------------------------------------------------------------------
# per-window split + global-order merge
# ---------------------------------------------------------------------------


def _split_windows(response: NodeResponse) -> dict[int, dict[str, np.ndarray]]:
    """Split a shard's concatenated output into per-GLOBAL-window chunks.

    The i-th entry of the node's window ledger corresponds to the i-th
    ascending global window this shard owns (window-aligned shards keep
    local and global window order identical).
    """
    result = response.result
    rows = result.extras.get("window_rows")
    if rows is None:
        raise ValueError(
            "node result lacks extras['window_rows'] — not a mergeable result"
        )
    if len(rows) != len(response.window_ids):
        raise ValueError(
            f"shard {response.shard_id}: ledger has {len(rows)} windows, "
            f"shard owns {len(response.window_ids)}"
        )
    out_store = response.result.output
    ks = np.array([k for _, _, k in rows], dtype=np.int64)
    bounds = np.concatenate([[0], np.cumsum(ks)])
    chunks: dict[int, dict[str, np.ndarray]] = {
        w: {} for w in response.window_ids
    }
    flat_cache: dict[str, np.ndarray] = {}
    for name, br in out_store.branches.items():
        if br.jagged:
            continue
        arr = out_store.read_flat(name)
        flat_cache[name] = arr
        for i, w in enumerate(response.window_ids):
            chunks[w][name] = arr[bounds[i] : bounds[i + 1]]
    for name, br in out_store.branches.items():
        if not br.jagged:
            continue
        values = out_store.read_jagged(name)[0]
        counts = flat_cache[br.counts_branch].astype(np.int64)
        voffsets = np.concatenate([[0], np.cumsum(counts)])
        for i, w in enumerate(response.window_ids):
            chunks[w][name] = values[
                voffsets[bounds[i]] : voffsets[bounds[i + 1]]
            ]
    return chunks


def merge_responses(
    responses: list[NodeResponse],
    basket_events: int,
    codec: str,
) -> tuple[EventStore, int, int]:
    """Reassemble shard outputs in global window order.

    Returns ``(output_store, n_input, n_passed)``.  The concatenation
    order — per branch, per global window, survivors in window order —
    is exactly the single-node executor's, and the store is rebuilt with
    the same basketing and codec, so rows, counts, and output bytes are
    bit-identical to the unsharded run.
    """
    template = max(
        (r for r in responses if r.result.output.branches),
        key=lambda r: r.result.output.n_events,
        default=None,
    )
    if template is None:
        raise ValueError("no shard produced an output schema")
    out_branches = template.result.output.branches
    jagged = {
        n: b.counts_branch for n, b in out_branches.items() if b.jagged
    }

    per_window: dict[int, dict[str, np.ndarray]] = {}
    for r in responses:
        per_window.update(_split_windows(r))

    order = sorted(per_window)
    columns: dict[str, np.ndarray] = {}
    for name, br in out_branches.items():
        parts = [per_window[w][name] for w in order if name in per_window[w]]
        columns[name] = (
            np.concatenate(parts)
            if parts
            else np.empty(0, dtype=br.np_dtype())
        )
    merged = EventStore.from_arrays(
        columns, jagged=jagged, basket_events=basket_events, codec=codec
    )
    n_input = sum(r.result.n_input for r in responses)
    n_passed = sum(r.result.n_passed for r in responses)
    return merged, n_input, n_passed


# ---------------------------------------------------------------------------
# the coordinator
# ---------------------------------------------------------------------------


class ClusterCoordinator:
    """Scatter a query to N storage nodes, gather one merged result.

    ``replicas`` maps shard_id -> a standby :class:`StorageNode` holding
    the same shard; a primary that raises a retryable fault is re-issued
    there under ``retry_policy`` (default: the historical one-replica
    retry).  ``hedge`` (optional :class:`HedgePolicy`) re-issues shards
    whose modeled time sits in the straggler tail.  ``cache`` (optional)
    is consulted per (query, shard manifest) before any node executes.
    ``metrics`` (optional :class:`~repro.obs.metrics.MetricsRegistry`)
    counts retries, hedges, and quarantined baskets.
    ``allow_partial`` sets the default degradation stance for
    :meth:`run` / :meth:`iter_run` (refused unless enabled).
    """

    def __init__(
        self,
        nodes: list[StorageNode],
        replicas: dict[int, StorageNode] | None = None,
        cache: SkimResultCache | None = None,
        concurrency: str = "serial",
        basket_events: int | None = None,
        codec: str | None = None,
        prune: bool = True,
        shard_timeout_s: float | None = None,
        retry_policy: RetryPolicy = DEFAULT_RETRY_POLICY,
        hedge: HedgePolicy | None = None,
        metrics=None,
        allow_partial: bool = False,
    ):
        if not nodes:
            raise ValueError("need at least one storage node")
        if concurrency not in CONCURRENCY_MODES:
            raise ValueError(
                f"concurrency must be one of {CONCURRENCY_MODES}, "
                f"got {concurrency!r}"
            )
        self.nodes = list(nodes)
        self.replicas = dict(replicas or {})
        self.cache = cache
        self.concurrency = concurrency
        # consult shard-level aggregate zone-map stats before any RPC
        # (DESIGN.md §9): a shard whose manifest proves zero survivors is
        # answered by the coordinator itself — no node, no cache traffic.
        self.prune = prune
        if shard_timeout_s is not None and shard_timeout_s <= 0:
            raise ValueError("shard_timeout_s must be positive (or None)")
        # per-shard deadline: wall-clock in threads mode, modeled in
        # serial mode; None = wait forever
        self.shard_timeout_s = shard_timeout_s
        self.retry_policy = retry_policy
        self.hedge = hedge
        self.metrics = metrics
        self.allow_partial = allow_partial
        ref = nodes[0].shard.store
        self.basket_events = basket_events or ref.basket_events
        self.codec = codec or ref.codec
        self.total_events = sum(n.shard.n_events for n in self.nodes)

    # -- single query ---------------------------------------------------------

    def _compile_once(self, query: Query | dict | str) -> tuple[Query, str]:
        """Parse + compile the query once for the whole fan-out.

        Works on a private copy of a caller-supplied ``Query`` so the
        attached program can never go stale if the caller mutates and
        reuses their object elsewhere."""
        if isinstance(query, Query):
            q = replace(query, meta=dict(query.meta))
        else:
            q = parse_query(query)
        qh = query_hash(q)
        from repro.kernels.predicate_eval import compile_query

        # every node's planner picks this up instead of recompiling
        # (SkimPlan.compiled_program checks the query's meta)
        q.meta["_compiled_program"] = compile_query(q)
        return q, qh

    @staticmethod
    def _hit_response(hit: NodeResponse, node: StorageNode) -> NodeResponse:
        """Rebind a cached response to the serving node.  A hit pays only
        output transfer; everything else (phase 1, decode, filter,
        phase 2, write) is skipped."""
        return replace(
            hit,
            node_id=node.node_id,
            shard_id=node.shard.shard_id,
            window_ids=list(node.shard.window_ids),
            modeled_s=hit.result.breakdown.output_transfer,
            straggle_s=0.0,
            wall_s=0.0,
            cached=True,
            trace=None,  # a replay has no execution of its own to trace
        )

    def _pruned_response(self, node: StorageNode, query: Query) -> NodeResponse | None:
        """Answer a shard from its manifest alone, or ``None``.

        Consults the shard-level aggregate zone-map stats
        (:meth:`Shard.zone_stats` via :func:`classify_span` over the whole
        shard): when they prove no event of the shard can survive, the
        coordinator synthesizes the node's answer — an empty output with
        the full per-window ledger, exactly what the node's executor
        would have produced (zero survivors emit no jagged map, matching
        the engine's empty-output convention) — and the StorageNode is
        never contacted.  Shards the aggregate cannot prove still get
        window-level pruning inside the node's engine.
        """
        shard = node.shard
        st = shard.store
        if st.n_events == 0:
            return None  # empty shards execute trivially; keep one path
        if classify_span(query, st, 0, st.n_events) != PRUNE:
            return None
        # the aggregate interval proved the shard; every window prunes a
        # fortiori (window stats are subsets of the shard hull), so price
        # the skip per window directly — no re-classification needed, and
        # the per-window request model matches what the node's executor
        # would have ledgered
        plan = plan_skim(query, st)
        spans = [
            (s, min(s + shard.window_events, st.n_events))
            for s in range(0, st.n_events, shard.window_events)
        ]
        stats = FetchStats()
        for a, bnd in spans:
            nbytes, nb = st.range_comp_bytes(plan.filter_branches, a, bnd)
            stats.skip(nbytes, _skipped_requests(nbytes, nb, True))
        cols = {
            name: np.empty(0, dtype=st.branches[name].np_dtype())
            for name in plan.output_branches
        }
        out = EventStore.from_arrays(
            cols, jagged={}, basket_events=st.basket_events, codec=st.codec
        )
        report = SkimReport(
            mode="near_data",
            fused=False,
            pipelined=False,
            prune=True,
            output_bytes=out.compressed_bytes(),
            window_rows=[(a, b, 0) for a, b in spans],
            pruned_windows=[(a, b, PRUNE) for a, b in spans],
            shard_pruned=True,
        )
        result = SkimResult(
            mode="near_data",
            output=out,
            n_input=st.n_events,
            n_passed=0,
            breakdown=Breakdown(),
            stats=stats,
            plan=plan,
            busy_fraction=0.0,
            extras=report.legacy_extras(),
            report=report,
        )
        return NodeResponse(
            node_id=node.node_id,
            shard_id=shard.shard_id,
            window_ids=list(shard.window_ids),
            result=result,
            modeled_s=0.0,
            straggle_s=0.0,
            wall_s=0.0,
            pruned=True,
        )

    @staticmethod
    def _node_tracer(tracer, node: StorageNode):
        """A fresh node-local tracer per execution attempt (same clock as
        the coordinator's) — its spans ride back on the response for
        :meth:`Tracer.adopt`.  ``None`` when tracing is off keeps the
        node on the NULL_TRACER fast path."""
        if tracer is None or not tracer.enabled:
            return None
        return Tracer(clock=tracer.clock, name=f"node-{node.node_id}")

    def _execute_node(self, node: StorageNode, query: Query, tracer=None):
        """One execution attempt on one node.  The tracer kwarg is passed
        only when tracing — fault-injection tests stub ``execute`` with
        plain callables."""
        ntr = self._node_tracer(tracer, node)
        return (
            node.execute(query, tracer=ntr)
            if ntr is not None
            else node.execute(query)
        )

    def _inc(self, name: str, **labels) -> None:
        if self.metrics is not None:
            self.metrics.inc(name, **labels)

    @staticmethod
    def _responses_identical(a: NodeResponse, b: NodeResponse) -> bool:
        """Bit-identity of two executions of the same shard: survivor
        counts, the per-window ledger, and the content address of the
        output baskets (manifest hash covers every blob digest)."""
        ra, rb = a.result, b.result
        return (
            ra.n_passed == rb.n_passed
            and ra.n_input == rb.n_input
            and list(ra.extras.get("window_rows", []))
            == list(rb.extras.get("window_rows", []))
            and ra.output.manifest_hash() == rb.output.manifest_hash()
        )

    def _terminal_error(
        self, node: StorageNode, kind: str, attempts: int
    ) -> ClusterError:
        sid = node.shard.shard_id
        verb = "returned corrupt data" if kind == "corrupt" else "failed"
        if attempts == 0:
            exc = ClusterError(
                f"shard {sid}: primary node {node.node_id} {verb} "
                "and no replica is configured"
            )
        else:
            exc = ClusterError(
                f"shard {sid}: primary and replica both failed "
                f"(retry budget {self.retry_policy.budget} exhausted, "
                f"last fault: {kind})"
            )
        exc.kind = kind
        return exc

    def _maybe_hedge(
        self,
        node: StorageNode,
        resp: NodeResponse,
        query: Query,
        g: _Gather,
        tracer=None,
    ) -> NodeResponse:
        """Hedge a modeled straggler onto its replica (DESIGN.md §14).

        Operates on the modeled clock: when the completed response's
        modeled time exceeds the hedge delay (fixed or quantile of the
        gather's completed shards), the shard is re-issued to the
        replica and the faster of the two modeled finishes wins —
        primary at ``modeled_s``, replica at ``delay + modeled_s`` —
        after the two responses are proven bit-identical
        (:class:`IntegrityError` otherwise, never a silent pick)."""
        if self.hedge is None or resp.cached or resp.pruned:
            return resp
        replica = self.replicas.get(node.shard.shard_id)
        if replica is None or resp.node_id == replica.node_id:
            return resp
        delay = self.hedge.delay(list(g.samples))
        if resp.modeled_s <= delay:
            return resp
        sid = node.shard.shard_id
        try:
            hresp = self._execute_node(replica, query, tracer=tracer)
        except RETRYABLE:
            # the hedge itself faulted: keep the primary's response
            g.hedges.append((sid, "cancelled"))
            self._inc("cluster_hedges_total", outcome="cancelled")
            return resp
        if not self._responses_identical(resp, hresp):
            raise IntegrityError(
                f"shard {sid}: hedged replica {replica.node_id} disagrees "
                f"with node {resp.node_id} bit-for-bit — refusing to pick"
            )
        # the guard keeps the race deterministic: modeled times carry
        # measured components that jitter run-to-run, and switching
        # between bit-identical responses on sub-jitter margins would
        # make the ledger (and modeled_total_s) nondeterministic
        effective = delay + hresp.modeled_s
        if effective < resp.modeled_s * (1.0 - self.hedge.jitter_guard):
            g.hedges.append((sid, "won"))
            self._inc("cluster_hedges_total", outcome="won")
            return replace(hresp, modeled_s=effective)
        g.hedges.append((sid, "lost"))
        self._inc("cluster_hedges_total", outcome="lost")
        return resp

    def _serve_shard(
        self,
        node: StorageNode,
        query: Query,
        qh: str,
        g: _Gather,
        tracer=None,
    ) -> NodeResponse:
        """Prune consult -> cache consult -> primary -> retry loop under
        the :class:`RetryPolicy` -> hedge consult."""
        if self.prune:
            pruned = self._pruned_response(node, query)
            if pruned is not None:
                return pruned
        key = versioned_key(qh, node.shard.manifest_hash)
        if self.cache is not None:
            hit = self.cache.get(key)
            if hit is not None:
                return self._hit_response(hit, node)
        policy = self.retry_policy
        replica = self.replicas.get(node.shard.shard_id)
        targets = policy.targets(node, replica)
        sid = node.shard.shard_id
        current = node
        attempt = 0
        backoff_total = 0.0
        while True:
            try:
                resp = self._execute_node(current, query, tracer=tracer)
                break
            except RETRYABLE as exc:
                kind = classify_fault(exc)
                if kind == "corrupt":
                    g.corrupts.append(sid)
                    self._inc("cluster_corrupt_baskets_total")
                if attempt >= len(targets):
                    raise self._terminal_error(node, kind, attempt) from exc
                nxt = targets[attempt]
                attempt += 1
                backoff = policy.backoff_s(attempt, sid)
                backoff_total += backoff
                g.events.append(
                    RetryEvent(
                        sid, attempt, kind,
                        current.node_id, nxt.node_id, backoff,
                    )
                )
                g.retries.append((sid, current.node_id, nxt.node_id))
                self._inc("cluster_retries_total", error=kind)
                current = nxt
        if backoff_total:
            # backoff is modeled, never slept: it stretches the shard's
            # modeled time (and therefore the cluster makespan) exactly
            resp = replace(resp, modeled_s=resp.modeled_s + backoff_total)
        resp = self._maybe_hedge(node, resp, query, g, tracer=tracer)
        if not (resp.cached or resp.pruned):
            g.samples.append(resp.modeled_s)
        if self.cache is not None:
            # strip the span list: a future replay of this entry must not
            # re-adopt this execution's spans into an unrelated tree
            self.cache.put(
                key,
                replace(resp, trace=None),
                nbytes=resp.result.extras.get(
                    "output_bytes", resp.result.output.compressed_bytes()
                ),
                fetch_bytes=resp.result.stats.bytes_fetched,
            )
        return resp

    def _timeout_fallback(
        self,
        node: StorageNode,
        query: Query,
        qh: str,
        g: _Gather,
        tracer=None,
        modeled: bool = False,
    ) -> NodeResponse:
        """A primary blew the shard deadline (wall-clock in threads mode,
        modeled in serial mode): re-issue under the retry policy, or
        raise :class:`NodeTimeout`.  Retries run on the gather thread —
        a second wall deadline would need its own pool — and a fallback
        that is *itself* over the modeled deadline still times out."""
        sid = node.shard.shard_id
        replica = self.replicas.get(sid)
        targets = self.retry_policy.targets(node, replica)
        if not targets:
            raise NodeTimeout(
                f"shard {sid}: node {node.node_id} "
                f"exceeded the {self.shard_timeout_s}s shard deadline "
                "and no replica is configured"
            )
        policy = self.retry_policy
        failed = node
        resp = None
        last: Exception | None = None
        backoff_total = 0.0
        for attempt, nxt in enumerate(targets, start=1):
            backoff = policy.backoff_s(attempt, sid)
            backoff_total += backoff
            g.events.append(
                RetryEvent(
                    sid, attempt, "timeout" if attempt == 1 else
                    classify_fault(last), failed.node_id, nxt.node_id,
                    backoff,
                )
            )
            g.retries.append((sid, failed.node_id, nxt.node_id))
            self._inc("cluster_retries_total", error="timeout")
            try:
                resp = self._execute_node(nxt, query, tracer=tracer)
                break
            except RETRYABLE as exc:
                if classify_fault(exc) == "corrupt":
                    g.corrupts.append(sid)
                    self._inc("cluster_corrupt_baskets_total")
                last = exc
                failed = nxt
        if resp is None:
            exc = NodeTimeout(
                f"shard {sid}: node {node.node_id} "
                f"exceeded the {self.shard_timeout_s}s shard deadline "
                "and the replica failed"
            )
            exc.kind = "timeout"
            raise exc from last
        resp = replace(resp, modeled_s=resp.modeled_s + backoff_total)
        if modeled and self._deadline_blown(resp):
            exc = NodeTimeout(
                f"shard {sid}: retry target node {resp.node_id} also "
                f"exceeded the {self.shard_timeout_s}s modeled shard "
                "deadline"
            )
            exc.kind = "timeout"
            raise exc
        if self.cache is not None:
            self.cache.put(
                versioned_key(qh, node.shard.manifest_hash),
                replace(resp, trace=None),
                nbytes=resp.result.extras.get(
                    "output_bytes", resp.result.output.compressed_bytes()
                ),
                fetch_bytes=resp.result.stats.bytes_fetched,
            )
        return resp

    def _deadline_blown(self, resp: NodeResponse) -> bool:
        """Modeled-clock deadline check — serial mode only.  Threads
        mode keeps the deadline in the wall currency (the two are not
        comparable: a modeled straggler resolves instantly on this
        host, and a wall hang has no modeled time at all)."""
        return (
            self.shard_timeout_s is not None
            and not resp.cached
            and not resp.pruned
            and resp.modeled_s > self.shard_timeout_s
        )

    def _shard_error(self, node: StorageNode, exc: Exception) -> ShardError:
        """Fold one terminal shard failure into the degradation
        manifest: every window the shard owned, with its global event
        span, is explicitly missing."""
        kind = getattr(exc, "kind", None) or (
            "timeout" if isinstance(exc, NodeTimeout) else "fail"
        )
        we = node.shard.window_events
        spans = [
            (w * we, min(w * we + we, self.total_events))
            for w in node.shard.window_ids
        ]
        self._inc("cluster_degraded_shards_total", error=kind)
        return ShardError(
            shard_id=node.shard.shard_id,
            node_id=node.node_id,
            kind=kind,
            message=str(exc),
            window_ids=list(node.shard.window_ids),
            spans=spans,
        )

    def _gather_serial(
        self, query: Query, qh: str, g: _Gather, tracer, allow_partial: bool
    ):
        """Serially-deterministic gather.  ``shard_timeout_s`` is
        enforced against the modeled clock (a serial in-process loop has
        no wall-clock preemption point) — a shard whose modeled time
        exceeds the deadline is re-issued exactly like a threads-mode
        wall timeout."""
        for node in self.nodes:
            try:
                resp = self._serve_shard(node, query, qh, g, tracer=tracer)
                if self._deadline_blown(resp):
                    resp = self._timeout_fallback(
                        node, query, qh, g, tracer=tracer, modeled=True
                    )
            except ClusterError as exc:
                if not allow_partial:
                    raise
                g.errors.append(self._shard_error(node, exc))
                continue
            yield resp

    def _gather_threads(
        self, query: Query, qh: str, g: _Gather, tracer, allow_partial: bool
    ):
        """Scatter to the pool, yield responses in shard order as they
        resolve, each bounded by ``shard_timeout_s``.  With a deadline
        configured the pool is NOT joined on exit — a hung worker must
        not block the gather that just timed it out (see
        :class:`NodeTimeout` for the leak semantics); gather threads are
        named ``skim-gather-*`` so a leaked one is identifiable."""
        ex = ThreadPoolExecutor(
            max_workers=len(self.nodes), thread_name_prefix="skim-gather"
        )
        try:
            futs = [
                ex.submit(self._serve_shard, node, query, qh, g, tracer)
                for node in self.nodes
            ]
            for node, fut in zip(self.nodes, futs):
                try:
                    try:
                        resp = fut.result(timeout=self.shard_timeout_s)
                    except FutureTimeout:
                        resp = self._timeout_fallback(
                            node, query, qh, g, tracer=tracer
                        )
                except ClusterError as exc:
                    if not allow_partial:
                        raise
                    g.errors.append(self._shard_error(node, exc))
                    continue
                yield resp
        finally:
            ex.shutdown(
                wait=self.shard_timeout_s is None, cancel_futures=True
            )

    def run(
        self,
        query: Query | dict | str,
        tracer=None,
        allow_partial: bool | None = None,
    ) -> ClusterSkimResult:
        return drain(
            self.iter_run(query, tracer=tracer, allow_partial=allow_partial)
        )

    def iter_run(
        self,
        query: Query | dict | str,
        tracer=None,
        allow_partial: bool | None = None,
    ):
        """Streaming form of :meth:`run`: a generator yielding each
        shard's :class:`NodeResponse` (with its per-window survivor
        ledger) as the gather progresses, in shard order, and returning
        the merged :class:`ClusterSkimResult` as the generator's value
        (``drain()`` recovers it).  Closing the generator between
        shards abandons the remaining gather — the service layer's
        cancellation point.

        ``allow_partial`` (default: the coordinator's stance) degrades
        shards that exhaust their retry budget into a
        :class:`DegradedResult` instead of raising — unless *every*
        shard failed, which always raises.  :class:`IntegrityError`
        always propagates regardless.

        ``tracer`` records the cluster span tree: a ``cluster_query``
        root, the one-shot plan/compile, and — under the ``merge``
        umbrella — one ``shard`` span per response with the node's own
        spans adopted beneath it (exactly once; cached and pruned
        responses have none), plus one ``retry`` / ``hedge`` span per
        fault-layer event."""
        if allow_partial is None:
            allow_partial = self.allow_partial
        tr = tracer if tracer is not None else NULL_TRACER
        t0 = time.perf_counter()
        qsid = tr.begin(
            "cluster_query",
            kind="query",
            n_nodes=len(self.nodes),
            concurrency=self.concurrency,
        )
        plan_t0 = tr.now()
        q, qh = self._compile_once(query)
        tr.add_span(
            "plan", kind="plan", t0=plan_t0, t1=tr.now(),
            parent=qsid, query_hash=qh,
        )
        g = _Gather()

        if self.concurrency == "threads":
            gather = self._gather_threads(q, qh, g, tracer, allow_partial)
        else:
            gather = self._gather_serial(q, qh, g, tracer, allow_partial)
        # the merge span is the umbrella for the whole gather: every
        # shard span (and the node spans adopted under it) re-parents
        # here, so the export shows scatter + reassembly as one phase
        msid = tr.begin("merge", kind="merge")
        responses: list[NodeResponse] = []
        for resp in gather:
            ssid = tr.begin(
                f"shard[{resp.shard_id}]",
                kind="shard",
                shard=resp.shard_id,
                node=resp.node_id,
                cached=resp.cached,
                pruned=resp.pruned,
            )
            if resp.trace:
                tr.adopt(resp.trace, parent=ssid)
            tr.end(ssid, n_passed=resp.result.n_passed)
            responses.append(resp)
            try:
                yield resp
            except GeneratorExit:
                tr.end(msid, cancelled=True)
                tr.end(qsid, cancelled=True)
                raise
        for ev in g.events:
            tr.add_span(
                f"retry[shard {ev.shard_id}]", kind="retry",
                t0=tr.now(), t1=tr.now(), parent=msid,
                shard=ev.shard_id, attempt=ev.attempt, error=ev.error,
                failed_node=ev.failed_node, next_node=ev.next_node,
                backoff_s=ev.backoff_s,
            )
        for sid, outcome in g.hedges:
            tr.add_span(
                f"hedge[shard {sid}]", kind="hedge",
                t0=tr.now(), t1=tr.now(), parent=msid,
                shard=sid, outcome=outcome,
            )
        if not responses:
            tr.end(msid, failed=True)
            tr.end(qsid, failed=True)
            errs = "; ".join(e.message for e in g.errors) or "no shards"
            raise ClusterError(f"every shard failed: {errs}")

        t_merge = time.perf_counter()
        output, n_input, n_passed = merge_responses(
            responses, self.basket_events, self.codec
        )
        merge_s = time.perf_counter() - t_merge
        tr.end(msid, merge_s=merge_s)

        breakdown = Breakdown.merged([r.result.breakdown for r in responses])
        stats = FetchStats.merged([r.result.stats for r in responses])
        slowest = max((r.modeled_s for r in responses), default=0.0)
        tr.end(qsid, n_passed=n_passed, bytes=stats.bytes_fetched)
        extras = make_extras(
            output_bytes=output.compressed_bytes(),
            n_nodes=len(self.nodes),
            concurrency=self.concurrency,
            query_hash=qh,
            pruned_shards=[r.shard_id for r in responses if r.pruned],
            prune_saved_bytes=stats.bytes_skipped,
            retry_attempts=len(g.events),
            retry_backoff_s=g.backoff_s,
            corrupt_baskets=len(g.corrupts),
        )
        if self.hedge is not None:
            extras.update(
                make_extras(
                    hedges_won=g.hedge_count("won"),
                    hedges_lost=g.hedge_count("lost"),
                    hedges_cancelled=g.hedge_count("cancelled"),
                )
            )
        common = dict(
            output=output,
            n_input=n_input,
            n_passed=n_passed,
            breakdown=breakdown,
            stats=stats,
            responses=responses,
            retries=g.retries,
            modeled_total_s=slowest + merge_s,
            merge_s=merge_s,
            wall_s=time.perf_counter() - t0,
            extras=extras,
        )
        if g.errors:
            result = DegradedResult(**common, errors=list(g.errors))
            extras.update(
                make_extras(
                    degraded=True,
                    missing_windows=result.missing_windows,
                )
            )
            return result
        return ClusterSkimResult(**common)

    # -- tenant batches (shared scan per node) --------------------------------

    def run_batch(
        self, queries: list[Query | dict | str]
    ) -> ClusterBatchResult:
        """Scatter a tenant batch: each node runs ONE shared scan for all
        non-cached tenants; per-tenant results merge exactly like single
        queries.  A tenant is served from cache only when *every* shard
        hits (partial hits re-run with the batch — the shared pass is one
        fetch either way)."""
        t0 = time.perf_counter()
        compiled = [self._compile_once(qdoc) for qdoc in queries]

        cached_responses: dict[int, list[NodeResponse]] = {}
        if self.cache is not None:
            for ti, (_q, qh) in enumerate(compiled):
                keys = [
                    versioned_key(qh, node.shard.manifest_hash)
                    for node in self.nodes
                ]
                hits = self.cache.get_many(keys)  # atomic all-or-nothing
                if hits is not None:
                    cached_responses[ti] = [
                        self._hit_response(hit, node)
                        for hit, node in zip(hits, self.nodes)
                    ]
        live = [ti for ti in range(len(compiled)) if ti not in cached_responses]

        batch_responses: list[BatchResponse] = []
        retries: list[tuple[int, int, int]] = []
        if live:
            live_queries = [compiled[ti][0] for ti in live]

            def scan(node: StorageNode) -> BatchResponse:
                """Shared scan under the same retry policy as single
                queries: re-issue on any RETRYABLE fault, walking the
                policy's target list."""
                sid = node.shard.shard_id
                replica = self.replicas.get(sid)
                targets = self.retry_policy.targets(node, replica)
                current, attempt = node, 0
                while True:
                    try:
                        return current.execute_batch(live_queries)
                    except RETRYABLE as exc:
                        kind = classify_fault(exc)
                        if attempt >= len(targets):
                            if attempt == 0:
                                raise ClusterError(
                                    f"shard {sid}: primary failed "
                                    "and no replica is configured"
                                ) from exc
                            raise ClusterError(
                                f"shard {sid}: primary and "
                                "replica both failed"
                            ) from exc
                        nxt = targets[attempt]
                        attempt += 1
                        retries.append((sid, current.node_id, nxt.node_id))
                        self._inc("cluster_retries_total", error=kind)
                        current = nxt

            if self.concurrency == "threads":
                with ThreadPoolExecutor(
                    max_workers=len(self.nodes), thread_name_prefix="skim-batch"
                ) as ex:
                    batch_responses = list(ex.map(scan, self.nodes))
            else:
                batch_responses = [scan(node) for node in self.nodes]

            if self.cache is not None:
                for br in batch_responses:
                    for li, resp in enumerate(br.responses):
                        _, qh = compiled[live[li]]
                        node = next(
                            n for n in self.nodes
                            if n.shard.shard_id == br.shard_id
                        )
                        self.cache.put(
                            versioned_key(qh, node.shard.manifest_hash),
                            resp,
                            nbytes=resp.result.extras.get("output_bytes", 0),
                            fetch_bytes=resp.result.stats.bytes_fetched,
                        )

        results: list[ClusterSkimResult] = []
        merge_s_total = 0.0
        for ti in range(len(compiled)):
            if ti in cached_responses:
                responses = cached_responses[ti]
            else:
                li = live.index(ti)
                responses = [br.responses[li] for br in batch_responses]
            t_m = time.perf_counter()
            output, n_input, n_passed = merge_responses(
                responses, self.basket_events, self.codec
            )
            merge_s = time.perf_counter() - t_m
            merge_s_total += merge_s
            results.append(
                ClusterSkimResult(
                    output=output,
                    n_input=n_input,
                    n_passed=n_passed,
                    breakdown=Breakdown.merged(
                        [r.result.breakdown for r in responses]
                    ),
                    stats=FetchStats.merged(
                        [r.result.stats for r in responses]
                    ),
                    responses=responses,
                    retries=[r for r in retries],
                    modeled_total_s=max(
                        (r.modeled_s for r in responses), default=0.0
                    )
                    + merge_s,
                    merge_s=merge_s,
                    wall_s=0.0,
                    extras=make_extras(
                        output_bytes=output.compressed_bytes(),
                        tenant=ti,
                        query_hash=compiled[ti][1],
                    ),
                )
            )

        shared_bytes = sum(
            br.shared.shared_stats.bytes_fetched for br in batch_responses
        )
        naive_bytes = sum(
            br.shared.naive_phase1_bytes for br in batch_responses
        )
        # cluster bound: the slowest live shared scan, or — fully warm —
        # the slowest cached shard's output transfer (same currency as
        # run()'s warm path)
        slowest = max(
            (br.modeled_s for br in batch_responses),
            default=0.0,
        )
        slowest = max(
            [slowest]
            + [r.modeled_s for rs in cached_responses.values() for r in rs]
        )
        return ClusterBatchResult(
            results=results,
            shared_phase1_bytes=shared_bytes,
            naive_phase1_bytes=naive_bytes,
            modeled_total_s=slowest + merge_s_total,
            wall_s=time.perf_counter() - t0,
            cached_tenants=sorted(cached_responses),
        )


# ---------------------------------------------------------------------------
# convenience builder
# ---------------------------------------------------------------------------


def build_cluster(
    store: EventStore,
    n_nodes: int,
    policy: str = "round_robin",
    window_events: int | None = None,
    replication: bool = True,
    cache: SkimResultCache | None = None,
    concurrency: str = "serial",
    prune: bool = True,
    cascade: bool = True,
    shard_timeout_s: float | None = None,
    retry_policy: RetryPolicy = DEFAULT_RETRY_POLICY,
    hedge: HedgePolicy | None = None,
    metrics=None,
    allow_partial: bool = False,
    **node_kw,
) -> ClusterCoordinator:
    """Partition ``store`` over ``n_nodes`` storage nodes and wire up a
    coordinator.  ``replication=True`` places a standby replica node per
    shard (sharing the shard's baskets — replication is free in-process);
    ``node_kw`` passes link tiers / executor flags to every node.
    ``prune`` controls zone-map pushdown at every level: the
    coordinator's pre-RPC shard skip AND the nodes' window-level
    pruning (DESIGN.md §9).  ``cascade`` controls the nodes' cascaded
    phase-1 executor (DESIGN.md §11); ``False`` restores the PR-4
    full-preload accounting reference."""
    from repro.cluster.shard import partition_store

    shards = partition_store(
        store, n_nodes, policy=policy, window_events=window_events
    )
    nodes = [
        StorageNode(sh, prune=prune, cascade=cascade, **node_kw)
        for sh in shards
    ]
    replicas = (
        {
            sh.shard_id: StorageNode(
                sh, node_id=n_nodes + sh.shard_id, prune=prune,
                cascade=cascade, **node_kw
            )
            for sh in shards
        }
        if replication
        else {}
    )
    return ClusterCoordinator(
        nodes,
        replicas=replicas,
        cache=cache,
        concurrency=concurrency,
        basket_events=store.basket_events,
        codec=store.codec,
        prune=prune,
        shard_timeout_s=shard_timeout_s,
        retry_policy=retry_policy,
        hedge=hedge,
        metrics=metrics,
        allow_partial=allow_partial,
    )
