"""Storage node: one shard behind a small request API (DESIGN.md §5b).

A :class:`StorageNode` is the cluster's unit of placement and failure —
the near-storage server (DPU analogue) that owns one shard and runs the
PR-1 fast path against it: a per-shard
:class:`~repro.core.engine.SkimEngine` for single queries and a
:class:`~repro.serve.engine.SharedScanEngine` for multi-tenant batches.
Its link tiers are its own (``near_input_link`` for the storage-side
fetch the prefetcher hides, ``output_link`` for survivors crossing back
to the client), so a cluster can model heterogeneous fleets.

Failure realism is injectable and deterministic: ``inject_fault("fail")``
makes the next request(s) raise :class:`NodeFailure` (the coordinator
retries under its :class:`~repro.cluster.retry.RetryPolicy`);
``inject_fault("straggle", delay_s=...)`` adds modeled seconds to the
response so tail-latency behavior is visible in the cluster schedule
without sleeping the host; ``inject_fault("corrupt")`` flips bits on the
node's read path for the next request — the store's integrity digests
catch it (:class:`~repro.data.store.CorruptBasket`), the node
quarantines the (shard, branch, basket) in :attr:`StorageNode.quarantine`,
and the blob is restored afterwards (transient read corruption, so the
replica — which shares the baskets in-process — re-fetches clean bytes).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.cluster.shard import Shard
from repro.core.engine import PCIE_128G, NetworkModel, SkimEngine, SkimResult, WAN_1G
from repro.core.query import Query, parse_query
from repro.data.store import CorruptBasket
from repro.serve.engine import SharedScanEngine, SharedScanResult

FAULT_KINDS = ("fail", "straggle", "corrupt")


class NodeFailure(RuntimeError):
    """A storage node refused or dropped a request (crash/timeout model)."""


@dataclass
class _Fault:
    kind: str  # "fail" | "straggle" | "corrupt"
    remaining: int  # requests still affected
    delay_s: float = 0.0
    # corrupt faults: which basket to damage; branch=None picks the
    # query's first filter branch (guaranteed to be fetched for any
    # non-pruned window)
    branch: str | None = None
    basket: int = 0


@dataclass
class NodeResponse:
    """One shard's answer to one query."""

    node_id: int
    shard_id: int
    window_ids: list[int]
    result: SkimResult
    modeled_s: float  # node-local modeled time (pipeline bound + straggle)
    straggle_s: float = 0.0
    wall_s: float = 0.0  # realized time on this host
    cached: bool = False  # filled by the coordinator on cache hits
    pruned: bool = False  # synthesized by the coordinator from zone-map
    # stats — the node was never contacted (DESIGN.md §9)
    # node-local span list (repro.obs.trace.Span); the coordinator adopts
    # these into its own tree, and they are stripped before cache.put —
    # a replayed response must not re-adopt a stale execution's spans
    trace: list | None = None


@dataclass
class BatchResponse:
    """One shard's answer to a shared-scan tenant batch."""

    node_id: int
    shard_id: int
    responses: list[NodeResponse]  # per tenant, request order
    shared: SharedScanResult
    modeled_s: float  # one shared phase 1 + all tenants' private work


def modeled_node_seconds(result: SkimResult) -> float:
    """The node's modeled wall-clock for one skim: the exact
    double-buffered schedule when the executor pipelined, the serial
    stage sum otherwise."""
    return result.extras.get("pipeline_total", result.breakdown.total())


class StorageNode:
    """One shard + the engines that serve it."""

    def __init__(
        self,
        shard: Shard,
        node_id: int | None = None,
        near_input_link: NetworkModel = PCIE_128G,
        output_link: NetworkModel = WAN_1G,
        fused: bool = True,
        pipeline: bool | str = True,
        prune: bool = True,
        cascade: bool = True,
        device_batch: int | None = None,
        fused_backend: str | None = None,
    ):
        self.shard = shard
        self.node_id = shard.shard_id if node_id is None else node_id
        self.near_input_link = near_input_link
        self.output_link = output_link
        self.prune = prune
        self.cascade = cascade
        self.engine = SkimEngine(
            shard.store,
            input_link=output_link,
            output_link=output_link,
            chunk_events=shard.window_events,
            fused=fused,
            pipeline=pipeline,
            near_input_link=near_input_link,
            prune=prune,
            cascade=cascade,
            device_batch=device_batch,
            fused_backend=fused_backend,
        )
        self.shared_engine = SharedScanEngine(
            shard.store,
            input_link=near_input_link,
            output_link=output_link,
            chunk_events=shard.window_events,
            fused=fused,
            prune=prune,
            cascade=cascade,
            device_batch=device_batch,
            fused_backend=fused_backend,
        )
        self._faults: list[_Fault] = []
        self.requests_served = 0
        # node-local quarantine of baskets that failed their integrity
        # digest on this node's read path: {(shard_id, branch, basket)}.
        # The coordinator ledgers its size (extras["corrupt_baskets"])
        # and re-fetches the shard from the replica (DESIGN.md §14).
        self.quarantine: set[tuple[int, str, int]] = set()

    # -- fault injection -----------------------------------------------------

    def inject_fault(
        self,
        kind: str,
        n: int = 1,
        delay_s: float = 0.0,
        branch: str | None = None,
        basket: int = 0,
    ) -> None:
        """Arm a deterministic fault for the next ``n`` requests.
        ``branch``/``basket`` pick the corruption target for
        ``kind="corrupt"`` (default: the query's first filter branch,
        basket 0)."""
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r} (want {FAULT_KINDS})")
        self._faults.append(
            _Fault(kind, max(int(n), 1), delay_s, branch=branch, basket=basket)
        )

    def _consume_fault(self) -> tuple[float, _Fault | None]:
        """Apply at most one armed fault; returns ``(straggle_s,
        corrupt_fault_or_None)``."""
        straggle = 0.0
        for f in list(self._faults):
            if f.remaining <= 0:
                self._faults.remove(f)
                continue
            f.remaining -= 1
            if f.remaining <= 0:
                self._faults.remove(f)
            if f.kind == "fail":
                raise NodeFailure(
                    f"node {self.node_id} (shard {self.shard.shard_id}): "
                    "injected failure"
                )
            if f.kind == "corrupt":
                return 0.0, f
            straggle += f.delay_s
            break  # one fault per request
        return straggle, None

    def _arm_corruption(self, query, fault: _Fault):
        """Damage the fault's target blob on this node's store; returns
        the ``restore()`` callable (transient read-path corruption)."""
        store = self.shard.store
        branch = fault.branch
        if branch is None:
            from repro.core.planner import plan_skim

            q = query if isinstance(query, Query) else parse_query(query)
            plan = plan_skim(q, store)
            branch = plan.filter_branches[0]
        basket = min(fault.basket, max(store.n_baskets(branch) - 1, 0))
        return store.corrupt_blob(branch, basket)

    # -- request API ---------------------------------------------------------

    def execute(self, query: Query | dict | str, tracer=None) -> NodeResponse:
        """Run one skim over this node's shard (near-data mode).

        ``tracer`` is a node-local :class:`~repro.obs.trace.Tracer`; its
        recorded spans travel back on ``NodeResponse.trace`` for the
        coordinator to adopt into the query-level tree."""
        straggle, corrupt = self._consume_fault()
        restore = (
            self._arm_corruption(query, corrupt) if corrupt is not None else None
        )
        t0 = time.perf_counter()
        try:
            result = self.engine.run(query, mode="near_data", tracer=tracer)
        except CorruptBasket as exc:
            self.quarantine.add(
                (self.shard.shard_id, exc.branch, exc.basket_id)
            )
            raise
        finally:
            if restore is not None:
                restore()
        self.requests_served += 1
        return NodeResponse(
            node_id=self.node_id,
            shard_id=self.shard.shard_id,
            window_ids=list(self.shard.window_ids),
            result=result,
            modeled_s=modeled_node_seconds(result) + straggle,
            straggle_s=straggle,
            wall_s=time.perf_counter() - t0,
            trace=tracer.spans() if tracer is not None else None,
        )

    def execute_batch(
        self, queries: list[Query | dict | str], tracer=None
    ) -> BatchResponse:
        """Run a tenant batch as ONE shared scan over this node's shard."""
        straggle, corrupt = self._consume_fault()
        restore = (
            self._arm_corruption(queries[0], corrupt)
            if corrupt is not None and queries
            else None
        )
        t0 = time.perf_counter()
        try:
            batch = self.shared_engine.run_batch(queries, tracer=tracer)
        except CorruptBasket as exc:
            self.quarantine.add(
                (self.shard.shard_id, exc.branch, exc.basket_id)
            )
            raise
        finally:
            if restore is not None:
                restore()
        self.requests_served += 1
        wall = time.perf_counter() - t0
        responses = [
            NodeResponse(
                node_id=self.node_id,
                shard_id=self.shard.shard_id,
                window_ids=list(self.shard.window_ids),
                result=r,
                modeled_s=r.breakdown.total() + straggle,
                straggle_s=straggle,
                wall_s=wall,
            )
            for r in batch.results
        ]
        modeled = (
            batch.shared_breakdown.total()
            + sum(r.breakdown.total() for r in batch.results)
            + straggle
        )
        return BatchResponse(
            node_id=self.node_id,
            shard_id=self.shard.shard_id,
            responses=responses,
            shared=batch,
            modeled_s=modeled,
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (
            f"StorageNode(id={self.node_id}, shard={self.shard.shard_id}, "
            f"windows={len(self.shard.window_ids)}, "
            f"events={self.shard.n_events})"
        )
