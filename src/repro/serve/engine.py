"""Serving engine: batched single-token decode against preallocated caches.

``make_serve_step`` is what the dry-run lowers for the ``decode_*`` /
``long_*`` shapes; :class:`ServeEngine` is the host-level request loop
used by the examples (continuous batching over a fixed slot pool).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import decode_step, init_cache, prefill


def make_serve_step(cfg):
    """serve_step(params, cache, tokens (B,1), pos (B,)) -> (logits, cache)."""

    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cfg, cache, tokens, pos)

    return serve_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching: up to ``n_slots`` concurrent
    sequences share one cache; finished slots are refilled from the queue."""

    def __init__(self, cfg, params, n_slots: int = 4, s_max: int = 256):
        self.cfg, self.params = cfg, params
        self.n_slots, self.s_max = n_slots, s_max
        self.cache = init_cache(cfg, n_slots, s_max)
        self.pos = np.zeros(n_slots, np.int32)
        self.cur = np.zeros(n_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * n_slots
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos),
        )

    def _admit(self, req: Request, slot: int) -> None:
        # prefill the slot: simple per-token decode warmup (small prompts)
        B = self.n_slots
        toks = jnp.asarray(req.prompt)[None]
        for t in range(len(req.prompt)):
            tok_b = jnp.zeros((B, 1), jnp.int32).at[slot, 0].set(int(req.prompt[t]))
            pos_b = jnp.asarray(self.pos)
            logits, self.cache = self._step(self.params, self.cache, tok_b, pos_b)
            self.pos[slot] += 1
        self.cur[slot] = int(jnp.argmax(logits[slot, 0]))
        self.slot_req[slot] = req

    def run(self, requests: list[Request], max_steps: int = 10_000) -> list[Request]:
        queue = list(requests)
        done: list[Request] = []
        steps = 0
        while (queue or any(self.slot_req)) and steps < max_steps:
            # fill free slots
            for s in range(self.n_slots):
                if self.slot_req[s] is None and queue:
                    self.pos[s] = 0
                    self._admit(queue.pop(0), s)
            # one batched decode step for all active slots
            toks = jnp.asarray(self.cur, jnp.int32)[:, None]
            logits, self.cache = self._step(
                self.params, self.cache, toks, jnp.asarray(self.pos)
            )
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
            for s in range(self.n_slots):
                req = self.slot_req[s]
                if req is None:
                    continue
                req.out.append(int(self.cur[s]))
                self.pos[s] += 1
                self.cur[s] = nxt[s]
                if len(req.out) >= req.max_new or self.pos[s] >= self.s_max - 1:
                    req.done = True
                    done.append(req)
                    self.slot_req[s] = None
            steps += 1
        return done
