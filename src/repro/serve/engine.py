"""Serving layer: shared-scan skim batching (DESIGN.md §4c).

:class:`SharedScanEngine` is the skim service path: N concurrent tenant
queries execute over ONE pass of the same dataset.  With the cascaded
executor (DESIGN.md §11) the shared pass is demand-driven: the
double-buffered load stage fetches only the union of the tenants' pinned
*head* stages, each tenant's remaining cascade stages fetch alive
baskets on demand through a window-shared basket ledger, and phase 2
flows through the same ledger — so every ``(branch, basket)`` pair moves
at most once per window across the whole batch.  I/O and decode amortize
across tenants — the paper's interactive-rate multi-user skimming
regime — while each tenant still gets a private phase-2 output and its
own :class:`~repro.core.engine.SkimResult`, bit-identical to running the
query alone.  ``cascade=False`` restores the PR-4 union-preload pass.

(The LM decode-serving engine that shared this module in the seed lives
in ``attic/`` now — the skim tree is the repo's single story.)
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.engine import (
    PCIE_128G,
    Breakdown,
    NetworkModel,
    SkimResult,
    WindowPartial,
    _concat_output,
    _decode_branches,
    _select_columns,
    _skipped_requests,
    _Timer,
    _window_phase2,
    _write_output,
    drain,
)
from repro.core.planner import plan_skim
from repro.core.query import Query, parse_query
from repro.core.zonemap import ACCEPT_ALL, PRUNE, SCAN
from repro.data.store import EventStore, FetchStats, WindowPrefetcher
from repro.obs.schema import SkimReport
from repro.obs.trace import NULL_TRACER

# ---------------------------------------------------------------------------
# shared-scan skim service
# ---------------------------------------------------------------------------


@dataclass
class BatchWindowPartial:
    """One basket window of a shared scan, streamed per tenant.

    ``tenants[i]`` is tenant *i*'s :class:`~repro.core.engine.WindowPartial`
    for this window — survivor columns exactly as they will land in that
    tenant's final output, so per-tenant unions of streamed partials are
    bit-identical to the batch result by construction (DESIGN.md §12).
    """

    index: int
    start: int
    stop: int
    tenants: list  # per tenant, request order: WindowPartial


@dataclass
class SharedScanResult:
    """Batch result of one shared scan over N tenant queries."""

    results: list[SkimResult]  # per-query, in request order
    shared_stats: FetchStats  # the single phase-1 pass (union branches)
    shared_breakdown: Breakdown  # fetch/decode of that pass (+ modeled link)
    naive_phase1_bytes: int  # what N independent scans would have fetched
    wall_s: float = 0.0

    @property
    def n_queries(self) -> int:
        return len(self.results)

    @property
    def saved_bytes(self) -> int:
        """Phase-1 bytes the shared scan avoided vs N independent skims."""
        return self.naive_phase1_bytes - self.shared_stats.bytes_fetched

    @property
    def amortization(self) -> float:
        """naive/shared phase-1 byte ratio (>= 1; ~N for similar queries)."""
        return self.naive_phase1_bytes / max(self.shared_stats.bytes_fetched, 1)


class SharedScanEngine:
    """Multi-tenant skim executor: N queries, one pass over the dataset.

    Phase 1 runs once per basket window for the whole batch: the load
    stage fetches + decodes the union of the tenants' phase-1 head sets
    (prefetched double-buffered, like the single-query pipelined
    executor), then every tenant's cascade evaluates against the shared
    decoded window, pulling later-stage branches on demand through a
    window-shared basket ledger.  Phase 2 stays per-tenant: only baskets
    holding that tenant's survivors move, into that tenant's private
    output.  Per-query outputs are bit-identical to running each query
    alone through ``SkimEngine.run(..., mode="near_data")``.
    """

    def __init__(
        self,
        store: EventStore,
        input_link: NetworkModel = PCIE_128G,
        output_link: NetworkModel | None = None,
        chunk_events: int | None = None,
        fused: bool = True,
        pipeline: bool | str = False,
        prune: bool = True,
        cascade: bool = True,
        device_batch: int | None = None,
        fused_backend: str | None = None,
    ):
        self.store = store
        self.input_link = input_link
        self.output_link = output_link or input_link
        self.chunk_events = chunk_events or store.basket_events
        self.fused = fused
        # zone-map pushdown (DESIGN.md §9): per-tenant window decisions;
        # the shared union fetch skips a window only when EVERY tenant
        # prunes it.  ``False`` is the reference path.
        self.prune = prune
        # cascaded phase 1 (DESIGN.md §11); ``False`` restores the PR-4
        # union-preload pass.  Applies to the fused path only.
        self.cascade = cascade
        # False = serial window loop; "threads" = real WindowPrefetcher
        # worker.  (The modeled pipeline schedule is a single-query
        # SkimEngine feature; the shared scan's win is byte amortization.)
        if pipeline not in (False, "threads"):
            raise ValueError(
                f"pipeline must be False or 'threads', got {pipeline!r}"
            )
        self.pipeline = pipeline
        # device-resident batched cascade (DESIGN.md §16): group this
        # many shared-scan windows per tenant cascade dispatch.  Applies
        # only to all-cascade batches; mixed batches keep the per-window
        # path (their ledger semantics differ per tenant anyway).
        if device_batch is not None and int(device_batch) < 1:
            raise ValueError(f"device_batch must be >= 1, got {device_batch}")
        self.device_batch = int(device_batch) if device_batch else None
        if fused_backend not in (None, "pallas", "xla", "host"):
            raise ValueError(f"unknown fused backend {fused_backend!r}")
        self.fused_backend = fused_backend

    def run_batch(
        self, queries: list[Query | dict | str], tracer=None
    ) -> SharedScanResult:
        return drain(self.iter_batch(queries, tracer=tracer))

    def iter_batch(self, queries: list[Query | dict | str], tracer=None):
        """Streaming form of :meth:`run_batch`: a generator yielding one
        :class:`BatchWindowPartial` per basket window (every tenant's
        ledger entry for that window together, since the scan is shared)
        and returning the final :class:`SharedScanResult`.  Window
        boundaries are the job service's cancellation points; a tenant
        cancelled mid-batch simply stops collecting its partials — the
        shared pass is one fetch either way (DESIGN.md §12)."""
        from repro.core.neardata import fused_window_skim, window_pad_K
        from repro.core.plan import CascadeExecutor, mark_fetched, unfetched_bytes

        store, chunk = self.store, self.chunk_events
        n = store.n_events
        t0 = time.perf_counter()
        tr = tracer if tracer is not None else NULL_TRACER

        bsid = tr.begin(
            "batch", kind="query", n_tenants=len(queries), n_events=n
        )
        plan_t0 = tr.now()
        parsed = [q if isinstance(q, Query) else parse_query(q) for q in queries]

        def _wants_cascade(q: Query) -> bool:
            flag = q.cascade if q.cascade is not None else self.cascade
            return bool(flag) and self.fused

        plans = [
            plan_skim(
                q, store, window_events=chunk, prune=self.prune,
                cascade=_wants_cascade(q),
            )
            for q in parsed
        ]
        programs = [p.compiled_program() if self.fused else None for p in plans]
        executors = [
            CascadeExecutor(
                p, store, tracer=tr, backend=self.fused_backend
            )
            if p.cascade is not None
            else None
            for p in plans
        ]
        tr.add_span("plan", kind="plan", t0=plan_t0, t1=tr.now())

        # full union of filter branches, first-seen order: the pricing /
        # amortization reference (what the PR-4 union preload moved)
        union: list[str] = []
        seen: set[str] = set()
        for plan in plans:
            for br in plan.filter_branches:
                if br not in seen:
                    seen.add(br)
                    union.append(br)
        # what the load stage actually fetches per window: each tenant's
        # pinned head stage when cascading, its full filter set otherwise
        load_union: list[str] = []
        seen_load: set[str] = set()
        for plan, ex in zip(plans, executors):
            for br in (ex.head_branches if ex is not None else plan.filter_branches):
                if br not in seen_load:
                    seen_load.add(br)
                    load_union.append(br)

        shared_b, shared_stats = Breakdown(), FetchStats()

        # per-tenant zone-map decisions (DESIGN.md §9)
        decisions = [p.window_decisions for p in plans]

        def _tenant_kind(i: int, wi: int) -> str:
            return decisions[i][wi].decision if decisions[i] is not None else SCAN

        # the shared union fetch is skipped only when EVERY tenant prunes
        # the window: accept-all tenants still want the union decoded
        # (their phase 2 reuses it — dropping the shared pass would make
        # each of them re-fetch the overlap privately and cost MORE bytes
        # than the unpruned reference)
        n_windows = -(-n // chunk) if n else 0
        load_windows = {
            wi
            for wi in range(n_windows)
            if any(_tenant_kind(i, wi) != PRUNE for i in range(len(plans)))
        }

        def load_window(start: int, stop: int):
            if start // chunk not in load_windows:
                # every tenant proved this window empty: the shared union
                # fetch never happens and no tenant runs phase 2 either
                # (skip priced against the full-union preload reference)
                ls = FetchStats()
                nbytes, nb = store.range_comp_bytes(union, start, stop)
                ls.skip(nbytes, _skipped_requests(nbytes, nb, coalesce=True))
                return None, Breakdown(), ls
            lb, ls = Breakdown(), FetchStats()
            # prefetch worker threads never touch the consumer span stack
            ltr = NULL_TRACER if self.pipeline == "threads" else tr
            lsid = ltr.begin("load_window", kind="fetch", window=start // chunk)
            data = _decode_branches(
                store, load_union, start, stop, lb, ls, coalesce=True,
                tracer=ltr,
            )
            ltr.end(lsid, bytes=ls.bytes_fetched)
            return data, lb, ls

        # per-query accumulation state
        per_b = [Breakdown() for _ in plans]
        per_stats = [FetchStats() for _ in plans]
        out_cols = [{k: [] for k in p.output_branches} for p in plans]
        jagged_maps: list[dict[str, str]] = [{} for _ in plans]
        n_passed = [0] * len(plans)
        pad_K = [0] * len(plans)  # monotonic per-query pad shapes
        # per-tenant (start, stop, k) ledger — same mergeable-result
        # contract as the single-query executor (DESIGN.md §5)
        window_rows: list[list[tuple[int, int, int]]] = [[] for _ in plans]

        src = WindowPrefetcher(
            n, chunk, load_window, enabled=(self.pipeline == "threads")
        )

        # device-batched shared scan (DESIGN.md §16): group loaded
        # windows, run each tenant's cascade ONCE per group through
        # run_window_batch, and replay the outcomes through the unchanged
        # per-tenant ledger loop below.  Windows every tenant pruned
        # (data is None) pass through unbatched.
        G = (
            self.device_batch
            if executors and all(ex is not None for ex in executors)
            else None
        )
        pending_out: dict[tuple[int, int], object] = {}
        window_ledgers: dict[int, dict] = {}

        def scan_items():
            numbered = enumerate(src)
            if not G or G <= 1:
                for wi_, (start_, stop_, payload_) in numbered:
                    yield wi_, start_, stop_, payload_
                return
            buf: list = []

            def flush():
                if not buf:
                    return
                for wi_, start_, stop_, (data_, _lb, _ls) in buf:
                    led: dict[str, set] = {}
                    if data_ is not None:
                        mark_fetched(store, load_union, start_, stop_, led)
                    window_ledgers[wi_] = led
                for i_, ex_ in enumerate(executors):
                    sel = [
                        w for w in buf
                        if w[3][0] is not None
                        and _tenant_kind(i_, w[0]) == SCAN
                    ]
                    if not sel:
                        continue
                    entries = [
                        (
                            start_, stop_, data_, per_b[i_], shared_stats,
                            window_ledgers[wi_],
                        )
                        for wi_, start_, stop_, (data_, _lb, _ls) in sel
                    ]
                    outs = ex_.run_window_batch(entries, pad_B=G)
                    for (wi_, *_rest), out in zip(sel, outs):
                        pending_out[(i_, wi_)] = out
                items = list(buf)
                buf.clear()
                yield from items

            for wi_, (start_, stop_, payload_) in numbered:
                if payload_[0] is not None:
                    buf.append((wi_, start_, stop_, payload_))
                    if len(buf) == G:
                        yield from flush()
                else:
                    yield from flush()
                    yield (wi_, start_, stop_, payload_)
            yield from flush()

        for wi, start, stop, (data, lb, ls) in scan_items():
            shared_b.merge(lb)
            shared_stats.merge(ls)
            wsid = tr.begin(f"window[{wi}]", kind="window", index=wi)
            m = stop - start
            # window-shared basket ledger (DESIGN.md §11): every
            # (branch, basket) pair moves at most once per window across
            # all tenants and both phases
            ledger: dict[str, set] | None = window_ledgers.pop(wi, None)
            if ledger is None:
                ledger = {}
                if data is not None:
                    mark_fetched(store, load_union, start, stop, ledger)
            tenant_parts: list[WindowPartial] = [
                WindowPartial(
                    index=wi, start=start, stop=stop, n_passed=0,
                    cols={}, jagged={}, decision=_tenant_kind(i, wi),
                )
                for i in range(len(plans))
            ]
            for i, plan in enumerate(plans):
                b = per_b[i]
                ex = executors[i]
                dev_cols: dict[str, np.ndarray] = {}
                full_loaded: dict = {}
                kind = _tenant_kind(i, wi)
                if kind == PRUNE:
                    # provably no survivor for this tenant: no filter
                    # eval, no phase 2
                    window_rows[i].append((start, stop, 0))
                    continue
                if kind == SCAN and ex is not None and data is not None:
                    # cascaded phase 1: head evaluates from the shared
                    # decoded window, later stages fetch alive baskets on
                    # demand — bytes charged to the SHARED pass (they are
                    # reusable by every tenant through the ledger), eval
                    # and decode time to this tenant
                    outcome = pending_out.pop((i, wi), None)
                    if outcome is None:
                        outcome = ex.run_window(
                            start, stop, data, b, shared_stats, ledger=ledger
                        )
                    mask = outcome.mask
                    full_loaded = outcome.full_loaded
                elif kind == ACCEPT_ALL and ex is not None and data is not None:
                    # provably all survive: no predicate eval; the cascade
                    # tenant's phase 2 below flows through the ledger (the
                    # fused payload shortcut needs the full filter preload
                    # the cascade deliberately no longer does)
                    mask = np.ones(m, dtype=bool)
                else:
                    with _Timer(b, "filter"):
                        if (
                            kind == ACCEPT_ALL
                            and self.fused
                            and data is not None
                            and plan.filter_branches  # selection-free: no data
                        ):
                            # provably all survive: the fused executor's
                            # decision short-circuit skips predicate eval and
                            # passes the payload columns through whole
                            mask, dev_cols = fused_window_skim(
                                data, programs[i], store,
                                payload_branches=plan.payload_branches,
                                decision=ACCEPT_ALL,
                            )
                        elif kind == ACCEPT_ALL:
                            mask = np.ones(m, dtype=bool)
                        elif not plan.filter_branches:
                            # constant predicate: a selection-free projection
                            # passes everything, an OR over absent-era triggers
                            # passes nothing (DESIGN.md §10)
                            if self.fused:
                                from repro.core.neardata import program_eval_np

                                mask = program_eval_np(
                                    data if data is not None else {},
                                    programs[i], m,
                                )
                            else:
                                from repro.core.query import eval_stage

                                mask = np.ones(m, dtype=bool)
                                for _, stage in plan.query.stages():
                                    if stage:
                                        mask &= eval_stage(
                                            stage, data if data is not None
                                            else {}, m,
                                        )
                        elif self.fused:
                            pad_K[i] = max(
                                pad_K[i], window_pad_K(data, programs[i], store)
                            )
                            mask, dev_cols = fused_window_skim(
                                data, programs[i], store,
                                payload_branches=plan.payload_branches,
                                K=pad_K[i],
                                pad_to=chunk,
                            )
                        else:
                            from repro.core.query import eval_stage

                            mask = np.ones(m, dtype=bool)
                            for _, stage in plan.query.stages():
                                if stage and mask.any():
                                    mask &= eval_stage(stage, data, m)
                k = int(mask.sum())
                window_rows[i].append((start, stop, k))
                tenant_parts[i].n_passed = k
                if k == 0:
                    continue
                n_passed[i] += k
                p2sid = tr.begin("phase2", kind="fetch", tenant=i, window=wi)
                if ex is not None and data is not None:
                    # phase 2 through the shared ledger: baskets any stage
                    # (or an earlier tenant) already moved are not re-paid
                    known = {**data, **full_loaded}
                    full = ex.fetch_full(
                        plan.output_branches, start, stop, b, per_stats[i],
                        ledger, known=known,
                    )
                    with _Timer(b, "deserialize"):
                        cols, jagged = _select_columns(
                            {k2: full[k2] for k2 in plan.output_branches},
                            mask, store,
                        )
                else:
                    cols, jagged = _window_phase2(
                        store, plan, start, stop, mask, dev_cols,
                        data if data is not None else {}, b,
                        per_stats[i], coalesce=True, tracer=tr,
                    )
                tr.end(p2sid, bytes=per_stats[i].bytes_fetched)
                jagged_maps[i].update(jagged)
                for k2, v in cols.items():
                    out_cols[i][k2].append(v)
                tenant_parts[i].cols = cols
                tenant_parts[i].jagged = jagged
            if data is not None and executors and all(
                ex is not None for ex in executors
            ):
                # cascaded-batch savings vs the union-preload reference,
                # ledgered AFTER every tenant's phase 2 (which flows
                # through the same ledger): a union basket counts as
                # skipped only if nothing in the batch ever moved it.
                # Mixed batches skip the ledger — non-cascade tenants'
                # phase 2 bypasses it, so 0 is the honest floor.
                shared_stats.cascade_bytes_skipped += unfetched_bytes(
                    store, union, start, stop, ledger
                )
            tr.end(wsid, n_passed=sum(p.n_passed for p in tenant_parts))
            try:
                yield BatchWindowPartial(
                    index=wi, start=start, stop=stop, tenants=tenant_parts
                )
            except GeneratorExit:
                tr.end(bsid, cancelled=True)
                raise

        # phase-1 link time is paid once for the whole batch
        shared_b.fetch = self.input_link.transfer_time(
            shared_stats.bytes_fetched, shared_stats.requests
        )

        results: list[SkimResult] = []
        for i, plan in enumerate(plans):
            b = per_b[i]
            cat = _concat_output(out_cols[i], n_passed[i], plan, store)
            out = _write_output(cat, jagged_maps[i], store, b)
            b.fetch = self.input_link.transfer_time(
                per_stats[i].bytes_fetched, per_stats[i].requests
            )
            out_bytes = out.compressed_bytes()
            b.output_transfer = self.output_link.transfer_time(out_bytes, 1)
            report = SkimReport(
                mode="shared_scan",
                fused=self.fused,
                pipelined=self.pipeline == "threads",
                prune=decisions[i] is not None,
                cascade=executors[i] is not None,
                output_bytes=out_bytes,
                window_rows=window_rows[i],
                pruned_windows=[
                    (d.start, d.stop, d.decision)
                    for d in decisions[i] or ()
                    if d.decision != SCAN
                ],
                shared_scan=True,
            )
            if executors[i] is not None:
                report.cascade_order = executors[i].order()
                report.cascade_stages = executors[i].state.report()
            results.append(
                SkimResult(
                    "shared_scan", out, n, n_passed[i], b, per_stats[i], plan,
                    extras=report.legacy_extras(),
                    report=report,
                )
            )
        tr.end(bsid, n_passed=sum(n_passed))

        naive = sum(
            store.compressed_bytes(p.filter_branches) for p in plans
        )
        return SharedScanResult(
            results=results,
            shared_stats=shared_stats,
            shared_breakdown=shared_b,
            naive_phase1_bytes=naive,
            wall_s=time.perf_counter() - t0,
        )
