"""Serving layer: shared-scan skim batching + LM decode serving.

Two multi-tenant engines live here:

  * :class:`SharedScanEngine` — the skim service path (DESIGN.md §4c).
    N concurrent tenant queries execute over ONE pass of the same
    dataset: the union of their filter branches is fetched + decoded once
    per basket window (double-buffered behind filtering), then each
    query's compiled predicate program runs against the shared decoded
    window.  I/O and decode amortize across tenants — the paper's
    interactive-rate multi-user skimming regime — while each tenant still
    gets a private phase-2 (survivor-only output fetch) and its own
    :class:`~repro.core.engine.SkimResult`, bit-identical to running the
    query alone.
  * :class:`ServeEngine` — batched single-token LM decode against
    preallocated caches (continuous batching over a fixed slot pool);
    ``make_serve_step`` is what the dry-run lowers for the ``decode_*`` /
    ``long_*`` shapes.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    PCIE_128G,
    Breakdown,
    NetworkModel,
    SkimResult,
    _concat_output,
    _decode_branches,
    _skipped_requests,
    _Timer,
    _window_phase2,
    _write_output,
)
from repro.core.planner import plan_skim
from repro.core.query import Query, parse_query
from repro.core.zonemap import ACCEPT_ALL, PRUNE, SCAN
from repro.data.store import EventStore, FetchStats, WindowPrefetcher
from repro.models.model import decode_step, init_cache, prefill


# ---------------------------------------------------------------------------
# shared-scan skim service
# ---------------------------------------------------------------------------


@dataclass
class SharedScanResult:
    """Batch result of one shared scan over N tenant queries."""

    results: list[SkimResult]  # per-query, in request order
    shared_stats: FetchStats  # the single phase-1 pass (union branches)
    shared_breakdown: Breakdown  # fetch/decode of that pass (+ modeled link)
    naive_phase1_bytes: int  # what N independent scans would have fetched
    wall_s: float = 0.0

    @property
    def n_queries(self) -> int:
        return len(self.results)

    @property
    def saved_bytes(self) -> int:
        """Phase-1 bytes the shared scan avoided vs N independent skims."""
        return self.naive_phase1_bytes - self.shared_stats.bytes_fetched

    @property
    def amortization(self) -> float:
        """naive/shared phase-1 byte ratio (>= 1; ~N for similar queries)."""
        return self.naive_phase1_bytes / max(self.shared_stats.bytes_fetched, 1)


class SharedScanEngine:
    """Multi-tenant skim executor: N queries, one pass over the dataset.

    Phase 1 fetches + decodes the *union* of all tenants' filter branches
    once per basket window (prefetched double-buffered, like the
    single-query pipelined executor) and evaluates every tenant's
    compiled predicate program against the shared decoded window.  Phase
    2 stays per-tenant: only baskets holding that tenant's survivors
    move, into that tenant's private output.  Per-query outputs are
    bit-identical to running each query alone through
    ``SkimEngine.run(..., mode="near_data")``.
    """

    def __init__(
        self,
        store: EventStore,
        input_link: NetworkModel = PCIE_128G,
        output_link: NetworkModel | None = None,
        chunk_events: int | None = None,
        fused: bool = True,
        pipeline: bool | str = False,
        prune: bool = True,
    ):
        self.store = store
        self.input_link = input_link
        self.output_link = output_link or input_link
        self.chunk_events = chunk_events or store.basket_events
        self.fused = fused
        # zone-map pushdown (DESIGN.md §9): per-tenant window decisions;
        # the shared union fetch skips a window only when EVERY tenant
        # prunes it.  ``False`` is the reference path.
        self.prune = prune
        # False = serial window loop; "threads" = real WindowPrefetcher
        # worker.  (The modeled pipeline schedule is a single-query
        # SkimEngine feature; the shared scan's win is byte amortization.)
        if pipeline not in (False, "threads"):
            raise ValueError(
                f"pipeline must be False or 'threads', got {pipeline!r}"
            )
        self.pipeline = pipeline

    def run_batch(self, queries: list[Query | dict | str]) -> SharedScanResult:
        from repro.core.neardata import fused_window_skim, window_pad_K

        store, chunk = self.store, self.chunk_events
        n = store.n_events
        t0 = time.perf_counter()

        parsed = [q if isinstance(q, Query) else parse_query(q) for q in queries]
        plans = [
            plan_skim(q, store, window_events=chunk, prune=self.prune)
            for q in parsed
        ]
        programs = [p.compiled_program() if self.fused else None for p in plans]

        # union of filter branches, first-seen order (deterministic)
        union: list[str] = []
        seen: set[str] = set()
        for plan in plans:
            for br in plan.filter_branches:
                if br not in seen:
                    seen.add(br)
                    union.append(br)

        shared_b, shared_stats = Breakdown(), FetchStats()

        # per-tenant zone-map decisions (DESIGN.md §9)
        decisions = [p.window_decisions for p in plans]

        def _tenant_kind(i: int, wi: int) -> str:
            return decisions[i][wi].decision if decisions[i] is not None else SCAN

        # the shared union fetch is skipped only when EVERY tenant prunes
        # the window: accept-all tenants still want the union decoded
        # (their phase 2 reuses it — dropping the shared pass would make
        # each of them re-fetch the overlap privately and cost MORE bytes
        # than the unpruned reference)
        n_windows = -(-n // chunk) if n else 0
        load_windows = {
            wi
            for wi in range(n_windows)
            if any(_tenant_kind(i, wi) != PRUNE for i in range(len(plans)))
        }

        def load_window(start: int, stop: int):
            if start // chunk not in load_windows:
                # every tenant proved this window empty: the shared union
                # fetch never happens and no tenant runs phase 2 either
                ls = FetchStats()
                nbytes, nb = store.range_comp_bytes(union, start, stop)
                ls.skip(nbytes, _skipped_requests(nbytes, nb, coalesce=True))
                return None, Breakdown(), ls
            lb, ls = Breakdown(), FetchStats()
            data = _decode_branches(store, union, start, stop, lb, ls, coalesce=True)
            return data, lb, ls

        # per-query accumulation state
        per_b = [Breakdown() for _ in plans]
        per_stats = [FetchStats() for _ in plans]
        out_cols = [{k: [] for k in p.output_branches} for p in plans]
        jagged_maps: list[dict[str, str]] = [{} for _ in plans]
        n_passed = [0] * len(plans)
        pad_K = [0] * len(plans)  # monotonic per-query pad shapes
        # per-tenant (start, stop, k) ledger — same mergeable-result
        # contract as the single-query executor (DESIGN.md §5)
        window_rows: list[list[tuple[int, int, int]]] = [[] for _ in plans]

        src = WindowPrefetcher(
            n, chunk, load_window, enabled=(self.pipeline == "threads")
        )
        for wi, (start, stop, (data, lb, ls)) in enumerate(src):
            shared_b.merge(lb)
            shared_stats.merge(ls)
            m = stop - start
            for i, plan in enumerate(plans):
                b = per_b[i]
                dev_cols: dict[str, np.ndarray] = {}
                kind = _tenant_kind(i, wi)
                if kind == PRUNE:
                    # provably no survivor for this tenant: no filter
                    # eval, no phase 2
                    window_rows[i].append((start, stop, 0))
                    continue
                with _Timer(b, "filter"):
                    if (
                        kind == ACCEPT_ALL
                        and self.fused
                        and data is not None
                        and plan.filter_branches  # selection-free: no data
                    ):
                        # provably all survive: the fused executor's
                        # decision short-circuit skips predicate eval and
                        # passes the payload columns through whole
                        mask, dev_cols = fused_window_skim(
                            data, programs[i], store,
                            payload_branches=plan.payload_branches,
                            decision=ACCEPT_ALL,
                        )
                    elif kind == ACCEPT_ALL:
                        mask = np.ones(m, dtype=bool)
                    elif not plan.filter_branches:
                        # constant predicate: a selection-free projection
                        # passes everything, an OR over absent-era triggers
                        # passes nothing (DESIGN.md §10)
                        if self.fused:
                            from repro.core.neardata import program_eval_np

                            mask = program_eval_np(
                                data if data is not None else {},
                                programs[i], m,
                            )
                        else:
                            from repro.core.query import eval_stage

                            mask = np.ones(m, dtype=bool)
                            for _, stage in plan.query.stages():
                                if stage:
                                    mask &= eval_stage(
                                        stage, data if data is not None
                                        else {}, m,
                                    )
                    elif self.fused:
                        pad_K[i] = max(
                            pad_K[i], window_pad_K(data, programs[i], store)
                        )
                        mask, dev_cols = fused_window_skim(
                            data, programs[i], store,
                            payload_branches=plan.payload_branches,
                            K=pad_K[i],
                            pad_to=chunk,
                        )
                    else:
                        from repro.core.query import eval_stage

                        mask = np.ones(m, dtype=bool)
                        for _, stage in plan.query.stages():
                            if stage and mask.any():
                                mask &= eval_stage(stage, data, m)
                k = int(mask.sum())
                window_rows[i].append((start, stop, k))
                if k == 0:
                    continue
                n_passed[i] += k
                cols, jagged = _window_phase2(
                    store, plan, start, stop, mask, dev_cols,
                    data if data is not None else {}, b,
                    per_stats[i], coalesce=True,
                )
                jagged_maps[i].update(jagged)
                for k2, v in cols.items():
                    out_cols[i][k2].append(v)

        # phase-1 link time is paid once for the whole batch
        shared_b.fetch = self.input_link.transfer_time(
            shared_stats.bytes_fetched, shared_stats.requests
        )

        results: list[SkimResult] = []
        for i, plan in enumerate(plans):
            b = per_b[i]
            cat = _concat_output(out_cols[i], n_passed[i], plan, store)
            out = _write_output(cat, jagged_maps[i], store, b)
            b.fetch = self.input_link.transfer_time(
                per_stats[i].bytes_fetched, per_stats[i].requests
            )
            out_bytes = out.compressed_bytes()
            b.output_transfer = self.output_link.transfer_time(out_bytes, 1)
            results.append(
                SkimResult(
                    "shared_scan", out, n, n_passed[i], b, per_stats[i], plan,
                    extras={
                        "output_bytes": out_bytes,
                        "fused": self.fused,
                        "pipelined": self.pipeline == "threads",
                        "shared_scan": True,
                        "window_rows": window_rows[i],
                        "pruned_windows": [
                            (d.start, d.stop, d.decision)
                            for d in decisions[i] or ()
                            if d.decision != SCAN
                        ],
                        "prune": decisions[i] is not None,
                    },
                )
            )

        naive = sum(
            store.compressed_bytes(p.filter_branches) for p in plans
        )
        return SharedScanResult(
            results=results,
            shared_stats=shared_stats,
            shared_breakdown=shared_b,
            naive_phase1_bytes=naive,
            wall_s=time.perf_counter() - t0,
        )


# ---------------------------------------------------------------------------
# LM decode serving
# ---------------------------------------------------------------------------


def make_serve_step(cfg):
    """serve_step(params, cache, tokens (B,1), pos (B,)) -> (logits, cache)."""

    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cfg, cache, tokens, pos)

    return serve_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching: up to ``n_slots`` concurrent
    sequences share one cache; finished slots are refilled from the queue."""

    def __init__(self, cfg, params, n_slots: int = 4, s_max: int = 256):
        self.cfg, self.params = cfg, params
        self.n_slots, self.s_max = n_slots, s_max
        self.cache = init_cache(cfg, n_slots, s_max)
        self.pos = np.zeros(n_slots, np.int32)
        self.cur = np.zeros(n_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * n_slots
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos),
        )

    def _admit(self, req: Request, slot: int) -> None:
        # prefill the slot: simple per-token decode warmup (small prompts)
        B = self.n_slots
        toks = jnp.asarray(req.prompt)[None]
        for t in range(len(req.prompt)):
            tok_b = jnp.zeros((B, 1), jnp.int32).at[slot, 0].set(int(req.prompt[t]))
            pos_b = jnp.asarray(self.pos)
            logits, self.cache = self._step(self.params, self.cache, tok_b, pos_b)
            self.pos[slot] += 1
        self.cur[slot] = int(jnp.argmax(logits[slot, 0]))
        self.slot_req[slot] = req

    def run(self, requests: list[Request], max_steps: int = 10_000) -> list[Request]:
        queue = list(requests)
        done: list[Request] = []
        steps = 0
        while (queue or any(self.slot_req)) and steps < max_steps:
            # fill free slots
            for s in range(self.n_slots):
                if self.slot_req[s] is None and queue:
                    self.pos[s] = 0
                    self._admit(queue.pop(0), s)
            # one batched decode step for all active slots
            toks = jnp.asarray(self.cur, jnp.int32)[:, None]
            logits, self.cache = self._step(
                self.params, self.cache, toks, jnp.asarray(self.pos)
            )
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
            for s in range(self.n_slots):
                req = self.slot_req[s]
                if req is None:
                    continue
                req.out.append(int(self.cur[s]))
                self.pos[s] += 1
                self.cur[s] = nxt[s]
                if len(req.out) >= req.max_new or self.pos[s] >= self.s_max - 1:
                    req.done = True
                    done.append(req)
                    self.slot_req[s] = None
            steps += 1
        return done
