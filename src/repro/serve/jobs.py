"""Job model for the async skim service (DESIGN.md §12).

A :class:`SkimJob` is one submitted query moving through the lifecycle

    submit -> PENDING -> RUNNING -> DONE | FAILED | CANCELLED
                   \\-> REJECTED            (admission control)

Everything here is deliberately inert data + pure pricing:

  * :func:`price_query` prices a query with the cascade cost model
    (:func:`repro.core.plan.estimate_plan_bytes`) **before** it runs —
    basket metadata only, zero bytes fetched — and wraps the numbers in
    a :class:`CostEstimate`, the admission-control currency;
  * :class:`TenantQuota` is a tenant's byte/wall budget and fair-share
    weight; the service enforces it against priced estimates;
  * :class:`PartialResult` is one streamed window-granular ledger entry
    (survivor columns included), appended to ``job.partials`` as the
    executor completes each window;
  * :class:`ManualClock` is the injectable deterministic clock — tests
    advance it explicitly, so every timestamp is replayable.

Scheduling itself lives in :mod:`repro.serve.service`.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.data.store import FetchStats

# -- job lifecycle states ---------------------------------------------------

PENDING = "PENDING"
RUNNING = "RUNNING"
DONE = "DONE"
FAILED = "FAILED"
CANCELLED = "CANCELLED"
REJECTED = "REJECTED"
#: states a job can never leave
TERMINAL = frozenset({DONE, FAILED, CANCELLED, REJECTED})


class ManualClock:
    """Injectable deterministic clock: ``now()`` only moves when the
    owner calls :meth:`advance`.  The service stamps every lifecycle
    transition with it, so a test controls — and can assert — all
    timestamps without wall-clock sleeps."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("clocks only move forward")
        self._now += float(dt)
        return self._now


@dataclass(frozen=True)
class CostEstimate:
    """A query's plan-priced cost, computed before any basket moves.

    ``est_bytes`` is the admission currency (phase 1 + phase 2);
    ``est_wall_s`` the modeled link time of moving them.  ``per_stage``
    keeps the per-cascade-stage byte split for explainable rejections.
    """

    est_bytes: int
    est_phase1_bytes: int
    est_phase2_bytes: int
    est_requests: int
    est_wall_s: float
    est_selectivity: float
    n_windows: int
    n_windows_pruned: int
    per_stage: dict = field(default_factory=dict)
    # stage index -> stage kind ("cut"/"trigger"/"mass"/...), the join
    # key for priced-vs-observed calibration (repro.obs.metrics)
    per_stage_kinds: dict = field(default_factory=dict)

    def describe(self) -> str:
        return (
            f"~{self.est_bytes / 1e6:.2f} MB "
            f"(p1 {self.est_phase1_bytes / 1e6:.2f} + "
            f"p2 {self.est_phase2_bytes / 1e6:.2f}), "
            f"~{self.est_wall_s * 1e3:.1f} ms modeled, "
            f"sel~{self.est_selectivity:.3f}, "
            f"{self.n_windows_pruned}/{self.n_windows} windows pruned"
        )


def price_query(
    query,
    store,
    window_events: int | None = None,
    link=None,
    calibration: dict | None = None,
) -> CostEstimate:
    """Price one query against one store — metadata only, nothing fetched.

    Plans with pruning + cascading on (the service's execution
    configuration), prices the plan with
    :func:`repro.core.plan.estimate_plan_bytes`, and converts bytes to
    modeled seconds over ``link`` (default: the near-data PCIe tier).
    ``calibration`` is an optional observed/priced ratio prior per stage
    kind (:meth:`repro.obs.metrics.MetricsRegistry.calibration_priors`)
    — the service's feedback loop from settled jobs back into pricing.
    Raises whatever :func:`plan_skim` raises on malformed queries
    (unknown branches etc.) — the service turns that into a rejection.
    """
    from repro.core.engine import PCIE_128G
    from repro.core.plan import estimate_plan_bytes
    from repro.core.planner import plan_skim
    from repro.core.query import Query, parse_query

    q = query if isinstance(query, Query) else parse_query(query)
    window_events = window_events or store.basket_events
    plan = plan_skim(q, store, window_events=window_events, prune=True, cascade=True)
    est = estimate_plan_bytes(plan, store, window_events, calibration=calibration)
    link = link or PCIE_128G
    return CostEstimate(
        est_bytes=est["total"],
        est_phase1_bytes=est["phase1"],
        est_phase2_bytes=est["phase2"],
        est_requests=est["requests"],
        est_wall_s=link.transfer_time(est["total"], est["requests"]),
        est_selectivity=est["est_selectivity"],
        n_windows=est["n_windows"],
        n_windows_pruned=est["n_windows_pruned"],
        per_stage=est["per_stage"],
        per_stage_kinds=est["per_stage_kinds"],
    )


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission budget and fair-share weight.

    ``byte_budget`` caps the sum of priced bytes a tenant may have
    admitted (reserved + settled); ``wall_budget_s`` the same in modeled
    seconds.  ``weight`` scales the tenant's share of the weighted-fair
    queue — a weight-2 tenant drains twice as fast as a weight-1 one.
    """

    byte_budget: float = float("inf")
    wall_budget_s: float = float("inf")
    weight: float = 1.0


@dataclass
class PartialResult:
    """One streamed window-granular ledger entry of a running job.

    ``cols`` holds the window's survivor columns exactly as the final
    output will concatenate them — the union of a completed job's
    partials is bit-identical to the synchronous result (pinned by
    tests/test_service.py).  Cluster-backed jobs stream one entry per
    *shard* instead (``meta["shard_id"]``), with the per-window ledger
    in ``meta["window_rows"]``.
    """

    job_id: int
    seq: int  # per-job stream ordinal (0, 1, 2, ...)
    start: int
    stop: int
    n_passed: int
    cols: dict = field(default_factory=dict)
    jagged: dict = field(default_factory=dict)
    meta: dict = field(default_factory=dict)


@dataclass
class SkimJob:
    """One submitted query and everything the service knows about it."""

    job_id: int
    tenant: str
    query: object
    state: str = PENDING
    estimate: CostEstimate | None = None
    partials: list[PartialResult] = field(default_factory=list)
    result: object = None  # SkimResult / ClusterSkimResult once DONE
    error: str | None = None  # FAILED cause or REJECTED reason
    cancel_requested: bool = False
    submitted_at: float = 0.0
    started_at: float | None = None
    finished_at: float | None = None
    # weighted-fair virtual finish time + submission ordinal (FIFO tiebreak)
    vfinish: float = 0.0
    seq: int = 0
    # journal recovery (repro.serve.journal): windows already streamed
    # before the crash — the restarted executor recomputes but does not
    # re-stream them, so the post-recovery stream is the suffix
    resume_skip: int = 0
    # per-job span tree (repro.obs.trace.Tracer) when the service runs
    # with tracing on; root_span is the job[..] span every lifecycle
    # span parents under
    tracer: object = None
    root_span: int = 0

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL

    @property
    def stats(self) -> FetchStats:
        """The job's fetch ledger: the result's once DONE, an all-zero
        ledger otherwise — a REJECTED job provably moved nothing."""
        if self.result is not None:
            return self.result.stats
        return FetchStats()

    @property
    def n_passed(self) -> int:
        """Survivors streamed so far (== result total once DONE)."""
        return sum(p.n_passed for p in self.partials)

    def windows_streamed(self) -> list[tuple[int, int]]:
        """(start, stop) of every streamed partial, in stream order."""
        return [(p.start, p.stop) for p in self.partials]


def union_columns(job: SkimJob) -> tuple[dict, dict]:
    """Concatenate a job's streamed partial columns in stream order.

    Returns ``(cols, jagged)`` — the branch-wise union of every
    streamed window's survivor columns, which must equal the final
    output bit-for-bit (the streaming contract, DESIGN.md §12).  Jobs
    whose partials carried no columns (nothing passed anywhere) return
    empty dicts.
    """
    per_branch: dict[str, list] = {}
    jagged: dict[str, str] = {}
    for p in job.partials:
        for name, arr in p.cols.items():
            per_branch.setdefault(name, []).append(arr)
        jagged.update(p.jagged)
    cols = {
        name: np.concatenate(parts) for name, parts in per_branch.items()
    }
    return cols, jagged


__all__ = [
    "CANCELLED",
    "DONE",
    "FAILED",
    "PENDING",
    "REJECTED",
    "RUNNING",
    "TERMINAL",
    "CostEstimate",
    "ManualClock",
    "PartialResult",
    "SkimJob",
    "TenantQuota",
    "price_query",
    "union_columns",
]
