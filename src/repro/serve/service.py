"""Async skim job service: queue, cost-based admission, streaming (DESIGN.md §12).

Every engine in this repo is a synchronous library call; this module is
the *service* a multi-tenant front door needs (ROADMAP item 1): jobs are
submitted, priced, admitted against per-tenant quotas, scheduled through
a weighted-fair queue, executed cooperatively one basket window per
quantum, and streamed back window-granular partial results as each
window's ledger entry completes.

Design pillars:

  * **Cost-based admission.**  :func:`~repro.serve.jobs.price_query`
    prices each submission with the cascade cost model *before* it runs
    (basket metadata only).  Over-quota submissions are REJECTED with
    the priced estimate attached and provably zero bytes fetched.
  * **Weighted-fair queueing.**  Each admitted job gets a virtual
    finish time ``vstart + priced_cost / tenant_weight`` (``vstart``
    continues the tenant's backlog); every quantum runs the job with
    the smallest one.  Cheap queries from other tenants therefore
    schedule ahead of — and preempt, at window boundaries — an
    expensive query instead of queueing behind it.
  * **Cooperative execution.**  The engines' streaming generators
    (:meth:`SkimEngine.iter_run`, :meth:`SharedScanEngine.iter_batch`,
    :meth:`ClusterCoordinator.iter_run`) advance one window (or shard)
    per quantum.  Window boundaries are the cancellation points, and
    every yielded partial is appended to ``job.partials`` immediately —
    the union of a completed job's partials is bit-identical to the
    synchronous result by construction.
  * **Determinism.**  One thread, an injectable
    :class:`~repro.serve.jobs.ManualClock`, and a
    :class:`DeterministicExecutor` that records every scheduling
    decision in a replayable trace.  No sleeps anywhere; tests replay
    schedules exactly.
  * **Batch coalescing.**  With ``batching=True``, compatible queued
    jobs start as ONE :meth:`SharedScanEngine.iter_batch` pass —
    phase 1 amortizes across tenants while each job still streams its
    own partials and finishes with its own bit-identical result.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass

from repro.core.engine import SkimEngine, WindowPartial
from repro.obs.metrics import (
    MetricsRegistry,
    observed_phase2_bytes,
    observed_stage_bytes,
    priced_stage_bytes,
)
from repro.obs.trace import Tracer, chrome_trace, trace_json
from repro.serve.engine import SharedScanEngine
from repro.serve.journal import JobJournal
from repro.serve.jobs import (
    CANCELLED,
    DONE,
    FAILED,
    PENDING,
    REJECTED,
    RUNNING,
    CostEstimate,
    ManualClock,
    PartialResult,
    SkimJob,
    TenantQuota,
    price_query,
)

#: bytes per unit of virtual time (WFQ cost currency: priced megabytes)
COST_SCALE_BYTES = 1e6


class ServiceError(RuntimeError):
    """Typed failure of the service layer itself (not of any one job) —
    e.g. the quantum budget exhausting with jobs still live.  Subclasses
    ``RuntimeError`` so pre-existing ``except RuntimeError`` callers keep
    working; the skim fabric's D004 lint requires the typed form."""


# ---------------------------------------------------------------------------
# backends: where a job actually executes
# ---------------------------------------------------------------------------


class EngineBackend:
    """Single-store backend: solo jobs run on
    :meth:`SkimEngine.iter_run`, coalesced batches on
    :meth:`SharedScanEngine.iter_batch` — both stream
    :class:`~repro.core.engine.WindowPartial` per basket window."""

    supports_batch = True

    def __init__(
        self,
        store,
        engine: SkimEngine | None = None,
        shared: SharedScanEngine | None = None,
        mode: str = "near_data",
        **engine_kw,
    ):
        self.store = store
        self.engine = engine or SkimEngine(store, **engine_kw)
        self.shared = shared or SharedScanEngine(
            store,
            chunk_events=self.engine.chunk_events,
            fused=self.engine.fused,
            prune=self.engine.prune,
            cascade=self.engine.cascade,
        )
        self.mode = mode

    def price(self, query, calibration: dict | None = None) -> CostEstimate:
        return price_query(
            query,
            self.store,
            window_events=self.engine.chunk_events,
            link=self.engine.near_input_link,
            calibration=calibration,
        )

    def start(self, query, tracer=None):
        return self.engine.iter_run(query, mode=self.mode, tracer=tracer)

    def start_batch(self, queries, tracer=None):
        return self.shared.iter_batch(queries, tracer=tracer)


class ClusterBackend:
    """Scatter-gather backend: a job fans out over the coordinator's
    shards and streams one partial per *shard* response (each carrying
    its per-window ledger) as the gather progresses."""

    supports_batch = False

    def __init__(self, coordinator):
        self.coordinator = coordinator

    def price(self, query, calibration: dict | None = None) -> CostEstimate:
        parts = [
            price_query(
                query,
                node.shard.store,
                window_events=node.shard.window_events,
                link=node.near_input_link,
                calibration=calibration,
            )
            for node in self.coordinator.nodes
        ]
        per_stage: dict[int, int] = {}
        per_stage_kinds: dict[int, str] = {}
        for p in parts:
            for si, v in p.per_stage.items():
                per_stage[si] = per_stage.get(si, 0) + v
            per_stage_kinds.update(p.per_stage_kinds)
        n_events = sum(
            node.shard.store.n_events for node in self.coordinator.nodes
        )
        return CostEstimate(
            est_bytes=sum(p.est_bytes for p in parts),
            est_phase1_bytes=sum(p.est_phase1_bytes for p in parts),
            est_phase2_bytes=sum(p.est_phase2_bytes for p in parts),
            est_requests=sum(p.est_requests for p in parts),
            # shards serve in parallel: the modeled wall is the slowest
            est_wall_s=max((p.est_wall_s for p in parts), default=0.0),
            est_selectivity=(
                sum(
                    p.est_selectivity * node.shard.store.n_events
                    for p, node in zip(parts, self.coordinator.nodes)
                )
                / max(n_events, 1)
            ),
            n_windows=sum(p.n_windows for p in parts),
            n_windows_pruned=sum(p.n_windows_pruned for p in parts),
            per_stage=per_stage,
            per_stage_kinds=per_stage_kinds,
        )

    def start(self, query, tracer=None):
        return self._gen(query, tracer)

    def _gen(self, query, tracer=None):
        it = self.coordinator.iter_run(query, tracer=tracer)
        while True:
            try:
                resp = next(it)
            except StopIteration as stop:
                return stop.value
            rows = resp.result.extras.get("window_rows", [])
            try:
                yield WindowPartial(
                    index=resp.shard_id,
                    start=rows[0][0] if rows else 0,
                    stop=rows[-1][1] if rows else 0,
                    n_passed=resp.result.n_passed,
                    cols={},
                    jagged={},
                    decision=f"shard:{resp.shard_id}",
                )
            except GeneratorExit:
                # close the coordinator promptly so its tracer's root
                # span settles now, not at garbage collection
                it.close()
                raise


# ---------------------------------------------------------------------------
# scheduler internals
# ---------------------------------------------------------------------------


@dataclass
class _TenantState:
    quota: TenantQuota
    reserved_bytes: float = 0.0  # priced bytes of admitted, unfinished jobs
    spent_bytes: float = 0.0  # observed bytes of finished jobs
    reserved_wall_s: float = 0.0
    spent_wall_s: float = 0.0
    vlast: float = 0.0  # tenant's last virtual finish (backlog tail)


@dataclass
class _Run:
    """One open executor generator: a solo job or a coalesced batch."""

    gen: object
    jobs: list[SkimJob]
    batch: bool = False
    windows: int = 0  # quanta advanced so far


class DeterministicExecutor:
    """Single-threaded cooperative quantum runner.

    The injectable executor seam: the service hands it one quantum
    (advance one run unit by one window) at a time, and it records a
    replayable trace of every scheduling decision —
    ``(quantum, picked_job_id, run_member_ids)``.  Single-threaded by
    construction, so two runs over the same submissions make identical
    decisions in identical order.
    """

    def __init__(self):
        self.trace: list[tuple[int, int, tuple[int, ...]]] = []
        self.quanta = 0

    def run_quantum(self, fn, picked: int, members: tuple[int, ...]):
        self.quanta += 1
        self.trace.append((self.quanta, picked, members))
        return fn()


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------


class SkimService:
    """Multi-tenant async skim job service over one execution backend.

    ``backend`` is an :class:`EngineBackend` (single store; supports
    batch coalescing) or :class:`ClusterBackend` (scatter-gather).  A
    bare :class:`~repro.data.store.EventStore` is wrapped in an
    :class:`EngineBackend` for convenience.  ``quotas`` maps tenant
    name -> :class:`~repro.serve.jobs.TenantQuota`; unknown tenants get
    the (unlimited, weight-1) default.  ``clock`` and ``executor`` are
    the deterministic seams — inject your own to control timestamps and
    observe scheduling.

    The service is cooperative and single-threaded: nothing executes
    until :meth:`step` (one scheduling quantum = one basket window of
    one job), :meth:`run_until_idle`, :meth:`result`, or
    :meth:`stream` drives it.
    """

    def __init__(
        self,
        backend,
        quotas: dict[str, TenantQuota] | None = None,
        clock: ManualClock | None = None,
        executor: DeterministicExecutor | None = None,
        batching: bool = False,
        tracing: bool = False,
        metrics: MetricsRegistry | None = None,
        calibrate: bool = False,
        journal: JobJournal | None = None,
    ):
        if not hasattr(backend, "start"):
            backend = EngineBackend(backend)
        self.backend = backend
        self.quotas = dict(quotas or {})
        self.clock = clock or ManualClock()
        self.executor = executor or DeterministicExecutor()
        self.batching = batching and backend.supports_batch
        # observability seams (DESIGN.md §13): ``tracing`` gives every
        # job its own span tree (export with :meth:`export_trace`);
        # ``metrics`` is the shared registry (a private one by default);
        # ``calibrate`` feeds settled jobs' observed/priced ratios back
        # into admission pricing as per-stage-kind priors
        self.tracing = tracing
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.calibrate = calibrate
        # durability seam (DESIGN.md §14): every lifecycle transition is
        # appended to the journal before the service moves on, and
        # :meth:`recover` replays a journal into a fresh service.
        # Journaling requires JSON-able query docs (dict/str).
        self.journal = journal
        self._batch_tracers: list[Tracer] = []
        self.jobs: dict[int, SkimJob] = {}
        self._tenants: dict[str, _TenantState] = {}
        self._runs: dict[int, _Run] = {}  # job_id -> its run unit
        self._ids = itertools.count(1)
        self._seq = itertools.count()
        self._vtime = 0.0  # virtual time of the last service start

    # -- tenants -------------------------------------------------------------

    def _tenant(self, name: str) -> _TenantState:
        if name not in self._tenants:
            self._tenants[name] = _TenantState(
                self.quotas.get(name, TenantQuota())
            )
        return self._tenants[name]

    def tenant_usage(self, name: str) -> dict:
        ts = self._tenant(name)
        return {
            "reserved_bytes": ts.reserved_bytes,
            "spent_bytes": ts.spent_bytes,
            "reserved_wall_s": ts.reserved_wall_s,
            "spent_wall_s": ts.spent_wall_s,
            "byte_budget": ts.quota.byte_budget,
            "wall_budget_s": ts.quota.wall_budget_s,
            "weight": ts.quota.weight,
        }

    # -- submission / admission ----------------------------------------------

    def submit(self, query, tenant: str = "default") -> SkimJob:
        """Price, admit (or reject), and enqueue one query.

        Never blocks and never fetches: pricing is basket metadata only.
        The returned job is PENDING (admitted — it will run when the
        fair queue reaches it) or REJECTED (``job.error`` says why,
        ``job.estimate`` carries the price that condemned it, and
        ``job.stats`` is all-zero).
        """
        job = SkimJob(
            job_id=next(self._ids),
            tenant=tenant,
            query=query,
            submitted_at=self.clock.now(),
            seq=next(self._seq),
        )
        if self.tracing:
            job.tracer = Tracer(clock=self.clock, name=f"job-{job.job_id}")
            job.root_span = job.tracer.begin(
                f"job[{job.job_id}]", kind="job",
                job_id=job.job_id, tenant=tenant,
            )
        self.jobs[job.job_id] = job
        if self.journal is not None:
            self.journal.append(
                "submit", job.job_id, job.submitted_at,
                tenant=tenant, seq=job.seq, query=query,
            )
        ts = self._tenant(tenant)
        calib = self.metrics.calibration_priors() if self.calibrate else None
        try:
            est = (
                self.backend.price(query, calibration=calib)
                if calib
                else self.backend.price(query)
            )
        except Exception as exc:  # malformed query: reject at the door
            return self._reject(job, f"unpriceable query: {exc}")
        job.estimate = est
        q = ts.quota
        byte_used = ts.reserved_bytes + ts.spent_bytes
        if byte_used + est.est_bytes > q.byte_budget:
            return self._reject(
                job,
                f"over byte quota: priced {est.est_bytes} B, "
                f"{q.byte_budget - byte_used:.0f} B left of "
                f"{q.byte_budget:.0f} B budget ({est.describe()})",
            )
        wall_used = ts.reserved_wall_s + ts.spent_wall_s
        if wall_used + est.est_wall_s > q.wall_budget_s:
            return self._reject(
                job,
                f"over wall-clock quota: priced {est.est_wall_s:.4f} s, "
                f"{q.wall_budget_s - wall_used:.4f} s left of "
                f"{q.wall_budget_s:.4f} s budget ({est.describe()})",
            )
        ts.reserved_bytes += est.est_bytes
        ts.reserved_wall_s += est.est_wall_s
        # weighted-fair virtual finish: continue the tenant's backlog,
        # never start in the past
        cost = est.est_bytes / COST_SCALE_BYTES
        vstart = max(self._vtime, ts.vlast)
        job.vfinish = vstart + cost / max(q.weight, 1e-9)
        ts.vlast = job.vfinish
        if job.tracer is not None:
            job.tracer.add_span(
                "admission", kind="admission",
                t0=job.submitted_at, t1=self.clock.now(),
                parent=job.root_span,
                admitted=True, est_bytes=est.est_bytes,
            )
        if self.journal is not None:
            self.journal.append(
                "admit", job.job_id, self.clock.now(),
                vfinish=job.vfinish,
                est_bytes=est.est_bytes,
                est_phase1_bytes=est.est_phase1_bytes,
                est_phase2_bytes=est.est_phase2_bytes,
                est_requests=est.est_requests,
                est_wall_s=est.est_wall_s,
                est_selectivity=est.est_selectivity,
                n_windows=est.n_windows,
                n_windows_pruned=est.n_windows_pruned,
            )
        self.metrics.inc("service_jobs_submitted", tenant=tenant)
        return job

    def _reject(self, job: SkimJob, reason: str) -> SkimJob:
        job.state = REJECTED
        job.error = reason
        job.finished_at = self.clock.now()
        if job.tracer is not None:
            job.tracer.add_span(
                "admission", kind="admission",
                t0=job.submitted_at, t1=job.finished_at,
                parent=job.root_span,
                admitted=False, reason=reason,
            )
            job.tracer.end(job.root_span, state=REJECTED)
        if self.journal is not None:
            self.journal.append(
                "reject", job.job_id, job.finished_at, reason=reason
            )
        self.metrics.inc("service_jobs_submitted", tenant=job.tenant)
        self.metrics.inc(
            "service_jobs_total", state=REJECTED, tenant=job.tenant
        )
        return job

    # -- cancellation --------------------------------------------------------

    def cancel(self, job_id: int) -> bool:
        """Cancel a job.  PENDING jobs leave the queue immediately;
        RUNNING jobs stop at the current window boundary (cooperative —
        the service is between quanta whenever this can be called), keep
        the partials they already streamed, and settle CANCELLED.  A
        batch member's cancellation never aborts the shared pass the
        other tenants are riding.  Returns ``False`` for jobs already
        terminal."""
        job = self.jobs[job_id]
        if job.terminal:
            return False
        job.cancel_requested = True
        if job.state == RUNNING:
            run = self._runs.pop(job.job_id, None)
            if run is not None and not run.batch:
                run.gen.close()
        self._settle(job, CANCELLED)
        return True

    # -- scheduling ----------------------------------------------------------

    def _runnable(self) -> SkimJob | None:
        """The weighted-fair pick: smallest virtual finish time wins,
        submission order breaks ties."""
        best = None
        for job in self.jobs.values():
            if job.state in (PENDING, RUNNING):
                key = (job.vfinish, job.seq)
                if best is None or key < (best.vfinish, best.seq):
                    best = job
        return best

    def step(self) -> bool:
        """Run ONE scheduling quantum: pick the fair-queue head, advance
        its run unit by one basket window (starting it first if
        pending), deliver the streamed partial.  Returns ``False`` when
        no job is runnable (the service is idle)."""
        job = self._runnable()
        if job is None:
            return False
        run = self._runs.get(job.job_id)
        if run is None:
            run = self._start(job)
            if run is None:  # start itself failed -> job already settled
                return True
        members = tuple(j.job_id for j in run.jobs)
        self.executor.run_quantum(
            lambda: self._advance(run), job.job_id, members
        )
        return True

    def run_until_idle(self, max_quanta: int = 1_000_000) -> int:
        """Drive quanta until every job is terminal; returns how many ran."""
        n = 0
        while self.step():
            n += 1
            if n >= max_quanta:
                raise ServiceError(
                    f"service still busy after {max_quanta} quanta"
                )
        return n

    def result(self, job_id: int) -> SkimJob:
        """Drive the service until ``job_id`` is terminal; return it."""
        job = self.jobs[job_id]
        while not job.terminal and self.step():
            pass
        return job

    def stream(self, job_id: int):
        """Generator of the job's :class:`PartialResult`\\ s, driving the
        scheduler as needed: yields each streamed window as soon as the
        fair queue lets the job produce it, ends when the job is
        terminal.  Other tenants' quanta interleave underneath — this is
        the subscriber's view of one job, not a private executor."""
        job = self.jobs[job_id]
        i = 0
        while True:
            while i < len(job.partials):
                yield job.partials[i]
                i += 1
            if job.terminal or not self.step():
                return

    # -- run units -----------------------------------------------------------

    def _start(self, job: SkimJob) -> _Run | None:
        """Open the executor generator for a pending job — or, with
        batching on, for EVERY pending job as one coalesced shared
        scan."""
        now = self.clock.now()
        if self.batching and not job.resume_skip:
            # recovered mid-stream jobs run solo: their fast-forward
            # watermark has no meaning inside a coalesced batch
            members = sorted(
                (
                    j for j in self.jobs.values()
                    if j.state == PENDING and not j.resume_skip
                ),
                key=lambda j: (j.vfinish, j.seq),
            )
        else:
            members = [job]
        try:
            if len(members) > 1:
                # a coalesced batch executes under ONE shared tracer (the
                # scan is genuinely shared work); per-job tracers keep
                # their own admission/queue/settle lifecycle spans
                btr = None
                if self.tracing:
                    btr = Tracer(
                        clock=self.clock,
                        name=f"batch-{len(self._batch_tracers)}",
                    )
                    self._batch_tracers.append(btr)
                gen = self.backend.start_batch(
                    [j.query for j in members], tracer=btr
                )
                run = _Run(gen=gen, jobs=members, batch=True)
            else:
                members = [job]
                gen = (
                    self.backend.start(job.query, tracer=job.tracer)
                    if job.tracer is not None
                    else self.backend.start(job.query)
                )
                run = _Run(gen=gen, jobs=members)
        except Exception as exc:
            job.error = f"{type(exc).__name__}: {exc}"
            self._settle(job, FAILED)
            return None
        for j in run.jobs:
            if j.tracer is not None:
                j.tracer.add_span(
                    "queue_wait", kind="queue",
                    t0=j.submitted_at, t1=now, parent=j.root_span,
                )
            self.metrics.observe(
                "service_queue_wait_s", now - j.submitted_at
            )
            j.state = RUNNING
            j.started_at = now
            self._runs[j.job_id] = run
            if self.journal is not None:
                self.journal.append(
                    "start", j.job_id, now, resume=j.resume_skip
                )
        # virtual time advances to the service start of the picked job
        self._vtime = max(self._vtime, job.vfinish)
        if not run.batch and job.resume_skip:
            # journal recovery: deterministically re-advance the fresh
            # generator past the windows whose partials were already
            # streamed before the crash — recomputed, never re-streamed,
            # so the post-recovery stream is exactly the suffix
            try:
                for _ in range(job.resume_skip):
                    next(run.gen)
            except StopIteration as stop:
                # the crash hit after the final window: settle directly
                self._finish(run, stop.value)
                return None
            except Exception as exc:
                self._fail(run, exc)
                return None
        return run

    def _advance(self, run: _Run) -> None:
        """One quantum: advance the generator one window and dispatch."""
        try:
            part = next(run.gen)
        except StopIteration as stop:
            self._finish(run, stop.value)
        except Exception as exc:
            self._fail(run, exc)
        else:
            run.windows += 1
            self._deliver(run, part)

    def _deliver(self, run: _Run, part) -> None:
        if run.batch:
            for i, j in enumerate(run.jobs):
                if j.state == RUNNING:
                    self._append_partial(j, part.tenants[i])
        else:
            self._append_partial(run.jobs[0], part)

    def _append_partial(self, job: SkimJob, wp: WindowPartial) -> None:
        job.partials.append(
            PartialResult(
                job_id=job.job_id,
                seq=len(job.partials),
                start=wp.start,
                stop=wp.stop,
                n_passed=wp.n_passed,
                cols=wp.cols,
                jagged=wp.jagged,
                meta={"decision": wp.decision, "window": wp.index},
            )
        )
        if self.journal is not None:
            # the watermark seq is GLOBAL across crashes: a recovered
            # job's suffix continues where the journaled prefix stopped
            self.journal.append(
                "window", job.job_id, self.clock.now(),
                seq=job.resume_skip + len(job.partials) - 1,
                start=wp.start, stop=wp.stop, n_passed=wp.n_passed,
            )
        if len(job.partials) == 1:
            self.metrics.observe(
                "service_first_partial_s",
                self.clock.now() - job.submitted_at,
            )

    def _finish(self, run: _Run, value) -> None:
        if run.batch:
            results = value.results  # SharedScanResult, request order
            for i, j in enumerate(run.jobs):
                if j.state != RUNNING:
                    continue  # cancelled mid-batch: already settled
                j.result = results[i]
                self._runs.pop(j.job_id, None)
                self._settle(j, DONE)
        else:
            job = run.jobs[0]
            job.result = value
            self._runs.pop(job.job_id, None)
            self._settle(job, DONE)

    def _fail(self, run: _Run, exc: Exception) -> None:
        cause = f"{type(exc).__name__}: {exc}"
        for j in run.jobs:
            self._runs.pop(j.job_id, None)
            if not j.terminal:
                j.error = cause
                self._settle(j, FAILED)

    def _settle(self, job: SkimJob, state: str) -> None:
        """Terminal-state bookkeeping: release the admission
        reservation; DONE jobs charge their *observed* ledger (the
        estimate trues up against reality, so a tenant's budget drains
        by what it actually moved)."""
        job.state = state
        job.finished_at = self.clock.now()
        ts = self._tenant(job.tenant)
        if job.estimate is not None:
            ts.reserved_bytes -= job.estimate.est_bytes
            ts.reserved_wall_s -= job.estimate.est_wall_s
        if state == DONE and job.result is not None:
            ts.spent_bytes += job.result.stats.bytes_fetched
            ts.spent_wall_s += _modeled_seconds(job.result)
            self._record_calibration(job)
        if self.journal is not None:
            observed = (
                job.result.stats.bytes_fetched
                if job.result is not None
                else 0
            )
            self.journal.append(
                "settle", job.job_id, job.finished_at,
                state=state, error=job.error,
                observed_bytes=observed,
                modeled_s=(
                    _modeled_seconds(job.result)
                    if state == DONE and job.result is not None
                    else 0.0
                ),
            )
        self.metrics.inc("service_jobs_total", state=state, tenant=job.tenant)
        self.metrics.set_gauge(
            "tenant_spent_bytes", ts.spent_bytes, tenant=job.tenant
        )
        self.metrics.set_gauge(
            "tenant_reserved_bytes", ts.reserved_bytes, tenant=job.tenant
        )
        if job.tracer is not None:
            observed = (
                job.result.stats.bytes_fetched
                if job.result is not None
                else 0
            )
            job.tracer.add_span(
                "settle", kind="settle",
                t0=job.finished_at, t1=job.finished_at,
                parent=job.root_span,
                state=state,
                observed_bytes=observed,
                priced_bytes=(
                    job.estimate.est_bytes
                    if job.estimate is not None
                    else None
                ),
            )
            job.tracer.end(job.root_span, state=state)

    def _record_calibration(self, job: SkimJob) -> None:
        """Feed one DONE job's observed ledger back against its priced
        estimate: total bytes, the phase-2 split when the result reports
        one, and per-cascade-stage-kind bytes (the prior
        :func:`~repro.core.plan.estimate_plan_bytes` consumes)."""
        est = job.estimate
        if est is None or job.result is None:
            return
        self.metrics.record_price_ratio(
            "total", est.est_bytes, job.result.stats.bytes_fetched
        )
        p2 = observed_phase2_bytes(job.result)
        if p2 is not None and est.est_phase2_bytes > 0:
            self.metrics.record_price_ratio(
                "phase2", est.est_phase2_bytes, p2
            )
        observed = observed_stage_bytes(job.result)
        for kind, priced in priced_stage_bytes(est).items():
            if kind in observed:
                self.metrics.record_price_ratio(kind, priced, observed[kind])

    # -- introspection -------------------------------------------------------

    @property
    def trace(self):
        """The executor's replayable decision log."""
        return self.executor.trace

    def calibration_summary(self) -> dict:
        """Priced-vs-observed byte totals (and ratio) per cascade-stage
        kind, accumulated from every DONE job."""
        return self.metrics.calibration_summary()

    def export_trace(self, path: str | None = None) -> dict:
        """Assemble every traced job (and coalesced batch) into ONE
        Chrome-trace document — one ``pid`` per job, batch passes on
        pids from 10000 — and optionally write its canonical JSON to
        ``path``.  Requires ``tracing=True``; returns the document."""
        groups = [
            (job.job_id, f"job-{job.job_id} [{job.tenant}]", job.tracer)
            for job in self.jobs.values()
            if job.tracer is not None
        ]
        groups += [
            (10_000 + i, btr.name, btr)
            for i, btr in enumerate(self._batch_tracers)
        ]
        doc = chrome_trace(groups)
        if path is not None:
            with open(path, "w") as fh:
                fh.write(trace_json(doc))
        return doc

    # -- durability ----------------------------------------------------------

    @classmethod
    def recover(cls, journal: JobJournal, backend, **service_kw) -> "SkimService":
        """Reconstruct a service from a :class:`JobJournal` after a crash.

        Replays the journal's lifecycle records into a fresh service
        over ``backend`` (which must serve the same data — the journal
        stores queries and watermarks, not baskets):

          * terminal jobs return with state, error, and settle-time
            tenant accounting;
          * admitted PENDING jobs re-enter the fair queue with their
            journaled estimate and virtual finish time;
          * jobs journaled RUNNING resume from their window watermark —
            the restarted generator recomputes the already-streamed
            windows without re-streaming them, so the post-recovery
            stream equals the uninterrupted run's suffix and the final
            result is bit-identical (pinned by tests/test_journal.py).

        The returned service keeps journaling to the same journal, so
        recovery composes across repeated crashes.
        """
        svc = cls(backend, **service_kw)
        by_job: dict[int, dict] = {}
        for rec in journal.records():
            svc.metrics.inc("journal_replays_total", event=rec["event"])
            d = by_job.setdefault(rec["job_id"], {"watermark": -1})
            ev = rec["event"]
            if ev == "window":
                d["watermark"] = max(d["watermark"], rec["seq"])
            else:
                d[ev] = rec
        max_id, max_seq = 0, -1
        for jid in sorted(by_job):
            d = by_job[jid]
            sub = d.get("submit")
            if sub is None:
                continue  # torn journal head: nothing to rebuild from
            max_id = max(max_id, jid)
            max_seq = max(max_seq, sub["seq"])
            job = SkimJob(
                job_id=jid,
                tenant=sub["tenant"],
                query=sub["query"],
                submitted_at=sub["t"],
                seq=sub["seq"],
            )
            svc.jobs[jid] = job
            ts = svc._tenant(job.tenant)
            rej = d.get("reject")
            if rej is not None:
                job.state = REJECTED
                job.error = rej["reason"]
                job.finished_at = rej["t"]
                continue
            adm = d.get("admit")
            if adm is not None:
                job.estimate = CostEstimate(
                    est_bytes=adm["est_bytes"],
                    est_phase1_bytes=adm["est_phase1_bytes"],
                    est_phase2_bytes=adm["est_phase2_bytes"],
                    est_requests=adm["est_requests"],
                    est_wall_s=adm["est_wall_s"],
                    est_selectivity=adm["est_selectivity"],
                    n_windows=adm["n_windows"],
                    n_windows_pruned=adm["n_windows_pruned"],
                )
                job.vfinish = adm["vfinish"]
                ts.vlast = max(ts.vlast, job.vfinish)
            st = d.get("settle")
            if st is not None:
                job.state = st["state"]
                job.error = st.get("error")
                job.finished_at = st["t"]
                ts.spent_bytes += st.get("observed_bytes", 0)
                ts.spent_wall_s += st.get("modeled_s", 0.0)
                svc._vtime = max(svc._vtime, job.vfinish)
                continue
            # PENDING (admitted, never started) or RUNNING (crashed
            # mid-stream): both re-enter the queue; the latter carries
            # its fast-forward watermark
            if job.estimate is not None:
                ts.reserved_bytes += job.estimate.est_bytes
                ts.reserved_wall_s += job.estimate.est_wall_s
            job.state = PENDING
            if d.get("start") is not None:
                job.resume_skip = d["watermark"] + 1
                svc._vtime = max(svc._vtime, job.vfinish)
            if svc.tracing:
                job.tracer = Tracer(clock=svc.clock, name=f"job-{jid}")
                job.root_span = job.tracer.begin(
                    f"job[{jid}]", kind="job", job_id=jid, tenant=job.tenant
                )
                job.tracer.add_span(
                    "recover", kind="recover",
                    t0=svc.clock.now(), t1=svc.clock.now(),
                    parent=job.root_span,
                    resume_skip=job.resume_skip,
                )
        svc._ids = itertools.count(max_id + 1)
        svc._seq = itertools.count(max_seq + 1)
        svc.journal = journal
        return svc

    def queue_depth(self) -> int:
        return sum(
            1 for j in self.jobs.values() if j.state in (PENDING, RUNNING)
        )

    def describe(self) -> str:
        by_state: dict[str, int] = {}
        for j in self.jobs.values():
            by_state[j.state] = by_state.get(j.state, 0) + 1
        states = ", ".join(f"{k}={v}" for k, v in sorted(by_state.items()))
        return (
            f"SkimService({states or 'empty'}, "
            f"quanta={self.executor.quanta}, batching={self.batching})"
        )


def _modeled_seconds(result) -> float:
    """A finished job's modeled wall-clock, in the same currency the
    admission estimate priced (link + measured stages)."""
    total = getattr(result, "modeled_total_s", None)  # ClusterSkimResult
    if total is not None:
        return total
    return result.extras.get("pipeline_total", result.breakdown.total())


__all__ = [
    "COST_SCALE_BYTES",
    "ClusterBackend",
    "DeterministicExecutor",
    "EngineBackend",
    "SkimService",
]
