"""Durable job journal for the skim service (DESIGN.md §14).

The :class:`~repro.serve.service.SkimService` is deliberately
single-threaded and in-memory — which means a crashed process forgets
every queued and half-streamed job.  :class:`JobJournal` fixes that with
the classic write-ahead pattern: the service appends one JSON-lines
record per lifecycle transition (``submit`` / ``admit`` / ``reject`` /
``start`` / ``window`` / ``settle``), and
:meth:`SkimService.recover <repro.serve.service.SkimService.recover>`
replays the log into a fresh service:

  * terminal jobs come back with their state, error, and settle-time
    accounting (a recovered tenant's budget is exactly as drained as it
    was);
  * admitted-but-unstarted jobs re-enter the weighted-fair queue with
    their journaled estimate and virtual finish time — no re-pricing,
    no queue-order drift;
  * RUNNING jobs resume from their **window watermark**: the executor
    generator is reopened and deterministically fast-forwarded past the
    windows whose partials were already streamed (recomputed, not
    re-streamed), so the post-recovery stream is exactly the
    uninterrupted run's suffix and the final result is bit-identical.

The journal is append-only; records are never rewritten.  ``path=None``
keeps it in memory (tests, or callers who persist elsewhere); with a
path every append is flushed before returning so a crash loses at most
the transition in flight.
"""

from __future__ import annotations

import json
import os

#: every record kind the service appends, in lifecycle order
JOURNAL_EVENTS = (
    "submit",
    "admit",
    "reject",
    "start",
    "window",
    "settle",
)

#: bump when the record shape changes incompatibly
JOURNAL_VERSION = 1


class JobJournal:
    """Append-only JSON-lines journal of service lifecycle transitions.

    Every record is one JSON object with at least ``event`` (one of
    :data:`JOURNAL_EVENTS`), ``job_id``, and ``t`` (the service's
    deterministic clock).  Opening an existing path loads its records —
    the crash-recovery entry point — and appends after them.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self._records: list[dict] = []
        if path is not None and os.path.exists(path):
            with open(path) as fh:
                for line in fh:
                    line = line.strip()
                    if line:
                        self._records.append(json.loads(line))
        # the append handle stays open for the journal's lifetime;
        # line-buffered JSON so each record hits the OS on write
        self._fh = open(path, "a") if path is not None else None

    def append(self, event: str, job_id: int, t: float, **fields) -> dict:
        """Record one transition; returns the appended record."""
        if event not in JOURNAL_EVENTS:
            raise ValueError(
                f"unknown journal event {event!r} (want {JOURNAL_EVENTS})"
            )
        rec = {"v": JOURNAL_VERSION, "event": event, "job_id": job_id, "t": t}
        rec.update(fields)
        try:
            line = json.dumps(rec, sort_keys=True)
        except TypeError as exc:
            raise TypeError(
                f"journal record for {event!r} is not JSON-able: {exc} — "
                "submit queries as dict/str docs when journaling"
            ) from None
        self._records.append(rec)
        if self._fh is not None:
            self._fh.write(line + "\n")
            self._fh.flush()
        return rec

    def records(self, event: str | None = None) -> list[dict]:
        """All records in append order, optionally one event kind."""
        if event is None:
            return list(self._records)
        return [r for r in self._records if r["event"] == event]

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __len__(self) -> int:
        return len(self._records)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        where = self.path or "<memory>"
        return f"JobJournal({where!r}, records={len(self._records)})"


__all__ = ["JOURNAL_EVENTS", "JOURNAL_VERSION", "JobJournal"]
