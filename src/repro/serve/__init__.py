from repro.serve.engine import SharedScanEngine, SharedScanResult

__all__ = ["SharedScanEngine", "SharedScanResult"]
