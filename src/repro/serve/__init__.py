"""Service layer: shared-scan batching + the async skim job service.

:class:`SharedScanEngine` amortizes one phase-1 pass over a tenant
batch (DESIGN.md §6); :class:`SkimService` (DESIGN.md §12) puts a job
lifecycle in front of every backend — cost-based admission, per-tenant
quotas, a weighted-fair queue, and window-granular streaming of partial
results.
"""

from repro.serve.engine import BatchWindowPartial, SharedScanEngine, SharedScanResult
from repro.serve.jobs import (
    CANCELLED,
    DONE,
    FAILED,
    PENDING,
    REJECTED,
    RUNNING,
    TERMINAL,
    CostEstimate,
    ManualClock,
    PartialResult,
    SkimJob,
    TenantQuota,
    price_query,
    union_columns,
)
from repro.serve.journal import JOURNAL_EVENTS, JOURNAL_VERSION, JobJournal
from repro.serve.service import (
    ClusterBackend,
    DeterministicExecutor,
    EngineBackend,
    ServiceError,
    SkimService,
)

__all__ = [
    "CANCELLED",
    "DONE",
    "FAILED",
    "PENDING",
    "REJECTED",
    "RUNNING",
    "TERMINAL",
    "BatchWindowPartial",
    "ClusterBackend",
    "CostEstimate",
    "DeterministicExecutor",
    "EngineBackend",
    "JOURNAL_EVENTS",
    "JOURNAL_VERSION",
    "JobJournal",
    "ManualClock",
    "PartialResult",
    "ServiceError",
    "SharedScanEngine",
    "SharedScanResult",
    "SkimJob",
    "SkimService",
    "TenantQuota",
    "price_query",
    "union_columns",
]
