"""Distributed skim cluster walkthrough (DESIGN.md §5).

A fleet of storage nodes stripes one synthetic NanoAOD-like dataset;
the scatter-gather coordinator fans a Higgs-style query out to every
node, merges the per-shard results bit-identically to a single-node
run, and demonstrates the operational story on top:

  1. cold scatter-gather across N nodes vs the single-node run,
  2. a node failure mid-fleet, transparently retried on a replica,
  3. a straggling node stretching the modeled makespan,
  4. a warm content-addressed result cache serving every shard without
     touching a node,
  5. a multi-tenant batch: one shared scan per node, phase-1 bytes
     amortized across tenants,
  6. zone-map predicate pushdown (DESIGN.md §9): a selective run-range
     query whose basket stats prove most windows empty — per-node pruned
     windows and saved bytes, and (striped finely enough) whole shards
     answered by the coordinator without any RPC.

Deterministic: the dataset is seeded, faults are injected, links are
modeled.  Run: PYTHONPATH=src python examples/skim_cluster.py
"""

import argparse

from repro.cluster import SkimResultCache, build_cluster, window_spans
from repro.core.engine import LOCAL_DISK, SkimEngine
from repro.data.synth import make_nanoaod_like

QUERY = {
    "branches": ["Electron_*", "Jet_*", "MET_*", "HLT_*"],
    "selection": {
        "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                ],
            }
        ],
        "event": [{"type": "cut", "branch": "MET_pt", "op": ">", "value": 25.0}],
    },
}

TENANTS = [
    {"branches": ["Muon_*", "MET_*"], "selection": {
        "preselection": [{"branch": "MET_pt", "op": ">", "value": 20.0}],
        "object": [{"collection": "Muon",
                    "cuts": [{"var": "pt", "op": ">", "value": 15.0}]}]}},
    {"branches": ["Jet_*", "MET_*"], "selection": {
        "preselection": [{"branch": "MET_pt", "op": ">", "value": 20.0}],
        "object": [{"collection": "Jet",
                    "cuts": [{"var": "pt", "op": ">", "value": 30.0}],
                    "min_count": 2}]}},
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=40_000)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--policy", default="size_balanced",
                    choices=["round_robin", "size_balanced"])
    args = ap.parse_args()

    store = make_nanoaod_like(args.events, n_hlt=16, n_filler=24, seed=args.seed)
    print(f"dataset: {args.events} events, {len(store.branch_names())} branches, "
          f"{store.compressed_bytes()/1e6:.1f} MB compressed")

    single = SkimEngine(store, near_input_link=LOCAL_DISK).run(QUERY, "near_data")
    print(f"single node: {single.n_passed}/{single.n_input} events pass, "
          f"modeled {single.extras['pipeline_total']*1e3:.1f} ms\n")

    cache = SkimResultCache(budget_bytes=128 << 20)
    coord = build_cluster(
        store, args.nodes, policy=args.policy, cache=cache,
        near_input_link=LOCAL_DISK,
    )
    print(f"cluster: {args.nodes} nodes ({args.policy}), one replica per shard")
    for node in coord.nodes:
        sh = node.shard
        print(f"  node {node.node_id}: {len(sh.window_ids)} windows, "
              f"{sh.n_events} events, {sh.comp_bytes/1e6:.1f} MB, "
              f"manifest {sh.manifest_hash[:12]}…")

    # 1. cold scatter-gather --------------------------------------------------
    res = coord.run(QUERY)
    assert res.n_passed == single.n_passed
    assert res.output.compressed_bytes() == single.output.compressed_bytes()
    print(f"\ncold run: {res.n_passed} survivors (bit-identical to single node), "
          f"modeled {res.modeled_total_s*1e3:.1f} ms "
          f"(slowest node + {res.merge_s*1e3:.1f} ms merge), "
          f"realized {res.wall_s*1e3:.0f} ms")

    # 2. node failure -> replica retry ---------------------------------------
    cache.clear()
    coord.nodes[1].inject_fault("fail")
    res = coord.run(QUERY)
    assert res.n_passed == single.n_passed
    sid, dead, used = res.retries[0]
    print(f"node failure: shard {sid} primary (node {dead}) died, replica "
          f"node {used} served it — output unchanged")

    # 3. straggler ------------------------------------------------------------
    cache.clear()
    coord.nodes[0].inject_fault("straggle", delay_s=0.25)
    res = coord.run(QUERY)
    print(f"straggler: +250 ms on node 0 -> modeled "
          f"{res.modeled_total_s*1e3:.1f} ms (max-over-nodes absorbs it)")

    # 4. warm cache -----------------------------------------------------------
    warm = coord.run(QUERY)
    assert warm.cache_hits == args.nodes
    assert warm.n_passed == single.n_passed
    print(f"warm cache: {warm.cache_hits}/{args.nodes} shards served from cache "
          f"({cache.stats.saved_fetch_bytes/1e6:.1f} MB fetch skipped), modeled "
          f"{warm.modeled_total_s*1e3:.1f} ms")

    # 5. multi-tenant batch ---------------------------------------------------
    batch = coord.run_batch(TENANTS)
    print(f"\ntenant batch: {len(TENANTS)} queries, one shared scan per node")
    for i, r in enumerate(batch.results):
        print(f"  tenant {i}: {r.n_passed}/{r.n_input} events "
              f"({100*r.selectivity:.2f}%)")
    print(f"  phase-1 {batch.shared_phase1_bytes/1e6:.2f} MB shared vs "
          f"{batch.naive_phase1_bytes/1e6:.2f} MB naive -> "
          f"{batch.amortization:.2f}x amortization")

    # 6. zone-map predicate pushdown ------------------------------------------
    # a run-range skim: luminosityBlock is recorded monotonically, so the
    # per-basket min/max prove most windows empty before any fetch
    lumi_max = (args.events // 1000) // 20  # ~5% of luminosity blocks
    selective = {
        "branches": ["Electron_*", "MET_*", "event", "luminosityBlock"],
        "selection": {
            "preselection": [
                {"branch": "luminosityBlock", "op": "<=", "value": lumi_max}
            ],
            "event": [
                {"type": "cut", "branch": "MET_pt", "op": ">", "value": 25.0}
            ],
        },
    }
    single_sel = SkimEngine(store, near_input_link=LOCAL_DISK).run(
        selective, "near_data", prune=False
    )
    res = coord.run(selective)
    assert res.n_passed == single_sel.n_passed
    assert res.output.compressed_bytes() == single_sel.output.compressed_bytes()
    print(f"\nzone-map pushdown: lumi <= {lumi_max} & MET > 25 -> "
          f"{res.n_passed}/{res.n_input} events "
          f"({100 * res.selectivity:.2f}%), bit-identical to unpruned")
    for r in res.responses:
        pw = r.result.extras.get("pruned_windows", [])
        print(f"  node {r.node_id}: {len(pw)}/{len(r.window_ids)} windows "
              f"pruned, {r.result.stats.bytes_skipped / 1e3:.1f} KB fetch "
              f"proved away{' [shard skipped, no RPC]' if r.pruned else ''}")
    print(f"  cluster total: {res.extras['prune_saved_bytes'] / 1e6:.2f} MB "
          f"never moved, {len(res.pruned_shards)} shard(s) answered "
          "from manifests alone")

    # striped one window per node, whole shards become skippable
    fine = build_cluster(
        store, len(window_spans(store.n_events, store.basket_events)),
        replication=False, near_input_link=LOCAL_DISK,
    )
    res = fine.run(selective)
    assert res.n_passed == single_sel.n_passed
    print(f"  striped 1 window/node ({len(fine.nodes)} nodes): "
          f"{len(res.pruned_shards)} shards skipped before any RPC")


if __name__ == "__main__":
    main()
