"""Async skim job service demo — submit N tenants, watch partials
stream, cancel one (DESIGN.md §12).

Three tenants hit one :class:`SkimService` front door:

  * ``alice`` submits the full Higgs-style skim;
  * ``bob`` submits a tighter variant — and gets cancelled mid-stream;
  * ``carol`` is over her byte quota, so admission control rejects her
    *before anything is fetched*, with the plan-priced estimate attached;
  * ``dave`` submits a cheap counting query AFTER alice's expensive one
    and still finishes first — the weighted-fair queue refuses to
    head-of-line block him.

Every scheduling decision runs on the deterministic single-threaded
executor with an injected clock, so the run is bit-reproducible: same
partials, same order, same byte accounting, every time.

Run: PYTHONPATH=src python examples/skim_service_async.py [--events 50000]
"""

import argparse

from repro.data.synth import make_nanoaod_like
from repro.serve import ManualClock, SkimService, TenantQuota, union_columns

QUERY = {
    "branches": ["Electron_*", "Muon_*", "Jet_*", "MET_*", "HLT_*"]
    + [f"Filler_{i:03d}" for i in range(40)],
    "selection": {
        "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                ],
            }
        ],
        "event": [
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 25.0}
        ],
    },
}

QUERY_TIGHT = {
    **QUERY,
    "selection": {
        **QUERY["selection"],
        "event": [
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 40.0}
        ],
    },
}

QUERY_CHEAP = {
    "branches": ["nMuon", "event"],
    "selection": {
        "preselection": [{"branch": "nMuon", "op": ">=", "value": 3}]
    },
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=50_000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    store = make_nanoaod_like(
        args.events, n_hlt=32, n_filler=60, seed=args.seed
    )
    print(
        f"store: {args.events} events, {len(store.branch_names())} "
        f"branches, {store.compressed_bytes() / 1e6:.1f} MB\n"
    )

    svc = SkimService(
        store,
        clock=ManualClock(),
        quotas={
            "carol": TenantQuota(byte_budget=1_000),  # ~nothing
            "dave": TenantQuota(weight=2.0),
        },
    )

    alice = svc.submit(QUERY, tenant="alice")
    bob = svc.submit(QUERY_TIGHT, tenant="bob")
    carol = svc.submit(QUERY, tenant="carol")
    dave = svc.submit(QUERY_CHEAP, tenant="dave")

    for job, who in ((alice, "alice"), (bob, "bob"), (carol, "carol"),
                     (dave, "dave")):
        tag = f"job {job.job_id} ({who})"
        if job.estimate:
            print(f"{tag:>16}: {job.state:<9} {job.estimate.describe()}")
        if job.state == "REJECTED":
            print(f"{' ':>16}  rejected: {job.error.split('(')[0].strip()}")
            print(
                f"{' ':>16}  bytes fetched for this job: "
                f"{job.stats.bytes_fetched}"
            )
    print()

    # drive the scheduler by hand, narrating every streamed partial;
    # cancel bob after his second window
    seen: dict[int, int] = {}
    while svc.step():
        for job, who in ((alice, "alice"), (bob, "bob"), (dave, "dave")):
            for p in job.partials[seen.get(job.job_id, 0):]:
                print(
                    f"  quantum {svc.executor.quanta:>2}: {who:<6} "
                    f"window [{p.start:>6},{p.stop:>6}) -> "
                    f"{p.n_passed} survivors"
                )
            seen[job.job_id] = len(job.partials)
        if len(bob.partials) == 2 and not bob.terminal:
            svc.cancel(bob.job_id)
            print("  >> cancelled bob at the window boundary")

    print()
    for job, who in ((alice, "alice"), (bob, "bob"), (carol, "carol"),
                     (dave, "dave")):
        line = f"{who:>16}: {job.state:<9} {len(job.partials)} partials"
        if job.state == "DONE":
            cols, _ = union_columns(job)
            line += (
                f", {job.n_passed} survivors, "
                f"{job.stats.bytes_fetched / 1e6:.2f} MB fetched"
            )
        print(line)

    order = []
    for _, picked, _ in svc.trace:
        if picked not in order:
            order.append(picked)
    print(
        f"\nfair-queue service order (job ids): {order}"
        f" — dave's cheap query was never head-of-line blocked"
    )


if __name__ == "__main__":
    main()
