"""Skim service comparison — the paper's evaluation (Figs. 4a/4b/5a/5b)
as a runnable scenario: four placements x three network tiers, plus the
multi-tenant shared-scan batch mode, which fetches + decodes phase 1
once for all tenants and prints the resulting amortization ratio
(approaches Nx for N tenants with overlapping filter sets).

The synthetic dataset is seeded (``--seed``, default 0), so every run
reproduces the same events, survivor counts, and byte accounting.

Run: PYTHONPATH=src python examples/skim_service.py [--events 50000]
"""

import argparse

from repro.core.engine import NetworkModel, SkimEngine
from repro.data.synth import make_nanoaod_like
from repro.serve.engine import SharedScanEngine

QUERY = {
    "branches": ["Electron_*", "Muon_*", "Jet_*", "MET_*", "HLT_*"]
    + [f"Filler_{i:03d}" for i in range(40)],
    "selection": {
        "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                ],
            }
        ],
        "event": [{"type": "cut", "branch": "MET_pt", "op": ">", "value": 25.0}],
    },
}

MODES = ["client_plain", "client_opt", "server_side", "near_data"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=50_000)
    ap.add_argument("--seed", type=int, default=0,
                    help="dataset RNG seed (fixed -> bit-reproducible runs)")
    args = ap.parse_args()

    store = make_nanoaod_like(args.events, n_hlt=32, n_filler=60, seed=args.seed)
    print(f"store: {args.events} events, {len(store.branch_names())} branches, "
          f"{store.compressed_bytes()/1e6:.1f} MB\n")

    print(f"{'mode':<14}", end="")
    for gbps in (1, 10, 100):
        print(f"{gbps} Gb/s".rjust(12), end="")
    print(f"{'busy%':>8}")

    for mode in MODES:
        print(f"{mode:<14}", end="")
        busy = 0.0
        for gbps in (1, 10, 100):
            link = NetworkModel(gbps, rtt_s=0.010 if gbps == 1 else 0.001)
            res = SkimEngine(store, input_link=link).run(QUERY, mode)
            print(f"{res.breakdown.total():>11.2f}s", end="")
            busy = res.busy_fraction
        print(f"{100*busy:>7.0f}%")

    res = SkimEngine(store).run(QUERY, "near_data")
    print(f"\nnear-data breakdown: "
          + ", ".join(f"{k}={v:.3f}s" for k, v in res.breakdown.as_dict().items()))

    # -- multi-tenant shared scan: N queries, one pass over the store -----
    # realistic tenant mix: everyone gates on MET + a trigger, each
    # analysis adds its own object leg
    def tenant(extra: dict) -> dict:
        return {
            "branches": ["Electron_*", "Muon_*", "Jet_*", "MET_*"],
            "selection": {
                "preselection": [{"branch": "MET_pt", "op": ">", "value": 20.0}],
                "event": [{"type": "any", "branches": ["HLT_IsoMu24"]}],
                **extra,
            },
        }

    tenants = [
        tenant({"object": [{"collection": "Electron",
                            "cuts": [{"var": "pt", "op": ">", "value": 20.0}]}]}),
        tenant({"object": [{"collection": "Muon",
                            "cuts": [{"var": "pt", "op": ">", "value": 15.0}]}]}),
        tenant({"object": [{"collection": "Jet",
                            "cuts": [{"var": "pt", "op": ">", "value": 30.0}],
                            "min_count": 2}]}),
        tenant({}),
    ]
    batch = SharedScanEngine(store).run_batch(tenants)
    print(f"\nshared scan: {batch.n_queries} tenant queries, one pass")
    for i, r in enumerate(batch.results):
        print(f"  tenant {i}: {r.n_passed}/{r.n_input} events "
              f"({100 * r.selectivity:.2f}%)")
    print(f"  phase-1 bytes shared={batch.shared_stats.bytes_fetched / 1e6:.2f} MB "
          f"vs naive={batch.naive_phase1_bytes / 1e6:.2f} MB "
          f"-> {batch.amortization:.2f}x phase-1 amortization "
          f"({batch.n_queries} tenants)")


if __name__ == "__main__":
    main()
