"""Skim service comparison — the paper's evaluation (Figs. 4a/4b/5a/5b)
as a runnable scenario: four placements x three network tiers.

Run: PYTHONPATH=src python examples/skim_service.py [--events 50000]
"""

import argparse

from repro.core.engine import NetworkModel, SkimEngine
from repro.data.synth import make_nanoaod_like

QUERY = {
    "branches": ["Electron_*", "Muon_*", "Jet_*", "MET_*", "HLT_*"]
    + [f"Filler_{i:03d}" for i in range(40)],
    "selection": {
        "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                ],
            }
        ],
        "event": [{"type": "cut", "branch": "MET_pt", "op": ">", "value": 25.0}],
    },
}

MODES = ["client_plain", "client_opt", "server_side", "near_data"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=50_000)
    args = ap.parse_args()

    store = make_nanoaod_like(args.events, n_hlt=32, n_filler=60)
    print(f"store: {args.events} events, {len(store.branch_names())} branches, "
          f"{store.compressed_bytes()/1e6:.1f} MB\n")

    print(f"{'mode':<14}", end="")
    for gbps in (1, 10, 100):
        print(f"{str(gbps)+' Gb/s':>12}", end="")
    print(f"{'busy%':>8}")

    for mode in MODES:
        print(f"{mode:<14}", end="")
        busy = 0.0
        for gbps in (1, 10, 100):
            link = NetworkModel(gbps, rtt_s=0.010 if gbps == 1 else 0.001)
            res = SkimEngine(store, input_link=link).run(QUERY, mode)
            print(f"{res.breakdown.total():>11.2f}s", end="")
            busy = res.busy_fraction
        print(f"{100*busy:>7.0f}%")

    res = SkimEngine(store).run(QUERY, "near_data")
    print(f"\nnear-data breakdown: "
          + ", ".join(f"{k}={v:.3f}s" for k, v in res.breakdown.as_dict().items()))


if __name__ == "__main__":
    main()
