"""Derived-kinematics queries: the physics cuts JSON could not say before.

1. build a synthetic NanoAOD-like store,
2. run a Z→ee skim — an invariant-mass window, ΔR(e, jet) isolation,
   and an arithmetic run-range expression — through the fused executor,
3. show the zone maps pruning basket windows for the derived cut
   (interval arithmetic over the expression tree, DESIGN.md §10),
4. demonstrate era-robust trigger ORs: an HLT branch this store never
   carried counts as False instead of killing the skim.

Run: PYTHONPATH=src python examples/skim_expr.py
"""

from repro.core import SkimEngine
from repro.core.engine import LOCAL_DISK, WAN_1G
from repro.data.synth import make_nanoaod_like

N_EVENTS = 20_000

ZEE_QUERY = {
    "input": "events.skim",
    "output": "zee.skim",
    "branches": ["Electron_*", "Jet_pt", "MET_*", "run", "event",
                 "luminosityBlock"],
    "selection": {
        "event": [
            # dilepton invariant-mass window from the two leading electrons
            {"type": "mass", "collections": ["Electron", "Electron"],
             "window": [80.0, 100.0]},
            # leading electron isolated from the leading jet
            {"type": "deltaR", "collections": ["Electron", "Jet"],
             "op": ">", "value": 0.4},
            # arithmetic run-range cut: first ~10% of luminosity blocks
            {"type": "expr", "expr": "2*luminosityBlock + 0.01*MET_pt",
             "op": "<", "value": 2.0 * (N_EVENTS // 1000) / 10},
        ],
    },
}


def main() -> None:
    print("== 1. synthesize a NanoAOD-like store ==")
    store = make_nanoaod_like(N_EVENTS, n_hlt=16, n_filler=8)
    print(f"   {store.n_events} events x {len(store.branch_names())} branches, "
          f"{store.compressed_bytes() / 1e6:.1f} MB compressed")

    print("== 2. Z->ee skim through the fused executor ==")
    engine = SkimEngine(store, input_link=WAN_1G, near_input_link=LOCAL_DISK)
    res = engine.run(ZEE_QUERY, mode="near_data")
    print(f"   {res.plan.describe()}")
    print(f"   passed {res.n_passed}/{res.n_input} events "
          f"({100 * res.selectivity:.3f}%)")

    print("== 3. expression pushdown: windows proved empty before any fetch ==")
    ref = engine.run(ZEE_QUERY, mode="near_data", prune=False)
    assert ref.n_passed == res.n_passed  # bit-identical to the reference
    pruned = [w for w in res.extras["pruned_windows"] if w[2] == "prune"]
    print(f"   {len(pruned)} basket windows pruned by interval analysis "
          f"(mass/deltaR degrade to scan; the linear expr cut carries them)")
    print(f"   bytes fetched {res.stats.bytes_fetched:,} vs "
          f"{ref.stats.bytes_fetched:,} unpruned; "
          f"{res.stats.bytes_skipped:,} proved away")

    print("== 4. era-robust trigger OR ==")
    mixed = {
        "branches": ["MET_*", "HLT_*"],
        "selection": {"event": [
            {"type": "any",
             "branches": ["HLT_Mu50_FromAnOlderEra", "HLT_IsoMu24"]},
        ]},
    }
    r = engine.run(mixed, mode="near_data")
    print(f"   OR over (absent, present) triggers: {r.n_passed} events "
          f"(absent branch counted as False; strict=True would raise)")


if __name__ == "__main__":
    main()
