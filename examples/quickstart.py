"""Quickstart: the SkimROOT workflow in five minutes.

1. build a synthetic NanoAOD-like columnar store,
2. write a JSON selection query (paper Fig. 2c),
3. inspect the physical plan (zone-map window decisions + the cascaded
   phase-1 stage order, DESIGN.md §9/§11),
4. run the near-data two-phase skim,
5. compare against the legacy client-side baseline (paper Fig. 4b).

Run: PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import SkimEngine, WAN_1G
from repro.data.synth import make_nanoaod_like

QUERY = {
    "input": "events.skim",
    "output": "skimmed.skim",
    "branches": ["Electron_*", "Muon_*", "Jet_*", "MET_*", "HLT_*"],
    "selection": {
        "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                ],
                "min_count": 1,
            }
        ],
        "event": [
            {"type": "any", "branches": ["HLT_IsoMu24", "HLT_Ele32_WPTight_Gsf"]},
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 15.0},
        ],
    },
}


def main() -> None:
    print("== 1. synthesize a NanoAOD-like store ==")
    store = make_nanoaod_like(20_000, n_hlt=16, n_filler=8)
    print(f"   {store.n_events} events x {len(store.branch_names())} branches, "
          f"{store.compressed_bytes()/1e6:.1f} MB compressed")

    print("== 2./3. near-data two-phase skim (cascaded phase 1) ==")
    engine = SkimEngine(store, input_link=WAN_1G)
    res = engine.run(QUERY, mode="near_data")
    print(f"   {res.plan.describe()}")
    print(f"   passed {res.n_passed}/{res.n_input} events "
          f"({100*res.selectivity:.2f}%)")
    print(f"   moved {res.stats.bytes_fetched/1e6:.2f} MB in "
          f"{res.stats.requests} requests"
          + (f"; cascade skipped {res.stats.cascade_bytes_skipped/1e6:.2f} MB "
             "of phase-1 fetch"
             if res.stats.cascade_bytes_skipped else ""))

    print("== 4. operation breakdown (Fig. 4b analogue) ==")
    for op, secs in res.breakdown.as_dict().items():
        print(f"   {op:16s} {secs:8.4f}s")

    print("== 5. legacy client-side baseline ==")
    legacy = engine.run(QUERY, mode="client_plain")
    print(f"   client_plain moved {legacy.stats.bytes_fetched/1e6:.2f} MB "
          f"({legacy.stats.bytes_fetched/max(res.stats.bytes_fetched, 1):.1f}x "
          "more than near-data)")
    print(f"   speedup vs legacy client-side: "
          f"{legacy.breakdown.total()/res.breakdown.total():.1f}x")
    print("done.")


if __name__ == "__main__":
    main()
