"""Quickstart: the SkimROOT workflow in five minutes.

1. build a synthetic NanoAOD-like columnar store,
2. write a JSON selection query (paper Fig. 2c),
3. run the near-data two-phase skim,
4. inspect the operation breakdown (paper Fig. 4b),
5. feed the survivors into a (tiny) training run.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import SkimEngine, WAN_1G
from repro.data.pipeline import SkimTokenPipeline
from repro.data.synth import make_nanoaod_like
from repro.models.model import init_params
from repro.train.loop import TrainConfig, train_loop
from repro.train.optim import AdamWConfig

QUERY = {
    "input": "events.skim",
    "output": "skimmed.skim",
    "branches": ["Electron_*", "Muon_*", "Jet_*", "MET_*", "HLT_*"],
    "selection": {
        "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                ],
                "min_count": 1,
            }
        ],
        "event": [
            {"type": "any", "branches": ["HLT_IsoMu24", "HLT_Ele32_WPTight_Gsf"]},
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 15.0},
        ],
    },
}


def main() -> None:
    print("== 1. synthesize a NanoAOD-like store ==")
    store = make_nanoaod_like(20_000, n_hlt=16, n_filler=8)
    print(f"   {store.n_events} events x {len(store.branch_names())} branches, "
          f"{store.compressed_bytes()/1e6:.1f} MB compressed")

    print("== 2./3. near-data two-phase skim ==")
    engine = SkimEngine(store, input_link=WAN_1G)
    res = engine.run(QUERY, mode="near_data")
    print(f"   {res.plan.describe()}")
    print(f"   passed {res.n_passed}/{res.n_input} events "
          f"({100*res.selectivity:.2f}%)")

    print("== 4. operation breakdown (Fig. 4b analogue) ==")
    for op, secs in res.breakdown.as_dict().items():
        print(f"   {op:16s} {secs:8.4f}s")
    legacy = engine.run(QUERY, mode="client_plain")
    print(f"   speedup vs legacy client-side: "
          f"{legacy.breakdown.total()/res.breakdown.total():.1f}x")

    print("== 5. train a tiny LM on the skimmed physics tokens ==")
    cfg = get_config("gemma3-1b", smoke=True)
    pipe = SkimTokenPipeline(store, QUERY, cfg.vocab, seq_len=32, global_batch=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(optim=AdamWConfig(lr=3e-3, warmup_steps=2), log_every=5)
    train_loop(
        cfg, params,
        lambda s: {k: jnp.asarray(v) for k, v in pipe.batch(s % 4).items()},
        tcfg, n_steps=20,
    )
    print("done.")


if __name__ == "__main__":
    main()
