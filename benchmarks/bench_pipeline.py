"""Pipelined near-data executor: serial vs pipelined vs fused-pipelined.

Compares three ``near_data`` executor configurations over the shared
benchmark store (DESIGN.md §4):

  * ``serial``          — reference two-pass host evaluator, no overlap,
  * ``pipelined``       — double-buffered window prefetch (fetch+decode of
    window i+1 behind filtering of window i), host evaluator,
  * ``fused_pipelined`` — prefetch + the fused one-pass predicate/compact
    executor (the PR-4 preload fast path; the cascaded phase-1 executor
    layered on top of it is benchmarked in bench_cascade.py).

The near-storage input is modeled at the SSD tier (``LOCAL_DISK``) rather
than the optimistic PCIe default: that is the fetch the DPU-side
prefetcher exists to hide, and it is comparable to decode+filter compute,
so the pipeline bound ``max(fetch, compute)`` vs the serial sum
``fetch + compute`` is visible.  Per configuration we report:

  * modeled end-to-end seconds (measured compute stages + modeled links;
    the suite's common currency — the pipeline bound for overlapped runs),
  * measured wall seconds of the window loop (``phase_wall_s``) — on this
    container real thread overlap is limited by the small core count, so
    wall rows are informational.

Throughput rows are events/s on the modeled base.
"""

from __future__ import annotations

import sys

from benchmarks import common
from benchmarks.common import QUERY, csv_row, get_store
from repro.core.engine import LOCAL_DISK, SkimEngine, WAN_1G

# cascade=False pins the PR-4 preload executor: this figure isolates the
# prefetch-overlap + fused-kernel story at the seek-y SSD tier, where the
# cascade's extra per-stage fetch rounds are a separate trade-off —
# measured on its own workload in bench_cascade.py
CONFIGS = [
    ("serial", dict(fused=False, pipeline=False, cascade=False)),
    ("pipelined", dict(fused=False, pipeline=True, cascade=False)),
    ("fused_pipelined", dict(fused=True, pipeline=True, cascade=False)),
]

REPEATS = 3


def _modeled_total(res) -> float:
    """Pipeline-bound modeled seconds: overlapped runs pay the exact
    double-buffered schedule makespan, serial runs the plain stage sum."""
    if res.extras.get("pipelined"):
        return res.extras["pipeline_total"]
    return res.breakdown.total()


def run(smoke: bool = False) -> dict:
    if smoke:
        common.N_EVENTS = min(common.N_EVENTS, 20_000)
    # best-of-N even in smoke: the configs differ by a few ms of measured
    # compute and this container's shared cores are throttle-y — a single
    # run per config is too noisy for the ordering assertion
    repeats = REPEATS
    store = get_store("bitpack")
    engine = SkimEngine(store, input_link=WAN_1G, near_input_link=LOCAL_DISK)
    # warm the caches (jit for the device backends, page cache for numpy)
    engine.run(QUERY, "near_data", fused=True, pipeline=False)

    out: dict = {}
    for name, kw in CONFIGS:
        best = None
        for _ in range(repeats):
            res = engine.run(QUERY, "near_data", **kw)
            modeled = _modeled_total(res)
            if best is None or modeled < best["modeled_s"]:
                best = {
                    "modeled_s": modeled,
                    "wall_s": res.extras["phase_wall_s"],
                    "fetch_s": res.breakdown.fetch,
                    "n_passed": res.n_passed,
                }
        out[name] = best
        best["events_per_s"] = store.n_events / max(best["modeled_s"], 1e-9)
        csv_row(
            f"pipeline/{name}/modeled", best["modeled_s"] * 1e6,
            "end-to-end, SSD-tier input (modeled links)",
        )
        csv_row(f"pipeline/{name}/wall", best["wall_s"] * 1e6, "measured window loop")
        csv_row(
            f"pipeline/{name}/throughput",
            best["events_per_s"],
            f"events/s passed={best['n_passed']}",
        )

    # all three configurations must select identical survivors
    counts = {c["n_passed"] for c in out.values()}
    assert len(counts) == 1, f"survivor mismatch across executors: {out}"

    speedup = out["serial"]["modeled_s"] / max(
        out["fused_pipelined"]["modeled_s"], 1e-9
    )
    csv_row("pipeline/fused_pipelined_speedup", speedup, "x vs serial unfused")
    assert out["fused_pipelined"]["events_per_s"] >= out["serial"]["events_per_s"], (
        "pipelined fused executor slower than serial reference",
        out,
    )
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
