"""Device-resident batched cascade vs per-window dispatch (DESIGN.md §16).

Same era-correlated conditions store as bench_cascade (zone maps blind,
three of four windows die at the cheap object stage), rebuilt with a
smaller basket so even the smoke run has enough windows to batch.  Three
A/Bs, all on the **device** tier (``fused_backend="xla"`` on this CPU
container; the Pallas route on TPU):

  * **dispatch count** — the per-window executor pays one device
    dispatch per (window, stage, alive-span); the batched executor pays
    one per (batch, stage): O(windows) -> O(windows/B).  Read from the
    engine's ``device_dispatches`` ledger, asserted reduced >= 4x.
  * **realized wall** — ``pipeline="threads"`` end-to-end host
    wall-clock, best-of-N, batched asserted >= 1.5x faster (the
    acceptance contract: dispatch overhead, not predicate math,
    dominates the per-window device path).
  * **decode tier** — on-device basket decode (``decode_backend=
    "device"``, the jitted codec mirror on CPU) vs the host numpy
    codec, bit-identical by contract; a zlib store shows the
    test-visible host fallback (``decode_fallbacks``).

Survivor sets are asserted bit-identical between the two executors
(and against the staged reference pinned by bench_cascade's workload).

``--smoke`` shrinks the store for CI.
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

import numpy as np

from benchmarks import common
from benchmarks.bench_cascade import QUERY, _make_store
from benchmarks.common import csv_row
from repro.core.engine import SkimEngine, WAN_1G
from repro.data.store import EventStore

REPEATS = 5
BASKET = 1024  # smaller than bench_cascade's 4096: more windows to batch
BATCH = 16


def _get_store(n_events: int) -> EventStore:
    from repro.data.store import ZONEMAP_VERSION

    path = os.path.join(
        tempfile.gettempdir(),
        f"repro_bench_device_z{ZONEMAP_VERSION}_b{BASKET}_{n_events}.skim",
    )
    if os.path.exists(path):
        return EventStore.load(path)
    st = _make_store(n_events, basket_events=BASKET)
    st.save(path)
    return st


def _survivors(res) -> tuple:
    ev = res.output.read_flat("event")
    return (res.n_passed, int(ev.sum()), tuple(ev[:16].tolist()))


def _best(engine, repeats: int = REPEATS) -> dict:
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = engine.run(QUERY, "near_data", pipeline="threads")
        wall = time.perf_counter() - t0
        if best is None or wall < best["wall_s"]:
            best = {
                "wall_s": wall,
                "dispatches": res.extras["device_dispatches"],
                "survivors": _survivors(res),
                "bytes": res.stats.bytes_fetched,
                "windows": len(res.extras["window_rows"]),
            }
    return best


def _bench_decode(store: EventStore) -> None:
    """On-device vs host basket decode A/B over the heavy filter branch."""
    name = "Track_pt"
    blobs = list(store._blobs[name])
    arms: dict[str, tuple[float, list]] = {}
    for backend in ("host", "device"):
        probe = store
        probe.decode_backend = backend
        probe._decode_backend_resolved = None
        probe.decode_cache_baskets = 0  # measure the codec, not the LRU
        probe.decode_device_baskets = probe.decode_host_baskets = 0
        probe.decode_fallbacks = 0
        probe.decode_blobs(name, blobs[:2])  # warm (jit compile on device)
        t0 = time.perf_counter()
        out = probe.decode_blobs(name, blobs)
        arms[backend] = (time.perf_counter() - t0, out)
        stats = probe.decode_backend_stats()
        assert stats["backend"] == backend, stats
        assert stats["fallbacks"] == 0, ("bitpack decode must not fall back", stats)
    store.decode_backend = None
    store._decode_backend_resolved = None
    host_s, host_out = arms["host"]
    dev_s, dev_out = arms["device"]
    for a, b in zip(host_out, dev_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    n = len(blobs)
    csv_row("device/decode/host", host_s * 1e6, f"{n} baskets, numpy codec")
    csv_row(
        "device/decode/device", dev_s * 1e6,
        f"{n} baskets, one kernel dispatch per plane group; bit-identical",
    )

    # the fallback contract: a non-bitpack store asked for device decode
    # degrades to host, visibly
    zl = _make_store(4 * BASKET, basket_events=BASKET)
    arrs = {nm: zl.read_flat(nm) for nm in ("MET_pt", "event")}
    zstore = EventStore.from_arrays(
        arrs, basket_events=BASKET, codec="zlib", decode_backend="device"
    )
    zstore.read_flat("MET_pt")
    zstats = zstore.decode_backend_stats()
    assert zstats["fallbacks"] > 0, ("zlib fallback must be ledgered", zstats)
    csv_row(
        "device/decode/fallbacks", zstats["fallbacks"],
        "zlib store on device tier -> host codec, counted",
    )


def run(smoke: bool = False) -> dict:
    # pinned smoke size (not the possibly-clamped common.N_EVENTS): the
    # dispatch A/B needs enough windows for several batches regardless
    # of which modules ran earlier in the suite
    n_events = 40_000 if smoke else common.N_EVENTS
    store = _get_store(n_events)

    per_window = SkimEngine(
        store, input_link=WAN_1G, chunk_events=BASKET, fused_backend="xla"
    )
    batched = SkimEngine(
        store, input_link=WAN_1G, chunk_events=BASKET, fused_backend="xla",
        device_batch=BATCH,
    )
    # warm jit/page caches on both engines so walls are steady-state
    per_window.run(QUERY, "near_data", pipeline="threads")
    batched.run(QUERY, "near_data", pipeline="threads")

    ref = _best(per_window)
    bat = _best(batched)

    assert bat["survivors"] == ref["survivors"], (
        "batched cascade changed the survivor set", bat, ref,
    )
    csv_row(
        "device/per_window/wall", ref["wall_s"] * 1e6,
        f"{ref['windows']} windows, {ref['dispatches']} device dispatches",
    )
    csv_row(
        "device/batched/wall", bat["wall_s"] * 1e6,
        f"B={BATCH}, {bat['dispatches']} device dispatches",
    )
    speedup = ref["wall_s"] / max(bat["wall_s"], 1e-12)
    csv_row(
        "device/batched/speedup", speedup,
        "x realized (threads), batched vs per-window dispatch",
    )
    reduction = ref["dispatches"] / max(bat["dispatches"], 1)
    csv_row(
        "device/batched/dispatch_reduction", reduction,
        f"{ref['dispatches']} -> {bat['dispatches']} dispatches/query",
    )
    # acceptance: O(windows) -> O(windows/B) dispatches and a real wall
    # win — the per-window device path pays per-dispatch overhead the
    # batched path amortizes
    assert reduction >= 4.0, (
        "batched cascade must cut device dispatches >= 4x", ref, bat,
    )
    assert speedup >= 1.5, (
        "batched cascade must be >= 1.5x faster realized", ref, bat,
    )

    _bench_decode(store)
    return {"per_window": ref, "batched": bat}


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
