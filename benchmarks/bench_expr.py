"""Derived-expression tier: Z-window skim, fused vs staged, pruned vs
reference (DESIGN.md §10).

The physics-real query the JSON language could not express before this
tier: a dilepton invariant-mass window (Z → ee: 80 < m_ee < 100 GeV),
ΔR(e, jet) separation, and an arithmetic run-range cut over flat
branches — all compiled into the fused one-pass predicate/compact
program and analyzed by the zone maps.

Three executions of the same query:

  * ``staged``       — the two-pass reference (``fused=False``,
    ``prune=False``): stage-by-stage AST evaluation, no pushdown.
  * ``fused``        — the compiled-program one-pass executor with the
    pipelined schedule, pruning off.
  * ``fused_pruned`` — the default path: the arithmetic cut's interval
    analysis proves most basket windows empty before any fetch (the
    mass/ΔR nodes alone degrade to SCAN — AND-semantics let the linear
    cut carry the pruning).

Asserted (the acceptance contract): identical survivor counts and output
bytes everywhere; the fused+pruned run moves strictly fewer phase-1
bytes than the staged reference on this selective derived cut, and its
modeled time is no worse than the unpruned fused run.  ``--smoke``
shrinks the store for CI.
"""

from __future__ import annotations

import sys

from benchmarks import common
from benchmarks.common import csv_row
from repro.core.engine import LOCAL_DISK, SkimEngine, WAN_1G

REPEATS = 3


def _query(n_events: int) -> dict:
    # arithmetic run-range cut: keep ~10% of luminosity blocks (1000
    # events each in the synthetic store); the 0.01*MET term exercises
    # the interval arithmetic without changing which blocks survive
    lumi_cut = max((n_events // 1000) // 10, 1)
    return {
        "input": "bench.skim",
        "output": "bench_zee.skim",
        "branches": ["Electron_*", "Jet_pt", "MET_*",
                     "run", "event", "luminosityBlock"],
        "selection": {
            "event": [
                {"type": "mass", "collections": ["Electron", "Electron"],
                 "window": [80.0, 100.0]},
                {"type": "deltaR", "collections": ["Electron", "Jet"],
                 "op": ">", "value": 0.4},
                {"type": "expr",
                 "expr": "2*luminosityBlock + 0.01*MET_pt",
                 "op": "<", "value": 2.0 * lumi_cut},
            ],
        },
    }


def _modeled_total(res) -> float:
    if res.extras.get("pipelined"):
        return res.extras["pipeline_total"]
    return res.breakdown.total()


def _best(engine, query, repeats: int, **kw) -> dict:
    best = None
    for _ in range(repeats):
        res = engine.run(query, "near_data", **kw)
        modeled = _modeled_total(res)
        if best is None or modeled < best["modeled_s"]:
            best = {
                "modeled_s": modeled,
                "n_passed": res.n_passed,
                "bytes": res.stats.bytes_fetched,
                "phase1_bytes": res.extras["phase1_bytes"],
                "bytes_skipped": res.stats.bytes_skipped,
                "pruned_windows": len(res.extras.get("pruned_windows", [])),
                "output_bytes": res.extras["output_bytes"],
            }
    return best


def run(smoke: bool = False) -> dict:
    if smoke:
        common.N_EVENTS = min(common.N_EVENTS, 20_000)
    store = common.get_store("bitpack")
    # cascade=False: this is the PR-4 derived-expression figure, priced
    # against the preload executor (the cascade is bench_cascade.py's)
    engine = SkimEngine(store, input_link=WAN_1G, near_input_link=LOCAL_DISK,
                        cascade=False)
    query = _query(store.n_events)
    # warm jit/numpy/page caches so stage timings are clean
    engine.run(query, "near_data", fused=True, prune=False)

    # identical decode costs on both sides of every A/B (see bench_prune)
    saved_lru = store.decode_cache_baskets
    store.decode_cache_baskets = 0

    out = {
        "staged": _best(engine, query, REPEATS, fused=False, pipeline=False,
                        prune=False),
        "fused": _best(engine, query, REPEATS, fused=True, pipeline=True,
                       prune=False),
        "fused_pruned": _best(engine, query, REPEATS, fused=True,
                              pipeline=True, prune=True),
    }
    store.decode_cache_baskets = saved_lru

    staged, fused, pruned = out["staged"], out["fused"], out["fused_pruned"]
    for name, r in out.items():
        csv_row(
            f"expr/zwindow/{name}", r["modeled_s"] * 1e6,
            f"{r['n_passed']} survivors, "
            f"{r['phase1_bytes'] / 1e6:.2f} MB phase-1",
        )
    byte_ratio = staged["phase1_bytes"] / max(pruned["phase1_bytes"], 1)
    csv_row(
        "expr/zwindow/phase1_reduction", byte_ratio,
        f"x fewer phase-1 bytes, fused+pruned vs staged; "
        f"{pruned['pruned_windows']} windows decided from stats, "
        f"{pruned['bytes_skipped'] / 1e6:.2f} MB proved away",
    )

    # bit-identity across executors (the §10 contract)
    assert staged["n_passed"] == fused["n_passed"] == pruned["n_passed"], out
    assert (
        staged["output_bytes"] == fused["output_bytes"] == pruned["output_bytes"]
    ), out
    # the acceptance bound: the selective derived cut prunes real traffic
    assert pruned["phase1_bytes"] < staged["phase1_bytes"], out
    assert pruned["pruned_windows"] > 0 and pruned["bytes_skipped"] > 0, out
    # pruning may only remove work from the fused byte/time model
    assert pruned["bytes"] <= fused["bytes"], out
    assert pruned["modeled_s"] <= fused["modeled_s"] * 1.01, out
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
