"""Distributed skim cluster: 1→8 node scaling + result-cache warm/cold.

Scatter-gather over window-aligned shards (DESIGN.md §5): each node
runs the pipelined fused executor against its shard at the SSD input
tier, and the coordinator's modeled cluster wall-clock is
``max`` over nodes of the per-node pipeline bound plus the measured
merge.  Reported per node count:

  * modeled end-to-end seconds (the suite's common currency),
  * the slowest node's bound and the merge cost (the scaling floor),
  * events/s on the modeled base.

The cache rows run the same query twice through a content-addressed
result cache: the warm run serves every shard from cache (phase 1 and 2
skipped entirely) and pays only output transfer + merge.

Asserted: merged output equals the single-node run (count), 8-node
modeled wall-clock < single-node, warm < cold.

``--smoke`` shrinks the store for CI.
"""

from __future__ import annotations

import sys

from benchmarks import common
from benchmarks.common import QUERY, csv_row
from repro.cluster import SkimResultCache, build_cluster
from repro.core.engine import LOCAL_DISK

NODE_COUNTS = (1, 2, 4, 8)
REPEATS = 2


def _best_run(coord, repeats: int):
    best = None
    for _ in range(repeats):
        res = coord.run(QUERY)
        if best is None or res.modeled_total_s < best.modeled_total_s:
            best = res
    return best


def run(smoke: bool = False) -> dict:
    if smoke:
        common.N_EVENTS = min(common.N_EVENTS, 20_000)
    # best-of-N even in smoke: the merge stage is measured host time and
    # this container's clocks are coarse (single runs are too noisy for
    # the 8-node-vs-1 assertion at small scale)
    repeats = REPEATS
    store = common.get_store("bitpack")

    out: dict = {}
    for n in NODE_COUNTS:
        coord = build_cluster(
            store, n, replication=False, near_input_link=LOCAL_DISK
        )
        coord.run(QUERY)  # warm numpy/jit paths so stage timings are clean
        res = _best_run(coord, repeats)
        slowest = max(r.modeled_s for r in res.responses)
        out[n] = {
            "modeled_s": res.modeled_total_s,
            "slowest_node_s": slowest,
            "merge_s": res.merge_s,
            "n_passed": res.n_passed,
            "events_per_s": store.n_events / max(res.modeled_total_s, 1e-9),
        }
        csv_row(
            f"cluster/nodes{n}/modeled", res.modeled_total_s * 1e6,
            "max-over-nodes + merge, SSD-tier input",
        )
        csv_row(f"cluster/nodes{n}/slowest_node", slowest * 1e6, "pipeline bound")
        csv_row(f"cluster/nodes{n}/merge", res.merge_s * 1e6, "gather + re-basket")
        csv_row(
            f"cluster/nodes{n}/throughput", out[n]["events_per_s"],
            f"events/s passed={res.n_passed}",
        )

    # every node count must select the same survivors
    counts = {c["n_passed"] for c in out.values()}
    assert len(counts) == 1, f"survivor mismatch across node counts: {out}"
    if smoke:
        # at smoke scale the measured merge (host time on 2 shared cores,
        # grows with node count) can swamp the node win, so assert the
        # distributed quantity: the slowest node's pipeline bound
        assert out[8]["slowest_node_s"] < out[1]["slowest_node_s"], (
            "8-node slowest-node bound not below single node", out,
        )
    else:
        assert out[8]["modeled_s"] < out[1]["modeled_s"], (
            "8-node cluster not faster than single node (modeled)", out,
        )
    csv_row(
        "cluster/scaling_8x", out[1]["modeled_s"] / out[8]["modeled_s"],
        "x modeled speedup, 8 nodes vs 1",
    )

    # -- content-addressed result cache: cold vs warm -------------------------
    cache = SkimResultCache(budget_bytes=256 << 20)
    coord = build_cluster(
        store, 4, replication=False, near_input_link=LOCAL_DISK, cache=cache
    )
    cold = coord.run(QUERY)
    warm = coord.run(QUERY)
    assert warm.cache_hits == 4, f"expected 4 shard hits, got {warm.cache_hits}"
    assert warm.n_passed == cold.n_passed
    assert warm.modeled_total_s < cold.modeled_total_s, (
        "warm cache not faster than cold", cold.modeled_total_s,
        warm.modeled_total_s,
    )
    out["cache"] = {
        "cold_s": cold.modeled_total_s,
        "warm_s": warm.modeled_total_s,
        "saved_fetch_bytes": cache.stats.saved_fetch_bytes,
    }
    csv_row("cluster/cache_cold/modeled", cold.modeled_total_s * 1e6, "4 nodes")
    csv_row(
        "cluster/cache_warm/modeled", warm.modeled_total_s * 1e6,
        f"all shards cached, {cache.stats.saved_fetch_bytes/1e6:.1f} MB "
        "fetch skipped",
    )
    csv_row(
        "cluster/cache_speedup", cold.modeled_total_s / warm.modeled_total_s,
        "x cold/warm",
    )
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
