"""Paper Fig. 5b: compute-busy fraction per placement (CPU-utilization proxy).

busy_fraction = measured compute time / end-to-end latency: ~99% for the
deserialize-bound legacy client, low for the fetch-bound optimized client,
high again for the DPU-placed filter (87% in the paper).
"""

from __future__ import annotations

from benchmarks.common import QUERY, csv_row, get_store
from repro.core.engine import SkimEngine, WAN_1G


def run() -> dict:
    out = {}
    for mode in ("client_plain", "client_opt", "server_side", "near_data"):
        res = SkimEngine(get_store("bitpack"), input_link=WAN_1G).run(QUERY, mode)
        out[mode] = res.busy_fraction
        csv_row(f"utilization/{mode}", res.busy_fraction * 100, "% busy")
    return out


if __name__ == "__main__":
    run()
