"""Beyond-paper: multi-shard near-data scaling + prefetch overlap.

The paper's future work names "advanced data prefetching strategies,
improved parallelization, and scalability across multiple DPUs".  Both
are implemented here:

  * overlap: double-buffered basket prefetch -> pipeline bound
    max(fetch, compute) instead of fetch + compute,
  * multi-shard: the store partitions by event ranges across N near-data
    filter shards (the mesh data axis / N DPUs); end-to-end latency is
    the max over shards + the (tiny) survivor merge.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import QUERY, csv_row, get_store
from repro.core.engine import PCIE_128G, WAN_1G, SkimEngine
from repro.data.store import EventStore


def _slice_store(store: EventStore, start: int, stop: int) -> EventStore:
    cols, jagged = {}, {}
    for name, br in store.branches.items():
        if br.jagged:
            v, _ = store.read_jagged(name, start, stop)
            cols[name] = v
            jagged[name] = br.counts_branch
        else:
            cols[name] = store.read_flat(name, start, stop)
    return EventStore.from_arrays(
        cols, jagged=jagged, basket_events=store.basket_events, codec=store.codec
    )


def run() -> dict:
    store = get_store("bitpack")
    base = SkimEngine(store, input_link=WAN_1G).run(QUERY, "near_data")
    csv_row("scaling/1shard/total", base.breakdown.total() * 1e6, "serial")
    csv_row(
        "scaling/1shard/overlap",
        base.extras["overlap_total"] * 1e6,
        f"{base.breakdown.total()/base.extras['overlap_total']:.2f}x from prefetch overlap",
    )

    out = {"overlap_1": base.extras["overlap_total"]}
    n = store.n_events
    for shards in (2, 4, 8):
        bounds = np.linspace(0, n, shards + 1).astype(int)
        per = []
        passed = 0
        for s in range(shards):
            sub = _slice_store(store, bounds[s], bounds[s + 1])
            r = SkimEngine(sub, input_link=WAN_1G).run(QUERY, "near_data")
            per.append(r.extras["overlap_total"])
            passed += r.n_passed
        latency = max(per)  # shards run in parallel
        out[f"shards_{shards}"] = latency
        csv_row(
            f"scaling/{shards}shard/latency",
            latency * 1e6,
            f"speedup={out['overlap_1']/latency:.2f}x passed={passed}",
        )
    assert passed == base.n_passed  # sharding must not change the physics
    return out


if __name__ == "__main__":
    run()
