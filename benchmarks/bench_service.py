"""Async skim service: queue throughput + time-to-first-partial (DESIGN.md §12).

What the service layer buys over the blocking library call (*Toward
real-time data query systems in HEP*: users want first partials in
seconds, not a batch barrier):

  * **time-to-first-partial** — wall clock from submit to the first
    streamed window-granular partial, vs the blocking ``run_skim`` call
    that returns nothing until every window is done.  The stream pays
    one window; the block pays all of them.
  * **admission pricing cost** — ``price_query`` is the per-submission
    overhead every job pays before running (metadata only); it must stay
    microscopic next to a single window's execution.
  * **queue throughput** — submissions drained per second through the
    deterministic scheduler, solo vs coalesced (batching mode shares
    one phase-1 pass across all queued tenants, same contract as
    bench_cluster's shared scan).

``--smoke`` shrinks the store for CI.
"""

from __future__ import annotations

import time

from benchmarks import common
from benchmarks.common import csv_row
from repro.serve import SkimService, price_query

REPEATS = 3
N_JOBS = 6


def _best(fn, repeats: int = REPEATS) -> tuple[float, object]:
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        ret = fn()
        dt = time.perf_counter() - t0
        if dt < best:
            best, out = dt, ret
    return best, out


def run(smoke: bool = False) -> dict:
    if smoke:
        common.N_EVENTS = min(common.N_EVENTS, 20_000)
    store = common.get_store("bitpack")
    query = common.QUERY

    # warm jit/page caches so the stream-vs-block gap is executor shape,
    # not first-call compilation
    warm = SkimService(store)
    warm.result(warm.submit(query).job_id)

    # -- time-to-first-partial vs blocking call ----------------------------
    def first_partial():
        svc = SkimService(store)
        job = svc.submit(query)
        return next(svc.stream(job.job_id))

    def blocking():
        svc = SkimService(store)
        return svc.result(svc.submit(query).job_id)

    t_first, part = _best(first_partial)
    t_block, job = _best(blocking)
    n_windows = len(job.partials)
    csv_row(
        "service_first_partial_us",
        t_first * 1e6,
        f"window0 of {n_windows}: {part.n_passed} survivors",
    )
    csv_row(
        "service_blocking_total_us",
        t_block * 1e6,
        f"first partial {t_block / max(t_first, 1e-12):.1f}x earlier "
        "than the blocking return",
    )

    # -- admission pricing overhead ----------------------------------------
    t_price, est = _best(lambda: price_query(query, store), repeats=20)
    csv_row(
        "service_admission_price_us",
        t_price * 1e6,
        f"priced {est.est_bytes / 1e6:.2f} MB over {est.n_windows} "
        "windows, zero fetched",
    )

    # -- queue throughput: solo vs coalesced -------------------------------
    def drain(batching: bool):
        svc = SkimService(store, batching=batching)
        for i in range(N_JOBS):
            svc.submit(query, tenant=f"t{i}")
        quanta = svc.run_until_idle()
        return svc, quanta

    t_solo, (svc_solo, q_solo) = _best(lambda: drain(False), repeats=1)
    t_batch, (svc_batch, q_batch) = _best(lambda: drain(True), repeats=1)
    fetched_solo = sum(j.stats.bytes_fetched for j in svc_solo.jobs.values())
    fetched_batch = sum(
        j.stats.bytes_fetched for j in svc_batch.jobs.values()
    )
    csv_row(
        "service_drain_solo_us",
        t_solo * 1e6,
        f"{N_JOBS} jobs, {q_solo} quanta, "
        f"{N_JOBS / max(t_solo, 1e-12):.0f} jobs/s",
    )
    csv_row(
        "service_drain_batched_us",
        t_batch * 1e6,
        f"{N_JOBS} jobs coalesced, {q_batch} quanta, "
        f"{fetched_solo / max(fetched_batch, 1):.2f}x fewer bytes",
    )

    return {
        "first_partial_s": t_first,
        "blocking_s": t_block,
        "price_s": t_price,
        "drain_solo_s": t_solo,
        "drain_batched_s": t_batch,
    }


if __name__ == "__main__":
    import sys

    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv)
