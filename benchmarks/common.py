"""Shared benchmark fixtures: a NanoAOD-scale store + the Higgs-style query.

The evaluation store mirrors the paper's file *structurally*: jagged
physics collections, a trigger-bit block, and a long tail of output-only
branches; 27-ish filter branches and ~90 output branches.  Absolute sizes
are scaled to this container (REPRO_BENCH_EVENTS overrides).
"""

from __future__ import annotations

import os
import tempfile

from repro.data.store import EventStore
from repro.data.synth import make_nanoaod_like

N_EVENTS = int(os.environ.get("REPRO_BENCH_EVENTS", "100000"))
N_HLT = 64
N_FILLER = 120

QUERY = {
    "input": "bench.skim",
    "output": "bench_out.skim",
    "branches": [
        "Electron_*", "Muon_*", "Jet_*", "MET_*", "HLT_*",
        "PV_npvs", "run", "event", "luminosityBlock",
        *(f"Filler_{i:03d}" for i in range(60)),
    ],
    "selection": {
        "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                    {"var": "mvaId", "op": ">=", "value": 0.5},
                ],
                "min_count": 1,
            },
            {
                "collection": "Jet",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 25.0},
                    {"var": "eta", "op": "abs<", "value": 4.7},
                ],
                "min_count": 2,
            },
        ],
        "event": [
            {
                "type": "ht", "collection": "Jet", "var": "pt",
                "object_cuts": [{"var": "pt", "op": ">", "value": 30.0}],
                "op": ">", "value": 80.0,
            },
            {"type": "any", "branches": [
                "HLT_IsoMu24", "HLT_Ele32_WPTight_Gsf",
            ]},
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 10.0},
        ],
    },
}

_CACHE: dict[str, EventStore] = {}


def get_store(codec: str = "bitpack") -> EventStore:
    """Build (or load from a disk cache) the benchmark store.

    The cache filename carries the store's zone-map schema version so a
    stats upgrade re-materializes stale files instead of silently running
    the pruning benchmarks without statistics.
    """
    from repro.data.store import ZONEMAP_VERSION

    if codec in _CACHE:
        return _CACHE[codec]
    path = os.path.join(
        tempfile.gettempdir(),
        f"repro_bench_z{ZONEMAP_VERSION}_{codec}_{N_EVENTS}.skim",
    )
    if os.path.exists(path):
        st = EventStore.load(path)
    else:
        st = make_nanoaod_like(
            N_EVENTS, n_hlt=N_HLT, n_filler=N_FILLER, codec=codec, seed=12
        )
        st.save(path)
    _CACHE[codec] = st
    return st


# every csv_row lands here too, so harness drivers (benchmarks/run.py
# --json) can dump a machine-readable BENCH_<pr>.json of the same rows
BENCH_ROWS: list[dict] = []


def csv_row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    BENCH_ROWS.append(
        {"name": name, "value": float(us_per_call), "derived": derived}
    )
