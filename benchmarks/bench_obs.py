"""Observability layer: no-op tracer overhead + traced service export (DESIGN.md §13).

The tracing layer's acceptance contract has two halves:

  * **The hot path must not regress.**  Every engine call site now goes
    through a tracer — but the default is the shared ``NULL_TRACER``,
    whose methods are empty calls.  We measure the null begin/end unit
    cost, count the spans an *enabled* run of the cascade workload
    actually records (= the number of null calls an untraced run makes),
    and assert the implied worst-case overhead stays ≤5% of the untraced
    wall.  The synthetic bound is used because it is noise-free on a
    loaded CI host; the measured traced-vs-untraced delta is also
    reported for reference.
  * **Traces export and replay.**  A 6-job multi-tenant service drain
    under a :class:`~repro.serve.jobs.ManualClock` must export a valid
    Chrome-trace JSON document — and two identical drains must export
    byte-identical JSON (the determinism contract).  The document is
    written next to the harness's ``BENCH_<pr>.json`` so CI uploads it
    as an inspectable artifact.

``--smoke`` shrinks the store for CI.
"""

from __future__ import annotations

import json
import time

from benchmarks import common
from benchmarks.bench_cascade import QUERY, _get_store
from benchmarks.common import csv_row
from repro.core.engine import SkimEngine, WAN_1G
from repro.obs.trace import NULL_TRACER, Tracer, trace_json
from repro.serve import ManualClock, SkimService
from repro.serve.service import EngineBackend

REPEATS = 3
N_JOBS = 6
#: acceptance bound: worst-case null-tracer overhead vs untraced wall
MAX_OVERHEAD = 0.05


def _best(fn, repeats: int = REPEATS) -> tuple[float, object]:
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        ret = fn()
        dt = time.perf_counter() - t0
        if dt < best:
            best, out = dt, ret
    return best, out


def _null_call_cost(n: int = 200_000) -> float:
    """Unit cost of one NULL_TRACER begin+end pair, best of 3."""
    tr = NULL_TRACER

    def loop():
        for _ in range(n):
            tr.end(tr.begin("x", kind="window"))

    best, _ = _best(loop)
    return best / n


def run(smoke: bool = False) -> dict:
    n_events = min(common.N_EVENTS, 20_000) if smoke else common.N_EVENTS
    store = _get_store(n_events)

    def engine():
        return SkimEngine(
            store, input_link=WAN_1G, output_link=WAN_1G,
            chunk_events=4096, fused=True, pipeline=False, cascade=True,
        )

    # warm compilation/page caches off the books
    engine().run(QUERY, mode="near_data")

    # -- untraced wall (the production default: NULL_TRACER) ---------------
    t_off, res_off = _best(lambda: engine().run(QUERY, mode="near_data"))

    # -- enabled tracer: span count + measured delta ------------------------
    def traced():
        tr = Tracer()
        res = engine().run(QUERY, mode="near_data", tracer=tr)
        return tr, res

    t_on, (tr, res_on) = _best(traced)
    n_spans = len(tr.spans())
    assert res_on.n_passed == res_off.n_passed

    # worst-case null overhead: every recorded span is one begin+end
    # pair an untraced run still pays as two empty calls
    unit = _null_call_cost()
    bound = (n_spans * unit) / max(t_off, 1e-12)
    assert bound <= MAX_OVERHEAD, (
        f"no-op tracer overhead bound {bound:.2%} exceeds "
        f"{MAX_OVERHEAD:.0%}: {n_spans} spans x {unit * 1e9:.1f} ns "
        f"against {t_off * 1e3:.1f} ms untraced"
    )
    csv_row(
        "obs_null_call_us",
        unit * 1e6,
        f"{n_spans} spans/run -> {bound:.3%} worst-case overhead "
        f"(bound {MAX_OVERHEAD:.0%})",
    )
    csv_row(
        "obs_traced_run_us",
        t_on * 1e6,
        f"enabled tracer {t_on / max(t_off, 1e-12):.3f}x untraced "
        f"({t_off * 1e3:.2f} ms), {n_spans} spans",
    )

    # -- 6-job service drain: valid + deterministic Chrome export ----------
    def drain():
        svc = SkimService(
            EngineBackend(store),
            clock=ManualClock(),
            tracing=True,
            calibrate=True,
        )
        for i in range(N_JOBS):
            svc.submit(QUERY, tenant=f"t{i % 3}")
        svc.run_until_idle()
        return svc

    t_drain, svc = _best(lambda: drain(), repeats=1)
    doc = svc.export_trace()
    payload = trace_json(doc)
    parsed = json.loads(payload)  # must round-trip as JSON
    events = parsed["traceEvents"]
    pids = {e["pid"] for e in events}
    assert len(pids) == N_JOBS, f"expected one pid per job, got {pids}"
    assert all("ph" in e and "pid" in e for e in events)
    # byte-determinism: an identical drain exports identical bytes
    assert trace_json(drain().export_trace()) == payload

    trace_path = f"BENCH_{_pr_number()}_trace.json"
    with open(trace_path, "w") as fh:
        fh.write(payload)
    csv_row(
        "obs_service_drain_us",
        t_drain * 1e6,
        f"{N_JOBS} traced jobs, {len(events)} events -> {trace_path} "
        "(deterministic)",
    )

    ratios = {
        kind: round(cell["ratio"], 3)
        for kind, cell in svc.calibration_summary().items()
        if cell["ratio"] is not None
    }
    csv_row(
        "obs_calibration_kinds",
        0.0,
        f"observed/priced ratios {ratios}",
    )

    return {
        "null_call_s": unit,
        "spans_per_run": n_spans,
        "overhead_bound": bound,
        "untraced_s": t_off,
        "traced_s": t_on,
        "trace_events": len(events),
        "trace_path": trace_path,
    }


def _pr_number() -> int:
    from benchmarks.run import PR_NUMBER

    return PR_NUMBER


if __name__ == "__main__":
    import sys

    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
