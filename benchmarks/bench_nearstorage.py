"""Paper Fig. 5a: server-side filtering vs near-data (SkimROOT).

Server-side reads locally but per-basket (no TTreeCache); near-data keeps
coalesced prefetching over the PCIe-class link and the vectorized decode.
"""

from __future__ import annotations

from benchmarks.common import QUERY, csv_row, get_store
from repro.core.engine import SkimEngine, WAN_1G


def run() -> dict:
    out = {}
    for mode in ("server_side", "near_data"):
        res = SkimEngine(get_store("bitpack"), input_link=WAN_1G).run(QUERY, mode)
        out[mode] = res.breakdown.as_dict()
        out[mode]["requests"] = res.stats.requests
        for op, secs in res.breakdown.as_dict().items():
            if op != "total":
                csv_row(f"nearstorage/{mode}/{op}", secs * 1e6, "")
        csv_row(f"nearstorage/{mode}/requests", res.stats.requests, "basket reads")
    csv_row(
        "nearstorage/speedup",
        out["server_side"]["total"] / max(out["near_data"]["total"], 1e-9),
        "x (3.18x in paper)",
    )
    return out


if __name__ == "__main__":
    run()
