"""Cascaded phase-1 execution vs the fused+pruned preload path (DESIGN.md §11).

The workload is the cascade's home turf: a selective multi-branch skim
over a store with **era-correlated detector conditions** that zone maps
cannot see.  In three of every four basket windows the electron ID is
mis-calibrated — every object passing ``pt > 20`` fails ``mvaId >= 0.5``
and vice versa — so the *joint* object selection kills those windows
outright, while every per-branch basket statistic stays undecidable
(``pt`` spans the cut, ``mvaId`` has both values): the PR-4 zone-map
pushdown prunes nothing and its preloading executor still fetches the
full filter set — including a deliberately heavy ``Track`` collection
feeding an HT cut — for every window.

The cascaded executor runs the cheap selective stages first and fetches
the heavy HT branches **only for baskets still alive**, so the bad-era
windows never move a Track byte.  Asserted (the acceptance contract):

  * bit-identical survivors, cascade on vs off vs the staged reference,
  * strictly fewer phase-1 bytes than the fused+pruned preload path,
  * exact savings ledger: ``fetched + cascade_bytes_skipped`` equals the
    preload reference's fetched bytes,
  * modeled end-to-end no slower (best-of-N; fetch + decode dominate).

``--smoke`` shrinks the store for CI.
"""

from __future__ import annotations

import os
import sys
import tempfile

import numpy as np

from benchmarks import common
from benchmarks.common import csv_row
from repro.core.engine import SkimEngine, WAN_1G
from repro.data.store import EventStore

REPEATS = 5
BASKET = 4096

QUERY = {
    "input": "bench.skim",
    "output": "bench_cascade_out.skim",
    "branches": ["Electron_*", "MET_*", "event", "luminosityBlock"],
    "selection": {
        "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                    {"var": "mvaId", "op": ">=", "value": 0.5},
                ],
                "min_count": 1,
            }
        ],
        "event": [
            {
                # the heavy stage: ~25 tracks/event feed the HT sum — the
                # cost model prices it last, the cascade fetches it only
                # for windows the cheap stages left alive
                "type": "ht", "collection": "Track", "var": "pt",
                "object_cuts": [{"var": "pt", "op": ">", "value": 1.0}],
                "op": ">", "value": 20.0,
            },
            {"type": "any", "branches": [
                "HLT_IsoMu24", "HLT_Ele32_WPTight_Gsf",
            ]},
            {"type": "cut", "branch": "MET_pt", "op": ">", "value": 10.0},
        ],
    },
}


def _make_store(
    n_events: int, seed: int = 7, basket_events: int = BASKET
) -> EventStore:
    """Conditions-era store: window w is a *good era* iff w % 4 == 0.

    Bad-era electrons have ``mvaId == (pt <= 20)`` — no object jointly
    passes the ID+pt selection there, but every per-branch basket stat
    stays undecidable (pt spans the threshold, mvaId holds both values).
    """
    rng = np.random.default_rng(seed)
    era_good = (np.arange(n_events) // basket_events) % 4 == 0

    cols: dict[str, np.ndarray] = {}
    jagged: dict[str, str] = {}

    n_el = rng.poisson(1.2, n_events).astype(np.int32)
    tot = int(n_el.sum())
    el_pt = (rng.exponential(25.0, tot) + 3.0).astype(np.float32)
    el_eta = rng.uniform(-2.5, 2.5, tot).astype(np.float32)
    obj_good = np.repeat(era_good, n_el)
    el_mva = np.where(obj_good, rng.random(tot) > 0.3, el_pt <= 20.0)
    cols["nElectron"] = n_el
    for name, arr in [("Electron_pt", el_pt), ("Electron_eta", el_eta),
                      ("Electron_mvaId", el_mva)]:
        cols[name] = arr
        jagged[name] = "nElectron"

    # the heavy filter-only collection (HT input): ~25 objects/event
    n_trk = rng.poisson(25.0, n_events).astype(np.int32)
    cols["nTrack"] = n_trk
    cols["Track_pt"] = (
        rng.exponential(5.0, int(n_trk.sum())) + 0.5
    ).astype(np.float32)
    jagged["Track_pt"] = "nTrack"

    cols["MET_pt"] = (rng.exponential(30.0, n_events) + 1.0).astype(np.float32)
    cols["MET_phi"] = rng.uniform(-np.pi, np.pi, n_events).astype(np.float32)
    cols["HLT_IsoMu24"] = rng.random(n_events) < 0.3
    cols["HLT_Ele32_WPTight_Gsf"] = rng.random(n_events) < 0.2
    cols["event"] = np.arange(n_events, dtype=np.int32)
    cols["luminosityBlock"] = (np.arange(n_events) // 1000).astype(np.int32)

    return EventStore.from_arrays(
        cols, jagged=jagged, basket_events=basket_events, codec="bitpack"
    )


def _get_store(n_events: int) -> EventStore:
    from repro.data.store import ZONEMAP_VERSION

    path = os.path.join(
        tempfile.gettempdir(),
        f"repro_bench_cascade_z{ZONEMAP_VERSION}_{n_events}.skim",
    )
    if os.path.exists(path):
        return EventStore.load(path)
    st = _make_store(n_events)
    st.save(path)
    return st


def _modeled_total(res) -> float:
    if res.extras.get("pipelined"):
        return res.extras["pipeline_total"]
    return res.breakdown.total()


def _best(engine, cascade: bool, repeats: int) -> dict:
    best = None
    for _ in range(repeats):
        res = engine.run(QUERY, "near_data", cascade=cascade)
        modeled = _modeled_total(res)
        if best is None or modeled < best["modeled_s"]:
            best = {
                "modeled_s": modeled,
                "n_passed": res.n_passed,
                "bytes": res.stats.bytes_fetched,
                "phase1_bytes": res.extras["phase1_bytes"],
                "requests": res.stats.requests,
                "cascade_skipped": res.stats.cascade_bytes_skipped,
                "output_bytes": res.extras["output_bytes"],
                "events": [
                    tuple(res.output.read_flat("event")[:16].tolist()),
                    int(res.output.read_flat("event").sum()),
                ],
                "order": res.extras.get("cascade_order"),
                "stages": res.extras.get("cascade_stages"),
            }
    return best


def run(smoke: bool = False) -> dict:
    n_events = min(common.N_EVENTS, 20_000) if smoke else common.N_EVENTS
    store = _get_store(n_events)
    # the near-storage input is the DPU's PCIe tier (the near_data
    # default): the cascade trades a few extra fetch rounds for strictly
    # fewer bytes AND strictly less predicate/decode compute, so the
    # modeled win comes from the measured stages it never runs
    engine = SkimEngine(store, input_link=WAN_1G)
    # warm jit/numpy/page caches so stage timings are clean
    engine.run(QUERY, "near_data", cascade=False)

    # staged (fused=False) reference pins the survivor set
    staged = engine.run(QUERY, "near_data", fused=False, pipeline=False,
                        prune=False, cascade=False)

    ref = _best(engine, cascade=False, repeats=REPEATS)
    cas = _best(engine, cascade=True, repeats=REPEATS)

    assert cas["n_passed"] == ref["n_passed"] == staged.n_passed, (
        "cascade changed the survivor set", cas["n_passed"], ref["n_passed"],
        staged.n_passed,
    )
    assert cas["events"] == ref["events"], "survivor rows diverged"
    assert cas["output_bytes"] == ref["output_bytes"]
    assert 0 < cas["n_passed"] < n_events // 2, "workload lost its selectivity"

    csv_row(
        "cascade/selective/modeled", cas["modeled_s"] * 1e6,
        f"cascade=True, order {cas['order']}",
    )
    csv_row(
        "cascade/selective/modeled_ref", ref["modeled_s"] * 1e6,
        "cascade=False (PR-4 fused+pruned preload)",
    )
    csv_row(
        "cascade/selective/phase1_mb", cas["phase1_bytes"] / 1e6,
        f"vs {ref['phase1_bytes']/1e6:.2f} MB preloaded; "
        f"{cas['cascade_skipped']/1e6:.2f} MB never fetched",
    )
    ratio = ref["phase1_bytes"] / max(cas["phase1_bytes"], 1)
    csv_row(
        "cascade/selective/byte_reduction", ratio,
        "x fewer phase-1 fetched bytes",
    )
    csv_row(
        "cascade/selective/speedup",
        ref["modeled_s"] / max(cas["modeled_s"], 1e-12),
        "x modeled, cascaded vs preload",
    )

    # the acceptance contract: strictly fewer phase-1 bytes than the
    # PR-4 best path, with an exact savings ledger
    assert cas["phase1_bytes"] < ref["phase1_bytes"], (
        "cascade must move strictly fewer phase-1 bytes", cas, ref,
    )
    assert cas["bytes"] + cas["cascade_skipped"] == ref["bytes"], (
        "cascade ledger must account every byte of the preload reference",
        cas, ref,
    )
    # time bound with headroom for this container's coarse shared-core
    # clocks: the byte and ledger contracts above are the deterministic
    # acceptance; the modeled win (alive-only predicate eval + decode)
    # shows in the reported speedup
    assert cas["modeled_s"] <= 1.2 * ref["modeled_s"], (
        "cascaded run modeled much slower than the preload path", cas, ref,
    )
    return {"cascade": cas, "reference": ref}


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
