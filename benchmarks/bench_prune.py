"""Zone-map predicate pushdown: pruned vs reference executor (DESIGN.md §9).

Three queries over the shared benchmark store, each run through the
default ``near_data`` executor with ``prune=True`` and with the
``prune=False`` reference:

  * ``selective``     — a run-range style skim (``luminosityBlock`` cut,
    ~5% selectivity) on a monotonically-recorded branch: most basket
    windows are provably empty from their stats, so phase 1 *and*
    phase 2 never touch them.  The paper's "fastest byte is the one
    never moved", now applied before any byte moves.
  * ``accept_all``    — a 100%-selectivity skim (``MET_pt`` floor below
    the generator's minimum): every window is provably all-surviving, so
    predicate fetch+eval is skipped and the output set moves in one
    phase-2 round per window.
  * ``undecidable``   — a median ``MET_pt`` cut whose per-basket stats
    prove nothing: the pruned run must degrade to the reference scan
    with no accounting drift (the ≤1% overhead guard).

Reported per query: modeled end-to-end seconds (pipeline bound), phase-1
fetched bytes, and skipped bytes/requests.  Asserted (the acceptance
contract): identical survivor counts everywhere; on ``selective`` the
pruned run moves ≥2x fewer bytes AND is modeled-faster; on the
100%-selectivity and undecidable queries pruned modeled time is within
1% of the reference.

The near-storage input is modeled at the SSD tier (LOCAL_DISK), the
fetch pruning actually avoids.  ``--smoke`` shrinks the store for CI.
"""

from __future__ import annotations

import sys

from benchmarks import common
from benchmarks.common import csv_row
from repro.core.engine import LOCAL_DISK, SkimEngine, WAN_1G

REPEATS = 5


def _queries(n_events: int) -> dict[str, dict]:
    # ~5% of luminosity blocks (1000 events each in the synthetic store)
    lumi_cut = max((n_events // 1000) // 20 - 1, 0)
    base_branches = ["Electron_*", "MET_*", "HLT_*",
                     "run", "event", "luminosityBlock"]
    return {
        "selective": {
            "branches": base_branches,
            "selection": {
                "preselection": [
                    {"branch": "luminosityBlock", "op": "<=", "value": lumi_cut}
                ],
                "event": [
                    {"type": "cut", "branch": "MET_pt", "op": ">", "value": 25.0}
                ],
            },
        },
        "accept_all": {
            "branches": base_branches,
            "selection": {
                "preselection": [
                    # synthetic MET_pt is exponential(30) + 1.0 >= 1.0
                    {"branch": "MET_pt", "op": ">", "value": 0.5}
                ],
            },
        },
        "undecidable": {
            "branches": base_branches,
            "selection": {
                "preselection": [
                    # near the MET median: stats can prove nothing
                    {"branch": "MET_pt", "op": ">", "value": 21.0}
                ],
            },
        },
    }


def _modeled_total(res) -> float:
    if res.extras.get("pipelined"):
        return res.extras["pipeline_total"]
    return res.breakdown.total()


def _best(engine, query, prune: bool, repeats: int) -> dict:
    best = None
    for _ in range(repeats):
        res = engine.run(query, "near_data", prune=prune)
        modeled = _modeled_total(res)
        if best is None or modeled < best["modeled_s"]:
            best = {
                "modeled_s": modeled,
                "n_passed": res.n_passed,
                "bytes": res.stats.bytes_fetched,
                "phase1_bytes": res.extras["phase1_bytes"],
                "requests": res.stats.requests,
                "bytes_skipped": res.stats.bytes_skipped,
                "requests_skipped": res.stats.requests_skipped,
                "pruned_windows": len(res.extras.get("pruned_windows", [])),
                "output_bytes": res.extras["output_bytes"],
            }
    return best


def run(smoke: bool = False) -> dict:
    if smoke:
        common.N_EVENTS = min(common.N_EVENTS, 20_000)
    # best-of-N even in smoke: modeled time includes measured compute and
    # this container's clocks are coarse — the pruned/reference gap on
    # the accept-all query (~5 ms: five fewer round trips + no predicate
    # eval) only dominates at the per-side floor, so take real minima
    repeats = REPEATS
    store = common.get_store("bitpack")
    # cascade=False pins the preload executor the pruning ledger is
    # priced against (DESIGN.md §9): the cascaded executor catches many
    # of the same dead windows dynamically (its own figure of merit —
    # bench_cascade.py), which would understate the pure zone-map win
    engine = SkimEngine(
        store, input_link=WAN_1G, near_input_link=LOCAL_DISK, cascade=False
    )
    queries = _queries(store.n_events)
    # warm jit/numpy/page caches so stage timings are clean
    engine.run(queries["selective"], "near_data", prune=False)

    # disable the decoded-basket LRU for the A/B: pruned and reference
    # runs must pay identical decode costs or the comparison measures
    # cache warmth, not pushdown
    saved_lru = store.decode_cache_baskets
    store.decode_cache_baskets = 0

    out: dict = {}
    for name, query in queries.items():
        ref = _best(engine, query, prune=False, repeats=repeats)
        res = _best(engine, query, prune=True, repeats=repeats)
        assert res["n_passed"] == ref["n_passed"], (
            f"{name}: pruned selection diverged", res, ref,
        )
        assert res["output_bytes"] == ref["output_bytes"], (
            f"{name}: pruned output bytes diverged", res, ref,
        )
        out[name] = {"pruned": res, "reference": ref}
        csv_row(
            f"prune/{name}/modeled", res["modeled_s"] * 1e6,
            f"prune=True, {res['pruned_windows']} windows decided from stats",
        )
        csv_row(
            f"prune/{name}/modeled_ref", ref["modeled_s"] * 1e6,
            "prune=False reference",
        )
        csv_row(
            f"prune/{name}/fetched_mb", res["bytes"] / 1e6,
            f"vs {ref['bytes']/1e6:.2f} MB unpruned; "
            f"{res['bytes_skipped']/1e6:.2f} MB + "
            f"{res['requests_skipped']} requests proved away",
        )
    store.decode_cache_baskets = saved_lru

    sel, ref = out["selective"]["pruned"], out["selective"]["reference"]
    byte_ratio = ref["phase1_bytes"] / max(sel["phase1_bytes"], 1)
    csv_row(
        "prune/selective/byte_reduction", byte_ratio,
        "x fewer phase-1 fetched bytes",
    )
    csv_row(
        "prune/selective/speedup",
        ref["modeled_s"] / max(sel["modeled_s"], 1e-12),
        "x modeled, pruned vs reference",
    )
    assert byte_ratio >= 2.0, (
        "selective query should fetch >=2x fewer bytes with pruning", out,
    )
    assert sel["modeled_s"] <= ref["modeled_s"], (
        "pruned selective run modeled slower than reference", out,
    )
    # 100%-selectivity query: <=1% modeled overhead (the acceptance bound;
    # in practice accept-all is faster — one round, no predicate eval).
    # The deterministic half first: same bytes, strictly fewer requests.
    r = out["accept_all"]
    assert r["pruned"]["bytes"] == r["reference"]["bytes"]
    assert r["pruned"]["requests"] < r["reference"]["requests"]
    assert r["pruned"]["modeled_s"] <= 1.01 * r["reference"]["modeled_s"], (
        "accept_all: pruning overhead above 1%", out,
    )
    # undecidable query: nothing was provable, so the pruned run executes
    # the IDENTICAL code path (decisions collapse to the reference) —
    # "no regression" here is the deterministic model, asserted exactly;
    # comparing two wall-clock measurements of the same code on shared
    # cores would only measure host throttle noise
    r = out["undecidable"]
    assert r["pruned"]["pruned_windows"] == 0
    assert r["pruned"]["requests"] == r["reference"]["requests"]
    assert r["pruned"]["phase1_bytes"] == r["reference"]["phase1_bytes"]
    assert r["pruned"]["bytes"] == r["reference"]["bytes"], (
        "undecidable query must not change the byte model", out,
    )
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
