"""Paper Fig. 4b: per-operation execution-time breakdown at 1 Gb/s."""

from __future__ import annotations

from benchmarks.common import QUERY, csv_row, get_store
from repro.core.engine import WAN_1G, SkimEngine


def run() -> dict:
    out = {}
    for label, codec, mode in [
        ("client_zlib", "zlib", "client_plain"),
        ("client_bitpack", "bitpack", "client_plain"),
        ("client_opt", "bitpack", "client_opt"),
        ("neardata", "bitpack", "near_data"),
    ]:
        res = SkimEngine(get_store(codec), input_link=WAN_1G).run(QUERY, mode)
        bd = res.breakdown.as_dict()
        out[label] = bd
        for op, secs in bd.items():
            if op != "total":
                csv_row(f"breakdown/{label}/{op}", secs * 1e6, "")
    # the paper's key observations, asserted as derived metrics
    csv_row(
        "breakdown/zlib_decompress_over_bitpack",
        out["client_zlib"]["decompress"] / max(out["client_bitpack"]["decompress"], 1e-9),
        "x (LZMA-vs-LZ4 axis)",
    )
    csv_row(
        "breakdown/deserialize_reduction_two_phase",
        out["client_bitpack"]["deserialize"] / max(out["client_opt"]["deserialize"], 1e-9),
        "x (240.4s -> 16.8s in paper)",
    )
    return out


if __name__ == "__main__":
    run()
