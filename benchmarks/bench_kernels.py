"""Kernel-path microbenchmarks.

Measures the host (numpy) decode — the production CPU path — against the
zlib stand-in (the LZMA-vs-LZ4 axis), plus throughput of the vectorized
predicate+compact pipeline.  Pallas kernels run in interpret mode here
(CPU container); their TPU performance is a dry-run/roofline question,
not a wall-clock one.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row
from repro.data.codecs import decode_basket, encode_basket


def _time(fn, reps=3):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}
    n = 1 << 20  # 1M values / basket batch
    arrs = {
        "int_deltas": np.cumsum(rng.integers(0, 16, n)).astype(np.int32),
        "float_pt": (rng.exponential(25, n) + 3).astype(np.float32),
        "bool_trig": rng.random(n) < 0.1,
    }
    for name, arr in arrs.items():
        for codec in ("bitpack", "zlib"):
            blob = encode_basket(arr, codec)
            t = _time(lambda b=blob, c=codec, d=arr.dtype: decode_basket(b, c, d))
            mbps = arr.nbytes / t / 1e6
            out[f"{name}/{codec}"] = mbps
            csv_row(
                f"kernel/decode/{name}/{codec}",
                t * 1e6,
                f"{mbps:.0f} MB/s ratio={arr.nbytes/len(blob):.2f}",
            )

    # predicate + compact (vectorized jnp path used by near-data filtering)
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.predicate_eval import Group, Program
    from repro.kernels.ref import GROUP_COUNT, OP_IDS

    E, K = 1 << 17, 8
    prog = Program(
        groups=(Group(GROUP_COUNT, (0, 1), (OP_IDS[">"], OP_IDS["abs<"]), (20.0, 2.4)),),
        term_branches=("pt", "eta"),
        group_collections=("X",),
        group_weights=(None,),
    )
    terms = jnp.asarray(rng.normal(20, 15, (2, E, K)), jnp.float32)
    valid = jnp.asarray((rng.random((1, E, K)) < 0.4), jnp.float32)
    weights = jnp.zeros((1, E, K), jnp.float32)

    def pred():
        ref.predicate_eval_ref(terms, valid, weights, prog).block_until_ready()

    t = _time(pred)
    out["predicate"] = E / t / 1e6
    csv_row("kernel/predicate_eval", t * 1e6, f"{E/t/1e6:.1f} Mevents/s")

    payload = jnp.asarray(rng.normal(size=(E, 16)), jnp.float32)
    mask = jnp.asarray(rng.random(E) < 0.05)

    def compact():
        ref.stream_compact_ref(payload, mask)[0].block_until_ready()

    t = _time(compact)
    out["compact"] = E / t / 1e6
    csv_row("kernel/stream_compact", t * 1e6, f"{E/t/1e6:.1f} Mevents/s")
    return out


if __name__ == "__main__":
    run()
