"""Paper Fig. 4a: end-to-end filtering latency across 1/10/100 Gb/s tiers.

Systems: client-side zlib (LZMA stand-in), client-side bitpack (LZ4
stand-in), two-phase client ("Client Opt"), and near-data (SkimROOT).
Compute stages are measured on this host; link stages use the byte-exact
analytic model (DESIGN.md §2c).
"""

from __future__ import annotations

from benchmarks.common import QUERY, csv_row, get_store
from repro.core.engine import NetworkModel, SkimEngine

TIERS = {"1g": 1.0, "10g": 10.0, "100g": 100.0}


def run() -> dict:
    out = {}
    for tier, gbps in TIERS.items():
        link = NetworkModel(gbps, rtt_s=0.010 if gbps == 1.0 else 0.001)
        rows = {}
        for label, codec, mode in [
            ("client_zlib", "zlib", "client_plain"),
            ("client_bitpack", "bitpack", "client_plain"),
            ("client_opt_bitpack", "bitpack", "client_opt"),
            ("neardata_bitpack", "bitpack", "near_data"),
        ]:
            res = SkimEngine(get_store(codec), input_link=link).run(QUERY, mode)
            rows[label] = res.breakdown.total()
            csv_row(
                f"latency/{tier}/{label}",
                rows[label] * 1e6,
                f"passed={res.n_passed}",
            )
        out[tier] = rows
        speedup = rows["client_bitpack"] / rows["neardata_bitpack"]
        csv_row(f"latency/{tier}/speedup_vs_client", speedup, "x")
    return out


if __name__ == "__main__":
    run()
