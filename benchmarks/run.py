"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per the harness contract).

  Fig 4a -> bench_latency      Fig 4b -> bench_breakdown
  Fig 5a -> bench_nearstorage  Fig 5b -> bench_utilization
  (ours)  -> bench_kernels,
             bench_pipeline (serial vs pipelined vs fused-pipelined
             near-data executor: window prefetch overlap + the fused
             predicate/compact device pass), bench_cluster (1->8 node
             scatter-gather scaling + result-cache warm/cold),
             bench_prune (zone-map predicate pushdown: pruned vs
             reference on selective / accept-all / undecidable queries),
             bench_expr (derived-expression tier: Z-window skim, fused
             vs staged and pruned vs reference),
             bench_cascade (cascaded phase-1 execution vs the
             fused+pruned preload path),
             bench_service (async job service: time-to-first-partial
             vs blocking, admission pricing, queue throughput),
             bench_obs (trace/metrics layer: no-op tracer overhead
             bound + deterministic Chrome-trace export of a traced
             service drain),
             bench_faults (fault-tolerance costs: hedged straggler
             makespan, corrupt-basket retry path, checksum overhead
             vs the 2% budget),
             bench_scaling (multi-shard)

Module selection (CI and the 2-core dev host pay for one figure, not the
suite)::

    python benchmarks/run.py --only prune,expr          # just these two
    python benchmarks/run.py --skip kernels             # all but these
    python benchmarks/run.py --only expr --smoke        # shrunken store

``--json [PATH]`` additionally writes every emitted row — modeled times
and bytes moved — to a machine-readable ``BENCH_<pr>.json`` (default
name), the perf-trajectory artifact CI uploads per PR.
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time

# Benchmarks measure the execution path, never the test-time verifier:
# REPRO_VERIFY is forced off here so an ambient setting (e.g. a shell
# that just ran the test suite) cannot skew the modeled-vs-wall rows.
os.environ["REPRO_VERIFY"] = "0"

# the PR this tree's benchmark artifact belongs to (BENCH_<pr>.json)
PR_NUMBER = 9


def _modules() -> list[tuple[str, str, str]]:
    """(short name, module attr, figure label) in run order."""
    return [
        ("latency", "bench_latency", "Fig4a latency"),
        ("breakdown", "bench_breakdown", "Fig4b breakdown"),
        ("nearstorage", "bench_nearstorage", "Fig5a near-storage"),
        ("utilization", "bench_utilization", "Fig5b utilization"),
        ("kernels", "bench_kernels", "kernel micro"),
        ("pipeline", "bench_pipeline", "pipelined/fused executor"),
        ("cluster", "bench_cluster", "distributed skim cluster"),
        ("prune", "bench_prune", "zone-map predicate pushdown"),
        ("expr", "bench_expr", "derived-expression tier"),
        ("cascade", "bench_cascade", "cascaded phase-1 execution"),
        ("service", "bench_service", "async skim job service"),
        ("obs", "bench_obs", "trace/metrics layer"),
        ("faults", "bench_faults", "fault tolerance: hedging + checksums"),
        ("scaling", "bench_scaling", "beyond-paper scaling/overlap"),
    ]


def _parse_names(raw: str | None, known: list[str]) -> set[str]:
    if not raw:
        return set()
    names = {n.strip() for n in raw.split(",") if n.strip()}
    unknown = names - set(known)
    if unknown:
        raise SystemExit(
            f"unknown benchmark(s): {sorted(unknown)}; known: {known}"
        )
    return names


def main(argv: list[str] | None = None) -> None:
    known = [name for name, _, _ in _modules()]
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", help=f"comma-separated subset of {known}")
    ap.add_argument("--skip", help="comma-separated modules to leave out")
    ap.add_argument(
        "--smoke", action="store_true",
        help="pass smoke mode (shrunken store) to modules that support it",
    )
    ap.add_argument(
        "--json", nargs="?", const=f"BENCH_{PR_NUMBER}.json", default=None,
        metavar="PATH",
        help="write the emitted rows as machine-readable JSON "
        f"(default path: BENCH_{PR_NUMBER}.json)",
    )
    args = ap.parse_args(argv)
    only = _parse_names(args.only, known)
    skip = _parse_names(args.skip, known)
    if only & skip:
        raise SystemExit(f"--only and --skip overlap: {sorted(only & skip)}")

    import benchmarks
    from benchmarks import common

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    per_module: dict[str, dict] = {}
    for name, attr, label in _modules():
        if (only and name not in only) or name in skip:
            continue
        __import__(f"benchmarks.{attr}")
        mod = getattr(benchmarks, attr)
        print(f"# --- {label} ---", file=sys.stderr)
        kwargs = (
            {"smoke": True}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters
            else {}
        )
        row0 = len(common.BENCH_ROWS)
        t_mod = time.perf_counter()
        mod.run(**kwargs)
        per_module[name] = {
            "label": label,
            "wall_s": time.perf_counter() - t_mod,
            "rows": common.BENCH_ROWS[row0:],
        }
    total_s = time.perf_counter() - t0
    print(f"# total {total_s:.1f}s", file=sys.stderr)

    if args.json:
        doc = {
            "pr": PR_NUMBER,
            "smoke": bool(args.smoke),
            "total_wall_s": total_s,
            "benchmarks": per_module,
        }
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
